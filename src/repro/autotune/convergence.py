"""Early-exit convergence monitoring on the step-driven PPR drivers (Fig. 7).

The paper's Fig. 7 observation: fixed-point PPR does not merely approach the
stationary distribution — it reaches an *absorbing state* in fewer iterations
than float32 needs to pass the 1e-6 threshold, because every further update
underflows the 2^-f grid.  A service that always runs its full iteration
budget therefore wastes the paper's "additional 2x speedup".

Empirically (and reproducibly with this repo's bit-exact datapath) the
absorbing state takes one of two shapes:

- a strict fixed point: one more eq. (1) iteration reproduces P bit-for-bit
  (checked by exact integer comparison — the float delta statistic cannot be
  trusted here, since casting raw uint32 to float32 rounds a 1-LSB change at
  raw values ≥ 2^24 to delta == 0); or
- a **period-2 absorbing cycle**: a handful of entries flip by one LSB each
  iteration and flip back (truncation alternately rounds them down and re-adds
  the lost mass), so consecutive states alternate A, B, A, B, … and the delta
  freezes at a constant value on the quantization noise floor.

Both are detected exactly.  The cycle case still permits *bit-identical* early
exit: once S_t == S_{t-2} is observed, every later state is determined by
parity, so the monitor returns S_t or S_{t-1} according to the parity of the
remaining budget — the result equals the full-budget run bit-for-bit, just
without running it.

The float32 path exits below ``epsilon`` (the paper's Fig. 7 threshold); its
ranks may differ microscopically from the full-budget run, which is why the
service's shadow estimator (repro.autotune.quality) keeps scoring served
results online.

The delta is the same statistic the core scan drivers trace: max over the κ
columns of the L2 norm of the state change, in value units (raw fixed-point
deltas are divided by the format scale).  Each check forces one device sync;
``check_every`` amortizes that for long budgets.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ConvergencePolicy:
    """When may a wave stop iterating early?

    ``epsilon``        float-path L2 threshold.  The fixed path ignores it:
                       only the exact absorbing state / absorbing cycle stops
                       a fixed wave (those exits are bit-identical, free wins).
    ``min_iterations`` never exit before this many iterations have run.
    ``check_every``    test for convergence every k-th iteration only (each
                       check is a host sync on the wave's state).
    """
    epsilon: float = 1e-6
    min_iterations: int = 2
    check_every: int = 1

    def __post_init__(self):
        if self.min_iterations < 1:
            raise ValueError("min_iterations must be >= 1")
        if self.check_every < 1:
            raise ValueError("check_every must be >= 1")


def wave_delta(P_new: Array, P_prev: Array, scale: Optional[int] = None) -> float:
    """Max-over-columns L2 state change in value units — the statistic the core
    ``lax.scan`` drivers trace, recomputed between two step-driver states.
    ``scale`` converts raw fixed-point deltas (pass ``fmt.scale``)."""
    d = P_new.astype(jnp.float32) - P_prev.astype(jnp.float32)
    delta = jnp.sqrt((d * d).sum(0)).max()
    if scale is not None:
        delta = delta / scale
    return float(delta)


def states_equal(a: Array, b: Array) -> bool:
    """Bit-exact state equality (one device reduction)."""
    return bool(jnp.array_equal(a, b))


class ConvergenceMonitor:
    """Stateful per-wave monitor: feed consecutive states, learn when to stop.

    ``update`` returns True once the wave may exit; ``cycle`` is then True when
    the exit was a period-2 absorbing cycle rather than a strict fixed point
    (the driver must pick the parity-correct state in that case).
    """

    def __init__(self, policy: ConvergencePolicy, *, fixed: bool,
                 scale: Optional[int] = None, track_deltas: bool = True):
        self.policy = policy
        self.fixed = fixed
        self.scale = scale
        # The fixed path converges on exact integer comparisons; its float
        # delta is telemetry only.  A driver that discards the trace (the
        # serving hot path) passes track_deltas=False to skip that second
        # full-array reduction + host sync per checked iteration.  The float
        # path always computes the delta — it *is* the exit criterion there.
        self.track_deltas = track_deltas
        self.iterations = 0
        self.deltas: List[float] = []
        self.converged = False
        self.cycle = False
        self._prev2: Optional[Array] = None    # S_{t-2}, fixed path only

    def update(self, P_new: Array, P_prev: Array) -> bool:
        """Record one completed iteration (S_{t-1} → S_t); True ⇒ may stop."""
        self.iterations += 1
        if self.converged:
            return True
        checking = self.iterations % self.policy.check_every == 0
        prev2 = self._prev2
        if self.fixed:
            self._prev2 = P_prev                # keep S_{t-1} as next S_{t-2}
        if not checking:
            return False                        # skip the host syncs
        if self.fixed:
            # The strict check must be exact integer equality, not the float
            # delta: ``wave_delta`` casts raw uint32 to float32, so for raw
            # values >= 2^24 (scores >= 0.5 in Q1.25) a 1-LSB state change
            # rounds to delta == 0.0 and a "bit-identical" exit would return
            # a non-fixed-point.  The float delta is telemetry-only here, and
            # its reduction is skipped when exact equality already proves it 0.
            strict = states_equal(P_new, P_prev)
            if self.track_deltas:
                self.deltas.append(
                    0.0 if strict else wave_delta(P_new, P_prev, self.scale))
            if self.iterations < self.policy.min_iterations:
                return False
            if strict:                          # strict absorbing state
                self.converged = True
            elif prev2 is not None and states_equal(P_new, prev2):
                self.converged = self.cycle = True
        else:
            delta = wave_delta(P_new, P_prev, self.scale)
            self.deltas.append(delta)
            if self.iterations < self.policy.min_iterations:
                return False
            self.converged = delta < self.policy.epsilon
        return self.converged


def run_until_converged(
    step: Callable[[Array], Array],
    P0: Array,
    max_iterations: int,
    policy: ConvergencePolicy,
    *,
    fixed: bool,
    scale: Optional[int] = None,
    track_deltas: bool = True,
) -> Tuple[Array, int, List[float]]:
    """Drive one eq. (1) step function until convergence or budget exhaustion.

    Returns (final state, iterations actually run, observed deltas).  Fixed
    point exits are bit-identical to the full-budget run: a strict absorbing
    state is a fixed point of ``step``, and on a period-2 absorbing cycle the
    full-budget result is recovered by parity (S_B = S_t when B ≡ t mod 2,
    else S_{t-1}).  ``track_deltas=False`` skips the fixed path's
    telemetry-only delta reductions; the returned trace is then empty there."""
    monitor = ConvergenceMonitor(policy, fixed=fixed, scale=scale,
                                 track_deltas=track_deltas)
    P = P0
    for t in range(1, max_iterations + 1):
        P_next = step(P)                        # P = S_{t-1}, P_next = S_t
        if monitor.update(P_next, P):
            if monitor.cycle and (max_iterations - t) % 2 != 0:
                return P, t, monitor.deltas     # parity lands on S_{t-1}
            return P_next, t, monitor.deltas
        P = P_next
    return P, max_iterations, monitor.deltas
