"""Quality-targeted precision ladder with hysteresis (paper Figs. 4-6 closed-loop).

The paper's offline finding — ranking quality degrades gracefully and
predictably as bits shrink from Q1.25 to Q1.19 — becomes a serving policy: a
per-graph ladder of Q formats ordered by cost, walked up and down by the shadow
estimator's window estimates so each ``precision="auto"`` query is served at
the *cheapest* format currently meeting its quality target.

Rungs are the configured fixed-point bit-widths (narrowest = cheapest first)
plus a float32 fallback rung above the widest — a graph whose quality target is
unreachable at any configured format degrades to exact float32 service instead
of failing.

Hysteresis: one bad shadow window must not thrash the ladder (a format change
invalidates wave batching locality and the per-format quantized-value cache is
re-warmed).  Demotion (→ wider) requires ``demote_patience`` *consecutive*
below-target estimates; promotion (→ narrower) requires ``promote_patience``
consecutive estimates clearing the target by ``promote_margin``.  Estimates in
the dead band between the two reset both streaks.  An alternating good/bad
sequence therefore never moves the rung in either direction.  A *reverted*
promotion (probe a narrower rung, get demoted straight back) doubles the
promote requirement for that (graph, target) — exponential backoff, reset
when a probe survives long enough to promote again or when the graph is
re-registered — so a format that persistently misses its target is re-probed
geometrically less often instead of thrash-cycling forever.

Float32-served auto queries are perfect by definition (score 1.0, no shadow
reference needed); feeding those 1.0s through ``observe_quality`` is what lets
a demoted graph climb back down to fixed point once ``promote_patience`` is
re-accumulated.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.fixed_point import QFormat, format_for_bits
from repro.autotune.quality import QualityEstimator, ShadowConfig

#: paper §5.3 bit-widths, cheapest first (20 bits = Q1.19 … 26 bits = Q1.25)
DEFAULT_LADDER: Tuple[int, ...] = (20, 22, 24, 26)

#: rung key for the float32 fallback (matches ppr_serving's FLOAT_KEY)
FLOAT_RUNG = "f32"


@dataclasses.dataclass(frozen=True)
class AutotuneConfig:
    """Ladder + hysteresis + shadow-sampling policy for one service."""
    ladder: Tuple[int, ...] = DEFAULT_LADDER
    default_target: float = 0.95
    promote_patience: int = 3          # consecutive good windows before narrowing
    demote_patience: int = 2           # consecutive bad windows before widening
    promote_margin: float = 0.005      # narrow only when target is cleared by this
    shadow: ShadowConfig = ShadowConfig()

    def __post_init__(self):
        if not self.ladder:
            raise ValueError("ladder must name at least one bit-width")
        if list(self.ladder) != sorted(set(self.ladder)):
            raise ValueError("ladder must be strictly increasing bit-widths")
        if self.promote_patience < 1 or self.demote_patience < 1:
            raise ValueError("patience values must be >= 1")


@dataclasses.dataclass
class _RungState:
    """Ladder position + hysteresis streaks for one (graph, target)."""
    rung: int                          # index into ladder; len(ladder) ⇒ float32
    good: int = 0
    bad: int = 0
    promote_backoff: int = 1           # multiplies promote_patience; doubles
    probing: bool = False              # each time a promotion is reverted


class PrecisionController:
    """Resolve ``precision="auto"`` to the cheapest format meeting the target."""

    def __init__(self, config: AutotuneConfig = AutotuneConfig(),
                 estimator: Optional[QualityEstimator] = None):
        self.config = config
        self.estimator = estimator or QualityEstimator(config.shadow)
        self._formats: Tuple[QFormat, ...] = tuple(
            format_for_bits(b) for b in config.ladder)
        self._states: Dict[Tuple[str, float], _RungState] = {}
        self._target_ceiling: Optional[float] = None
        self.promotions = 0
        self.demotions = 0

    # -- rung bookkeeping ----------------------------------------------
    def _target(self, target: Optional[float]) -> float:
        t = self.config.default_target if target is None else float(target)
        if not 0.0 < t <= 1.0:
            raise ValueError(f"quality target must be in (0, 1], got {t}")
        if self._target_ceiling is not None:
            t = min(t, self._target_ceiling)
        return round(t, 6)

    @property
    def target_ceiling(self) -> Optional[float]:
        """The SLO-degradation ceiling currently capping every effective
        quality target, or None when serving at requested quality."""
        return self._target_ceiling

    def set_target_ceiling(self, ceiling: Optional[float]) -> None:
        """Temporarily cap effective quality targets (SLO-aware degradation:
        a deep admission queue trades NDCG target for wave latency).

        While set, every ``resolve``/``observe_*`` maps its requested target
        through ``min(target, ceiling)`` — so degraded traffic walks its own
        (graph, degraded-target) ladder, whose rung may be a cheaper format,
        and shadow feedback gathered under the ceiling steers that ladder
        rather than polluting the full-quality one.  ``None`` lifts the cap;
        the full-quality ladders resume exactly where they left off."""
        if ceiling is not None and not 0.0 < float(ceiling) <= 1.0:
            raise ValueError(f"target ceiling must be in (0, 1] or None, "
                             f"got {ceiling}")
        self._target_ceiling = None if ceiling is None else float(ceiling)

    def _state(self, graph: str, target: Optional[float]) -> _RungState:
        key = (graph, self._target(target))
        if key not in self._states:
            # start at the widest fixed format: cheaper than float32 on day one,
            # and the paper's safest quality point to gather first samples at
            self._states[key] = _RungState(rung=len(self._formats) - 1)
        return self._states[key]

    def _rung_format(self, rung: int) -> Optional[QFormat]:
        return None if rung >= len(self._formats) else self._formats[rung]

    def rung_key(self, graph: str, target: Optional[float] = None) -> str:
        """Telemetry-friendly name of the current rung ('Q1.f' or 'f32')."""
        fmt = self._rung_format(self._state(graph, target).rung)
        return FLOAT_RUNG if fmt is None else fmt.name

    # -- the two public verbs ------------------------------------------
    def resolve(self, graph: str, target: Optional[float] = None
                ) -> Optional[QFormat]:
        """Precision for the next auto query on (graph, target): a ``QFormat``
        or None for the float32 fallback rung."""
        return self._rung_format(self._state(graph, target).rung)

    def observe_quality(self, graph: str, fmt_key: str, score: float,
                        target: Optional[float] = None) -> None:
        """Fold an externally-scored observation into the estimator and advance
        the ladder (used directly for float32-served queries, score 1.0)."""
        self.estimator.record(graph, fmt_key, score)
        self._steer(graph, fmt_key, target)

    def observe_shadow(self, graph: str, fmt_key: str,
                       approx: np.ndarray, ref: np.ndarray,
                       target: Optional[float] = None,
                       ref_order: Optional[np.ndarray] = None) -> float:
        """Score one shadow sample, then steer.  Returns the sample's score."""
        score = self.estimator.observe(graph, fmt_key, approx, ref, ref_order)
        self._steer(graph, fmt_key, target)
        return score

    # -- hysteresis ----------------------------------------------------
    def _steer(self, graph: str, fmt_key: str, target: Optional[float]) -> None:
        st = self._state(graph, target)
        current_fmt = self._rung_format(st.rung)
        current_key = FLOAT_RUNG if current_fmt is None else current_fmt.name
        if fmt_key != current_key:
            return                      # stale sample from a pre-move format
        est = self.estimator.estimate(graph, fmt_key)
        if est is None:
            return                      # window too thin — hold the rung
        t = self._target(target)
        if est < t:
            st.bad += 1
            st.good = 0
            if st.bad >= self.config.demote_patience \
                    and st.rung < len(self._formats):
                st.rung += 1            # widen (toward float32)
                if st.probing:          # the probed narrower rung failed:
                    st.promote_backoff = min(st.promote_backoff * 2, 64)
                st.probing = False      # re-probe it geometrically less often
                st.bad = st.good = 0
                self.demotions += 1
        elif est >= t + self.config.promote_margin:
            st.good += 1
            st.bad = 0
            if st.good >= self.config.promote_patience * st.promote_backoff \
                    and st.rung > 0:
                if st.probing:          # last probe stuck around long enough
                    st.promote_backoff = 1       # to promote again: trust it
                st.rung -= 1            # narrow (cheaper format)
                st.probing = True
                st.bad = st.good = 0
                self.promotions += 1
        else:
            # dead band: on target but without margin — hold, reset streaks
            st.good = st.bad = 0

    # -- lifecycle -----------------------------------------------------
    def decay_graph(self, graph: str, keep_fraction: float = 0.5) -> None:
        """Epoch change (edge delta applied): soften the evidence instead of
        forgetting it.  Rung positions and promote backoff survive — the
        quality/bit-width curve moves smoothly with small topology changes
        (paper Fig. 6's sparsity dependence) — while hysteresis streaks reset
        (they described the pre-delta topology) and the estimator windows
        decay toward fresh post-delta shadow samples."""
        for key, st in self._states.items():
            if key[0] == graph:
                st.good = st.bad = 0
        self.estimator.decay_graph(graph, keep_fraction)

    def forget_graph(self, graph: str) -> None:
        """Reset ladder state and estimator windows for a re-registered graph."""
        for key in [k for k in self._states if k[0] == graph]:
            del self._states[key]
        self.estimator.forget_graph(graph)

    def summary(self) -> Dict[str, float]:
        """Counters plus the current rung bit-width per (graph, target)
        (float32 fallback reported as 32)."""
        out = {"promotions": float(self.promotions),
               "demotions": float(self.demotions),
               "shadow_evaluations": float(self.estimator.shadow_evaluations)}
        for (graph, target), st in self._states.items():
            bits = 32 if st.rung >= len(self._formats) else self.config.ladder[st.rung]
            out[f"rung_bits_{graph}@{target}"] = float(bits)
        return out
