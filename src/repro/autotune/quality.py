"""Online ranking-quality estimation via shadow sampling (paper Figs. 4-6).

The paper establishes the quality/bit-width curve offline, on static graphs.
A serving system cannot: quality at a given Q format drifts with the graph
(sparsity, skew — Fig. 6) and with the query mix, so the controller needs an
*online* estimate of "how good is format F on graph G right now".

``QualityEstimator`` shadow-samples a configurable fraction of served queries:
for a sampled query the service re-runs the wave's personalization column at
the float32 reference precision and scores the served (fixed-point) ranking
against it with the paper's own metrics (``core.metrics`` NDCG / precision@k).
Scores land in per-(graph, format) sliding windows; the window mean is the
estimate the precision controller steers on.

Sampling uses a dedicated seeded ``numpy`` Generator so a replayed query
sequence makes identical sampling decisions — load tests and CI smoke runs are
reproducible bit-for-bit.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, Optional, Tuple

import numpy as np

from repro.core.metrics import ndcg, precision_at, ranking

#: supported online metrics: name → callable(approx, ref, k, ref_order) → score
_METRICS = {
    "ndcg": lambda a, r, k, ro: ndcg(a, r, k, ref_order=ro),
    "precision": lambda a, r, k, ro: precision_at(a, r, k, ref_order=ro),
}


@dataclasses.dataclass(frozen=True)
class ShadowConfig:
    """Shadow-sampling policy.

    ``sample_fraction``  probability a served query is shadow-scored (each
                         shadow costs one float32 reference column).
    ``window``           sliding-window length per (graph, format).
    ``min_samples``      below this many window entries ``estimate`` abstains
                         (returns None) — the controller holds its rung.
    ``metric``/``eval_k`` which paper metric the estimate is, and its cutoff.
    ``seed``             RNG seed for the sampling decisions (determinism).
    """
    sample_fraction: float = 0.25
    window: int = 32
    min_samples: int = 3
    metric: str = "ndcg"
    eval_k: int = 50
    seed: int = 0

    def __post_init__(self):
        if not 0.0 <= self.sample_fraction <= 1.0:
            raise ValueError("sample_fraction must be in [0, 1]")
        if self.metric not in _METRICS:
            raise ValueError(f"unknown metric {self.metric!r} "
                             f"(have {sorted(_METRICS)})")
        if self.window < 1 or self.min_samples < 1:
            raise ValueError("window and min_samples must be >= 1")


def score_quality(approx: np.ndarray, ref: np.ndarray, *,
                  metric: str = "ndcg", k: int = 50,
                  ref_order: Optional[np.ndarray] = None) -> float:
    """Score one served score vector against its float32 reference."""
    approx = np.asarray(approx, np.float64)
    ref = np.asarray(ref, np.float64)
    return float(_METRICS[metric](approx, ref, k, ref_order))


class QualityEstimator:
    """Per-(graph, format) sliding-window quality estimates from shadow samples."""

    def __init__(self, config: ShadowConfig = ShadowConfig()):
        self.config = config
        self._rng = np.random.default_rng(config.seed)
        self._windows: Dict[Tuple[str, str], Deque[float]] = {}
        self.shadow_evaluations = 0            # reference runs actually scored

    # -- sampling ------------------------------------------------------
    def should_sample(self) -> bool:
        """One deterministic coin flip per served candidate query."""
        if self.config.sample_fraction >= 1.0:
            return True
        if self.config.sample_fraction <= 0.0:
            return False
        return float(self._rng.random()) < self.config.sample_fraction

    # -- observation ---------------------------------------------------
    def record(self, graph: str, fmt_key: str, score: float) -> None:
        """Append an externally-computed quality score to a window (used for
        the float32-served path, whose quality is 1.0 by definition)."""
        key = (graph, fmt_key)
        if key not in self._windows:
            self._windows[key] = deque(maxlen=self.config.window)
        self._windows[key].append(float(score))

    def observe(self, graph: str, fmt_key: str,
                approx: np.ndarray, ref: np.ndarray,
                ref_order: Optional[np.ndarray] = None) -> float:
        """Score one shadow sample and fold it into the (graph, format) window.
        Pass ``ref_order=ranking(ref)`` when one reference scores several
        formats — the reference is then sorted once."""
        score = score_quality(approx, ref, metric=self.config.metric,
                              k=self.config.eval_k, ref_order=ref_order)
        self.shadow_evaluations += 1
        self.record(graph, fmt_key, score)
        return score

    # -- estimates -----------------------------------------------------
    def estimate(self, graph: str, fmt_key: str) -> Optional[float]:
        """Window-mean quality, or None while the window is too thin to act on."""
        w = self._windows.get((graph, fmt_key))
        if w is None or len(w) < self.config.min_samples:
            return None
        return float(np.mean(w))

    def samples(self, graph: str, fmt_key: str) -> int:
        w = self._windows.get((graph, fmt_key))
        return len(w) if w is not None else 0

    def snapshot(self) -> Dict[str, float]:
        """All current estimates, keyed 'graph/format' (telemetry/bench dump)."""
        out = {}
        for (graph, fmt_key) in self._windows:
            est = self.estimate(graph, fmt_key)
            if est is not None:
                out[f"{graph}/{fmt_key}"] = est
        return out

    def forget_graph(self, graph: str) -> None:
        """Drop a graph's windows (it was re-registered — estimates are stale)."""
        for key in [k for k in self._windows if k[0] == graph]:
            del self._windows[key]

    def decay_graph(self, graph: str, keep_fraction: float = 0.5) -> None:
        """Shrink a graph's windows to their newest ``keep_fraction`` samples.

        An edge delta makes old shadow scores *weaker* evidence, not no
        evidence — the topology moved a little, not wholesale.  Decayed
        windows may drop below ``min_samples``, in which case ``estimate``
        abstains until fresh shadow traffic refills them; full
        re-registration still hard-resets via ``forget_graph``."""
        if not 0.0 <= keep_fraction <= 1.0:
            raise ValueError(f"keep_fraction must be in [0, 1], got {keep_fraction}")
        for (g, _), w in self._windows.items():
            if g != graph or not w:
                continue
            keep = int(np.ceil(len(w) * keep_fraction))
            kept = list(w)[len(w) - keep:]
            w.clear()
            w.extend(kept)


__all__ = ["ShadowConfig", "QualityEstimator", "score_quality", "ranking"]
