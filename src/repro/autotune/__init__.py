"""Adaptive-precision subsystem: quality-targeted Q-format autotuning.

The paper's two headline empirical results are a *dial* and a *shortcut*:

- Figs. 4-6 (the dial): ranking fidelity (NDCG, precision@k, errors@N) degrades
  gracefully and monotonically as the fixed-point width shrinks from Q1.25 to
  Q1.19, with the exact curve depending on graph structure (Fig. 6's sparsity
  sweep).
- Fig. 7 (the shortcut): fixed-point PPR reaches an *absorbing state* — an
  iteration that changes nothing, every update underflowing the 2^-f grid — in
  fewer iterations than float32 takes to converge past 1e-6.

The repo's serving layer (repro.ppr_serving) previously exposed both results
only as manual knobs: the operator picked a Q format per query and every wave
ran a fixed iteration budget.  This package closes the loop:

DESIGN — component ↔ paper figure map
-------------------------------------
``quality.py``      The online analogue of Figs. 4-6's offline measurement:
                    shadow-samples a configurable fraction of served queries,
                    re-runs their personalization column at float32, scores the
                    served ranking with the paper's own metrics (core.metrics
                    NDCG / precision@k), and keeps per-(graph, format)
                    sliding-window estimates.  Seeded sampling keeps replays
                    deterministic.
``controller.py``   Walks Figs. 4-6's quality/bit-width curve as a per-graph
                    policy ladder: ``precision="auto"`` resolves to the
                    cheapest Q format whose window estimate meets the query's
                    quality target, with a float32 fallback rung above the
                    widest format.  Hysteresis (consecutive-window patience in
                    both directions plus a promote margin) keeps one bad
                    window from thrashing formats.
``convergence.py``  Fig. 7 as a serving policy: per-wave delta monitoring on
                    the step drivers (``ppr_step_float`` /
                    ``make_ppr_fixed_step``) stops a fixed-point wave at the
                    absorbing state (delta == 0, bit-identical to the full
                    run) and a float wave below the paper's 1e-6 threshold,
                    instead of always burning the full iteration budget.

Integration: ``repro.ppr_serving.PPRService`` resolves ``precision="auto"``
through the controller before wave admission (so auto queries batch with
same-format explicit traffic), drives waves through the convergence monitor,
feeds shadow scores back after each fixed-precision wave, and exports the
shadow / early-exit / served-precision counters through ``ServiceTelemetry``.
``benchmarks/bench_autotune.py`` sweeps quality targets against the static
formats.
"""
from repro.autotune.controller import (
    DEFAULT_LADDER,
    AutotuneConfig,
    PrecisionController,
)
from repro.autotune.convergence import (
    ConvergenceMonitor,
    ConvergencePolicy,
    run_until_converged,
    wave_delta,
)
from repro.autotune.quality import QualityEstimator, ShadowConfig, score_quality

__all__ = [
    "AutotuneConfig", "PrecisionController", "DEFAULT_LADDER",
    "QualityEstimator", "ShadowConfig", "score_quality",
    "ConvergencePolicy", "ConvergenceMonitor", "run_until_converged",
    "wave_delta",
]
