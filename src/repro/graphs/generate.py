"""Graph generators matching the paper's Table 1 datasets.

The paper evaluates on 6 synthetic graphs (Erdős–Rényi G(n,p), Watts–Strogatz
small-world, Holme–Kim powerlaw-cluster; |V| ∈ {1e5, 2e5}, |E| ≈ 1e6/2e6) and 2
SNAP graphs (Amazon co-purchasing, Twitter social circles).

Generators are vectorized numpy (networkx equivalents are used in tests only as a
cross-check — pure-python generation of 2e6 edges is too slow for benchmarks).
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from repro.core.coo import COOGraph


def _dedup(src: np.ndarray, dst: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Remove duplicate and self edges."""
    keep = src != dst
    src, dst = src[keep], dst[keep]
    key = src.astype(np.int64) * (dst.max(initial=0) + 1) + dst
    _, idx = np.unique(key, return_index=True)
    return src[idx], dst[idx]


def erdos_renyi(n: int, m: int, seed: int = 0) -> COOGraph:
    """G(n,M): M directed edges drawn uniformly (paper's G_{n,p} at same density)."""
    rng = np.random.default_rng(seed)
    over = int(m * 1.05) + 16
    src = rng.integers(0, n, over, dtype=np.int64)
    dst = rng.integers(0, n, over, dtype=np.int64)
    src, dst = _dedup(src, dst)
    src, dst = src[:m], dst[:m]
    return COOGraph.from_edges(src, dst, n)


def watts_strogatz(n: int, k: int = 10, beta: float = 0.1, seed: int = 0) -> COOGraph:
    """Small-world ring lattice with k neighbors, rewiring probability beta.

    Directed variant: each vertex points to its k/2 clockwise neighbors, and each
    such edge is rewired to a uniform target with probability beta.  Matches the
    paper's |E| = n·k/2 scaling (k=10 → 1e6 edges at n=2e5... n·k/2; the paper's
    1e5-vertex graph has exactly 1e6 edges ⇒ k=20).
    """
    rng = np.random.default_rng(seed)
    half = k // 2
    base = np.arange(n, dtype=np.int64)
    src = np.repeat(base, half)
    offs = np.tile(np.arange(1, half + 1, dtype=np.int64), n)
    dst = (src + offs) % n
    rewire = rng.random(src.shape[0]) < beta
    dst = np.where(rewire, rng.integers(0, n, src.shape[0], dtype=np.int64), dst)
    src, dst = _dedup(src, dst)
    return COOGraph.from_edges(src, dst, n)


def holme_kim_powerlaw(n: int, m: int = 10, p_triad: float = 0.1, seed: int = 0) -> COOGraph:
    """Holme–Kim powerlaw-cluster graph, vectorized preferential attachment.

    Each arriving vertex attaches m edges; with probability p_triad an edge closes
    a triangle instead of a fresh preferential pick.  We approximate preferential
    attachment by sampling from the running edge-endpoint list (the classic
    Barabási trick), which reproduces the powerlaw degree distribution the paper
    relies on ("dense communities, similarly to real social networks").
    """
    rng = np.random.default_rng(seed)
    # endpoint pool for preferential sampling; seed with a small clique
    m0 = m + 1
    pool = np.repeat(np.arange(m0, dtype=np.int64), m0 - 1)
    srcs = [np.repeat(np.arange(m0, dtype=np.int64), m0 - 1)]
    dsts = [np.tile(np.arange(m0, dtype=np.int64), m0)[: m0 * (m0 - 1)]]
    pool_list = [pool]
    pool_size = pool.shape[0]
    # batch arrivals for speed: sample targets against the *current* pool only
    batch = 2048
    pools = np.concatenate(pool_list)
    for start in range(m0, n, batch):
        stop = min(start + batch, n)
        nb = stop - start
        newv = np.arange(start, stop, dtype=np.int64)
        # sample m preferential targets per new vertex from the frozen pool
        tgt = pools[rng.integers(0, pool_size, (nb, m))]
        # triad closure: with prob p, replace target j>0 by a neighbor of target j-1
        # (approximated by re-using target j-1 offset by pool sampling locality)
        triad = rng.random((nb, m)) < p_triad
        triad[:, 0] = False
        tgt = np.where(triad, np.roll(tgt, 1, axis=1), tgt)
        s = np.repeat(newv, m)
        d = tgt.reshape(-1)
        srcs.append(s)
        dsts.append(d)
        pools = np.concatenate([pools, s, d])
        pool_size = pools.shape[0]
    src = np.concatenate(srcs)
    dst = np.concatenate(dsts)
    src, dst = _dedup(src, dst)
    return COOGraph.from_edges(src, dst, n)


def load_snap_edgelist(path: str, num_vertices: int | None = None) -> COOGraph:
    """Load a SNAP-format whitespace edge list (``# comment`` lines skipped)."""
    arr = np.loadtxt(path, dtype=np.int64, comments="#")
    src, dst = arr[:, 0], arr[:, 1]
    # densify ids
    ids, inv = np.unique(np.concatenate([src, dst]), return_inverse=True)
    src = inv[: src.shape[0]]
    dst = inv[src.shape[0]:]
    n = num_vertices or int(ids.shape[0])
    return COOGraph.from_edges(src, dst, n)


def paper_graph_suite(scale: float = 1.0, seed: int = 0) -> Dict[str, COOGraph]:
    """The paper's Table 1 synthetic suite, optionally scaled down for CI.

    scale=1.0 reproduces |V|∈{1e5, 2e5}, |E|≈{1e6, 2e6}.  The two SNAP graphs are
    substituted by statistically matched synthetics when the raw files are absent
    (documented in DESIGN.md §9): amazon-like (powerlaw, |V|=128000, |E|≈443378)
    and twitter-like (dense powerlaw, |V|=81306, |E|≈1572670).
    """
    v1 = max(64, int(1e5 * scale))
    v2 = max(128, int(2e5 * scale))
    suite = {
        "gnp_1e5": erdos_renyi(v1, max(32, int(1e6 * scale)), seed),
        "gnp_2e5": erdos_renyi(v2, max(64, int(2e6 * scale)), seed + 1),
        "ws_1e5": watts_strogatz(v1, k=20, seed=seed + 2),
        "ws_2e5": watts_strogatz(v2, k=20, seed=seed + 3),
        "pl_1e5": holme_kim_powerlaw(v1, m=10, seed=seed + 4),
        "pl_2e5": holme_kim_powerlaw(v2, m=10, seed=seed + 5),
        "amazon_like": holme_kim_powerlaw(max(64, int(128000 * scale)), m=3, seed=seed + 6),
        "twitter_like": holme_kim_powerlaw(max(64, int(81306 * scale)), m=19,
                                           p_triad=0.3, seed=seed + 7),
    }
    return suite
