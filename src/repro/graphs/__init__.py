from repro.graphs.generate import (
    erdos_renyi,
    holme_kim_powerlaw,
    load_snap_edgelist,
    paper_graph_suite,
    watts_strogatz,
)
from repro.graphs.reference import ppr_reference

__all__ = [
    "erdos_renyi",
    "watts_strogatz",
    "holme_kim_powerlaw",
    "load_snap_edgelist",
    "paper_graph_suite",
    "ppr_reference",
]
