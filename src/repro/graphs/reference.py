"""CPU reference PPR — the paper's PGX baseline stand-in.

scipy CSR float64 power iteration; this is the "ground truth at convergence"
(≥100 iterations) against which fixed-point rankings are scored (paper §5.3).
"""
from __future__ import annotations

import numpy as np
import scipy.sparse as sp

from repro.core.coo import COOGraph


def ppr_reference(
    g: COOGraph,
    personalization: np.ndarray,
    alpha: float = 0.85,
    iterations: int = 100,
    tol: float = 0.0,
) -> np.ndarray:
    """Batched PPR via scipy CSR, float64.  Returns [V, K] scores.

    Implements eq. (1): P_{t+1} = α·X·P_t + α/|V|·(d̄·P_t)·1 + (1−α)·V̄.
    """
    v = g.num_vertices
    pers = np.atleast_1d(np.asarray(personalization, np.int64))
    k = pers.shape[0]
    X = sp.csr_matrix(
        (g.val.astype(np.float64), (g.x.astype(np.int64), g.y.astype(np.int64))),
        shape=(v, v),
    )
    V = np.zeros((v, k), np.float64)
    V[pers, np.arange(k)] = 1.0
    d = g.dangling.astype(np.float64)
    P = V.copy()
    for _ in range(iterations):
        dangling_mass = d @ P                             # [K]
        Pn = alpha * (X @ P) + (alpha / v) * dangling_mass[None, :] + (1 - alpha) * V
        delta = np.linalg.norm(Pn - P, axis=0).max()
        P = Pn
        if tol and delta < tol:
            break
    return P
