"""Serving engine: continuous request batching over prefill/decode.

The paper's κ-batching (amortize one stream over κ requests) generalized to LM
serving: a slot-based batcher keeps ``batch_size`` concurrent sequences; free
slots are refilled from the queue, prefill runs per-admission, decode advances
all slots in lock-step with one jitted ``decode_step`` per token.

Single-host reference implementation — the multi-chip path shards the same
decode_step with distributed/sharding.cache_shardings.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import ModelApi


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # [S] int32
    max_new_tokens: int = 16
    out_tokens: Optional[List[int]] = None


class ServingEngine:
    """Greedy-decode engine with static batch slots (padded prompts)."""

    def __init__(self, api: ModelApi, params, batch_size: int, max_len: int):
        self.api = api
        self.params = params
        self.batch = batch_size
        self.max_len = max_len
        self._prefill = jax.jit(api.prefill)
        self._decode = jax.jit(api.decode_step)

    def serve(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Process all requests in κ-sized admission waves (paper §5.1:
        '100 random personalization vertices' → waves of κ)."""
        results: Dict[int, List[int]] = {}
        queue = list(requests)
        while queue:
            wave, queue = queue[: self.batch], queue[self.batch:]
            results.update(self._serve_wave(wave))
        return results

    def _serve_wave(self, wave: List[Request]) -> Dict[int, List[int]]:
        b = self.batch
        plen = max(len(r.prompt) for r in wave)
        toks = np.zeros((b, plen), np.int32)
        for i, r in enumerate(wave):
            toks[i, plen - len(r.prompt):] = r.prompt  # left-pad
        cache = self.api.init_cache(b, self.max_len)
        batch = {"tokens": jnp.asarray(toks)}
        logits, cache = self._prefill(self.params, batch, cache)
        out = {r.uid: [] for r in wave}
        cur = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        max_new = max(r.max_new_tokens for r in wave)
        for t in range(max_new):
            for i, r in enumerate(wave):
                if t < r.max_new_tokens:
                    out[r.uid].append(int(cur[i, 0]))
            logits, cache = self._decode(
                self.params, cur, jnp.asarray(plen + t, jnp.int32), cache)
            cur = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        return out
