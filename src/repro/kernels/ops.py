"""jit'd public wrappers around the Pallas kernels.

``coo_spmv`` does the host-side packet→block metadata prep (once per graph,
cached on the BlockedCOO) and the empty-dst-block masking that the kernel's
write-once discipline requires.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.coo import BlockedCOO
from repro.core.fixed_point import QFormat
from repro.kernels.coo_spmv import coo_spmv_pallas
from repro.kernels.fixed_matmul import quantized_matmul_pallas


def packet_metadata(blocked: BlockedCOO):
    """packet→(dst, src, first-of-dst, dst-touched) maps (host-side, O(E))."""
    starts = blocked.block_starts.astype(np.int64)
    n_dst, n_src = blocked.n_dst, blocked.n_src
    counts = np.diff(starts)                       # packets per (dst,src) block
    block_ids = np.nonzero(counts)[0]
    reps = counts[block_ids]
    packet_block = np.repeat(block_ids, reps)      # [num_packets]
    packet_dst = (packet_block // n_src).astype(np.int32)
    packet_src = (packet_block % n_src).astype(np.int32)
    first = np.zeros_like(packet_dst)
    if packet_dst.shape[0]:
        first[0] = 1
        first[1:] = (packet_dst[1:] != packet_dst[:-1]).astype(np.int32)
    touched = np.zeros(n_dst, bool)
    touched[np.unique(packet_dst)] = True
    return packet_dst, packet_src, first.astype(np.int32), touched


def coo_spmv(
    blocked: BlockedCOO,
    p: jax.Array,
    *,
    fmt: Optional[QFormat] = None,
    interpret: bool = True,
) -> jax.Array:
    """Streaming SpMM via the Pallas kernel.  p: [V_padded, K] where V_padded =
    n_src * v_tile (caller pads).  fmt=None → float; else p/val are raw uint32."""
    meta = getattr(blocked, "_packet_meta", None)
    if meta is None:
        meta = packet_metadata(blocked)
        object.__setattr__(blocked, "_packet_meta", meta) if hasattr(blocked, "__frozen__") \
            else setattr(blocked, "_packet_meta", meta)
    packet_dst, packet_src, first, touched = meta
    num_packets = packet_dst.shape[0]
    pk = blocked.packet
    xp_, yp_ = blocked.packed_indices()   # uint16 when v_tile ≤ 65536 (½ stream)
    x2 = jnp.asarray(xp_.reshape(num_packets, pk))
    y2 = jnp.asarray(yp_.reshape(num_packets, pk))
    if fmt is None:
        val2 = jnp.asarray(blocked.val.reshape(num_packets, pk))
        frac_bits = None
    else:
        raw = np.minimum(
            np.floor(np.clip(blocked.val.astype(np.float64), 0, None) * fmt.scale),
            fmt.max_raw,
        ).astype(np.uint32)
        val2 = jnp.asarray(raw.reshape(num_packets, pk))
        frac_bits = fmt.frac_bits
    out = coo_spmv_pallas(
        x2, y2, val2, p,
        jnp.asarray(packet_dst), jnp.asarray(packet_src), jnp.asarray(first),
        v_tile=blocked.v_tile, packet=pk, n_dst=blocked.n_dst,
        num_packets=num_packets, frac_bits=frac_bits, interpret=interpret,
    )
    # dst blocks with zero packets hold uninitialized memory — mask them.
    mask = jnp.asarray(np.repeat(touched, blocked.v_tile))
    return jnp.where(mask[:, None], out, jnp.zeros_like(out))


def pad_p_for_blocks(p: jax.Array, blocked: BlockedCOO) -> jax.Array:
    """Pad P [V, K] to [n_src*v_tile, K] for the kernel."""
    target = blocked.n_src * blocked.v_tile
    pad = target - p.shape[0]
    if pad == 0:
        return p
    return jnp.pad(p, ((0, pad), (0, 0)))


def quantized_matmul(a, w_q, scale, *, interpret: bool = True, **tiles):
    """Reduced-precision serving matmul (see fixed_matmul.py)."""
    return quantized_matmul_pallas(a, w_q, scale, interpret=interpret, **tiles)
