"""Pallas TPU kernels for the paper's compute hot-spots.

- coo_spmv:        the paper's streaming COO SpMM (packets → VMEM tiles →
                   MXU one-hot scatter), float and bit-exact fixed-point.
- fixed_matmul:    reduced-precision (int8 / Qm.f) serving matmul.
- flash_attention: fused blocked attention for the LM stack (causal /
                   local-window / GQA) — the framework's own hot-spot.

ops.py holds the jit'd wrappers; ref.py the pure-jnp oracles every kernel is
validated against (interpret=True) in tests/.
"""
from repro.kernels import ops, ref
from repro.kernels.coo_spmv import coo_spmv_pallas
from repro.kernels.fixed_matmul import quantized_matmul_pallas
from repro.kernels.flash_attention import flash_attention_gqa, flash_attention_pallas

__all__ = [
    "ops", "ref", "coo_spmv_pallas", "quantized_matmul_pallas",
    "flash_attention_pallas", "flash_attention_gqa",
]
