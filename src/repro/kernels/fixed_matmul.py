"""Pallas TPU kernel: reduced-precision matmul for the LM serving path.

The paper's truncation-quantization applied to dense layers: activations (f32 or
bf16) × int8 per-channel-quantized weights, f32 MXU accumulation, scale folded in
at the epilogue.  8-bit weights halve (vs bf16) or quarter (vs f32) the HBM
weight traffic — the dominant term of the decode roofline — exactly the paper's
"bit-width buys bandwidth" argument transplanted to LM inference.

Tiling: classic (bm × bk) · (bk × bn) grid with K-innermost accumulation in a
VMEM scratch accumulator; the MXU sees hardware-aligned 128-multiples.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mm_kernel(a_ref, w_ref, scale_ref, out_ref, acc_ref, *, n_k: int):
    """Grid (m, n, k), k innermost; acc lives in VMEM scratch across the k loop."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)            # int8 → f32 on load (VREG convert)
    acc_ref[...] += jnp.dot(a, w, preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _epilogue():
        out_ref[...] = acc_ref[...] * scale_ref[0, :].astype(jnp.float32)[None, :]


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret")
)
def quantized_matmul_pallas(
    a: jax.Array,        # [M, K] f32/bf16 activations
    w_q: jax.Array,      # [K, N] int8 weights
    scale: jax.Array,    # [N] f32 per-out-channel scales
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = True,
) -> jax.Array:
    m, kdim = a.shape
    _, n = w_q.shape
    if m % bm or n % bn or kdim % bk:
        raise ValueError(f"shape ({m},{kdim},{n}) not divisible by tile ({bm},{bk},{bn})")
    n_k = kdim // bk
    grid = (m // bm, n // bn, n_k)
    return pl.pallas_call(
        functools.partial(_mm_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, w_q, scale[None, :])
