"""Pallas TPU kernel: streaming COO SpMM (the paper's §4.1.1 pipeline).

TPU mapping of the FPGA architecture (paper §4.1.1; see PAPER.md for the
abstract and README.md "Architecture map" for where this sits in the repo):

  FPGA                                  TPU (this kernel)
  ----------------------------------    ----------------------------------------
  DRAM burst read, 256-bit packets      HBM→VMEM streaming: 1-D grid over edge
                                        packets; BlockSpec auto double-buffers
  URAM-resident P_t                     VMEM-resident (v_tile × K) src slice of P,
                                        selected per packet via scalar-prefetched
                                        packet→src-block map
  B×B comparator crossbar aggregator    one-hot MXU matmul:
                                        acc += onehot(x_local)ᵀ @ (val·P[y_local])
  FSM, 2 buffers, 1 write per block     Pallas output revisiting: consecutive
                                        packets of one dst block accumulate in
                                        VMEM; the block is written to HBM once,
                                        when the dst index advances
  fixed-point DSP multiply              uint32 16-bit-limb multiply (bit-exact)

Grid: one step per packet (PACKET edges).  Scalar-prefetch arrays give each
packet its (dst_block, src_block) and a first-packet-of-dst-block flag.
Packets are dst-major sorted, so each output block is revisited consecutively
— the same "write each block exactly once" discipline as the paper's FSM.

Roofline choice of tile sizes: the one-hot matmul costs 2·v_tile·K flop/edge
vs 12 B/edge of HBM traffic, so the kernel turns compute-bound once
2·v_tile·K/12 > 240 flop/B (v5e ridge), i.e. keep v_tile·K ≲ 1440 to stay
on the bandwidth-bound side the paper's streaming argument assumes.
Measured iteration latencies live in the committed BENCH_*.json baselines
(benchmarks/bench_spmv.py writes the SpMV section).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.fixed_point import QFormat

_MASK16 = np.uint32(0xFFFF)


def _fixed_mul_u32(a, b, frac_bits: int):
    """Bit-exact (a*b) >> f on uint32 via 16-bit limbs (no 64-bit ops) — the
    in-kernel replica of QFormat.mul, kept local so the kernel body has no
    host-side dependencies."""
    a0 = a & _MASK16
    a1 = a >> 16
    b0 = b & _MASK16
    b1 = b >> 16
    ll = a0 * b0
    lh = a0 * b1
    hl = a1 * b0
    hh = a1 * b1
    mid = lh + hl
    mid_carry = (mid < lh).astype(jnp.uint32)
    # repro: allow[FXP002] carry-tracked — bits >=32 of mid<<16 re-enter via mid>>16 (+ mid_carry) in hi
    lo = ll + (mid << 16)
    carry_lo = (lo < ll).astype(jnp.uint32)
    hi = hh + (mid >> 16) + (mid_carry << 16) + carry_lo
    f = frac_bits
    return (lo >> f) | (hi << (32 - f))


def _kernel_float(dst_blk, src_blk, first, x_ref, y_ref, val_ref, p_ref, out_ref):
    """One grid step = one packet of edges.

    x_ref/y_ref/val_ref: [1, PACKET] edge slices (this packet).
    p_ref:   [v_tile, K]  source slice of P (selected by src_blk[i]).
    out_ref: [v_tile, K]  destination accumulator (selected by dst_blk[i]).
    """
    i = pl.program_id(0)

    @pl.when(first[i] == 1)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[0, :].astype(jnp.int32)            # [P] local dst (u16-packed ok)
    y = y_ref[0, :].astype(jnp.int32)            # [P] local src
    val = val_ref[0, :]                          # [P]
    # stage 2 (paper): edge-wise multiply val[j] * P[y[j], :]
    gathered = p_ref[y, :]                       # [P, K] VMEM gather
    contrib = val[:, None] * gathered            # [P, K]
    # stage 3 (paper): aggregation — the B×B crossbar as a one-hot matmul
    v_tile = out_ref.shape[0]
    onehot = (x[:, None] == jax.lax.broadcasted_iota(jnp.int32, (x.shape[0], v_tile), 1))
    out_ref[...] += jnp.dot(
        onehot.astype(contrib.dtype).T, contrib,
        preferred_element_type=out_ref.dtype,
    )


def _kernel_fixed(frac_bits, dst_blk, src_blk, first,
                  x_ref, y_ref, val_ref, p_ref, out_ref):
    """Fixed-point variant: raw uint32 values, truncating limb multiply, exact
    integer aggregation (int32 one-hot matmul)."""
    i = pl.program_id(0)

    @pl.when(first[i] == 1)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[0, :].astype(jnp.int32)
    y = y_ref[0, :].astype(jnp.int32)
    val = val_ref[0, :]
    gathered = p_ref[y, :]                        # [P, K] uint32 raw
    contrib = _fixed_mul_u32(val[:, None], gathered, frac_bits)
    v_tile = out_ref.shape[0]
    onehot = (x[:, None] == jax.lax.broadcasted_iota(jnp.int32, (x.shape[0], v_tile), 1))
    acc = jnp.dot(onehot.astype(jnp.int32).T, contrib.astype(jnp.int32),
                  preferred_element_type=jnp.int32)
    out_ref[...] += acc.astype(jnp.uint32)


@functools.partial(
    jax.jit,
    static_argnames=("v_tile", "packet", "n_dst", "num_packets", "frac_bits", "interpret"),
)
def coo_spmv_pallas(
    x_local: jax.Array,       # [num_packets, packet] int32, dst index local to tile
    y_local: jax.Array,       # [num_packets, packet] int32, src index local to tile
    val: jax.Array,           # [num_packets, packet] f32 (or uint32 raw if fixed)
    p: jax.Array,             # [n_src * v_tile, K]
    packet_dst: jax.Array,    # [num_packets] int32  packet → dst block
    packet_src: jax.Array,    # [num_packets] int32  packet → src block
    packet_first: jax.Array,  # [num_packets] int32  1 = first packet of dst block
    *,
    v_tile: int,
    packet: int,
    n_dst: int,
    num_packets: int,
    frac_bits: Optional[int] = None,
    interpret: bool = True,
) -> jax.Array:
    """Returns out [n_dst * v_tile, K]; dst blocks with no packets are NOT
    written (caller masks them — see ops.coo_spmv)."""
    k = p.shape[-1]
    out_dtype = p.dtype
    kernel = (
        _kernel_float if frac_bits is None
        else functools.partial(_kernel_fixed, frac_bits)
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(num_packets,),
        in_specs=[
            pl.BlockSpec((1, packet), lambda i, pd, ps, pf: (i, 0)),   # x
            pl.BlockSpec((1, packet), lambda i, pd, ps, pf: (i, 0)),   # y
            pl.BlockSpec((1, packet), lambda i, pd, ps, pf: (i, 0)),   # val
            pl.BlockSpec((v_tile, k), lambda i, pd, ps, pf: (ps[i], 0)),  # P src slice
        ],
        out_specs=pl.BlockSpec((v_tile, k), lambda i, pd, ps, pf: (pd[i], 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_dst * v_tile, k), out_dtype),
        interpret=interpret,
    )(packet_dst, packet_src, packet_first, x_local, y_local, val, p)
