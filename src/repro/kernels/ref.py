"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.fixed_point import QFormat


def coo_spmv_ref(x, y, val, p, num_vertices: int) -> jax.Array:
    """Dense-semantics oracle for the streaming SpMM (float path)."""
    contrib = val[:, None] * p[y]
    return jax.ops.segment_sum(contrib, x, num_segments=num_vertices)


def coo_spmv_fixed_ref(x, y, val_raw, p_raw, num_vertices: int, fmt: QFormat) -> jax.Array:
    """Bit-exact fixed-point oracle (truncating multiply, exact raw add)."""
    prod = fmt.mul(val_raw[:, None], p_raw[y])
    acc = jax.ops.segment_sum(prod.astype(jnp.int32), x, num_segments=num_vertices)
    return acc.astype(jnp.uint32)


def quantized_matmul_ref(a, w_q, scale) -> jax.Array:
    """Oracle for fixed_matmul: (a @ w_q) * scale, accumulated in f32."""
    acc = jnp.dot(a.astype(jnp.float32), w_q.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    return acc * scale[None, :].astype(jnp.float32)


def flash_attention_ref(q, k, v, causal: bool = True, window: int = 0) -> jax.Array:
    """Oracle for the fused attention kernel: q/k/v [BH, S, d]."""
    import math

    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(d)
    sq, skv = q.shape[1], k.shape[1]
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= qpos >= kpos
    if window > 0:
        mask &= qpos - kpos < window
    s = jnp.where(mask[None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    # fully-masked rows → 0 output (kernel convention)
    any_valid = mask.any(axis=1)[None, :, None]
    out = jnp.einsum("bqk,bkd->bqd", w, v.astype(jnp.float32))
    return jnp.where(any_valid, out, 0.0).astype(q.dtype)
