"""Pallas TPU kernel: fused blocked attention (flash-attention style).

The LM stack's prefill/train attention is the framework's compute hot-spot and
— per the §Roofline tables — a large slice of the memory term comes from
materializing [Sq, Skv] score tensors in HBM.  This kernel computes
softmax(QKᵀ/√d + mask)·V with the online-softmax recurrence so scores never
leave VMEM:

  grid = (batch·heads, q_blocks, kv_blocks), kv innermost.
  carry (VMEM scratch): m (running max), l (running sum), acc (output).
  Supports causal masking and local windows (gemma2/gemma3/mixtral-SWA);
  out-of-window kv blocks are skipped by the mask (a production version would
  skip them in the index map — noted in EXPERIMENTS §Perf).

HBM traffic: Q + K + V + O only — the [Sq,Skv] term drops entirely.
Validated against ref.flash_attention_ref in interpret mode
(tests/test_flash_attention.py), including GQA via kv-head broadcasting
at the wrapper level.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
               *, scale: float, causal: bool, window: int,
               bq: int, bk: int, n_kv: int):
    """One (bh, qi, ki) grid step."""
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)              # [bq, d]
    k = k_ref[0].astype(jnp.float32)              # [bk, d]
    v = v_ref[0].astype(jnp.float32)              # [bk, d]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale  # [bq,bk]
    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), bool)
    if causal:
        mask &= qpos >= kpos
    if window > 0:
        mask &= qpos - kpos < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                           # [bq, 1]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                        # [bq, bk]
    alpha = jnp.exp(m_prev - m_new)               # [bq, 1]
    l_new = alpha * l_ref[...] + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == n_kv - 1)
    def _finish():
        # rows with no valid kv (l==0) output 0
        l = l_ref[...]
        o_ref[0, ...] = jnp.where(
            l > 0, acc_ref[...] / jnp.maximum(l, 1e-30), 0.0
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "bq", "bk", "interpret"),
)
def flash_attention_pallas(
    q: jax.Array,      # [BH, Sq, d]
    k: jax.Array,      # [BH, Skv, d]
    v: jax.Array,      # [BH, Skv, d]
    *,
    causal: bool = True,
    window: int = 0,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = True,
) -> jax.Array:
    bh, sq, d = q.shape
    skv = k.shape[1]
    if sq % bq or skv % bk:
        raise ValueError(f"seq ({sq},{skv}) not divisible by blocks ({bq},{bk})")
    n_kv = skv // bk
    scale = 1.0 / math.sqrt(d)
    grid = (bh, sq // bq, n_kv)
    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, n_kv=n_kv)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # m
            pltpu.VMEM((bq, 1), jnp.float32),   # l
            pltpu.VMEM((bq, d), jnp.float32),   # acc
        ],
        interpret=interpret,
    )(q, k, v)


def flash_attention_gqa(q, k, v, *, causal=True, window=0, interpret=True,
                        bq=128, bk=128):
    """GQA wrapper: q [B,Sq,H,hd], k/v [B,Skv,KV,hd] → [B,Sq,H,hd]."""
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    kb = jnp.repeat(k, g, axis=2)
    vb = jnp.repeat(v, g, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, hd)
    kf = kb.transpose(0, 2, 1, 3).reshape(b * h, k.shape[1], hd)
    vf = vb.transpose(0, 2, 1, 3).reshape(b * h, v.shape[1], hd)
    o = flash_attention_pallas(qf, kf, vf, causal=causal, window=window,
                               bq=bq, bk=bk, interpret=interpret)
    return o.reshape(b, h, sq, hd).transpose(0, 2, 1, 3)
