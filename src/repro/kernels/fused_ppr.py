"""Fused Pallas PPR iteration: SpMV + eq. (1) axpy + dangling fold, one launch.

The paper's core claim is a *streaming fused* pipeline (§4.1): SpMV, the
eq. (1) axpy and the dangling-mass fold execute as one pass over the edge
stream.  ``coo_spmv.py`` maps the §4.1.1 SpMV stage alone; this module fuses
the whole iteration

    P_{t+1} = α·X·P_t + α/|V|·(d̄ᵀP_t)·1 + (1−α)·V̄        (eq. 1)

into a single ``pallas_call`` so a serving wave pays one kernel launch per
iteration instead of the composed jax-ops dispatch chain.  The grid is

    [ n_blk dangling-fold steps | dst-major packet stream steps ]

- **Prologue** (one step per vertex block): accumulate d̄ᵀP into a [1, K]
  dangling-mass output whose constant index map keeps it VMEM-resident for
  the whole grid (Pallas output revisiting — it is written to HBM once, at
  grid end).  Raw uint32 products are summed in int32, so the partial-sums-
  per-block order is bit-identical (mod 2^32) to ``_fixed_dangling_mass``.
- **Stream** (one step per edge packet, dst-major): the one-hot-MXU SpMV
  accumulation of ``coo_spmv.py``.  On the *last* packet of each dst block
  the kernel applies the eq. (1) combine in place — for fixed point, the
  exact ``_fixed_combine`` nesting of truncating limb multiplies and
  saturating adds, so results are bit-identical (raw uint32) to the composed
  ``make_ppr_fixed_step`` datapath — and folds |ΔP| into a [3, K] residual
  output (L1 / ∞ / Σd² per column) for the early-exit driver, replacing the
  separate host-synced reductions of ``ConvergenceMonitor``.

Empty dst blocks get a sentinel step over a shared all-zero edge row so every
output block is still zeroed + combined (a vertex with no in-edges keeps its
(1−α)·V̄ + dangling terms).  Pad rows of the trailing ragged block are masked
to zero after the combine, so the next iteration's pads stay zero.

``interpret=True`` (the default off-TPU) runs the same kernel through the
Pallas interpreter — slow, but bit-exact, which keeps CPU-only CI meaningful.

Layout construction/incremental re-packetization lives in ``FusedLayout`` /
``build_fused_layout`` below; the serving integration is
``repro.ppr_serving.engine.pallas``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.coo import COOGraph, quantize_values
from repro.core.fixed_point import QFormat
from repro.core.ppr import _fixed_consts
from repro.kernels.coo_spmv import _fixed_mul_u32

__all__ = [
    "FusedLayout", "build_fused_layout", "quantize_layout_rows",
    "assemble_value_rows", "fused_ppr_iteration", "default_interpret",
]


@functools.lru_cache(maxsize=1)
def default_interpret() -> bool:
    """interpret=True unless a real TPU backend is present."""
    try:
        return jax.devices()[0].platform != "tpu"
    except Exception:  # pragma: no cover - no backend at all
        return True


# ---------------------------------------------------------------------------
# host-side layout: dst-major packetized edge stream + per-step schedule
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class FusedLayout:
    """Packetized dst-major edge layout + the kernel's per-step schedule.

    Per dst block ``d`` the edges are grouped by source block and padded to
    whole packets (``row_*[d]``: [p_d, packet] with local indices; pad entries
    are zero-valued self-edges to local vertex 0 — they contribute nothing).
    The assembled arrays carry one extra all-zero sentinel row at index
    ``num_rows - 1``, addressed by prologue steps and by the sentinel step of
    every empty dst block.

    The rebuild is per-dst-block and deterministic, so an incremental rebuild
    of only the dirty blocks is array-equal to a fresh build of the merged
    graph (tested) — the ``on_delta`` contract of the pallas engine family.
    """
    num_vertices: int
    num_edges: int
    v_tile: int
    packet: int
    n_blk: int
    row_x: List[np.ndarray]      # per dst block: [p_d, packet] int32 local dst
    row_y: List[np.ndarray]      # per dst block: [p_d, packet] int32 local src
    row_val: List[np.ndarray]    # per dst block: [p_d, packet] f64 edge values
    x2: np.ndarray               # [num_rows, packet] int32 (+ sentinel row)
    y2: np.ndarray               # [num_rows, packet] int32
    val2: np.ndarray             # [num_rows, packet] f32
    step_row: np.ndarray         # [num_steps] int32  step → edge row
    step_dst: np.ndarray         # [num_steps] int32  step → dst block
    step_src: np.ndarray         # [num_steps] int32  step → src block
    step_first: np.ndarray       # [num_steps] int32  1 = zero the dst block
    step_last: np.ndarray        # [num_steps] int32  1 = combine + residual

    @property
    def n_prologue(self) -> int:
        return self.n_blk

    @property
    def num_steps(self) -> int:
        return int(self.step_row.shape[0])

    @property
    def num_rows(self) -> int:
        return int(self.x2.shape[0])


def _build_dst_row(x, y, val, v_tile: int, packet: int, n_blk: int):
    """One dst block's edges, grouped by src block, packet-padded, localized."""
    src_blk = (np.asarray(y, np.int64) // v_tile)
    order = np.argsort(src_blk, kind="stable")   # keep (dst, src) order inside
    xs = np.asarray(x, np.int64)[order]
    ys = np.asarray(y, np.int64)[order]
    vs = np.asarray(val)[order]
    sbs = src_blk[order]
    counts = np.bincount(sbs, minlength=n_blk).astype(np.int64)
    pad_counts = (counts + packet - 1) // packet * packet
    total = int(pad_counts.sum())
    row_x = np.zeros(total, np.int32)
    row_y = np.zeros(total, np.int32)
    row_val = np.zeros(total, np.float64)
    src_off = np.zeros(n_blk + 1, np.int64)
    np.cumsum(counts, out=src_off[1:])
    dst_off = np.zeros(n_blk + 1, np.int64)
    np.cumsum(pad_counts, out=dst_off[1:])
    for b in np.nonzero(counts)[0]:
        s0, s1 = src_off[b], src_off[b + 1]
        d0 = dst_off[b]
        n = s1 - s0
        row_x[d0:d0 + n] = xs[s0:s1] % v_tile
        row_y[d0:d0 + n] = ys[s0:s1] % v_tile
        row_val[d0:d0 + n] = vs[s0:s1]
    p_d = total // packet
    row_src = np.repeat(np.arange(n_blk, dtype=np.int32),
                        (pad_counts // packet))
    return (row_x.reshape(p_d, packet), row_y.reshape(p_d, packet),
            row_val.reshape(p_d, packet), row_src)


def _assemble_rows(rows: Sequence[np.ndarray], packet: int, dtype) -> np.ndarray:
    """Stack per-block rows and append the shared all-zero sentinel row."""
    parts = [np.asarray(r, dtype) for r in rows if r.shape[0]]
    parts.append(np.zeros((1, packet), dtype))
    return np.concatenate(parts, axis=0)


def assemble_value_rows(rows: Sequence[np.ndarray], packet: int,
                        dtype=np.uint32) -> np.ndarray:
    """Assemble per-block *value* rows (e.g. per-format raw uint32) into the
    kernel's [num_rows, packet] operand, sentinel row included."""
    return _assemble_rows(rows, packet, dtype)


def build_fused_layout(g: COOGraph, v_tile: int, packet: int,
                       reuse: Optional[FusedLayout] = None,
                       dirty=None) -> FusedLayout:
    """Packetize ``g``'s (unpadded, (dst, src)-lexsorted) edge stream.

    ``reuse``/``dirty``: incremental re-packetization — per-block rows of
    clean dst blocks are taken from ``reuse`` (same arrays, not copies), only
    blocks in ``dirty`` are rebuilt.  Requires an unchanged block count;
    callers fall back to a full rebuild when ``n_blk`` moves.
    """
    v = g.num_vertices
    n_blk = max(1, -(-v // v_tile))
    if reuse is not None and (reuse.n_blk != n_blk or reuse.v_tile != v_tile
                              or reuse.packet != packet):
        raise ValueError("fused layout reuse requires identical block geometry")
    dirty_set = (set(range(n_blk)) if reuse is None or dirty is None
                 else {int(d) for d in dirty})
    # dst-major lexsorted stream ⇒ each dst block is one contiguous slice
    bounds = np.searchsorted(np.asarray(g.x), np.arange(n_blk + 1) * v_tile)
    rows_x, rows_y, rows_v, rows_s = [], [], [], []
    for d in range(n_blk):
        if reuse is not None and d not in dirty_set:
            rx, ry, rv = reuse.row_x[d], reuse.row_y[d], reuse.row_val[d]
            rs = np.full(rx.shape[0], d, np.int32)
        else:
            a, b = int(bounds[d]), int(bounds[d + 1])
            rx, ry, rv, rsrc = _build_dst_row(
                g.x[a:b], g.y[a:b], g.val[a:b], v_tile, packet, n_blk)
            rs = rsrc
        rows_x.append(rx)
        rows_y.append(ry)
        rows_v.append(rv)
        rows_s.append(rs)
    x2 = _assemble_rows(rows_x, packet, np.int32)
    y2 = _assemble_rows(rows_y, packet, np.int32)
    val2 = _assemble_rows(rows_v, packet, np.float32)
    sentinel = x2.shape[0] - 1
    # schedule: prologue folds dangling block b into dm; then the dst-major
    # stream, with one sentinel step per empty dst block
    srow = [sentinel] * n_blk
    sdst = [0] * n_blk
    ssrc = list(range(n_blk))
    sfirst = [0] * n_blk
    slast = [0] * n_blk
    base = 0
    for d in range(n_blk):
        p_d = rows_x[d].shape[0]
        if p_d == 0:
            srow.append(sentinel)
            sdst.append(d)
            ssrc.append(0)
            sfirst.append(1)
            slast.append(1)
            continue
        for j in range(p_d):
            srow.append(base + j)
            sdst.append(d)
            ssrc.append(int(rows_s[d][j]))
            sfirst.append(1 if j == 0 else 0)
            slast.append(1 if j == p_d - 1 else 0)
        base += p_d
    return FusedLayout(
        num_vertices=v, num_edges=int(g.num_edges), v_tile=v_tile,
        packet=packet, n_blk=n_blk,
        row_x=rows_x, row_y=rows_y, row_val=rows_v,
        x2=x2, y2=y2, val2=val2,
        step_row=np.asarray(srow, np.int32),
        step_dst=np.asarray(sdst, np.int32),
        step_src=np.asarray(ssrc, np.int32),
        step_first=np.asarray(sfirst, np.int32),
        step_last=np.asarray(slast, np.int32))


def quantize_layout_rows(layout: FusedLayout, fmt: QFormat,
                         reuse_rows: Optional[List[np.ndarray]] = None,
                         dirty=None) -> List[np.ndarray]:
    """Per-dst-block raw uint32 value rows for ``fmt``.

    The quantizer is per-edge and order-independent, so requantizing only the
    dirty blocks (reusing the rest) equals a from-scratch quantization of the
    merged stream bit-for-bit.  Pad entries quantize 0.0 → raw 0.
    """
    dirty_set = (set(range(layout.n_blk)) if reuse_rows is None or dirty is None
                 else {int(d) for d in dirty})
    rows = []
    for d in range(layout.n_blk):
        if reuse_rows is not None and d not in dirty_set:
            rows.append(reuse_rows[d])
        else:
            rv = layout.row_val[d]
            rows.append(quantize_values(rv.ravel(), fmt).reshape(rv.shape))
    return rows


# ---------------------------------------------------------------------------
# the fused kernels
# ---------------------------------------------------------------------------
def _spmv_accumulate_float(x_ref, y_ref, val_ref, ps_ref, out_ref):
    x = x_ref[0, :].astype(jnp.int32)
    y = y_ref[0, :].astype(jnp.int32)
    val = val_ref[0, :]
    contrib = val[:, None] * ps_ref[y, :]         # [P, K]
    v_tile = out_ref.shape[0]
    onehot = (x[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (x.shape[0], v_tile), 1))
    out_ref[...] += jnp.dot(onehot.astype(contrib.dtype).T, contrib,
                            preferred_element_type=out_ref.dtype)


def _spmv_accumulate_fixed(frac_bits, x_ref, y_ref, val_ref, ps_ref, out_ref):
    x = x_ref[0, :].astype(jnp.int32)
    y = y_ref[0, :].astype(jnp.int32)
    val = val_ref[0, :]
    contrib = _fixed_mul_u32(val[:, None], ps_ref[y, :], frac_bits)
    v_tile = out_ref.shape[0]
    onehot = (x[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (x.shape[0], v_tile), 1))
    acc = jnp.dot(onehot.astype(jnp.int32).T, contrib.astype(jnp.int32),
                  preferred_element_type=jnp.int32)
    out_ref[...] += acc.astype(jnp.uint32)


def _valid_rows(dst_blk, v_tile: int, num_vertices: int):
    """[v_tile, 1] mask of real (non-pad) rows of this dst block."""
    rows = dst_blk * v_tile + jax.lax.broadcasted_iota(
        jnp.int32, (v_tile, 1), 0)
    return rows < num_vertices


def _fold_residual(res_ref, pn, prev_f32_diff):
    """Accumulate this dst block's |ΔP| into the [3, K] (L1, ∞, Σd²) output."""
    r = res_ref[...]
    res_ref[...] = jnp.stack([
        r[0] + prev_f32_diff.sum(0),
        jnp.maximum(r[1], prev_f32_diff.max(0)),
        r[2] + (prev_f32_diff * prev_f32_diff).sum(0),
    ])


def _sat_add_u32(a, b, max_raw):
    """In-kernel replica of ``QFormat.add``: saturating uint32 add."""
    s = a + b
    over = (s < a) | (s > max_raw)
    return jnp.where(over, max_raw, s)


def _kernel_float_fused(alpha, num_vertices, n_prologue,
                        sr, sd, ss, sf, sl,
                        x_ref, y_ref, val_ref, ps_ref, pd_ref, vmat_ref,
                        dang_ref, out_ref, dm_ref, res_ref):
    """One grid step: prologue dangling fold, or one SpMV packet; the last
    packet of a dst block applies the eq. (1) combine + residual in place."""
    s = pl.program_id(0)

    @pl.when(s == 0)
    def _init():
        dm_ref[...] = jnp.zeros_like(dm_ref)
        res_ref[...] = jnp.zeros_like(res_ref)

    @pl.when(s < n_prologue)
    def _fold_dangling():
        dm_ref[...] += (dang_ref[...] * ps_ref[...]).sum(0, keepdims=True)

    @pl.when((s >= n_prologue) & (sf[s] == 1))
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(s >= n_prologue)
    def _spmv():
        _spmv_accumulate_float(x_ref, y_ref, val_ref, ps_ref, out_ref)

    @pl.when((s >= n_prologue) & (sl[s] == 1))
    def _combine():
        v_tile = out_ref.shape[0]
        pn = (alpha * out_ref[...]
              + (alpha / num_vertices) * dm_ref[...]
              + (1.0 - alpha) * vmat_ref[...])
        pn = jnp.where(_valid_rows(sd[s], v_tile, num_vertices),
                       pn, jnp.zeros_like(pn))
        out_ref[...] = pn
        _fold_residual(res_ref, pn, jnp.abs(pn - pd_ref[...]))


def _kernel_fixed_fused(frac_bits, alpha_raw, one_minus_alpha_raw,
                        alpha_over_v_raw, max_raw, num_vertices, n_prologue,
                        sr, sd, ss, sf, sl,
                        x_ref, y_ref, val_ref, ps_ref, pd_ref, vmat_ref,
                        dang_ref, out_ref, dm_ref, res_ref):
    """Fixed-point variant: raw uint32 SpMV + the exact ``_fixed_combine``
    nesting (truncating limb multiplies, saturating adds) — bit-identical to
    the composed ``make_ppr_fixed_step``."""
    s = pl.program_id(0)

    @pl.when(s == 0)
    def _init():
        dm_ref[...] = jnp.zeros_like(dm_ref)
        res_ref[...] = jnp.zeros_like(res_ref)

    @pl.when(s < n_prologue)
    def _fold_dangling():
        d = dang_ref[...].astype(jnp.uint32)
        dm_ref[...] += (d * ps_ref[...]).astype(jnp.int32).sum(0, keepdims=True)

    @pl.when((s >= n_prologue) & (sf[s] == 1))
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(s >= n_prologue)
    def _spmv():
        _spmv_accumulate_fixed(frac_bits, x_ref, y_ref, val_ref, ps_ref, out_ref)

    @pl.when((s >= n_prologue) & (sl[s] == 1))
    def _combine():
        v_tile = out_ref.shape[0]
        dm = dm_ref[...].astype(jnp.uint32)
        pn = _sat_add_u32(
            _sat_add_u32(_fixed_mul_u32(alpha_raw, out_ref[...], frac_bits),
                         _fixed_mul_u32(alpha_over_v_raw, dm, frac_bits),
                         max_raw),
            _fixed_mul_u32(one_minus_alpha_raw, vmat_ref[...], frac_bits),
            max_raw)
        pn = jnp.where(_valid_rows(sd[s], v_tile, num_vertices),
                       pn, jnp.zeros_like(pn))
        out_ref[...] = pn
        prev = pd_ref[...]
        diff = (jnp.maximum(pn, prev) - jnp.minimum(pn, prev)).astype(jnp.float32)
        _fold_residual(res_ref, pn, diff)


# ---------------------------------------------------------------------------
# the launch
# ---------------------------------------------------------------------------
@functools.partial(
    jax.jit,
    static_argnames=("v_tile", "packet", "n_blk", "num_steps", "num_vertices",
                     "alpha", "fmt", "interpret"),
)
def fused_ppr_iteration(
    step_row: jax.Array,     # [num_steps] int32  step → edge row
    step_dst: jax.Array,     # [num_steps] int32  step → dst block
    step_src: jax.Array,     # [num_steps] int32  step → src block
    step_first: jax.Array,   # [num_steps] int32
    step_last: jax.Array,    # [num_steps] int32
    x2: jax.Array,           # [num_rows, packet] int32 local dst
    y2: jax.Array,           # [num_rows, packet] int32 local src
    val2: jax.Array,         # [num_rows, packet] f32 (or uint32 raw if fixed)
    dang: jax.Array,         # [n_blk * v_tile, 1] f32 dangling indicator (padded)
    vmat: jax.Array,         # [V, K] personalization matrix
    p: jax.Array,            # [V, K] current state
    *,
    v_tile: int,
    packet: int,
    n_blk: int,
    num_steps: int,
    num_vertices: int,
    alpha: float,
    fmt: Optional[QFormat] = None,
    interpret: bool = True,
):
    """One full eq. (1) iteration as a single Pallas launch.

    Returns ``(P_next [V, K], res [3, K] f32)`` where ``res`` carries the
    per-column (L1, ∞, Σd²) of |P_next − P| — raw units for fixed point.  A
    zero ∞-residual is an exact bit-equality certificate (the minimum nonzero
    raw diff is 1.0, exactly representable in f32), which is what the early
    exit driver keys on.
    """
    k = p.shape[-1]
    padded = n_blk * v_tile
    grow = padded - num_vertices
    p_pad = jnp.pad(p, ((0, grow), (0, 0)))
    vmat_pad = jnp.pad(vmat, ((0, grow), (0, 0)))
    if fmt is None:
        kernel = functools.partial(_kernel_float_fused, alpha, num_vertices,
                                   n_blk)
        dm_dtype = jnp.float32
    else:
        a_raw, oma_raw, aov_raw = _fixed_consts(fmt, num_vertices, alpha)
        kernel = functools.partial(
            _kernel_fixed_fused, fmt.frac_bits, a_raw, oma_raw, aov_raw,
            np.uint32(fmt.max_raw), num_vertices, n_blk)
        dm_dtype = jnp.int32
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,
        grid=(num_steps,),
        in_specs=[
            pl.BlockSpec((1, packet),
                         lambda i, sr, sd, ss, sf, sl: (sr[i], 0)),   # x
            pl.BlockSpec((1, packet),
                         lambda i, sr, sd, ss, sf, sl: (sr[i], 0)),   # y
            pl.BlockSpec((1, packet),
                         lambda i, sr, sd, ss, sf, sl: (sr[i], 0)),   # val
            pl.BlockSpec((v_tile, k),
                         lambda i, sr, sd, ss, sf, sl: (ss[i], 0)),   # P src
            pl.BlockSpec((v_tile, k),
                         lambda i, sr, sd, ss, sf, sl: (sd[i], 0)),   # P dst
            pl.BlockSpec((v_tile, k),
                         lambda i, sr, sd, ss, sf, sl: (sd[i], 0)),   # V̄ dst
            pl.BlockSpec((v_tile, 1),
                         lambda i, sr, sd, ss, sf, sl: (ss[i], 0)),   # dangling
        ],
        out_specs=[
            pl.BlockSpec((v_tile, k),
                         lambda i, sr, sd, ss, sf, sl: (sd[i], 0)),   # P_next
            pl.BlockSpec((1, k), lambda i, sr, sd, ss, sf, sl: (0, 0)),  # dm
            pl.BlockSpec((3, k), lambda i, sr, sd, ss, sf, sl: (0, 0)),  # res
        ],
    )
    out, _, res = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((padded, k), p.dtype),
            jax.ShapeDtypeStruct((1, k), dm_dtype),
            jax.ShapeDtypeStruct((3, k), jnp.float32),
        ],
        interpret=interpret,
    )(step_row, step_dst, step_src, step_first, step_last,
      x2, y2, val2, p_pad, p_pad, vmat_pad, dang)
    return out[:num_vertices], res
