"""Findings baseline: a committed ledger of accepted findings.

The goal state is an **empty** baseline — every finding is either fixed or
carries an inline ``# repro: allow[...] reason``.  The baseline exists for
the migration window when a new rule lands against a tree with pre-existing
findings: ``--write-baseline`` records them (each entry may carry a
``reason``), ``--check`` then fails only on *new* findings — and also on
*stale* entries, so the ledger can only shrink.

Matching is line-insensitive (``rule``, ``path``, ``message``): an entry
survives unrelated edits above the finding but dies with any change to the
finding itself.
"""
from __future__ import annotations

import json
from collections import Counter
from typing import Dict, List, Tuple

from .core import AnalysisResult, Finding

BASELINE_VERSION = 1
DEFAULT_BASELINE = "ANALYSIS_baseline.json"


def dump_baseline(result: AnalysisResult) -> str:
    entries = [
        {"rule": f.rule_id, "path": f.path, "message": f.message,
         "reason": ""}
        for f in result.findings
    ]
    return json.dumps({"version": BASELINE_VERSION, "findings": entries},
                      indent=2, sort_keys=True) + "\n"


def load_baseline(path: str) -> List[dict]:
    with open(path, "r", encoding="utf-8") as fh:
        payload = json.load(fh)
    if payload.get("version") != BASELINE_VERSION:
        raise ValueError(
            f"unsupported baseline version {payload.get('version')!r} in {path}")
    entries = payload.get("findings", [])
    for e in entries:
        if not all(isinstance(e.get(k), str) for k in ("rule", "path", "message")):
            raise ValueError(f"malformed baseline entry in {path}: {e!r}")
    return entries


def apply_baseline(
    findings: List[Finding], entries: List[dict]
) -> Tuple[List[Finding], List[dict]]:
    """Split ``findings`` against the baseline.

    Returns ``(new_findings, stale_entries)``: findings not covered by any
    entry, and entries that matched nothing (stale — they must be removed so
    the ledger only shrinks).  Multiset semantics: one entry absorbs one
    finding."""
    budget: Counter = Counter(
        (e["rule"], e["path"], e["message"]) for e in entries)
    new: List[Finding] = []
    for f in findings:
        key = f.baseline_key()
        if budget.get(key, 0) > 0:
            budget[key] -= 1
        else:
            new.append(f)
    stale_keys: Dict[Tuple[str, str, str], int] = {
        k: n for k, n in budget.items() if n > 0}
    stale: List[dict] = []
    for e in entries:
        k = (e["rule"], e["path"], e["message"])
        if stale_keys.get(k, 0) > 0:
            stale_keys[k] -= 1
            stale.append(e)
    return new, stale
