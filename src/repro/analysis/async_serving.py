"""Rule pack 3 — async-serving discipline (ASY...).

The HTTP tier runs one asyncio event loop; anything that blocks inside an
``async def`` freezes admission, health checks, and every in-flight request
for the duration (ROADMAP item 3's "blocking inside the pump tick" seam).
These rules fire only inside ``async def`` bodies:

- **ASY301 blocking-call-in-async** — ``time.sleep``, blocking socket /
  subprocess / requests calls.  Use ``await asyncio.sleep`` or offload via
  ``loop.run_in_executor``.
- **ASY302 blocking-future-result** — ``<fut>.result()`` without a
  ``timeout=`` argument: ``PPRFuture.result()`` *drives the service
  synchronously* until resolution, and ``concurrent.futures`` results park
  the loop thread.  Pass ``timeout=0`` for a probe or bridge through an
  asyncio future.
- **ASY303 sync-service-call-in-async** — a direct ``service.poll()`` /
  ``flush()`` / ``run_batch()`` / ``serve()`` / ``drain()`` call: each runs
  whole engine waves on the caller's thread.  Offload to an executor so
  arrivals are admitted *during* compute.
- **ASY304 future-leak** — a ``submit(...)`` result discarded as a bare
  expression statement: nothing can ever resolve, time out, or observe that
  future, so its query silently vanishes on the exception path.
"""
from __future__ import annotations

import ast
from typing import Iterator

from . import _astutil as A
from .core import FileContext, Finding, Rule, register_rule

_BLOCKING_CALLS = {
    "time.sleep": "use `await asyncio.sleep(...)`",
    "socket.create_connection": "use asyncio streams / run_in_executor",
    "subprocess.run": "use asyncio.create_subprocess_exec",
    "subprocess.call": "use asyncio.create_subprocess_exec",
    "subprocess.check_call": "use asyncio.create_subprocess_exec",
    "subprocess.check_output": "use asyncio.create_subprocess_exec",
    "requests.get": "offload via run_in_executor",
    "requests.post": "offload via run_in_executor",
    "urllib.request.urlopen": "offload via run_in_executor",
}
_BLOCKING_METHOD_LEAVES = {"accept", "recv", "recv_into", "sendall", "makefile"}
_SERVICE_DRIVERS = {"poll", "flush", "run_batch", "serve", "drain", "pump"}
_SERVICE_RECEIVERS = {"service", "svc", "_service"}


def _async_defs(ctx: FileContext) -> Iterator[ast.AsyncFunctionDef]:
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.AsyncFunctionDef):
            yield node


def _own_nodes(fn: ast.AST) -> Iterator[ast.AST]:
    """Walk without descending into nested (sync or async) defs — a nested
    sync helper runs wherever it is *called*, not where it is defined."""
    stack = list(ast.iter_child_nodes(fn))
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        yield node
        stack.extend(ast.iter_child_nodes(node))


def _receiver_is_service(node: ast.AST) -> bool:
    """True for attribute chains ending in a service-ish name
    (``self.service``, ``svc``, ``app._service``)."""
    name = A.dotted_name(node)
    if name is None:
        return False
    leaf = name.rsplit(".", 1)[-1]
    return leaf in _SERVICE_RECEIVERS


@register_rule
class BlockingCallInAsync(Rule):
    id = "ASY301"
    name = "blocking-call-in-async"
    doc = ("time.sleep / blocking socket / subprocess / HTTP calls inside "
           "`async def` park the whole event loop.")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for fn in _async_defs(ctx):
            for node in _own_nodes(fn):
                if not isinstance(node, ast.Call):
                    continue
                name = A.call_name(node)
                if not name:
                    continue
                if name in _BLOCKING_CALLS or name.rsplit(".", 1)[-1] == "sleep" \
                        and name.split(".", 1)[0] == "time":
                    hint = _BLOCKING_CALLS.get(name, "offload via run_in_executor")
                    yield self.finding(
                        ctx, node,
                        f"blocking call {name}() inside async def "
                        f"`{fn.name}` parks the event loop; {hint}")


@register_rule
class BlockingFutureResult(Rule):
    id = "ASY302"
    name = "blocking-future-result"
    doc = (".result() without timeout= inside `async def`: PPRFuture.result() "
           "drives the service synchronously until resolution.")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for fn in _async_defs(ctx):
            for node in _own_nodes(fn):
                if not (isinstance(node, ast.Call)
                        and isinstance(node.func, ast.Attribute)
                        and node.func.attr == "result"):
                    continue
                if any(kw.arg == "timeout" for kw in node.keywords) or node.args:
                    continue
                yield self.finding(
                    ctx, node,
                    f".result() without timeout= inside async def "
                    f"`{fn.name}` blocks the loop until the future "
                    f"resolves; pass timeout=0 to probe or await an "
                    f"asyncio bridge")


@register_rule
class SyncServiceCallInAsync(Rule):
    id = "ASY303"
    name = "sync-service-call-in-async"
    doc = ("Direct service.poll()/flush()/run_batch()/serve()/drain() inside "
           "`async def` runs engine waves on the loop thread — offload to an "
           "executor so arrivals are admitted during compute.")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for fn in _async_defs(ctx):
            for node in _own_nodes(fn):
                if not (isinstance(node, ast.Call)
                        and isinstance(node.func, ast.Attribute)
                        and node.func.attr in _SERVICE_DRIVERS):
                    continue
                if _receiver_is_service(node.func.value):
                    yield self.finding(
                        ctx, node,
                        f"synchronous service.{node.func.attr}() inside "
                        f"async def `{fn.name}` blocks the event loop for "
                        f"the full wave; offload via "
                        f"loop.run_in_executor(...)")


@register_rule
class FutureLeak(Rule):
    id = "ASY304"
    name = "future-leak"
    doc = ("A submit(...) result discarded as a bare statement inside "
           "`async def`: the returned future can never be awaited, resolved, "
           "or timed out — its query vanishes on the exception path.")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for fn in _async_defs(ctx):
            for node in _own_nodes(fn):
                if not (isinstance(node, ast.Expr)
                        and isinstance(node.value, ast.Call)
                        and isinstance(node.value.func, ast.Attribute)
                        and node.value.func.attr == "submit"):
                    continue
                yield self.finding(
                    ctx, node,
                    f"submit() result discarded inside async def "
                    f"`{fn.name}` — hold the returned future so it can be "
                    f"resolved or cancelled on every exit path")
