"""repro.analysis — repo-specific static analysis, gated in CI.

Design note
===========

The paper's claim is *provably safe* reduced precision: Q-format choices
where raw accumulation cannot overflow and truncation error is bounded.
Until this package, those invariants — and the serving stack's "never block
the event loop / never sync inside a wave body" disciplines — were enforced
by convention and re-broken by hand in PRs 3–5.  This package turns the
conventions into checkable rules over the stdlib ``ast`` (no new runtime
dependencies; the analyzer must run anywhere CI does).

Architecture — three small layers:

``core``
    ``Finding`` / ``Rule`` + registry, ``FileContext`` (one parsed file with
    its ``tokenize``-derived comment tables), the driver, and the repo-derived
    ``AnalysisConfig`` (the widest registered ``QFormat`` is parsed out of
    ``core/fixed_point.py``'s AST, so width rules track the actual precision
    ladder).

rule packs
    ``fixedpoint`` (FXP001 raw-accumulation-width, FXP002
    shift-discards-bits, FXP003 raw-domain-discipline), ``jax_hygiene``
    (JAX101 implicit-sync, JAX102 host-numpy-on-traced, JAX103
    traced-control-flow — scoped to jitted or ``# repro: hot-path``-marked
    functions so telemetry/debug code stays exempt), ``async_serving``
    (ASY301 blocking-call-in-async, ASY302 blocking-future-result, ASY303
    sync-service-call-in-async, ASY304 future-leak — scoped to ``async def``
    bodies).

``baseline`` + ``cli``
    ``python -m repro.analysis`` with text/JSON output, ``--check`` gating in
    ``scripts/ci.sh``, and a committed (ideally empty) findings baseline.

Philosophy: rules are *taint passes with teeth* — deliberately simple
forward passes over one function at a time, tuned to this repo's idioms
(``_raw`` naming, ``fmt.mul``, ``service.poll``).  False-positive control is
structural (only fire on derived facts, e.g. FXP002 needs an actually
inferred width) plus explicit: every silenced finding needs an inline
``# repro: allow[RULE-ID] reason`` — a bare ``allow`` suppresses nothing and
is itself reported (SUP000).  The committed baseline can only shrink:
``--check`` fails on stale entries too.
"""
from .core import (AnalysisConfig, AnalysisResult, FileContext, Finding,
                   Rule, all_rules, analyze_paths, get_rule, load_config,
                   register_rule)

__all__ = [
    "AnalysisConfig", "AnalysisResult", "FileContext", "Finding", "Rule",
    "all_rules", "analyze_paths", "get_rule", "load_config", "register_rule",
]
