"""``python -m repro.analysis`` — the analyzer CLI.

Usage:
    python -m repro.analysis [paths...] [--check] [--json FILE]
                             [--baseline FILE] [--write-baseline]
                             [--list-rules] [--root DIR]

Default paths: ``src/repro benchmarks examples`` under ``--root`` (the repo
root, default cwd).  Exit codes: 0 clean, 1 findings (or stale baseline
entries under ``--check``), 2 usage errors.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List, Optional

from . import baseline as bl
from .core import DEFAULT_PATHS, AnalysisResult, all_rules, analyze_paths


def _build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Repo-specific static analysis: fixed-point width safety "
                    "(FXP*), JAX hot-path hygiene (JAX*), async-serving "
                    "discipline (ASY*).")
    p.add_argument("paths", nargs="*", default=None,
                   help=f"files/directories to scan (default: {' '.join(DEFAULT_PATHS)})")
    p.add_argument("--root", default=".",
                   help="repo root (baseline + default paths resolve here)")
    p.add_argument("--check", action="store_true",
                   help="gate mode: nonzero exit on any unbaselined finding "
                        "or stale baseline entry")
    p.add_argument("--json", metavar="FILE", default=None,
                   help="write the full findings report as JSON ('-' = stdout)")
    p.add_argument("--baseline", metavar="FILE", default=None,
                   help=f"baseline ledger (default: <root>/{bl.DEFAULT_BASELINE} "
                        f"when it exists)")
    p.add_argument("--write-baseline", action="store_true",
                   help="record current findings as the new baseline and exit")
    p.add_argument("--list-rules", action="store_true",
                   help="print the rule catalogue and exit")
    return p


def _report_json(result: AnalysisResult, new_findings, stale, dest: str) -> None:
    payload = {
        "version": 1,
        "files_scanned": result.files_scanned,
        "suppressed": result.suppressed,
        "baselined": len(result.findings) - len(new_findings),
        "stale_baseline_entries": [
            {"rule": e["rule"], "path": e["path"], "message": e["message"]}
            for e in stale],
        "findings": [f.to_dict() for f in new_findings],
    }
    text = json.dumps(payload, indent=2, sort_keys=True) + "\n"
    if dest == "-":
        sys.stdout.write(text)
    else:
        with open(dest, "w", encoding="utf-8") as fh:
            fh.write(text)


def main(argv: Optional[List[str]] = None) -> int:
    parser = _build_parser()
    args = parser.parse_args(argv)

    if args.list_rules:
        for rule in all_rules():
            print(f"{rule.id}  {rule.name}")
            print(f"        {rule.doc}")
        return 0

    root = os.path.abspath(args.root)
    if not os.path.isdir(root):
        parser.error(f"--root {args.root!r} is not a directory")
    paths = args.paths or [p for p in DEFAULT_PATHS
                           if os.path.exists(os.path.join(root, p))]
    if not paths:
        parser.error("nothing to scan: no paths given and no default paths exist")

    result = analyze_paths(paths, root)

    if args.write_baseline:
        dest = args.baseline or os.path.join(root, bl.DEFAULT_BASELINE)
        with open(dest, "w", encoding="utf-8") as fh:
            fh.write(bl.dump_baseline(result))
        print(f"wrote {len(result.findings)} finding(s) to {dest}")
        return 0

    baseline_path = args.baseline or os.path.join(root, bl.DEFAULT_BASELINE)
    entries: List[dict] = []
    if os.path.exists(baseline_path):
        try:
            entries = bl.load_baseline(baseline_path)
        except (ValueError, json.JSONDecodeError) as exc:
            print(f"error: {exc}", file=sys.stderr)
            return 2
    new_findings, stale = bl.apply_baseline(result.findings, entries)

    if args.json:
        _report_json(result, new_findings, stale, args.json)

    for f in new_findings:
        print(f.render())
    for e in stale:
        print(f"stale baseline entry: {e['rule']} {e['path']}: {e['message']}")
    n_baselined = len(result.findings) - len(new_findings)
    summary = (f"{result.files_scanned} file(s) scanned: "
               f"{len(new_findings)} finding(s), "
               f"{result.suppressed} suppressed, {n_baselined} baselined")
    if stale:
        summary += f", {len(stale)} stale baseline entr(y/ies)"
    print(summary)

    if new_findings or (args.check and stale):
        return 1
    return 0
