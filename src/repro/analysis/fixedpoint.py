"""Rule pack 1 — fixed-point width safety (FXP...).

The paper's correctness story is that raw Q-format arithmetic never silently
overflows: products go through the 16-bit-limb ``QFormat.mul``, accumulations
are cast to a wider signed dtype before summation (exact for mass-bounded
sums while the widest registered format stays under
``AnalysisConfig.int32_safe_bits``), and raw/float domains only meet inside
the blessed conversion helpers.  These rules make the conventions checkable:

- **FXP001 raw-accumulation-width** — ``segment_sum(...)`` / ``.sum(...)``
  over a raw-domain operand without an ``.astype(int32/int64)`` width guard.
- **FXP002 shift-discards-bits** — ``x << k`` (constant ``k``) where the
  inferred width of ``x`` plus ``k`` exceeds 32: set bits fall off the top of
  the uint32 lane.  Carry-tracked shifts (the limb multiplier) suppress this
  with an ``allow`` comment explaining how the lost bits are reconstructed.
- **FXP003 raw-domain-discipline** — ``*`` between two raw operands outside
  ``QFormat.mul`` (raw×raw needs the limb decomposition), or arithmetic
  mixing a raw operand with a float literal (scale confusion).

Raw-domain tracking is a per-function taint pass: parameters and locals whose
name contains ``raw`` seed the set; assignment propagates through arithmetic,
subscripts, and ``fmt.mul(...)`` results; ``to_float``/``astype(float...)``
clears the taint.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, Optional, Set

from . import _astutil as A
from .core import FileContext, Finding, Rule, register_rule

_INT_GUARDS = {"int32", "int64", "i32", "i64"}
_FLOAT_CASTS = {"float32", "float64", "f32", "f64", "float"}
_TO_FLOAT_HELPERS = {"to_float", "quantize_f32"}
_RAW_PRODUCERS = {"from_float", "quantize_raw"}


def _name_is_raw(name: str) -> bool:
    return "raw" in name.lower()


def _raw_vars_for_function(fn: ast.AST) -> Set[str]:
    """One forward pass over the function body collecting raw-tainted locals."""
    raw: Set[str] = {p for p in A.param_names(fn) if _name_is_raw(p)}

    def expr_is_raw(node: ast.AST) -> bool:
        if isinstance(node, ast.Call):
            name = A.call_name(node)
            if name:
                leaf = name.rsplit(".", 1)[-1]
                if leaf in _TO_FLOAT_HELPERS:
                    return False
                if leaf in _RAW_PRODUCERS or leaf == "mul":
                    return True
            if A.is_astype_to(node, _FLOAT_CASTS):
                return False
            if isinstance(node.func, ast.Attribute):
                # .astype(int)/.sum()/slicing helpers keep the domain
                return expr_is_raw(node.func.value) or any(
                    expr_is_raw(a) for a in node.args)
            return any(expr_is_raw(a) for a in node.args)
        if isinstance(node, ast.Name):
            return node.id in raw or _name_is_raw(node.id)
        if isinstance(node, ast.Attribute):
            return _name_is_raw(node.attr)
        if isinstance(node, ast.BinOp):
            return expr_is_raw(node.left) or expr_is_raw(node.right)
        if isinstance(node, ast.Subscript):
            return expr_is_raw(node.value)
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(expr_is_raw(e) for e in node.elts)
        return False

    for stmt in ast.walk(fn):
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
            tgt = stmt.targets[0]
            if isinstance(tgt, ast.Name):
                if expr_is_raw(stmt.value):
                    raw.add(tgt.id)
                else:
                    raw.discard(tgt.id)
    return raw


class _RawTaint:
    """Raw-domain query helper bound to one function's taint set."""

    def __init__(self, fn: ast.AST):
        self.raw = _raw_vars_for_function(fn)

    def is_raw(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Name):
            return node.id in self.raw or _name_is_raw(node.id)
        if isinstance(node, ast.Attribute):
            return _name_is_raw(node.attr)
        if isinstance(node, ast.Subscript):
            return self.is_raw(node.value)
        if isinstance(node, ast.BinOp):
            return self.is_raw(node.left) or self.is_raw(node.right)
        if isinstance(node, ast.Call):
            name = A.call_name(node)
            if name:
                leaf = name.rsplit(".", 1)[-1]
                if leaf in _TO_FLOAT_HELPERS:
                    return False
                if leaf in _RAW_PRODUCERS or leaf == "mul":
                    return True
            if A.is_astype_to(node, _FLOAT_CASTS):
                return False
            if isinstance(node.func, ast.Attribute):
                return self.is_raw(node.func.value)
        return False


def _has_int_guard(node: ast.AST) -> bool:
    """True when ``node`` is (or contains as its outermost cast) an
    ``.astype(int32/int64)``."""
    if A.is_astype_to(node, _INT_GUARDS):
        return True
    # (expr).astype(i32).sum(0): the receiver of .sum carries the guard
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute):
        return _has_int_guard(node.func.value)
    if isinstance(node, ast.Subscript):
        return _has_int_guard(node.value)
    return False


@register_rule
class RawAccumulationWidth(Rule):
    id = "FXP001"
    name = "raw-accumulation-width"
    doc = ("Raw-domain accumulation (segment_sum / .sum) without an "
           ".astype(int32/int64) width guard: uint32 lane sums of raw values "
           "can wrap once formats widen.")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        bits = ctx.config.max_format_bits
        guard = "int32" if bits <= ctx.config.int32_safe_bits else "int64"
        for fn in A.func_defs(ctx.tree):
            taint = _RawTaint(fn)
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                name = A.call_name(node)
                leaf = name.rsplit(".", 1)[-1] if name else ""
                if leaf == "segment_sum" and node.args:
                    acc = node.args[0]
                elif (leaf == "sum" and isinstance(node.func, ast.Attribute)):
                    acc = node.func.value
                else:
                    continue
                if taint.is_raw(acc) and not _has_int_guard(acc):
                    yield self.finding(
                        ctx, node,
                        f"raw-domain accumulation without a width guard; "
                        f"registered formats reach {bits} bits — cast the "
                        f"operand with .astype(jnp.{guard}) so the sum is "
                        f"exact, or widen the lane")


# -- FXP002: symbolic width inference ---------------------------------------

_WIDTH_UNKNOWN = 32


def _infer_width(node: ast.AST, local_widths: Dict[str, int]) -> int:
    """Upper bound on the number of significant bits of ``node`` in a uint32
    lane.  Unknown expressions are assumed full-width (32)."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return max(node.value.bit_length(), 1)
    if isinstance(node, ast.Name):
        return local_widths.get(node.id, _WIDTH_UNKNOWN)
    if isinstance(node, ast.Compare):
        return 1
    if isinstance(node, ast.Call):
        # (a < b).astype(u32) — a 0/1 mask keeps width 1
        if isinstance(node.func, ast.Attribute) and node.func.attr == "astype":
            return _infer_width(node.func.value, local_widths)
        return _WIDTH_UNKNOWN
    if isinstance(node, ast.BinOp):
        op = node.op
        lw = _infer_width(node.left, local_widths)
        rw = _infer_width(node.right, local_widths)
        if isinstance(op, ast.BitAnd):
            # masking bounds the result by the narrower side
            for side in (node.left, node.right):
                if isinstance(side, ast.Constant) and isinstance(side.value, int):
                    return max(side.value.bit_length(), 1)
            return min(lw, rw)
        if isinstance(op, ast.RShift):
            if isinstance(node.right, ast.Constant) and isinstance(node.right.value, int):
                return max(lw - node.right.value, 0)
            return lw
        if isinstance(op, ast.LShift):
            if isinstance(node.right, ast.Constant) and isinstance(node.right.value, int):
                return lw + node.right.value
            return 64
        if isinstance(op, ast.Mult):
            return lw + rw
        if isinstance(op, (ast.Add, ast.Sub)):
            return max(lw, rw) + 1
        if isinstance(op, (ast.BitOr, ast.BitXor)):
            return max(lw, rw)
    if isinstance(node, ast.Subscript):
        return _infer_width(node.value, local_widths)
    return _WIDTH_UNKNOWN


def _module_const_widths(tree: ast.AST) -> Dict[str, int]:
    """Widths of module-level integer constants, including wrapped ones like
    ``_MASK16 = np.uint32(0xFFFF)`` — the masks the limb code shifts against."""
    widths: Dict[str, int] = {}
    for stmt in getattr(tree, "body", []):
        if not (isinstance(stmt, ast.Assign) and len(stmt.targets) == 1
                and isinstance(stmt.targets[0], ast.Name)):
            continue
        value = stmt.value
        if isinstance(value, ast.Call) and len(value.args) == 1:
            value = value.args[0]
        if isinstance(value, ast.Constant) and isinstance(value.value, int):
            widths[stmt.targets[0].id] = max(value.value.bit_length(), 1)
    return widths


def _local_widths(fn: ast.AST, seed: Optional[Dict[str, int]] = None) -> Dict[str, int]:
    """Forward pass recording each single-assignment local's inferred width."""
    widths: Dict[str, int] = dict(seed or {})
    for stmt in ast.walk(fn):
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
            tgt = stmt.targets[0]
            if isinstance(tgt, ast.Name):
                widths[tgt.id] = _infer_width(stmt.value, widths)
    return widths


@register_rule
class ShiftDiscardsBits(Rule):
    id = "FXP002"
    name = "shift-discards-bits"
    doc = ("x << k where the inferred width of x plus k exceeds the 32-bit "
           "lane: high bits are silently dropped.  Carry-tracked shifts must "
           "carry an allow comment naming where the bits are recovered.")

    @staticmethod
    def _width_known(node: ast.AST, widths: Dict[str, int]) -> bool:
        """Only flag shifts whose operand width we actually derived — every
        bare Name must have an inferred local width (an unresolved name would
        default to 32 and spray false positives over arbitrary shifts)."""
        for n in ast.walk(node):
            if isinstance(n, ast.Name) and n.id not in widths:
                return False
        return True

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        module_widths = _module_const_widths(ctx.tree)
        for fn in A.func_defs(ctx.tree):
            widths = _local_widths(fn, module_widths)
            for node in ast.walk(fn):
                if not (isinstance(node, ast.BinOp)
                        and isinstance(node.op, ast.LShift)
                        and isinstance(node.right, ast.Constant)
                        and isinstance(node.right.value, int)):
                    continue
                if not self._width_known(node.left, widths):
                    continue
                w = _infer_width(node.left, widths)
                k = node.right.value
                if w + k > 32:
                    yield self.finding(
                        ctx, node,
                        f"left shift by {k} of a ~{w}-bit value exceeds the "
                        f"32-bit lane; set bits are discarded")


@register_rule
class RawDomainDiscipline(Rule):
    id = "FXP003"
    name = "raw-domain-discipline"
    doc = ("raw*raw multiplication outside QFormat.mul (needs the 16-bit limb "
           "decomposition), or arithmetic mixing a raw operand with a float "
           "literal (scale confusion between domains).")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        # fixed_point.py itself hosts the blessed helpers
        blessed_file = ctx.path.endswith("core/fixed_point.py")
        for fn in A.func_defs(ctx.tree):
            taint = _RawTaint(fn)
            blessed_fn = blessed_file or fn.name in (
                _TO_FLOAT_HELPERS | _RAW_PRODUCERS | {"mul", "add"})
            for node in ast.walk(fn):
                if not isinstance(node, ast.BinOp):
                    continue
                if isinstance(node.op, ast.Mult) and not blessed_fn:
                    if taint.is_raw(node.left) and taint.is_raw(node.right):
                        yield self.finding(
                            ctx, node,
                            "raw*raw product outside QFormat.mul — a plain "
                            "uint32 multiply wraps; use fmt.mul (16-bit limb "
                            "decomposition) or document exactness")
                        continue
                if isinstance(node.op, (ast.Mult, ast.Add, ast.Sub, ast.Div)):
                    sides = (node.left, node.right)
                    raw_side = any(taint.is_raw(s) for s in sides)
                    float_side = any(
                        isinstance(s, ast.Constant) and isinstance(s.value, float)
                        for s in sides)
                    if raw_side and float_side:
                        yield self.finding(
                            ctx, node,
                            "raw-domain operand mixed with a float literal — "
                            "convert through to_float/from_float instead of "
                            "mixing scales in one expression")
