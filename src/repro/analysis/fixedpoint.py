"""Rule pack 1 — fixed-point width safety (FXP...).

The paper's correctness story is that raw Q-format arithmetic never silently
overflows: products go through the 16-bit-limb ``QFormat.mul``, accumulations
are cast to a wider signed dtype before summation (exact for mass-bounded
sums while the widest registered format stays under
``AnalysisConfig.int32_safe_bits``), and raw/float domains only meet inside
the blessed conversion helpers.  These rules make the conventions checkable:

- **FXP001 raw-accumulation-width** — ``segment_sum(...)`` / ``.sum(...)``
  over a raw-domain operand without an ``.astype(int32/int64)`` width guard.
- **FXP002 shift-discards-bits** — ``x << k`` (constant ``k``) where the
  inferred width of ``x`` plus ``k`` exceeds 32: set bits fall off the top of
  the uint32 lane.  Width inference is interprocedural within a module
  (``_WidthEnv``): a call to a top-level local function resolves to the max
  width of its returns with parameters seeded from the call site, so limb
  helpers like ``_fixed_mul_u32`` type through their call sites instead of
  needing blanket suppressions.  Carry-tracked shifts (the limb multiplier)
  suppress this with an ``allow`` comment explaining how the lost bits are
  reconstructed.
- **FXP003 raw-domain-discipline** — ``*`` between two raw operands outside
  ``QFormat.mul`` (raw×raw needs the limb decomposition), or arithmetic
  mixing a raw operand with a float literal (scale confusion).

Raw-domain tracking is a per-function taint pass: parameters and locals whose
name contains ``raw`` seed the set; assignment propagates through arithmetic,
subscripts, and ``fmt.mul(...)`` results; ``to_float``/``astype(float...)``
clears the taint.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, Optional, Set

from . import _astutil as A
from .core import FileContext, Finding, Rule, register_rule

_INT_GUARDS = {"int32", "int64", "i32", "i64"}
_FLOAT_CASTS = {"float32", "float64", "f32", "f64", "float"}
_TO_FLOAT_HELPERS = {"to_float", "quantize_f32"}
_RAW_PRODUCERS = {"from_float", "quantize_raw"}


def _name_is_raw(name: str) -> bool:
    return "raw" in name.lower()


def _raw_vars_for_function(fn: ast.AST) -> Set[str]:
    """One forward pass over the function body collecting raw-tainted locals."""
    raw: Set[str] = {p for p in A.param_names(fn) if _name_is_raw(p)}

    def expr_is_raw(node: ast.AST) -> bool:
        if isinstance(node, ast.Call):
            name = A.call_name(node)
            if name:
                leaf = name.rsplit(".", 1)[-1]
                if leaf in _TO_FLOAT_HELPERS:
                    return False
                if leaf in _RAW_PRODUCERS or leaf == "mul":
                    return True
            if A.is_astype_to(node, _FLOAT_CASTS):
                return False
            if isinstance(node.func, ast.Attribute):
                # .astype(int)/.sum()/slicing helpers keep the domain
                return expr_is_raw(node.func.value) or any(
                    expr_is_raw(a) for a in node.args)
            return any(expr_is_raw(a) for a in node.args)
        if isinstance(node, ast.Name):
            return node.id in raw or _name_is_raw(node.id)
        if isinstance(node, ast.Attribute):
            return _name_is_raw(node.attr)
        if isinstance(node, ast.BinOp):
            return expr_is_raw(node.left) or expr_is_raw(node.right)
        if isinstance(node, ast.Subscript):
            return expr_is_raw(node.value)
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(expr_is_raw(e) for e in node.elts)
        return False

    for stmt in ast.walk(fn):
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
            tgt = stmt.targets[0]
            if isinstance(tgt, ast.Name):
                if expr_is_raw(stmt.value):
                    raw.add(tgt.id)
                else:
                    raw.discard(tgt.id)
    return raw


class _RawTaint:
    """Raw-domain query helper bound to one function's taint set."""

    def __init__(self, fn: ast.AST):
        self.raw = _raw_vars_for_function(fn)

    def is_raw(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Name):
            return node.id in self.raw or _name_is_raw(node.id)
        if isinstance(node, ast.Attribute):
            return _name_is_raw(node.attr)
        if isinstance(node, ast.Subscript):
            return self.is_raw(node.value)
        if isinstance(node, ast.BinOp):
            return self.is_raw(node.left) or self.is_raw(node.right)
        if isinstance(node, ast.Call):
            name = A.call_name(node)
            if name:
                leaf = name.rsplit(".", 1)[-1]
                if leaf in _TO_FLOAT_HELPERS:
                    return False
                if leaf in _RAW_PRODUCERS or leaf == "mul":
                    return True
            if A.is_astype_to(node, _FLOAT_CASTS):
                return False
            if isinstance(node.func, ast.Attribute):
                return self.is_raw(node.func.value)
        return False


def _has_int_guard(node: ast.AST) -> bool:
    """True when ``node`` is (or contains as its outermost cast) an
    ``.astype(int32/int64)``."""
    if A.is_astype_to(node, _INT_GUARDS):
        return True
    # (expr).astype(i32).sum(0): the receiver of .sum carries the guard
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute):
        return _has_int_guard(node.func.value)
    if isinstance(node, ast.Subscript):
        return _has_int_guard(node.value)
    return False


@register_rule
class RawAccumulationWidth(Rule):
    id = "FXP001"
    name = "raw-accumulation-width"
    doc = ("Raw-domain accumulation (segment_sum / .sum) without an "
           ".astype(int32/int64) width guard: uint32 lane sums of raw values "
           "can wrap once formats widen.")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        bits = ctx.config.max_format_bits
        guard = "int32" if bits <= ctx.config.int32_safe_bits else "int64"
        for fn in A.func_defs(ctx.tree):
            taint = _RawTaint(fn)
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                name = A.call_name(node)
                leaf = name.rsplit(".", 1)[-1] if name else ""
                if leaf == "segment_sum" and node.args:
                    acc = node.args[0]
                elif (leaf == "sum" and isinstance(node.func, ast.Attribute)):
                    acc = node.func.value
                else:
                    continue
                if taint.is_raw(acc) and not _has_int_guard(acc):
                    yield self.finding(
                        ctx, node,
                        f"raw-domain accumulation without a width guard; "
                        f"registered formats reach {bits} bits — cast the "
                        f"operand with .astype(jnp.{guard}) so the sum is "
                        f"exact, or widen the lane")


# -- FXP002: symbolic width inference ---------------------------------------

_WIDTH_UNKNOWN = 32


def _infer_width(node: ast.AST, local_widths: Dict[str, int],
                 env: Optional["_WidthEnv"] = None) -> int:
    """Upper bound on the number of significant bits of ``node`` in a uint32
    lane.  Unknown expressions are assumed full-width (32).  With a
    ``_WidthEnv``, calls to module-local functions resolve to the callee's
    return width (params seeded from the call site's argument widths)."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return max(node.value.bit_length(), 1)
    if isinstance(node, ast.Name):
        return local_widths.get(node.id, _WIDTH_UNKNOWN)
    if isinstance(node, ast.Compare):
        return 1
    if isinstance(node, ast.Call):
        # (a < b).astype(u32) — a 0/1 mask keeps width 1
        if isinstance(node.func, ast.Attribute) and node.func.attr == "astype":
            return _infer_width(node.func.value, local_widths, env)
        if env is not None:
            w = env.call_return_width(node, local_widths)
            if w is not None:
                return w
        return _WIDTH_UNKNOWN
    if isinstance(node, ast.BinOp):
        op = node.op
        lw = _infer_width(node.left, local_widths, env)
        rw = _infer_width(node.right, local_widths, env)
        if isinstance(op, ast.BitAnd):
            # masking bounds the result by the narrower side
            for side in (node.left, node.right):
                if isinstance(side, ast.Constant) and isinstance(side.value, int):
                    return max(side.value.bit_length(), 1)
            return min(lw, rw)
        if isinstance(op, ast.RShift):
            if isinstance(node.right, ast.Constant) and isinstance(node.right.value, int):
                return max(lw - node.right.value, 0)
            return lw
        if isinstance(op, ast.LShift):
            if isinstance(node.right, ast.Constant) and isinstance(node.right.value, int):
                return lw + node.right.value
            return 64
        if isinstance(op, ast.Mult):
            return lw + rw
        if isinstance(op, (ast.Add, ast.Sub)):
            return max(lw, rw) + 1
        if isinstance(op, (ast.BitOr, ast.BitXor)):
            return max(lw, rw)
    if isinstance(node, ast.Subscript):
        return _infer_width(node.value, local_widths, env)
    return _WIDTH_UNKNOWN


def _own_returns(fn: ast.AST):
    """``return`` expressions belonging to ``fn`` itself (nested defs and
    lambdas have their own return scopes and are not descended into)."""
    rets = []
    stack = list(getattr(fn, "body", []))
    while stack:
        n = stack.pop()
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            continue
        if isinstance(n, ast.Return):
            if n.value is not None:
                rets.append(n.value)
            continue
        stack.extend(ast.iter_child_nodes(n))
    return rets


class _WidthEnv:
    """Cross-function width resolution within one module.

    FXP002's width model is intra-procedural by default; limb helpers like
    ``_fixed_mul_u32`` would otherwise force either blanket suppressions at
    every call site or blind 32-bit assumptions.  This environment resolves a
    call to a *top-level same-module* function by seeding the callee's
    parameters with the call site's inferred argument widths (plus the module
    constants) and taking the max width over the callee's own ``return``
    expressions.  Recursion/cycles and deep chains degrade to unknown
    (``max_depth``), never to a wrong bound.
    """

    max_depth = 4

    def __init__(self, tree: ast.AST, module_widths: Dict[str, int]):
        self.module_widths = module_widths
        self.funcs: Dict[str, ast.FunctionDef] = {
            stmt.name: stmt for stmt in getattr(tree, "body", [])
            if isinstance(stmt, ast.FunctionDef)}
        self._active: list = []

    def _resolve(self, node: ast.Call) -> Optional[ast.FunctionDef]:
        name = A.call_name(node)
        if not name:
            return None
        fn = self.funcs.get(name.rsplit(".", 1)[-1])
        if fn is None or fn.name in self._active \
                or len(self._active) >= self.max_depth:
            return None
        return fn

    @staticmethod
    def _params(fn: ast.FunctionDef):
        return [a.arg for a in fn.args.posonlyargs + fn.args.args]

    def call_return_width(self, node: ast.Call,
                          caller_widths: Dict[str, int]) -> Optional[int]:
        """Max width over the callee's returns, or None when unresolvable."""
        fn = self._resolve(node)
        if fn is None:
            return None
        seed = dict(self.module_widths)
        for p, a in zip(self._params(fn), node.args):
            seed[p] = _infer_width(a, caller_widths, self)
        for kw in node.keywords or []:
            if kw.arg:
                seed[kw.arg] = _infer_width(kw.value, caller_widths, self)
        self._active.append(fn.name)
        try:
            rets = _own_returns(fn)
            if not rets:
                return None
            widths = _local_widths(fn, seed, self)
            return max(_infer_width(r, widths, self) for r in rets)
        finally:
            self._active.pop()

    def call_known(self, node: ast.Call, caller_widths: Dict[str, int]) -> bool:
        """True when every return expression of the callee has a derived
        width, with only the *known* call-site arguments blessing params."""
        fn = self._resolve(node)
        if fn is None:
            return False
        seed = dict(self.module_widths)
        for p, a in zip(self._params(fn), node.args):
            if _width_known(a, caller_widths, self):
                seed[p] = _infer_width(a, caller_widths, self)
        for kw in node.keywords or []:
            if kw.arg and _width_known(kw.value, caller_widths, self):
                seed[kw.arg] = _infer_width(kw.value, caller_widths, self)
        self._active.append(fn.name)
        try:
            rets = _own_returns(fn)
            if not rets:
                return False
            widths = _local_widths(fn, seed, self)
            return all(_width_known(r, widths, self) for r in rets)
        finally:
            self._active.pop()


def _width_known(node: ast.AST, widths: Dict[str, int],
                 env: Optional[_WidthEnv] = None) -> bool:
    """Only flag shifts whose operand width was actually derived.

    Structural recursion replacing the old every-Name-resolved walk: a bare
    Name must have an inferred width (an unresolved one would default to 32
    and spray false positives over arbitrary shifts), a constant mask blesses
    a BitAnd regardless of the other side (the width *is* bounded by the
    mask), and a call to a resolvable module-local function is known iff its
    returns are (``_WidthEnv.call_known``)."""
    if isinstance(node, ast.Constant):
        return isinstance(node.value, int)
    if isinstance(node, ast.Name):
        return node.id in widths
    if isinstance(node, ast.Compare):
        return True
    if isinstance(node, ast.BinOp):
        if isinstance(node.op, ast.BitAnd):
            if any(isinstance(s, ast.Constant) and isinstance(s.value, int)
                   for s in (node.left, node.right)):
                return True
        if isinstance(node.op, (ast.RShift, ast.LShift)) \
                and not (isinstance(node.right, ast.Constant)
                         and isinstance(node.right.value, int)):
            # symbolic shift amounts keep the old all-names-resolved demand
            if not _width_known(node.right, widths, env):
                return False
            return _width_known(node.left, widths, env)
        return (_width_known(node.left, widths, env)
                and _width_known(node.right, widths, env))
    if isinstance(node, ast.Subscript):
        return _width_known(node.value, widths, env)
    if isinstance(node, ast.Call):
        if isinstance(node.func, ast.Attribute) and node.func.attr == "astype":
            return _width_known(node.func.value, widths, env)
        return env is not None and env.call_known(node, widths)
    return False


def _module_const_widths(tree: ast.AST) -> Dict[str, int]:
    """Widths of module-level integer constants, including wrapped ones like
    ``_MASK16 = np.uint32(0xFFFF)`` — the masks the limb code shifts against."""
    widths: Dict[str, int] = {}
    for stmt in getattr(tree, "body", []):
        if not (isinstance(stmt, ast.Assign) and len(stmt.targets) == 1
                and isinstance(stmt.targets[0], ast.Name)):
            continue
        value = stmt.value
        if isinstance(value, ast.Call) and len(value.args) == 1:
            value = value.args[0]
        if isinstance(value, ast.Constant) and isinstance(value.value, int):
            widths[stmt.targets[0].id] = max(value.value.bit_length(), 1)
    return widths


def _local_widths(fn: ast.AST, seed: Optional[Dict[str, int]] = None,
                  env: Optional["_WidthEnv"] = None) -> Dict[str, int]:
    """Forward pass recording each single-assignment local's inferred width."""
    widths: Dict[str, int] = dict(seed or {})
    for stmt in ast.walk(fn):
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
            tgt = stmt.targets[0]
            if isinstance(tgt, ast.Name):
                widths[tgt.id] = _infer_width(stmt.value, widths, env)
    return widths


@register_rule
class ShiftDiscardsBits(Rule):
    id = "FXP002"
    name = "shift-discards-bits"
    doc = ("x << k where the inferred width of x plus k exceeds the 32-bit "
           "lane: high bits are silently dropped.  Width inference crosses "
           "same-module function boundaries (call-site argument widths seed "
           "the callee).  Carry-tracked shifts must carry an allow comment "
           "naming where the bits are recovered.")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        module_widths = _module_const_widths(ctx.tree)
        env = _WidthEnv(ctx.tree, module_widths)
        for fn in A.func_defs(ctx.tree):
            widths = _local_widths(fn, module_widths, env)
            for node in ast.walk(fn):
                if not (isinstance(node, ast.BinOp)
                        and isinstance(node.op, ast.LShift)
                        and isinstance(node.right, ast.Constant)
                        and isinstance(node.right.value, int)):
                    continue
                if not _width_known(node.left, widths, env):
                    continue
                w = _infer_width(node.left, widths, env)
                k = node.right.value
                if w + k > 32:
                    yield self.finding(
                        ctx, node,
                        f"left shift by {k} of a ~{w}-bit value exceeds the "
                        f"32-bit lane; set bits are discarded")


@register_rule
class RawDomainDiscipline(Rule):
    id = "FXP003"
    name = "raw-domain-discipline"
    doc = ("raw*raw multiplication outside QFormat.mul (needs the 16-bit limb "
           "decomposition), or arithmetic mixing a raw operand with a float "
           "literal (scale confusion between domains).")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        # fixed_point.py itself hosts the blessed helpers
        blessed_file = ctx.path.endswith("core/fixed_point.py")
        for fn in A.func_defs(ctx.tree):
            taint = _RawTaint(fn)
            blessed_fn = blessed_file or fn.name in (
                _TO_FLOAT_HELPERS | _RAW_PRODUCERS | {"mul", "add"})
            for node in ast.walk(fn):
                if not isinstance(node, ast.BinOp):
                    continue
                if isinstance(node.op, ast.Mult) and not blessed_fn:
                    if taint.is_raw(node.left) and taint.is_raw(node.right):
                        yield self.finding(
                            ctx, node,
                            "raw*raw product outside QFormat.mul — a plain "
                            "uint32 multiply wraps; use fmt.mul (16-bit limb "
                            "decomposition) or document exactness")
                        continue
                if isinstance(node.op, (ast.Mult, ast.Add, ast.Sub, ast.Div)):
                    sides = (node.left, node.right)
                    raw_side = any(taint.is_raw(s) for s in sides)
                    float_side = any(
                        isinstance(s, ast.Constant) and isinstance(s.value, float)
                        for s in sides)
                    if raw_side and float_side:
                        yield self.finding(
                            ctx, node,
                            "raw-domain operand mixed with a float literal — "
                            "convert through to_float/from_float instead of "
                            "mixing scales in one expression")
