"""Rule pack 2 — JAX hot-path hygiene (JAX...).

Wave latency is the denominator of every queries/s number this repo reports,
and one stray host sync inside a step body serializes the whole streaming
pipeline (the Top-K SpMV and CPU-FPGA codesign papers both call this out).
These rules police the *hot context*: any function that is jit-compiled —
``@jax.jit``, ``@jit``, or ``@functools.partial(jax.jit, static_argnames=…)``
— or explicitly marked with a ``# repro: hot-path`` comment on/above its
``def``.  Nested ``def``s (scan bodies, closures) inherit the hot context.
Telemetry and debug code outside marked/jitted functions is exempt by
construction.

- **JAX101 implicit-sync** — ``.item()`` / ``.tolist()`` / ``float()`` /
  ``int()`` / ``bool()`` on a traced value inside a hot context: each one
  blocks until the device catches up.
- **JAX102 host-numpy-on-traced** — ``np.*`` applied to a traced value:
  silently pulls the array to host memory.
- **JAX103 traced-control-flow** — Python ``if``/``while`` on a traced value
  inside a jit context: either a tracer error or a silent retrace per branch;
  use ``lax.cond``/``lax.while_loop``/``jnp.where``.

Taint: a jitted function's parameters are traced, **except** names listed in
``static_argnames``.  ``.shape``/``.dtype``/``.ndim``/``.size`` and ``len()``
of a traced array are static and clear the taint, as does an ``is None``
test.
"""
from __future__ import annotations

import ast
from typing import Iterator, List, Optional, Set, Tuple

from . import _astutil as A
from .core import FileContext, Finding, Rule, register_rule

_STATIC_ATTRS = {"shape", "dtype", "ndim", "size", "nbytes", "weak_type"}
_SYNC_CASTS = {"float", "int", "bool", "complex"}
_SYNC_METHODS = {"item", "tolist", "block_until_ready"}
_NUMPY_ALIASES = {"np", "onp", "numpy"}


def _jit_decoration(fn: ast.AST) -> Optional[Tuple[bool, List[str]]]:
    """(is_jitted, static_argnames) when ``fn`` carries a jit decorator."""
    for dec in getattr(fn, "decorator_list", []):
        name = A.dotted_name(dec if not isinstance(dec, ast.Call) else dec.func)
        if name is None:
            continue
        leaf = name.rsplit(".", 1)[-1]
        if leaf == "jit":
            return True, []
        if leaf == "partial" and isinstance(dec, ast.Call):
            inner = dec.args and A.dotted_name(dec.args[0])
            if inner and inner.rsplit(".", 1)[-1] == "jit":
                static: List[str] = []
                for kw in dec.keywords:
                    if kw.arg in ("static_argnames", "static_argnums"):
                        static.extend(A.const_str_tuple(kw.value))
                return True, static
    return None


def _hot_functions(ctx: FileContext) -> Iterator[Tuple[ast.AST, Set[str], bool]]:
    """Yield (fn, traced_param_names, jitted) for every hot-context function,
    including nested defs, which inherit hotness and trace their own params."""

    def emit(fn: ast.AST, jitted: bool, static: List[str]):
        traced = {p for p in A.param_names(fn)
                  if p not in static and p not in ("self", "cls")}
        yield fn, traced, jitted
        for sub in A.direct_child_defs(fn):
            sub_dec = _jit_decoration(sub)
            if sub_dec is not None:
                continue  # handled by the top-level walk below
            sub_traced = {p for p in A.param_names(sub) if p not in ("self", "cls")}
            yield sub, sub_traced, jitted

    for fn in A.func_defs(ctx.tree):
        dec = _jit_decoration(fn)
        if dec is not None:
            yield from emit(fn, True, dec[1])
        elif ctx.is_marked_hot(fn):
            yield from emit(fn, False, [])


class _TraceTaint:
    """Forward-pass taint over one function body."""

    def __init__(self, fn: ast.AST, traced_params: Set[str]):
        self.tainted: Set[str] = set(traced_params)
        for stmt in ast.walk(fn):
            if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
                tgt = stmt.targets[0]
                if isinstance(tgt, ast.Name):
                    if self.is_tainted(stmt.value):
                        self.tainted.add(tgt.id)
                    else:
                        self.tainted.discard(tgt.id)
                elif isinstance(tgt, ast.Tuple) and self.is_tainted(stmt.value):
                    for elt in tgt.elts:
                        if isinstance(elt, ast.Name):
                            self.tainted.add(elt.id)

    def is_tainted(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Name):
            return node.id in self.tainted
        if isinstance(node, ast.Attribute):
            if node.attr in _STATIC_ATTRS:
                return False
            return self.is_tainted(node.value)
        if isinstance(node, ast.Subscript):
            return self.is_tainted(node.value)
        if isinstance(node, ast.BinOp):
            return self.is_tainted(node.left) or self.is_tainted(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.is_tainted(node.operand)
        if isinstance(node, ast.BoolOp):
            return any(self.is_tainted(v) for v in node.values)
        if isinstance(node, ast.Compare):
            # `x is None` / `x is not None` is a static structure test
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
                return False
            return (self.is_tainted(node.left)
                    or any(self.is_tainted(c) for c in node.comparators))
        if isinstance(node, ast.Call):
            name = A.call_name(node)
            if name:
                leaf = name.rsplit(".", 1)[-1]
                if leaf == "len":
                    return False  # static under trace
            if isinstance(node.func, ast.Attribute):
                if self.is_tainted(node.func.value):
                    return True
            return any(self.is_tainted(a) for a in node.args) or any(
                self.is_tainted(kw.value) for kw in node.keywords)
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(self.is_tainted(e) for e in node.elts)
        if isinstance(node, ast.IfExp):
            return (self.is_tainted(node.body) or self.is_tainted(node.orelse))
        return False


def _own_nodes(fn: ast.AST) -> Iterator[ast.AST]:
    """Walk ``fn`` without descending into nested defs (those get their own
    taint pass)."""
    stack = list(ast.iter_child_nodes(fn))
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        yield node
        stack.extend(ast.iter_child_nodes(node))


@register_rule
class ImplicitSync(Rule):
    id = "JAX101"
    name = "implicit-sync"
    doc = (".item()/.tolist()/float()/int()/bool() on a traced value inside a "
           "hot context — a hidden host<->device sync that serializes the "
           "wave pipeline.")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for fn, traced, _jitted in _hot_functions(ctx):
            taint = _TraceTaint(fn, traced)
            for node in _own_nodes(fn):
                if not isinstance(node, ast.Call):
                    continue
                name = A.call_name(node)
                leaf = name.rsplit(".", 1)[-1] if name else ""
                if (isinstance(node.func, ast.Name)
                        and node.func.id in _SYNC_CASTS and node.args
                        and taint.is_tainted(node.args[0])):
                    yield self.finding(
                        ctx, node,
                        f"{node.func.id}() on a traced value forces a device "
                        f"sync in the hot path; keep it on device or move it "
                        f"out of the hot context")
                elif (isinstance(node.func, ast.Attribute)
                      and leaf in _SYNC_METHODS
                      and taint.is_tainted(node.func.value)):
                    yield self.finding(
                        ctx, node,
                        f".{leaf}() on a traced value forces a device sync "
                        f"in the hot path")


@register_rule
class HostNumpyOnTraced(Rule):
    id = "JAX102"
    name = "host-numpy-on-traced"
    doc = ("np.* applied to a traced value inside a hot context — pulls the "
           "array to host memory; use jnp.* instead.")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for fn, traced, _jitted in _hot_functions(ctx):
            taint = _TraceTaint(fn, traced)
            for node in _own_nodes(fn):
                if not isinstance(node, ast.Call):
                    continue
                name = A.call_name(node)
                if not name or "." not in name:
                    continue
                head = name.split(".", 1)[0]
                if head in _NUMPY_ALIASES and any(
                        taint.is_tainted(a) for a in node.args):
                    yield self.finding(
                        ctx, node,
                        f"{name}() on a traced value runs on host — use the "
                        f"jnp equivalent to stay on device")


@register_rule
class TracedControlFlow(Rule):
    id = "JAX103"
    name = "traced-control-flow"
    doc = ("Python if/while on a traced value inside a jit context — tracer "
           "error or per-branch retrace; use lax.cond / lax.while_loop / "
           "jnp.where.")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for fn, traced, jitted in _hot_functions(ctx):
            if not jitted:
                continue  # outside jit, Python branching on arrays is legal
            taint = _TraceTaint(fn, traced)
            for node in _own_nodes(fn):
                if isinstance(node, (ast.If, ast.While)) and taint.is_tainted(node.test):
                    kind = "if" if isinstance(node, ast.If) else "while"
                    yield self.finding(
                        ctx, node,
                        f"Python `{kind}` on a traced value inside jit — use "
                        f"lax.cond/lax.while_loop/jnp.where")
