"""Core machinery for the repro static-analysis suite.

Everything here is stdlib-only (``ast`` + ``tokenize``): the analyzer must
run in CI containers with no extra dependencies.  The moving parts:

- :class:`Finding` — one diagnostic, sortable and JSON-serializable.
- :class:`Rule` + :func:`register_rule` — the rule registry.  Rule packs
  (``fixedpoint``, ``jax_hygiene``, ``async_serving``) register themselves on
  import; :func:`all_rules` imports them lazily so ``core`` has no cycles.
- :class:`FileContext` — a parsed file plus the comment-derived side tables:
  inline suppressions (``# repro: allow[RULE-ID] reason``) and hot-path
  markers (``# repro: hot-path``).
- :func:`analyze_paths` — the driver: walk files, run rules, drop suppressed
  findings, return the rest deterministically sorted.

Suppression semantics: an ``allow`` comment applies to findings of that rule
on the comment's own line or, when the comment sits alone on a line, on the
next line.  A suppression **must** carry a non-empty reason; a bare
``# repro: allow[FXP002]`` does not suppress anything and is itself reported
(rule ``SUP000``), so every silenced finding documents why it is safe.
"""
from __future__ import annotations

import ast
import dataclasses
import io
import json
import os
import re
import tokenize
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Set, Tuple

ALLOW_RE = re.compile(r"#\s*repro:\s*allow\[([A-Z]{3}\d{3})\]\s*(.*)")
HOT_PATH_RE = re.compile(r"#\s*repro:\s*hot-path\b")

DEFAULT_PATHS = ("src/repro", "benchmarks", "examples")


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One diagnostic.  Ordering is (path, line, col, rule) so output and the
    JSON report are deterministic across runs."""
    path: str                  # repo-relative, '/'-separated
    line: int
    col: int
    rule_id: str
    message: str

    def to_dict(self) -> dict:
        return {
            "rule": self.rule_id,
            "path": self.path,
            "line": self.line,
            "col": self.col,
            "message": self.message,
        }

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule_id} {self.message}"

    def baseline_key(self) -> Tuple[str, str, str]:
        """Line-insensitive identity used for baseline matching, so a
        baseline survives unrelated edits above the finding."""
        return (self.rule_id, self.path, self.message)


class Rule:
    """Base class for a checker.  Subclasses set ``id``/``name``/``doc`` and
    implement :meth:`check` yielding findings for one parsed file."""

    id: str = ""
    name: str = ""
    doc: str = ""

    def check(self, ctx: "FileContext") -> Iterator[Finding]:  # pragma: no cover
        raise NotImplementedError

    def finding(self, ctx: "FileContext", node: ast.AST, message: str) -> Finding:
        return Finding(
            path=ctx.path,
            line=getattr(node, "lineno", 1),
            col=getattr(node, "col_offset", 0) + 1,
            rule_id=self.id,
            message=message,
        )


_REGISTRY: Dict[str, Rule] = {}


def register_rule(cls):
    """Class decorator: instantiate and register a :class:`Rule`."""
    inst = cls()
    if not inst.id:
        raise ValueError(f"rule {cls.__name__} has no id")
    if inst.id in _REGISTRY:
        raise ValueError(f"duplicate rule id {inst.id}")
    _REGISTRY[inst.id] = inst
    return cls


def all_rules() -> List[Rule]:
    """All registered rules, importing the rule packs on first use."""
    from . import async_serving, fixedpoint, jax_hygiene  # noqa: F401
    return [_REGISTRY[k] for k in sorted(_REGISTRY)]


def get_rule(rule_id: str) -> Optional[Rule]:
    all_rules()
    return _REGISTRY.get(rule_id)


@dataclasses.dataclass
class AnalysisConfig:
    """Repo-derived facts the rules consult.

    ``max_format_bits`` is parsed out of ``core/fixed_point.py``'s AST (the
    widest registered ``QFormat``), so the width-safety rules track the repo's
    actual precision ladder instead of hard-coding 26."""
    root: str = "."
    max_format_bits: int = 26
    # int32 accumulation of mass-bounded raw sums is exact while the widest
    # format stays under this; beyond it the rules demand int64.
    int32_safe_bits: int = 30


def load_config(root: str) -> AnalysisConfig:
    cfg = AnalysisConfig(root=root)
    fp = os.path.join(root, "src", "repro", "core", "fixed_point.py")
    try:
        with open(fp, "r", encoding="utf-8") as fh:
            tree = ast.parse(fh.read())
    except (OSError, SyntaxError):
        return cfg
    widths: List[int] = []
    for node in ast.walk(tree):
        if (
            isinstance(node, ast.Call)
            and isinstance(node.func, ast.Name)
            and node.func.id == "QFormat"
            and len(node.args) >= 2
            and all(isinstance(a, ast.Constant) and isinstance(a.value, int)
                    for a in node.args[:2])
        ):
            widths.append(node.args[0].value + node.args[1].value)
    if widths:
        cfg.max_format_bits = max(widths)
    return cfg


@dataclasses.dataclass
class Suppression:
    rule_id: str
    reason: str
    line: int          # line the comment sits on
    comment_only: bool # comment is alone on its line => applies to next line
    used: bool = False


class FileContext:
    """A parsed source file plus its comment side tables."""

    def __init__(self, path: str, source: str, tree: ast.AST,
                 config: AnalysisConfig):
        self.path = path
        self.source = source
        self.tree = tree
        self.config = config
        self.lines = source.splitlines()
        self.suppressions: List[Suppression] = []
        self.bare_allows: List[Tuple[int, str]] = []  # (line, rule_id) sans reason
        self.hot_lines: Set[int] = set()
        self._scan_comments()

    @classmethod
    def parse(cls, abs_path: str, rel_path: str,
              config: AnalysisConfig) -> Optional["FileContext"]:
        try:
            with open(abs_path, "r", encoding="utf-8") as fh:
                source = fh.read()
            tree = ast.parse(source, filename=rel_path)
        except (OSError, SyntaxError, ValueError):
            return None
        return cls(rel_path, source, tree, config)

    def _scan_comments(self) -> None:
        try:
            tokens = list(tokenize.generate_tokens(io.StringIO(self.source).readline))
        except tokenize.TokenizeError:
            return
        for tok in tokens:
            if tok.type != tokenize.COMMENT:
                continue
            line = tok.start[0]
            text = tok.string
            if HOT_PATH_RE.search(text):
                self.hot_lines.add(line)
            m = ALLOW_RE.search(text)
            if m:
                rule_id, reason = m.group(1), m.group(2).strip()
                comment_only = self.lines[line - 1].lstrip().startswith("#")
                if reason:
                    self.suppressions.append(
                        Suppression(rule_id, reason, line, comment_only))
                else:
                    self.bare_allows.append((line, rule_id))

    # -- suppression lookup -------------------------------------------------
    def suppression_for(self, finding: Finding) -> Optional[Suppression]:
        for sup in self.suppressions:
            if sup.rule_id != finding.rule_id:
                continue
            target = sup.line + 1 if sup.comment_only else sup.line
            if finding.line in (sup.line, target):
                return sup
        return None

    # -- hot-path markers ---------------------------------------------------
    def is_marked_hot(self, fn: ast.AST) -> bool:
        """A ``def`` is marked hot when ``# repro: hot-path`` sits on the def
        line, a decorator line, or the line directly above."""
        first = min([fn.lineno] + [d.lineno for d in getattr(fn, "decorator_list", [])])
        candidates = set(range(first - 1, getattr(fn, "body", [fn])[0].lineno))
        candidates.add(fn.lineno)
        return bool(candidates & self.hot_lines)


def iter_python_files(paths: Sequence[str], root: str) -> Iterator[Tuple[str, str]]:
    """Yield (abs_path, repo_relative_path) for every .py under ``paths``."""
    seen: Set[str] = set()
    for p in paths:
        abs_p = p if os.path.isabs(p) else os.path.join(root, p)
        if os.path.isfile(abs_p):
            files = [abs_p]
        else:
            files = []
            for dirpath, dirnames, filenames in os.walk(abs_p):
                dirnames.sort()
                dirnames[:] = [d for d in dirnames if d != "__pycache__"]
                files.extend(os.path.join(dirpath, f)
                             for f in sorted(filenames) if f.endswith(".py"))
        for f in files:
            if not f.endswith(".py"):
                continue
            rel = os.path.relpath(f, root).replace(os.sep, "/")
            if rel in seen:
                continue
            seen.add(rel)
            yield f, rel


@dataclasses.dataclass
class AnalysisResult:
    findings: List[Finding]
    suppressed: int
    files_scanned: int


class _BareAllowRule(Rule):
    id = "SUP000"
    name = "suppression-missing-reason"
    doc = ("`# repro: allow[...]` without a reason does not suppress anything; "
           "every silenced finding must say why it is safe.")


_BARE_ALLOW = _BareAllowRule()


def analyze_paths(paths: Sequence[str], root: str,
                  rules: Optional[Sequence[Rule]] = None) -> AnalysisResult:
    config = load_config(root)
    rules = list(all_rules()) if rules is None else list(rules)
    findings: List[Finding] = []
    suppressed = 0
    n_files = 0
    for abs_path, rel_path in iter_python_files(paths, root):
        ctx = FileContext.parse(abs_path, rel_path, config)
        if ctx is None:
            continue
        n_files += 1
        for line, rule_id in ctx.bare_allows:
            findings.append(Finding(
                path=rel_path, line=line, col=1, rule_id=_BARE_ALLOW.id,
                message=f"allow[{rule_id}] has no reason; suppression ignored"))
        for rule in rules:
            for finding in rule.check(ctx):
                sup = ctx.suppression_for(finding)
                if sup is not None:
                    sup.used = True
                    suppressed += 1
                else:
                    findings.append(finding)
    findings = sorted(set(findings))  # overlapping hot contexts may double-report
    return AnalysisResult(findings=findings, suppressed=suppressed,
                         files_scanned=n_files)


def findings_to_json(result: AnalysisResult) -> str:
    payload = {
        "version": 1,
        "files_scanned": result.files_scanned,
        "suppressed": result.suppressed,
        "findings": [f.to_dict() for f in result.findings],
    }
    return json.dumps(payload, indent=2, sort_keys=True) + "\n"
