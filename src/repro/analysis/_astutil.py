"""Small AST helpers shared by the rule packs (stdlib only)."""
from __future__ import annotations

import ast
from typing import Iterator, List, Optional, Set


def dotted_name(node: ast.AST) -> Optional[str]:
    """``a.b.c`` -> "a.b.c" for Name/Attribute chains, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(node: ast.Call) -> Optional[str]:
    """Dotted name of the called object ("time.sleep", "self.service.poll")."""
    return dotted_name(node.func)


def names_in(node: ast.AST) -> Set[str]:
    """Bare Name identifiers read anywhere inside ``node``."""
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def func_defs(tree: ast.AST) -> Iterator[ast.AST]:
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def direct_child_defs(fn: ast.AST) -> Iterator[ast.AST]:
    for node in ast.walk(fn):
        if node is not fn and isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def param_names(fn: ast.AST) -> List[str]:
    a = fn.args
    names = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return names


def const_str_tuple(node: ast.AST) -> List[str]:
    """Extract ("a", "b") / ["a"] / "a" literals, else []."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for elt in node.elts:
            if isinstance(elt, ast.Constant) and isinstance(elt.value, str):
                out.append(elt.value)
        return out
    return []


def is_astype_to(node: ast.AST, type_names: Set[str]) -> bool:
    """True when ``node`` is ``<expr>.astype(<t>)`` with ``t``'s trailing
    identifier in ``type_names`` (matches ``jnp.int32``, ``np.int64``, bare
    ``int32`` aliases such as ``_I32``)."""
    if not (isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute)
            and node.func.attr == "astype" and node.args):
        return False
    t = node.args[0]
    name = dotted_name(t)
    if name is None:
        return False
    leaf = name.rsplit(".", 1)[-1].lower().lstrip("_")
    return any(leaf == t or leaf.endswith(t) for t in type_names)
