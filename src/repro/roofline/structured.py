"""Trip-count-correct roofline: structured per-component lowering.

XLA's HloCostAnalysis counts a while-loop body ONCE regardless of trip count
(verified in EXPERIMENTS.md §Roofline), so a scanned-layers model compiled as
one graph under-reports flops/bytes by ~num_layers×.  The fix used here —
the same approach production estimators take — is to lower each *component*
separately with the exact boundary shardings the full graph pins
(shard_activation at layer boundaries, param rules for weights), read its
cost_analysis + collective bytes, and combine with known trip counts:

  train:   mb × [ Σ_seg reps·vjp(group) + vjp(embed→logits→loss) ]
           + adamw_update + grad-DP-all-reduce (analytic, once)
  prefill: Σ_seg reps·fwd(group) + fwd(base) (+ encoder)
  decode:  Σ_seg reps·decode(group) + decode(base)

The vjp components are lowered with param-grad out-shardings equal to the
param shardings, which makes GSPMD insert the data-axis gradient all-reduce
*inside* the component; since the real step all-reduces once (not once per
microbatch × layer), that per-layer AR is subtracted analytically and added
back exactly once.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import MAMBA, ModelConfig, ShapeConfig
from repro.distributed.sharding import (
    _spec_for,
    param_shardings,
    set_sharding_context,
    shard_activation,
)
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.common import find_segments, norm
from repro.models.transformer import (
    _apply_layer,
    _logits,
    build_model,
    init_params,
)
from repro.roofline.analysis import (
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS,
    RooflineTerms,
    collective_bytes,
    model_flops_forward,
    model_flops_train,
)

SDS = jax.ShapeDtypeStruct


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------
def _cost_of(lowered) -> Tuple[float, float, Dict[str, int]]:
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    colls = collective_bytes(compiled.as_text())
    return float(cost.get("flops", 0.0)), float(cost.get("bytes accessed", 0.0)), colls


def _merge(acc: Dict[str, int], new: Dict[str, int], mult: float):
    for k, v in new.items():
        acc[k] = acc.get(k, 0.0) + v * mult
    return acc


def _seg_param_specs(api, cfg) -> List[Any]:
    """eval_shape of params, sliced to one scan step per segment: [g, ...]."""
    ps = jax.eval_shape(lambda k: init_params(k, cfg), jax.random.PRNGKey(0))
    out = []
    for seg in ps["segments"]:
        out.append(jax.tree.map(lambda l: SDS(l.shape[1:], l.dtype), seg))
    return ps, out


def _shard_tree(tree, mesh, cfg=None):
    """Param-rule shardings for an arbitrary subtree (paths match rules)."""
    return param_shardings(tree, mesh, cfg=cfg)


def _local_param_bytes(tree, mesh) -> float:
    """Per-device f32 gradient bytes of a param subtree under the rules."""
    model = mesh.shape.get("model", 1)

    def one(path, leaf):
        from repro.distributed.sharding import _path_str
        spec = _spec_for(_path_str(path), len(leaf.shape))
        n = float(np.prod(leaf.shape))
        for axis_name in spec:
            if axis_name == "model":
                n /= model
            elif isinstance(axis_name, tuple) and "model" in axis_name:
                n /= model
        return n * 4.0

    sizes = jax.tree_util.tree_map_with_path(one, tree)
    return float(sum(jax.tree.leaves(sizes)))


# ---------------------------------------------------------------------------
# component builders
# ---------------------------------------------------------------------------
def _group_fwd(cfg, group, remat, with_enc=False):
    if with_enc:
        def f(h, gp, enc):
            for j, w in enumerate(group):
                lp = jax.tree.map(lambda a: a[j], gp)
                h = shard_activation(_apply_layer(h, lp, cfg, w, enc))
            return h
    else:
        def f(h, gp):
            for j, w in enumerate(group):
                lp = jax.tree.map(lambda a: a[j], gp)
                h = shard_activation(_apply_layer(h, lp, cfg, w, None))
            return h
    return jax.checkpoint(f) if remat else f


def _group_vjp(cfg, group, remat, with_enc=False):
    fwd = _group_fwd(cfg, group, remat, with_enc)
    if with_enc:
        def f(h, gp, enc, ct):
            out, pull = jax.vjp(fwd, h, gp, enc)
            return pull(ct)
    else:
        def f(h, gp, ct):
            out, pull = jax.vjp(fwd, h, gp)
            return pull(ct)
    return f


def _base_train(cfg, api):
    """embed → final norm → logits → CE (the non-layer part of the loss)."""
    def f(params, batch):
        from repro.models.transformer import _embed_inputs
        h = _embed_inputs(params, batch, cfg)
        h = norm(h, params["final_norm"], cfg.norm)
        logits = _logits(params, h, cfg)
        targets = batch["targets"]
        if cfg.num_patches:
            logits = logits[:, cfg.num_patches:]
        valid = targets >= 0
        logz = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, jnp.maximum(targets, 0)[..., None],
                                  axis=-1)[..., 0]
        return ((logz - tgt) * valid).sum() / jnp.maximum(valid.sum(), 1)
    return f


def _base_prefill(cfg, api):
    """embed → final norm → last-position logits (prefill's non-layer part)."""
    def f(params, batch):
        from repro.models.transformer import _embed_inputs
        h = _embed_inputs(params, batch, cfg)
        h = norm(h[:, -1:, :], params["final_norm"], cfg.norm)
        return _logits(params, h, cfg)
    return f


# ---------------------------------------------------------------------------
# main entry
# ---------------------------------------------------------------------------
def structured_roofline(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh: Mesh,
    microbatches: int = 1,
    decode_layer_fn=None,
    overrides: Optional[dict] = None,
) -> Dict[str, Any]:
    """Returns dict with combined flops/bytes/collectives (per device) and the
    three roofline terms.  ``overrides`` hooks let §Perf variants swap
    component builders (e.g. windowed KV cache)."""
    overrides = overrides or {}
    chips = int(np.prod(list(mesh.shape.values())))
    api = build_model(cfg, remat=(shape.kind == "train"))
    params_s, seg_specs = _seg_param_specs(api, cfg)
    segments = find_segments(cfg.layer_pattern)
    sp = overrides.get("sequence_parallel", shape.kind != "decode")
    set_sharding_context(mesh, sequence_parallel=sp)
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    act = cfg.act_dtype

    flops = 0.0
    bytes_ = 0.0
    colls: Dict[str, float] = {}

    try:
        if shape.kind in ("train", "prefill"):
            b_mb = shape.global_batch // (microbatches if shape.kind == "train" else 1)
            h_s = SDS((b_mb, shape.seq_len, cfg.d_model), act)
            h_sh = NamedSharding(mesh, P(dp, "model" if sp else None, None))
            mult_layers = microbatches if shape.kind == "train" else 1
            with_enc = cfg.enc_layers > 0
            enc_s = SDS((b_mb, cfg.enc_len, cfg.d_model), act) if with_enc else None
            enc_sh = NamedSharding(mesh, P(dp, None, None)) if with_enc else None

            for (group, reps), gp_s in zip(segments, seg_specs):
                gp_sh = _shard_tree(gp_s, mesh, cfg)
                builder = overrides.get("group", None)
                if shape.kind == "train":
                    fn = (builder or _group_vjp)(cfg, group, True, with_enc)
                    if with_enc:
                        low = jax.jit(fn, in_shardings=(h_sh, gp_sh, enc_sh, h_sh),
                                      out_shardings=(h_sh, gp_sh, enc_sh)).lower(
                                          h_s, gp_s, enc_s, h_s)
                    else:
                        low = jax.jit(fn, in_shardings=(h_sh, gp_sh, h_sh),
                                      out_shardings=(h_sh, gp_sh)).lower(h_s, gp_s, h_s)
                else:
                    fn = (builder or _group_fwd)(cfg, group, False, with_enc)
                    if with_enc:
                        low = jax.jit(fn, in_shardings=(h_sh, gp_sh, enc_sh),
                                      out_shardings=h_sh).lower(h_s, gp_s, enc_s)
                    else:
                        low = jax.jit(fn, in_shardings=(h_sh, gp_sh),
                                      out_shardings=h_sh).lower(h_s, gp_s)
                f, by, co = _cost_of(low)
                flops += f * reps * mult_layers
                bytes_ += by * reps * mult_layers
                if shape.kind == "train":
                    # remove the per-layer grad-DP-all-reduce (added back once)
                    ar = _local_param_bytes(gp_s, mesh)
                    co = dict(co)
                    co["all-reduce"] = max(0.0, co.get("all-reduce", 0) - ar)
                _merge(colls, co, reps * mult_layers)

            # base: embed→logits→loss (train: its vjp; prefill: fwd)
            batch_s = {"tokens": SDS((b_mb, shape.seq_len - (cfg.num_patches or 0)),
                                     jnp.int32)}
            if shape.kind == "train":
                batch_s["targets"] = SDS(
                    (b_mb, shape.seq_len - (cfg.num_patches or 0)), jnp.int32)
            if cfg.num_patches:
                batch_s["patches"] = SDS((b_mb, cfg.num_patches, cfg.d_model),
                                         jnp.float32)
            base_keys = [k for k in params_s
                         if k in ("embed", "unembed", "final_norm", "pos_embed",
                                  "patch_proj")]
            base_params_s = {k: params_s[k] for k in base_keys}
            base_sh = _shard_tree(base_params_s, mesh, cfg)
            bsh = jax.tree.map(lambda l: NamedSharding(
                mesh, P(dp, *([None] * (len(l.shape) - 1)))), batch_s)
            if shape.kind == "train":
                gfn = jax.value_and_grad(_base_train(cfg, api))
                low = jax.jit(gfn, in_shardings=(base_sh, bsh),
                              out_shardings=(None, base_sh)).lower(base_params_s, batch_s)
            else:
                low = jax.jit(_base_prefill(cfg, api), in_shardings=(base_sh, bsh),
                              out_shardings=None).lower(base_params_s, batch_s)
                # prefill additionally writes the K/V cache (not in the group
                # fwd bodies): 2·B·S·KV·hd per layer, model-sharded on S
                model = mesh.shape.get("model", 1)
                kv_bytes = (2 * shape.global_batch * shape.seq_len
                            * cfg.num_kv_heads * cfg.head_dim
                            * jnp.dtype(act).itemsize / model)
                n_attn_layers = sum(1 for w in cfg.layer_pattern if w != MAMBA)
                bytes_ += kv_bytes * n_attn_layers
            f, by, co = _cost_of(low)
            if shape.kind == "train":
                ar = _local_param_bytes(base_params_s, mesh)
                co = dict(co)
                co["all-reduce"] = max(0.0, co.get("all-reduce", 0) - ar)
            flops += f * mult_layers
            bytes_ += by * mult_layers
            _merge(colls, co, mult_layers)

            # whisper encoder (prefill/train): fwd (+vjp) of one enc layer × L
            if cfg.enc_layers:
                enc_s = SDS((b_mb, cfg.enc_len, cfg.d_model), act)
                enc_sh = NamedSharding(mesh, P(dp, None, None))
                lp_s = jax.tree.map(lambda l: SDS(l.shape[1:], l.dtype),
                                    params_s["encoder"])
                lp_sh = _shard_tree(lp_s, mesh, cfg)

                def enc_fwd(h, lp):
                    return _apply_layer(h, lp, cfg, 0, None, causal=False)

                if shape.kind == "train":
                    def enc_vjp(h, lp, ct):
                        out, pull = jax.vjp(enc_fwd, h, lp)
                        return pull(ct)
                    low = jax.jit(enc_vjp, in_shardings=(enc_sh, lp_sh, enc_sh),
                                  out_shardings=(enc_sh, lp_sh)).lower(enc_s, lp_s, enc_s)
                else:
                    low = jax.jit(enc_fwd, in_shardings=(enc_sh, lp_sh),
                                  out_shardings=enc_sh).lower(enc_s, lp_s)
                f, by, co = _cost_of(low)
                flops += f * cfg.enc_layers * mult_layers
                bytes_ += by * cfg.enc_layers * mult_layers
                _merge(colls, co, cfg.enc_layers * mult_layers)

            if shape.kind == "train":
                # optimizer (once) + the single true grad all-reduce (analytic)
                from repro.training.optimizer import AdamWConfig, adamw_update, init_opt_state
                opt_s = jax.eval_shape(init_opt_state, params_s)
                psh = param_shardings(params_s, mesh, cfg=cfg)
                osh = type(opt_s)(step=NamedSharding(mesh, P()), mu=psh, nu=psh)
                low = jax.jit(
                    functools.partial(adamw_update, AdamWConfig()),
                    in_shardings=(psh, osh, psh),
                    out_shardings=(psh, osh, None),
                ).lower(params_s, opt_s, params_s)
                f, by, co = _cost_of(low)
                flops += f
                bytes_ += by
                _merge(colls, co, 1.0)
                # the one true gradient DP all-reduce; grad_ar_scale models
                # wire-format compression (bf16=0.5, 12-bit fixed-point
                # w/ error feedback = 15/32 — the paper's truncation quantizer)
                ar_scale = overrides.get("grad_ar_scale", 1.0)
                _merge(colls, {"all-reduce":
                               _local_param_bytes(params_s, mesh) * ar_scale}, 1.0)
            tokens = shape.global_batch * shape.seq_len
            mflops = (model_flops_train(cfg, tokens) if shape.kind == "train"
                      else model_flops_forward(cfg, tokens))

        else:  # decode
            fn_builder = decode_layer_fn or _default_decode_components
            comp_flops, comp_bytes, comp_colls, mflops = fn_builder(
                cfg, shape, mesh, params_s, overrides)
            flops += comp_flops
            bytes_ += comp_bytes
            _merge(colls, comp_colls, 1.0)
    finally:
        set_sharding_context(None)

    cbytes = float(sum(colls.values()))
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_ / HBM_BW
    collective_s = cbytes / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    return {
        "flops_per_device": flops,
        "bytes_per_device": bytes_,
        "collective_bytes_per_device": cbytes,
        "collectives": {k: float(v) for k, v in colls.items()},
        "chips": chips,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "bottleneck": bottleneck,
        "model_flops": mflops,
        "useful_flops_ratio": mflops / (flops * chips) if flops else 0.0,
    }


# ---------------------------------------------------------------------------
# decode components
# ---------------------------------------------------------------------------
def _default_decode_components(cfg, shape, mesh, params_s, overrides):
    """base (embed+logits) + per-layer decode body × L (+ shared attn apps)."""
    from repro.models.decode import build_decode_fns  # for cache shapes only

    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_dp = int(np.prod([mesh.shape[a] for a in dp_axes])) if dp_axes else 1
    dp = dp_axes if (dp_axes and shape.global_batch % n_dp == 0) else None
    b = shape.global_batch
    act = cfg.act_dtype
    d = cfg.d_model
    flops = bytes_ = 0.0
    colls: Dict[str, float] = {}
    h_s = SDS((b, 1, d), act)
    h_sh = NamedSharding(mesh, P(dp, None, None))
    segments = find_segments(cfg.layer_pattern)
    ps, seg_specs = params_s, None
    seg_specs = []
    for seg in ps["segments"]:
        seg_specs.append(jax.tree.map(lambda l: SDS(l.shape[1:], l.dtype), seg))

    kv, hd = cfg.num_kv_heads, cfg.head_dim
    smax = shape.seq_len
    cache_len_fn = overrides.get("cache_len", lambda w: smax)
    kv_dtype = overrides.get("kv_dtype", act)
    # serving params stream at act dtype by default (bf16); int8 models the
    # paper's reduced-precision weights (kernels/fixed_matmul)
    param_dtype = overrides.get("param_dtype", act)

    def _as_param_dtype(tree):
        return jax.tree.map(
            lambda l: SDS(l.shape, param_dtype if jnp.issubdtype(l.dtype, jnp.floating)
                          else l.dtype), tree)

    ps = {k: (_as_param_dtype(v) if k != "segments" else
              [_as_param_dtype(s) for s in v]) for k, v in ps.items()}
    seg_specs = [jax.tree.map(lambda l: SDS(l.shape[1:], l.dtype), seg)
                 for seg in ps["segments"]]

    for (group, reps), gp_s in zip(segments, seg_specs):
        gp_sh = _shard_tree(gp_s, mesh, cfg)
        for j, w in enumerate(group):
            lp_s = jax.tree.map(lambda l: SDS(l.shape[1:], l.dtype), gp_s)
            lp_sh = _shard_tree(lp_s, mesh, cfg)
            if w == MAMBA:
                conv_dim = cfg.ssm_d_inner + 2 * ssm_mod.NGROUPS * cfg.ssm_state
                cv_s = SDS((b, cfg.ssm_conv - 1, conv_dim), jnp.float32)
                sd_s = SDS((b, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                           jnp.float32)
                cv_sh = NamedSharding(mesh, P(dp, None, "model"))
                sd_sh = NamedSharding(mesh, P(dp, "model", None, None))

                def mamba_body(h, lp, conv, sd):
                    y, nc = ssm_mod.mamba_decode_step(
                        norm(h, lp["ln1"], cfg.norm), lp["mamba"], cfg,
                        {"conv": conv, "ssd": sd})
                    return h + y, nc["conv"], nc["ssd"]

                low = jax.jit(mamba_body,
                              in_shardings=(h_sh, lp_sh, cv_sh, sd_sh),
                              out_shardings=(h_sh, cv_sh, sd_sh)).lower(
                                  h_s, lp_s, cv_s, sd_s)
            else:
                clen = cache_len_fn(w)
                k_s = SDS((b, clen, kv, hd), kv_dtype)
                k_sh = NamedSharding(mesh, P(dp, "model", None, None))
                body = overrides.get("decode_attn_body", _decode_attn_body)
                fn = body(cfg, w)
                if cfg.enc_layers:
                    ck_s = SDS((b, cfg.enc_len, kv, hd), kv_dtype)
                    ck_sh = NamedSharding(
                        mesh, P(dp, None, "model" if kv % mesh.shape.get("model", 1) == 0
                                else None, None))
                    low = jax.jit(fn, in_shardings=(h_sh, lp_sh, k_sh, k_sh,
                                                    NamedSharding(mesh, P()),
                                                    ck_sh, ck_sh),
                                  out_shardings=(h_sh, k_sh, k_sh)).lower(
                                      h_s, lp_s, k_s, k_s, SDS((), jnp.int32),
                                      ck_s, ck_s)
                else:
                    low = jax.jit(fn, in_shardings=(h_sh, lp_sh, k_sh, k_sh,
                                                    NamedSharding(mesh, P())),
                                  out_shardings=(h_sh, k_sh, k_sh)).lower(
                                      h_s, lp_s, k_s, k_s, SDS((), jnp.int32))
            f, by, co = _cost_of(low)
            flops += f * reps
            bytes_ += by * reps
            _merge(colls, co, reps)

    if cfg.shared_attn_every:
        apps = -(-cfg.num_layers // cfg.shared_attn_every)
        sp_s = jax.tree.map(lambda l: SDS(l.shape, l.dtype), ps["shared_attn"])
        sp_sh = _shard_tree(sp_s, mesh, cfg)
        k_s = SDS((b, smax, kv, hd), kv_dtype)
        k_sh = NamedSharding(mesh, P(dp, "model", None, None))

        def shared_body(h, sp, kc, vc, pos):
            a, kc, vc = attn_mod.decode_attention(
                norm(h, sp["ln1"], cfg.norm), sp["attn"], cfg, kc, vc, pos, window=0)
            h = h + a
            h = h + moe_mod.mlp(norm(h, sp["ln2"], cfg.norm), sp["mlp"], cfg)
            return h, kc, vc

        low = jax.jit(shared_body,
                      in_shardings=(h_sh, sp_sh, k_sh, k_sh, NamedSharding(mesh, P())),
                      out_shardings=(h_sh, k_sh, k_sh)).lower(
                          h_s, sp_s, k_s, k_s, SDS((), jnp.int32))
        f, by, co = _cost_of(low)
        flops += f * apps
        bytes_ += by * apps
        _merge(colls, co, apps)

    # base: embed one token + final norm + logits
    base_keys = [k for k in ps if k in ("embed", "unembed", "final_norm", "pos_embed")]
    bp_s = {k: ps[k] for k in base_keys}
    bp_sh = _shard_tree(bp_s, mesh, cfg)

    def base(params, token):
        h = params["embed"].astype(act)[token]
        h = norm(h, params["final_norm"], cfg.norm)
        return _logits(params, h, cfg)

    low = jax.jit(base, in_shardings=(bp_sh, NamedSharding(mesh, P(dp, None))),
                  out_shardings=None).lower(bp_s, SDS((b, 1), jnp.int32))
    f, by, co = _cost_of(low)
    flops += f
    bytes_ += by
    _merge(colls, co, 1.0)
    mflops = model_flops_forward(cfg, b)
    return flops, bytes_, colls, mflops


def _decode_attn_body(cfg, window):
    """One decode layer: cached self-attention (+ whisper cross) + FFN."""
    with_cross = cfg.enc_layers > 0

    def fn(h, lp, kc, vc, pos, ck=None, cv=None):
        a, kc, vc = attn_mod.decode_attention(
            norm(h, lp["ln1"], cfg.norm), lp["attn"], cfg, kc, vc, pos,
            window=window)
        if cfg.post_norms:
            a = norm(a, lp["post_ln1"], cfg.norm)
        h = h + a
        if with_cross and ck is not None:
            c = attn_mod.cross_attention_cached(
                norm(h, lp["ln_cross"], cfg.norm), lp["cross"], cfg, ck, cv)
            h = h + c
        mi = norm(h, lp["ln2"], cfg.norm)
        m = moe_mod.moe_ffn(mi, lp["moe"], cfg) if cfg.num_experts else \
            moe_mod.mlp(mi, lp["mlp"], cfg)
        if cfg.post_norms:
            m = norm(m, lp["post_ln2"], cfg.norm)
        return h + m, kc, vc

    return fn
