"""Roofline terms from the compiled dry-run artifact (no hardware needed).

  compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
  memory term     = HLO_bytes / (chips × HBM_bw)
  collective term = collective_bytes / (chips × link_bw)

Sources: ``compiled.cost_analysis()`` (flops / bytes accessed, reported for the
per-device SPMD program) and the compiled HLO text for collective operand
bytes (all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute result shapes, which in SPMD form are per-device).

Hardware model (TPU v5e, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
50 GB/s ICI link bandwidth.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12        # bf16 per chip
PEAK_FLOPS_F32 = 98.5e12   # f32 (half rate) — used when compute dtype is f32
HBM_BW = 819e9             # B/s per chip
ICI_BW = 50e9              # B/s per chip (per the assignment's formula)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVE_RE = re.compile(
    r"=\s*(?:\([^)]*\)|(?P<ty>\w+)\[(?P<shape>[\d,]*)\][^ ]*)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_TUPLE_ELT_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _bytes_of(ty: str, shape: str) -> int:
    n = 1
    for d in shape.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(ty, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result bytes of every collective op in per-device HLO text."""
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        if m.group("ty"):
            b = _bytes_of(m.group("ty"), m.group("shape"))
        else:
            # tuple result: sum elements inside the (...) before the op name
            prefix = line.split(op)[0]
            b = sum(_bytes_of(t, s) for t, s in _TUPLE_ELT_RE.findall(prefix))
        out[op] = out.get(op, 0) + b
    return out


@dataclasses.dataclass
class RooflineTerms:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    collectives: Dict[str, int]
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float = 0.0
    useful_flops_ratio: float = 0.0
    peak_flops: float = PEAK_FLOPS

    def as_dict(self):
        return dataclasses.asdict(self)


def roofline(
    cost: dict,
    hlo_text: str,
    chips: int,
    model_flops: float = 0.0,
    peak_flops: float = PEAK_FLOPS,
) -> RooflineTerms:
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    colls = collective_bytes(hlo_text)
    cbytes = float(sum(colls.values()))
    compute_s = flops / peak_flops
    memory_s = bytes_accessed / HBM_BW
    collective_s = cbytes / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    total_flops = flops * chips
    return RooflineTerms(
        flops_per_device=flops,
        bytes_per_device=bytes_accessed,
        collective_bytes_per_device=cbytes,
        collectives=colls,
        chips=chips,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        bottleneck=bottleneck,
        model_flops=model_flops,
        useful_flops_ratio=(model_flops / total_flops) if total_flops else 0.0,
        peak_flops=peak_flops,
    )


def model_flops_train(cfg, tokens: int) -> float:
    """6·N·D (dense) or 6·N_active·D (MoE) for one train step over D=tokens."""
    return 6.0 * cfg.active_param_count() * tokens


def model_flops_forward(cfg, tokens: int) -> float:
    return 2.0 * cfg.active_param_count() * tokens
