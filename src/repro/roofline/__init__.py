from repro.roofline.analysis import (
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS,
    RooflineTerms,
    collective_bytes,
    model_flops_forward,
    model_flops_train,
    roofline,
)

__all__ = [
    "roofline", "RooflineTerms", "collective_bytes",
    "model_flops_train", "model_flops_forward",
    "PEAK_FLOPS", "HBM_BW", "ICI_BW",
]
