"""End-to-end Personalized PageRank driver — the paper's own workload.

    PYTHONPATH=src python -m repro.launch.ppr_run --graph pl_1e5 --scale 0.02 \
        --bits 26 --requests 100 --kappa 8

Reproduces the paper's §5.1 protocol: compute PPR for N random personalization
vertices in κ-sized batches, at a chosen fixed-point bit-width, and score the
rankings against the float64 CPU oracle at convergence (§5.3 metrics).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import PPRConfig, batched_ppr, format_for_bits
from repro.core.metrics import aggregate_reports, full_report
from repro.graphs import paper_graph_suite, ppr_reference


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="pl_1e5")
    ap.add_argument("--scale", type=float, default=0.02,
                    help="graph-size scale (1.0 = paper size |V|=1e5/2e5)")
    ap.add_argument("--bits", type=int, default=26)
    ap.add_argument("--requests", type=int, default=100)
    ap.add_argument("--kappa", type=int, default=8)
    ap.add_argument("--iterations", type=int, default=10)
    ap.add_argument("--alpha", type=float, default=0.85)
    ap.add_argument("--float", dest="use_float", action="store_true",
                    help="run the F32 reference architecture instead")
    args = ap.parse_args()

    suite = paper_graph_suite(scale=args.scale)
    g = suite[args.graph]
    print(f"graph {args.graph}: |V|={g.num_vertices:,} |E|={g.num_edges:,} "
          f"sparsity={g.sparsity:.2e}")
    rng = np.random.default_rng(0)
    vertices = rng.integers(0, g.num_vertices, args.requests)
    cfg = PPRConfig(alpha=args.alpha, iterations=args.iterations, kappa=args.kappa)
    fmt = None if args.use_float else format_for_bits(args.bits)

    t0 = time.time()
    scores = batched_ppr(g, vertices, cfg, fmt=fmt)
    dt = time.time() - t0
    label = "float32" if fmt is None else fmt.name
    print(f"{label}: {args.requests} requests in {dt:.3f}s "
          f"({args.requests/dt:.1f} req/s, κ={args.kappa})")

    # accuracy vs converged CPU oracle (paper §5.3: ≥100 iterations)
    ref = ppr_reference(g, vertices[:8], alpha=args.alpha, iterations=100)
    reports = [full_report(scores[:, i], ref[:, i]) for i in range(8)]
    agg = aggregate_reports(reports)
    print("accuracy vs CPU oracle (first 8 requests):")
    for k in ["ndcg", "edit@10", "edit@20", "errors@10", "precision@50", "kendall@50", "mae"]:
        print(f"  {k:14s} {agg[k]:.5f}")


if __name__ == "__main__":
    main()
