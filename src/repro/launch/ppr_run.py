"""End-to-end Personalized PageRank driver — the paper's own workload.

    PYTHONPATH=src python -m repro.launch.ppr_run --graph pl_1e5 --scale 0.02 \
        --bits 26 --requests 100 --kappa 8

Reproduces the paper's §5.1 protocol: compute PPR for N random personalization
vertices in κ-sized batches, at a chosen fixed-point bit-width, and score the
rankings against the float64 CPU oracle at convergence (§5.3 metrics).

``--serve`` routes the same workload through ``PPRService`` (κ-batched waves,
top-K, telemetry) instead of the raw ``batched_ppr`` loop; ``--shards N``
additionally registers the graph on an N-way ``jax.sharding`` mesh so waves
run the sharded step bodies — the multi-host serving path.  When fewer than N
devices are visible, N host devices are forced (CPU demo of the layout; on a
real platform the flag is a no-op because devices are already there):

    PYTHONPATH=src python -m repro.launch.ppr_run --serve --shards 4
"""
from __future__ import annotations

import argparse
import os
import time


def _parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="pl_1e5")
    ap.add_argument("--scale", type=float, default=0.02,
                    help="graph-size scale (1.0 = paper size |V|=1e5/2e5)")
    ap.add_argument("--bits", type=int, default=26)
    ap.add_argument("--requests", type=int, default=100)
    ap.add_argument("--kappa", type=int, default=8)
    ap.add_argument("--iterations", type=int, default=10)
    ap.add_argument("--alpha", type=float, default=0.85)
    ap.add_argument("--float", dest="use_float", action="store_true",
                    help="run the F32 reference architecture instead")
    ap.add_argument("--serve", action="store_true",
                    help="route through PPRService (waves, top-K, telemetry)")
    ap.add_argument("--shards", type=int, default=1,
                    help="with --serve: register the graph on an N-way mesh "
                         "(N>1 implies the sharded step bodies)")
    ap.add_argument("--topk", type=int, default=10,
                    help="with --serve: recommendations per query")
    ap.add_argument("--http", type=int, default=None, metavar="PORT",
                    help="serve the graph over HTTP on PORT (0 = ephemeral): "
                         "asyncio tier with admission control, load shedding "
                         "and SLO-aware quality degradation; runs until "
                         "interrupted (POST /v1/ppr, GET /v1/healthz, "
                         "GET /v1/stats)")
    ap.add_argument("--replay-deltas", type=int, default=0, metavar="N",
                    help="dynamic-updates mode: serve a Zipf-ish query mix, "
                         "then replay N random edge-delta rounds against the "
                         "live service (scoped invalidation + warm-start), "
                         "re-serving the same traffic after each")
    ap.add_argument("--delta-edges", type=int, default=64,
                    help="with --replay-deltas: edge insertions per round "
                         "(half as many removals ride along)")
    ap.add_argument("--trace", action="store_true",
                    help="with --serve/--http/--replay-deltas: arm per-query "
                         "span tracing (every query records its admission "
                         "wait, cache probe, wave execution and convergence "
                         "into the flight recorder)")
    ap.add_argument("--dump-traces", type=int, default=0, metavar="N",
                    help="after the run, print the flight recorder's last N "
                         "traces as span trees plus control-plane events "
                         "(implies --trace)")
    ap.add_argument("--trace-sample", type=float, default=None, metavar="RATE",
                    help="head-sample tracing at RATE in (0, 1] instead of "
                         "tracing everything (implies --trace; seeded, so a "
                         "replayed run samples the same queries)")
    ap.add_argument("--slo", action="store_true",
                    help="with --http: arm the SLO burn-rate monitor "
                         "(default latency/shed/quality specs, GET /v1/slo, "
                         "burn-driven admission advisories)")
    ap.add_argument("--otlp-endpoint", default=None, metavar="URL",
                    help="with --http: export spans + delta metrics to an "
                         "OTLP/HTTP collector at URL (POSTs to URL/v1/traces "
                         "and URL/v1/metrics); the flight recorder still "
                         "records everything locally")
    return ap.parse_args(argv)


def main():
    args = _parse_args()
    if args.shards > 1:
        # must be set before the jax backend initializes; harmless when enough
        # real devices exist or the backend already came up (_serve then
        # reports the actual device shortfall with a remedy)
        flags = os.environ.get("XLA_FLAGS", "")
        if "--xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count={args.shards}"
            ).strip()

    import numpy as np

    from repro.core import PPRConfig, batched_ppr, format_for_bits
    from repro.core.metrics import aggregate_reports, full_report
    from repro.graphs import paper_graph_suite, ppr_reference

    suite = paper_graph_suite(scale=args.scale)
    g = suite[args.graph]
    print(f"graph {args.graph}: |V|={g.num_vertices:,} |E|={g.num_edges:,} "
          f"sparsity={g.sparsity:.2e}")
    rng = np.random.default_rng(0)
    vertices = rng.integers(0, g.num_vertices, args.requests)
    cfg = PPRConfig(alpha=args.alpha, iterations=args.iterations, kappa=args.kappa)
    fmt = None if args.use_float else format_for_bits(args.bits)
    label = "float32" if fmt is None else fmt.name

    if args.http is not None:
        _serve_http(args, g, fmt, label)
        return
    if args.replay_deltas:
        _replay_deltas(args, g, fmt, label)
        return
    if args.serve or args.shards > 1:
        scores = _serve(args, g, vertices, fmt, label)
    else:
        t0 = time.time()
        scores = batched_ppr(g, vertices, cfg, fmt=fmt)
        dt = time.time() - t0
        print(f"{label}: {args.requests} requests in {dt:.3f}s "
              f"({args.requests/dt:.1f} req/s, κ={args.kappa})")

    if scores is None:
        return
    # accuracy vs converged CPU oracle (paper §5.3: ≥100 iterations)
    n_acc = min(8, args.requests)
    ref = ppr_reference(g, vertices[:n_acc], alpha=args.alpha, iterations=100)
    reports = [full_report(scores[:, i], ref[:, i]) for i in range(n_acc)]
    agg = aggregate_reports(reports)
    print(f"accuracy vs CPU oracle (first {n_acc} requests):")
    for k in ["ndcg", "edit@10", "edit@20", "errors@10", "precision@50", "kendall@50", "mae"]:
        print(f"  {k:14s} {agg[k]:.5f}")


def _serve(args, g, vertices, fmt, label):
    """PPRService path: waves + top-K + telemetry, optionally mesh-sharded.

    Returns None (skipping the dense-score oracle comparison): the service
    returns ranked top-K results, not dense score matrices, and its numeric
    parity with the direct path is covered by tests/test_sharded_serving.py.
    This driver reports serving throughput and per-mesh wave telemetry."""
    import jax
    import numpy as np

    from repro.ppr_serving import PPRQuery, PPRService

    mesh = None
    if args.shards > 1:
        if jax.device_count() < args.shards:
            raise SystemExit(
                f"--shards {args.shards} needs {args.shards} devices, have "
                f"{jax.device_count()} (the jax backend initialized before "
                f"this driver could force host devices — set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={args.shards} "
                f"up front)")
        mesh = jax.make_mesh((args.shards,), ("shard",))
    svc = PPRService(kappa=args.kappa, iterations=args.iterations,
                     alpha=args.alpha, cache_capacity=0,      # measure compute
                     tracing=_tracing(args))
    svc.register_graph(args.graph, g,
                       formats=[] if fmt is None else [fmt], mesh=mesh)
    precision = None if fmt is None else fmt.name
    queries = [PPRQuery(args.graph, int(v), k=args.topk, precision=precision)
               for v in vertices]

    svc.run_batch(queries[: min(args.kappa, len(queries))])   # warm up jit
    svc.telemetry.reset()              # report only the timed traffic
    t0 = time.time()
    recs = svc.run_batch(queries)
    dt = time.time() - t0
    where = "single-device" if mesh is None else f"{args.shards}-shard mesh"
    print(f"{label} via PPRService on {where}: {len(recs)} queries in {dt:.3f}s "
          f"({len(recs)/dt:.1f} req/s, κ={args.kappa}, top-{args.topk})")
    t = svc.telemetry_summary()
    for k in sorted(t):
        if k.startswith(("waves", "queries_", "wave_latency", "mean_occ",
                         "engine_")):
            v = t[k]
            print(f"  {k:28s} {v:.5f}" if isinstance(v, float) else
                  f"  {k:28s} {v}")
    if args.dump_traces:
        _dump_recorder(svc, args.dump_traces)
    return None


def _serve_http(args, g, fmt, label):
    """HTTP serving mode: the registered graph behind the asyncio tier.

    Auto-precision is always armed (the SLO degradation path needs the
    controller); an explicit --bits additionally pre-quantizes that format so
    explicit-precision requests skip the first-touch quantization upload."""
    import asyncio

    from repro.ppr_serving import PPRHTTPServer, PPRService

    otlp = None
    if args.otlp_endpoint:
        from repro.obs import OTLPExporter
        otlp = OTLPExporter(args.otlp_endpoint)
    svc = PPRService(kappa=args.kappa, iterations=args.iterations,
                     alpha=args.alpha, max_wait=0.005, early_exit=True,
                     tracing=_tracing(args), slo=args.slo or None, otlp=otlp)
    svc.register_graph(args.graph, g, formats=[] if fmt is None else [fmt])
    server = PPRHTTPServer(svc, port=args.http)

    async def _run():
        await server.start()
        print(f"{label}: serving graph {args.graph!r} "
              f"(|V|={g.num_vertices:,}) on http://{server.host}:{server.port}")
        print(f"  POST /v1/ppr      "
              f'{{"graph": "{args.graph}", "vertex": 0, "k": {args.topk}, '
              f'"precision": "auto"}}')
        print("  GET  /v1/healthz  liveness + queue depth")
        print("  GET  /v1/stats    telemetry + admission counters")
        print("  GET  /v1/metrics  Prometheus text exposition (?format=json)")
        if svc.slo is not None:
            print("  GET  /v1/slo      SLO states + burn rates (?n=K events)")
        print("  GET  /v1/debug/traces  flight recorder (?n=K)")
        if otlp is not None:
            print(f"  exporting OTLP to {otlp.endpoint} "
                  f"(/v1/traces, /v1/metrics)")
        try:
            await asyncio.Event().wait()
        finally:
            await server.stop()

    try:
        asyncio.run(_run())
    except KeyboardInterrupt:
        print("\nshutting down")
    if otlp is not None:
        s = otlp.stats()
        print(f"otlp: {s['spans_exported']} spans in "
              f"{s['span_batches_sent']} batches, "
              f"{s['metric_pushes']} metric pushes, "
              f"{s['spans_dropped']} dropped, "
              f"{s['send_failures']} failed sends")
    if args.dump_traces:
        _dump_recorder(svc, args.dump_traces)


def _replay_deltas(args, g, fmt, label):
    """Dynamic-updates showcase: one live service absorbing delta rounds.

    Traffic is Zipf-ish (a small hot set queried every round) so the three
    update-time mechanisms are all visible: scoped invalidation keeps
    off-frontier cache entries serving, warm-start re-converges invalidated
    hot vertices in fewer iterations, and the prefetcher re-warms what the
    delta dropped during the idle pump between rounds."""
    import numpy as np

    from repro.graph_updates import localized_delta, random_delta
    from repro.ppr_serving import PPRQuery, PPRService

    rng = np.random.default_rng(0)
    hot = rng.integers(0, g.num_vertices, max(4, args.kappa))
    cold_pool = rng.integers(0, g.num_vertices, 4 * len(hot))

    svc = PPRService(kappa=args.kappa, iterations=args.iterations,
                     alpha=args.alpha, early_exit=True, warm_start=True,
                     prefetch=True, tracing=_tracing(args))
    svc.register_graph(args.graph, g,
                       formats=[] if fmt is None else [fmt])
    precision = None if fmt is None else fmt.name

    def traffic(round_i):
        verts = list(hot) + list(rng.choice(cold_pool, len(hot)))
        return [PPRQuery(args.graph, int(v), k=args.topk, precision=precision)
                for v in verts]

    svc.run_batch(traffic(0))                   # warm up jit + caches
    print(f"{label}: replaying {args.replay_deltas} delta rounds of "
          f"~{args.delta_edges + args.delta_edges // 2} edges on "
          f"{args.graph} (|V|={g.num_vertices:,})")
    for i in range(args.replay_deltas):
        rg = svc.registered_graph(args.graph)
        grow = args.delta_edges // 16 if i % 2 else 0
        # alternate global churn with localized low-connectivity bursts —
        # the localized rounds are where scoped invalidation retains entries
        if i % 2 == 0:
            d = localized_delta(rg.source, rng, n_add=args.delta_edges,
                                n_remove=args.delta_edges // 2)
        else:
            d = random_delta(rg.source, rng, n_add=args.delta_edges,
                             n_remove=args.delta_edges // 2, grow=grow)
        rep = svc.apply_delta(args.graph, d)
        svc.poll()                              # idle poll → prefetch re-warm
        t0 = time.time()
        recs = svc.run_batch(traffic(i + 1))
        dt = time.time() - t0
        cached = sum(r.source == "cache" for r in recs)
        print(f"  round {i + 1}: epoch={rep['epoch']} "
              f"+{rep['edges_added']}/-{rep['edges_removed']} edges "
              f"(apply {rep['apply_s'] * 1e3:.1f} ms, "
              f"frontier {rep['frontier_size']}), "
              f"cache dropped {rep['cache_dropped']} / kept {rep['cache_retained']}, "
              f"re-serve {len(recs)} q in {dt:.3f}s ({cached} cached)")
    t = svc.telemetry_summary()
    print("telemetry:")
    for k in ("deltas_applied", "edges_added", "edges_removed",
              "scoped_invalidations", "scoped_cache_retained",
              "warm_start_waves", "warm_start_iterations_saved",
              "prefetch_issued", "cache_hit_rate", "early_exit_waves",
              "iterations_saved"):
        v = t[k]
        print(f"  {k:28s} {v:.4f}" if isinstance(v, float) else
              f"  {k:28s} {v}")
    if args.dump_traces:
        _dump_recorder(svc, args.dump_traces)


def _tracing(args):
    """The service's ``tracing`` argument: a sample rate when requested,
    else the plain on/off bool."""
    if args.trace_sample is not None:
        return args.trace_sample
    return bool(args.trace or args.dump_traces)


def _dump_recorder(svc, n):
    """Print the flight recorder's tail: control-plane events (the incident
    timeline), then the last ``n`` completed traces as span trees."""
    from repro.obs import format_event, format_trace

    snap = svc.recorder.snapshot(n_traces=n, n_events=n)
    print(f"flight recorder: {snap['traces_recorded']} traces / "
          f"{snap['events_recorded']} events recorded "
          f"(rings {snap['trace_capacity']}/{snap['event_capacity']})")
    for ev in snap["events"]:
        print("  " + format_event(ev))
    for tr in snap["traces"]:
        for line in format_trace(tr).splitlines():
            print("  " + line)


if __name__ == "__main__":
    main()
