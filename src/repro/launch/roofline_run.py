import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).

"""Structured (trip-count-correct) roofline for every cell on the single-pod
mesh (§Roofline is single-pod per the run-book).

    PYTHONPATH=src python -m repro.launch.roofline_run [--arch A] [--shape S]
        [--out experiments/roofline] [--variant baseline]
"""
import argparse
import json
import time
import traceback

import jax.numpy as jnp

from repro.configs import LONG_CONTEXT_ARCHS, SHAPES, get_config, list_archs
from repro.launch.mesh import make_production_mesh
from repro.roofline.structured import structured_roofline

MICROBATCHES = {"train_4k": 8}

# §Perf hillclimb variants (hypothesis → change; see EXPERIMENTS.md §Perf).
# "baseline"/"it1_moe_sharding" share overrides={} — the MoE dispatch
# constraint is a library change, so the variant name records WHEN it landed.
VARIANTS = {
    "baseline": {},
    "final": {},            # library after all landed §Perf changes
    "it1_moe_sharding": {},
    # decode: local-attention layers keep only `window` KV entries
    "it_windowed_kv": {"cache_len": "windowed"},
    # decode: KV stored in int8 (the paper's truncation quantization on state)
    "it_int8_kv": {"cache_len": "windowed", "kv_dtype": jnp.int8},
    # decode: + int8 weight streaming (kernels/fixed_matmul serving path)
    "it_int8_weights": {"cache_len": "windowed", "kv_dtype": jnp.int8,
                        "param_dtype": jnp.int8},
    # decode int8 KV without windowing (for full-attention archs)
    "it_int8_kv_only": {"kv_dtype": jnp.int8},
    "it_int8_all": {"kv_dtype": jnp.int8, "param_dtype": jnp.int8},
    # train/prefill: disable sequence parallelism (batch-only activations)
    "it_no_sp": {"sequence_parallel": False},
    # train: 12-bit fixed-point gradient all-reduce w/ error feedback
    # wire format (1 sign + 2 int + 12 frac)/32 = 15/32
    "it_compressed_ar": {"grad_ar_scale": 15.0 / 32.0},
    "it_no_sp_compressed_ar": {"sequence_parallel": False,
                               "grad_ar_scale": 15.0 / 32.0},
    # MoE: tight capacity (1.0) — smaller dispatch buffers, more drops
    "it_cap1": {"cfg": {"moe_capacity_factor": 1.0}},
    "it_cap1_compressed": {"cfg": {"moe_capacity_factor": 1.0},
                           "grad_ar_scale": 15.0 / 32.0},
}


def resolve_overrides(name: str, shape) -> dict:
    ov = dict(VARIANTS[name])
    if ov.get("cache_len") == "windowed":
        smax = shape.seq_len
        ov["cache_len"] = lambda w: min(w, smax) if w else smax
    return ov


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--out", default="experiments/roofline")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=False)
    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    out_dir = os.path.join(args.out, args.variant)
    os.makedirs(out_dir, exist_ok=True)
    failures = []
    for arch in archs:
        for shape_name in shapes:
            if shape_name == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
                continue
            fn = os.path.join(out_dir, f"{arch}__{shape_name}.json")
            if args.skip_existing and os.path.exists(fn):
                continue
            cfg = get_config(arch)
            shape = SHAPES[shape_name]
            t0 = time.time()
            try:
                overrides = resolve_overrides(args.variant, shape)
                if "cfg" in overrides:
                    import dataclasses as _dc
                    cfg = _dc.replace(cfg, **overrides.pop("cfg"))
                rec = structured_roofline(
                    cfg, shape, mesh, microbatches=MICROBATCHES.get(shape_name, 1),
                    overrides=overrides)
                rec.update(arch=arch, shape=shape_name, variant=args.variant,
                           wall_s=round(time.time() - t0, 1))
                with open(fn, "w") as f:
                    json.dump(rec, f, indent=1)
                print(f"OK    {arch:22s} {shape_name:12s} "
                      f"compute={rec['compute_s']:.3e} memory={rec['memory_s']:.3e} "
                      f"coll={rec['collective_s']:.3e} {rec['bottleneck']:10s} "
                      f"useful={rec['useful_flops_ratio']:.3f} ({rec['wall_s']}s)",
                      flush=True)
            except Exception as e:
                failures.append((arch, shape_name, repr(e)))
                print(f"FAIL  {arch:22s} {shape_name}: {e!r}", flush=True)
                traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} failures")
    print("ALL STRUCTURED ROOFLINES DONE")


if __name__ == "__main__":
    main()
