"""Serving driver: batched greedy decoding with the slot-based engine.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --smoke \
        --requests 12 --batch 4 --new-tokens 8
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config, smoke_config
from repro.models import build_model
from repro.serving import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = dataclasses.replace(smoke_config(cfg), compute_dtype="float32")
    api = build_model(cfg, remat=False)
    params = api.init_params(jax.random.PRNGKey(0))
    engine = ServingEngine(api, params, batch_size=args.batch, max_len=args.max_len)

    rng = np.random.default_rng(0)
    reqs = [
        Request(uid=i,
                prompt=rng.integers(0, cfg.vocab_size, args.prompt_len).astype(np.int32),
                max_new_tokens=args.new_tokens)
        for i in range(args.requests)
    ]
    t0 = time.time()
    results = engine.serve(reqs)
    dt = time.time() - t0
    total_tokens = sum(len(v) for v in results.values())
    print(f"served {len(reqs)} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens/dt:,.1f} tok/s)")
    for uid in sorted(results)[:4]:
        print(f"  req {uid}: {results[uid]}")


if __name__ == "__main__":
    main()
