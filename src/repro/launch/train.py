"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --steps 200 \
        [--smoke] [--seq 512] [--batch 8] [--microbatches 2] \
        [--ckpt-dir /tmp/ckpt] [--compress-bits 0] [--mesh none|debug]

``--smoke`` uses the reduced config (CPU-runnable ~100M-class training); the
full configs are intended for the real mesh.  The loop is resumable: it picks
up the latest checkpoint in --ckpt-dir automatically (fault_tolerance.py).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_config
from repro.data import DataConfig, synthetic_batch
from repro.models import build_model
from repro.training import (
    AdamWConfig,
    FaultConfig,
    init_train_state,
    make_train_step,
    run_resumable,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--compress-bits", type=int, default=0,
                    help="fixed-point gradient compression fractional bits (0=off)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = dataclasses.replace(smoke_config(cfg), compute_dtype="float32")
    api = build_model(cfg, remat=True)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(5, args.steps // 20),
                          total_steps=args.steps)
    step_fn = jax.jit(make_train_step(
        api.loss_fn, opt_cfg, microbatches=args.microbatches,
        grad_compress_bits=args.compress_bits,
    ))
    dcfg = DataConfig(seq_len=args.seq, global_batch=args.batch)

    def init_state():
        params = api.init_params(jax.random.PRNGKey(0))
        return init_train_state(params, compress=args.compress_bits > 0)

    t0 = time.time()
    losses = []

    def on_metrics(step, m):
        losses.append(float(m["loss"]))
        if step % args.log_every == 0:
            tok_s = args.batch * args.seq * (step + 1) / max(1e-9, time.time() - t0)
            print(f"step {step:5d} loss {float(m['loss']):.4f} "
                  f"gnorm {float(m['grad_norm']):.3f} tok/s {tok_s:,.0f}", flush=True)

    fault = FaultConfig(
        ckpt_dir=args.ckpt_dir or f"/tmp/repro_train_{args.arch}",
        save_every=args.save_every, max_steps=args.steps,
    )
    state, steps_run, stragglers = run_resumable(
        fault, init_state, step_fn, lambda s: synthetic_batch(cfg, dcfg, s),
        on_metrics=on_metrics)
    print(f"done: ran {steps_run} steps, first loss {losses[0]:.4f} "
          f"last {losses[-1]:.4f}, stragglers {len(stragglers)}")


if __name__ == "__main__":
    main()
