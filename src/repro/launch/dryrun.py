import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on the
production meshes, and extract memory / cost / collective analyses.

    PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
        [--mesh single|multi|both] [--out experiments/dryrun]

Per cell it writes ``<out>/<mesh>/<arch>__<shape>.json`` with:
  - memory_analysis (per-device bytes: args / outputs / temps / peak)
  - cost_analysis   (flops / bytes accessed, per-device SPMD program)
  - collective op result bytes (parsed from compiled HLO)
  - the three roofline terms + bottleneck (§Roofline)

Any sharding mismatch / compile OOM / unsupported collective here is a bug in
the framework — the run fails loudly.
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import (
    LONG_CONTEXT_ARCHS,
    LONG_SKIP_REASON,
    SHAPES,
    get_config,
    list_archs,
)
from repro.distributed.sharding import (
    batch_shardings,
    cache_shardings,
    param_shardings,
    set_sharding_context,
)
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh
from repro.models.transformer import build_model
from repro.roofline.analysis import (
    PEAK_FLOPS,
    model_flops_forward,
    model_flops_train,
    roofline,
)
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import make_train_step

from jax.sharding import NamedSharding, PartitionSpec as P

MICROBATCHES = {"train_4k": 8}


def _mem_analysis(compiled):
    try:
        ma = compiled.memory_analysis()
        if ma is None:
            return {}
        keys = [
            "argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
            "alias_size_in_bytes",
        ]
        return {k: int(getattr(ma, k)) for k in keys if hasattr(ma, k)}
    except Exception as e:  # CPU backend may not implement it fully
        return {"error": str(e)}


def run_cell(arch: str, shape_name: str, mesh, mesh_name: str, out_dir: str,
             opt_level: str = "baseline") -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    chips = 1
    for n in mesh.shape.values():
        chips *= n
    api = build_model(cfg, remat=(shape.kind == "train"))
    params_s = S.params_specs(api)
    pshard = param_shardings(params_s, mesh, cfg=cfg)
    set_sharding_context(mesh, sequence_parallel=(shape.kind != "decode"))
    t0 = time.time()

    if shape.kind == "train":
        mb = MICROBATCHES.get(shape_name, 1)
        step = make_train_step(api.loss_fn, AdamWConfig(), microbatches=mb)
        state_s = S.train_state_specs(params_s)
        state_shard = type(state_s)(
            params=pshard,
            opt=type(state_s.opt)(
                step=NamedSharding(mesh, P()),
                mu=pshard, nu=pshard),
            residual=None,
        )
        batch_s = S.batch_specs(cfg, shape)
        bshard = batch_shardings(batch_s, mesh)
        jitted = jax.jit(step, in_shardings=(state_shard, bshard),
                         out_shardings=(state_shard, None))
        lowered = jitted.lower(state_s, batch_s)
        tokens = shape.global_batch * shape.seq_len
        mflops = model_flops_train(cfg, tokens)
    elif shape.kind == "prefill":
        batch_s = S.batch_specs(cfg, shape)
        bshard = batch_shardings(batch_s, mesh)
        cache_s = S.cache_specs(api, shape.global_batch, shape.seq_len)
        cshard = cache_shardings(cache_s, mesh, shape.global_batch)
        jitted = jax.jit(api.prefill, in_shardings=(pshard, bshard, cshard),
                         out_shardings=(None, cshard))
        lowered = jitted.lower(params_s, batch_s, cache_s)
        mflops = model_flops_forward(cfg, shape.global_batch * shape.seq_len)
    else:  # decode
        token_s, pos_s, cache_s = S.decode_specs(cfg, shape, api)
        cshard = cache_shardings(cache_s, mesh, shape.global_batch)
        tshard = batch_shardings(token_s, mesh,
                                 batch_divisible=shape.global_batch % 16 == 0)
        jitted = jax.jit(api.decode_step,
                         in_shardings=(pshard, tshard, NamedSharding(mesh, P()), cshard),
                         out_shardings=(None, cshard))
        lowered = jitted.lower(params_s, token_s, pos_s, cache_s)
        mflops = model_flops_forward(cfg, shape.global_batch)

    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    hlo = compiled.as_text()
    terms = roofline(cost, hlo, chips, model_flops=mflops)
    mem = _mem_analysis(compiled)
    set_sharding_context(None)

    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "chips": chips,
        "opt_level": opt_level,
        "params": cfg.param_count(), "active_params": cfg.active_param_count(),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory_analysis": mem,
        "cost_flops": float(cost.get("flops", 0.0)),
        "cost_bytes": float(cost.get("bytes accessed", 0.0)),
        "roofline": terms.as_dict(),
    }
    os.makedirs(out_dir, exist_ok=True)
    fn = os.path.join(out_dir, f"{arch}__{shape_name}.json")
    with open(fn, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single_pod_16x16", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi_pod_2x16x16", make_production_mesh(multi_pod=True)))

    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    failures = []
    for mesh_name, mesh in meshes:
        out_dir = os.path.join(args.out, mesh_name)
        for arch in archs:
            for shape_name in shapes:
                if shape_name == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
                    print(f"SKIP  {mesh_name:18s} {arch:22s} {shape_name}: "
                          f"{LONG_SKIP_REASON[arch]}")
                    continue
                fn = os.path.join(out_dir, f"{arch}__{shape_name}.json")
                if args.skip_existing and os.path.exists(fn):
                    print(f"have  {mesh_name:18s} {arch:22s} {shape_name}")
                    continue
                try:
                    rec = run_cell(arch, shape_name, mesh, mesh_name, out_dir)
                    r = rec["roofline"]
                    print(
                        f"PASS  {mesh_name:18s} {arch:22s} {shape_name:12s} "
                        f"compile={rec['compile_s']:.0f}s "
                        f"compute={r['compute_s']:.2e}s memory={r['memory_s']:.2e}s "
                        f"coll={r['collective_s']:.2e}s bottleneck={r['bottleneck']}",
                        flush=True,
                    )
                except Exception as e:
                    failures.append((mesh_name, arch, shape_name, repr(e)))
                    print(f"FAIL  {mesh_name:18s} {arch:22s} {shape_name}: {e!r}",
                          flush=True)
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES")
        raise SystemExit(1)
    print("\nALL CELLS PASS")


if __name__ == "__main__":
    main()
