"""Production mesh definitions (per run-book: function, not module constant)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 2, model: int = 2):
    """Small mesh for CI-scale distributed tests (requires ≥ data·model devices)."""
    return jax.make_mesh((data, model), ("data", "model"))
