import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).

"""Dry-run of the paper's own workload on the production meshes: the
dst-partitioned streaming SpMV PPR iteration, lowered + compiled at pod scale.

    PYTHONPATH=src python -m repro.launch.ppr_dryrun [--workload ppr-pod-16m]

The model axis partitions the vertex space (the paper's URAM → per-chip
memory); the data axis batches independent κ-groups of personalization
vertices (the paper's request batching, scaled 16×).
"""
import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.configs.ppr_paper import PPR_WORKLOADS
from repro.launch.mesh import make_production_mesh
from repro.roofline.analysis import HBM_BW, ICI_BW, collective_bytes

SDS = jax.ShapeDtypeStruct


def build_ppr_step(w, mesh):
    """One PPR iteration over the dst-partitioned COO graph, κ batched over
    the data axis.  Edges padded per model-shard; indices local to the shard."""
    n_model = mesh.shape["model"]
    n_data = mesh.shape["data"] * mesh.shape.get("pod", 1)
    v_local = w.num_vertices // n_model
    e_shard = w.num_edges // n_model

    def step(x_loc, y, val, p, dangling, pers_mat):
        # p arrives dst-sharded (the previous iteration's output); the step
        # all-gathers it over the model axis — the partitioned design's real
        # per-iteration collective (paper §4.1.2 partitioning trade-off).
        def local(x_l, y_l, v_l, p_shard, dang, pmat):
            p_full = jax.lax.all_gather(p_shard, "model", axis=0, tiled=True)
            contrib = v_l[0][:, None] * p_full[y_l[0]]   # gather full p rows
            xp = jax.ops.segment_sum(contrib, x_l[0], num_segments=v_local)
            dangling_mass = dang @ p_full                # [K]
            return (w.alpha * xp
                    + (w.alpha / w.num_vertices) * dangling_mass[None, :]
                    + (1 - w.alpha) * pmat)

        # κ-groups on the data axis are independent problems: shard P's
        # columns over data so the model-axis all-gather never spans them
        # (16× less collective traffic than gathering all K_total columns).
        kspec = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        return shard_map(
            local, mesh=mesh,
            in_specs=(P("model"), P("model"), P("model"),
                      P("model", kspec), P(), P("model", kspec)),
            out_specs=P("model", kspec),
        )(x_loc, y, val, p, dangling, pers_mat)

    k_total = w.kappa * n_data
    specs = (
        SDS((n_model, e_shard), jnp.int32),            # x_local per shard
        SDS((n_model, e_shard), jnp.int32),            # y (global src)
        SDS((n_model, e_shard), jnp.float32),          # val
        SDS((w.num_vertices, k_total), jnp.float32),   # P_t (replicated)
        SDS((w.num_vertices,), jnp.float32),           # dangling
        SDS((w.num_vertices, k_total), jnp.float32),   # personalization
    )
    shardings = (
        NamedSharding(mesh, P("model")),
        NamedSharding(mesh, P("model")),
        NamedSharding(mesh, P("model")),
        NamedSharding(mesh, P()),
        NamedSharding(mesh, P()),
        NamedSharding(mesh, P("model")),
    )
    return step, specs, shardings


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="ppr-pod-16m",
                    choices=sorted(PPR_WORKLOADS))
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()
    w = PPR_WORKLOADS[args.workload]
    for mesh_name, mesh in [
        ("single_pod_16x16", make_production_mesh(multi_pod=False)),
        ("multi_pod_2x16x16", make_production_mesh(multi_pod=True)),
    ]:
        step, specs, shardings = build_ppr_step(w, mesh)
        kspec = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        shardings = shardings[:3] + (
            NamedSharding(mesh, P("model", kspec)),
            shardings[4],
            NamedSharding(mesh, P("model", kspec)),
        )
        lowered = jax.jit(step, in_shardings=shardings,
                          out_shardings=NamedSharding(mesh, P("model", kspec))).lower(*specs)
        compiled = lowered.compile()
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        colls = collective_bytes(compiled.as_text())
        flops = float(cost.get("flops", 0))
        by = float(cost.get("bytes accessed", 0))
        cb = float(sum(colls.values()))
        rec = {
            "workload": w.name, "mesh": mesh_name,
            "V": w.num_vertices, "E": w.num_edges,
            "kappa_total": w.kappa * mesh.shape["data"] * mesh.shape.get("pod", 1),
            "flops_per_device": flops, "bytes_per_device": by,
            "collective_bytes_per_device": cb, "collectives": colls,
            "memory_s": by / HBM_BW, "collective_s": cb / ICI_BW,
        }
        os.makedirs(args.out, exist_ok=True)
        with open(os.path.join(args.out, f"ppr__{w.name}__{mesh_name}.json"), "w") as f:
            json.dump(rec, f, indent=1)
        print(f"PASS  {mesh_name:18s} {w.name}: memory_s={rec['memory_s']:.3e} "
              f"coll_s={rec['collective_s']:.3e} "
              f"(per-iteration, {rec['kappa_total']} concurrent requests)", flush=True)


if __name__ == "__main__":
    main()
