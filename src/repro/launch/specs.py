"""ShapeDtypeStruct stand-ins for every (arch × shape) dry-run cell.

No device allocation: params come from jax.eval_shape(init_params), the decode
cache from jax.eval_shape(init_cache), and the batch is built directly.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.transformer import build_model
from repro.training.optimizer import AdamState
from repro.training.train_loop import TrainState

SDS = jax.ShapeDtypeStruct


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Training/prefill batch stand-ins (modality frontends are stubs:
    precomputed frame/patch embeddings per the assignment)."""
    b, s = shape.global_batch, shape.seq_len
    specs: Dict[str, Any] = {}
    text_s = s - (cfg.num_patches or 0)
    specs["tokens"] = SDS((b, text_s), jnp.int32)
    if shape.kind == "train":
        specs["targets"] = SDS((b, s if not cfg.num_patches else text_s), jnp.int32)
    if cfg.enc_len:
        specs["frames"] = SDS((b, cfg.enc_len, cfg.d_model), jnp.float32)
    if cfg.num_patches:
        specs["patches"] = SDS((b, cfg.num_patches, cfg.d_model), jnp.float32)
    return specs


def params_specs(api) -> Any:
    return jax.eval_shape(lambda k: api.init_params(k), jax.random.PRNGKey(0))


def cache_specs(api, batch: int, max_len: int) -> Any:
    return jax.eval_shape(
        functools.partial(api.init_cache, batch, max_len))


def train_state_specs(params_s) -> TrainState:
    zeros = jax.tree.map(lambda l: SDS(l.shape, l.dtype), params_s)
    return TrainState(
        params=zeros,
        opt=AdamState(step=SDS((), jnp.int32),
                      mu=jax.tree.map(lambda l: SDS(l.shape, l.dtype), params_s),
                      nu=jax.tree.map(lambda l: SDS(l.shape, l.dtype), params_s)),
        residual=None,
    )


def decode_specs(cfg: ModelConfig, shape: ShapeConfig, api):
    """(token, pos, cache) stand-ins for one decode step with a seq_len cache."""
    b = shape.global_batch
    token = SDS((b, 1), jnp.int32)
    pos = SDS((), jnp.int32)
    cache = cache_specs(api, b, shape.seq_len)
    return token, pos, cache
