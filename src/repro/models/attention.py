"""Attention: GQA/MQA, local (sliding-window) and global, softcap, qk-norm,
query-chunked memory-bounded computation, and cached decode.

Window sizes are STATIC per call (the segment machinery guarantees it), so
local layers genuinely slice K/V to [W + qc] — sub-quadratic compute, not just
masking.  Query chunking bounds the scores transient to [B, KV, G, qc, Skv]
(a scan, not a materialized [Sq, Skv] tensor) — the XLA-level equivalent of a
flash-attention outer loop.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init, init_norm, rmsnorm, rope, softcap, split_keys

Array = jax.Array


def init_attention(key, cfg: ModelConfig) -> Dict:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = split_keys(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h * hd), d),
        "wk": dense_init(ks[1], (d, kv * hd), d),
        "wv": dense_init(ks[2], (d, kv * hd), d),
        "wo": dense_init(ks[3], (h * hd, d), h * hd),
    }
    if cfg.use_qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def _project_qkv(x, p, cfg: ModelConfig, positions):
    """x [B,S,D] → q [B,S,H,hd], k/v [B,S,KV,hd] with rope/qk-norm applied."""
    b, s, _ = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ p["wq"].astype(x.dtype)).reshape(b, s, h, hd)
    k = (x @ p["wk"].astype(x.dtype)).reshape(b, s, kv, hd)
    v = (x @ p["wv"].astype(x.dtype)).reshape(b, s, kv, hd)
    if cfg.use_qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    if not cfg.learned_pos:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _attend(q, k, v, qpos, kpos, cfg: ModelConfig, causal: bool) -> Array:
    """Masked GQA attention.  q [B,qc,H,hd]; k/v [B,Skv,KV,hd];
    qpos [qc], kpos [Skv] global positions (mask = causal ∧ window)."""
    b, qc, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, qc, kvh, g, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32)
    scores = scores / math.sqrt(hd)
    scores = softcap(scores, cfg.attn_softcap)
    mask = jnp.ones((qc, k.shape[1]), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    mask &= kpos[None, :] >= 0  # padding slots in sliced windows carry kpos=-1
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v)
    return out.reshape(b, qc, h, hd)


def attention(
    x: Array,
    p: Dict,
    cfg: ModelConfig,
    *,
    window: int,                 # STATIC: 0 = full, >0 = local window
    causal: bool = True,
    kv_override: Optional[Tuple[Array, Array]] = None,  # cross-attention
    chunk: int = 512,
    return_kv: bool = False,
) -> Array:
    """Training/prefill attention over a full sequence.  x [B,S,D] → [B,S,D]."""
    b, s, d = x.shape
    positions = jnp.arange(s)[None, :]
    q, k, v = _project_qkv(x, p, cfg, positions)
    if kv_override is not None:
        k, v = kv_override
    kpos_full = jnp.arange(k.shape[1])
    qc = min(chunk, s)
    while s % qc:       # largest divisor of S ≤ chunk (e.g. 1500 → 500)
        qc -= 1
    n_chunks = s // qc
    if n_chunks <= 1:
        if window and window < s and kv_override is None:
            out = _attend_window(q, k, v, 0, cfg, causal, window)
        else:
            out = _attend(q, k, v, jnp.arange(s), kpos_full, cfg, causal)
    else:
        qs = q.reshape(b, n_chunks, qc, cfg.num_heads, cfg.head_dim)

        def chunk_body(carry, i):
            qi = qs[:, i]
            start = i * qc
            if window and window < s and kv_override is None:
                out_i = _attend_window(qi, k, v, start, cfg, causal, window)
            else:
                out_i = _attend(qi, k, v, start + jnp.arange(qc), kpos_full, cfg, causal)
            return carry, out_i

        _, outs = jax.lax.scan(chunk_body, None, jnp.arange(n_chunks))
        # outs [n_chunks, B, qc, H, hd] → [B, S, H, hd]
        out = outs.transpose(1, 0, 2, 3, 4).reshape(b, s, cfg.num_heads, cfg.head_dim)
    y = out.reshape(b, s, cfg.num_heads * cfg.head_dim) @ p["wo"].astype(x.dtype)
    if return_kv:
        return y, k, v
    return y


def _attend_window(q_chunk, k, v, chunk_start, cfg: ModelConfig, causal: bool, window: int):
    """Local attention: slice K/V to [chunk_start-window, chunk_start+qc) —
    static size window+qc, true sub-quadratic compute for local layers."""
    b, qc, h, hd = q_chunk.shape
    s = k.shape[1]
    span = min(window + qc, s)
    start = jnp.clip(chunk_start - window, 0, s - span)
    ks = jax.lax.dynamic_slice_in_dim(k, start, span, axis=1)
    vs = jax.lax.dynamic_slice_in_dim(v, start, span, axis=1)
    qpos = chunk_start + jnp.arange(qc)
    kpos = start + jnp.arange(span)
    # window mask: attend only to the last `window` positions before each query
    out = _attend_masked_window(q_chunk, ks, vs, qpos, kpos, cfg, causal, window)
    return out


def _attend_masked_window(q, k, v, qpos, kpos, cfg, causal, window):
    b, qc, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, qc, kvh, g, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32) / math.sqrt(hd)
    scores = softcap(scores, cfg.attn_softcap)
    mask = (qpos[:, None] >= kpos[None, :]) if causal else jnp.ones((qc, k.shape[1]), bool)
    mask &= qpos[:, None] - kpos[None, :] < window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bkgqs,bskd->bqkgd", w, v).reshape(b, qc, h, hd)


# ---------------------------------------------------------------------------
# cached decode
# ---------------------------------------------------------------------------
def prefill_kv(x, p, cfg: ModelConfig) -> Tuple[Array, Array]:
    """Project K/V for the whole prompt (cache fill)."""
    positions = jnp.arange(x.shape[1])[None, :]
    _, k, v = _project_qkv(x, p, cfg, positions)
    return k, v


def decode_attention(
    x: Array,            # [B, 1, D] current token hidden
    p: Dict,
    cfg: ModelConfig,
    cache_k: Array,      # [B, Smax, KV, hd]
    cache_v: Array,
    pos: Array,          # scalar int32: index of the current token
    *,
    window: int,         # STATIC
) -> Tuple[Array, Array, Array]:
    """One-token attention against the cache; returns (out, new_k, new_v)."""
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(x, p, cfg, positions)
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k_new.astype(cache_k.dtype), pos, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v_new.astype(cache_v.dtype), pos, axis=1)
    smax = cache_k.shape[1]
    kpos = jnp.arange(smax)
    valid = kpos <= pos
    if window:
        valid &= kpos > pos - window
    kvh, hd, h = cfg.num_kv_heads, cfg.head_dim, cfg.num_heads
    g = h // kvh
    qg = q.reshape(b, 1, kvh, g, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, cache_k.astype(q.dtype)).astype(jnp.float32)
    scores = softcap(scores / math.sqrt(hd), cfg.attn_softcap)
    scores = jnp.where(valid[None, None, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, cache_v.astype(q.dtype)).reshape(b, 1, h * hd)
    return out @ p["wo"].astype(x.dtype), cache_k, cache_v


def decode_attention_windowed(
    x: Array,
    p: Dict,
    cfg: ModelConfig,
    cache_k: Array,      # [B, W, KV, hd] rolling buffer (slot = position % W)
    cache_v: Array,
    pos: Array,
    *,
    window: int,         # STATIC == cache length
) -> Tuple[Array, Array, Array]:
    """Local-attention decode against a rolling window buffer (§Perf
    it_windowed_kv made real): HBM cost is O(window), not O(max_len)."""
    b = x.shape[0]
    w = cache_k.shape[1]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(x, p, cfg, positions)
    slot = pos % w
    cache_k = jax.lax.dynamic_update_slice_in_dim(
        cache_k, k_new.astype(cache_k.dtype), slot, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(
        cache_v, v_new.astype(cache_v.dtype), slot, axis=1)
    # true position held by each slot j: largest p' ≤ pos with p' % w == j
    j = jnp.arange(w)
    kpos = pos - ((pos - j) % w)
    valid = (kpos >= 0) & (kpos > pos - window)
    kvh, hd, h = cfg.num_kv_heads, cfg.head_dim, cfg.num_heads
    g = h // kvh
    qg = q.reshape(b, 1, kvh, g, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg,
                        cache_k.astype(q.dtype)).astype(jnp.float32)
    scores = softcap(scores / math.sqrt(hd), cfg.attn_softcap)
    scores = jnp.where(valid[None, None, None, None, :], scores, -1e30)
    wgt = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", wgt,
                     cache_v.astype(q.dtype)).reshape(b, 1, h * hd)
    return out @ p["wo"].astype(x.dtype), cache_k, cache_v


def fill_windowed_cache(cache_k, cache_v, k, v):
    """Prefill a rolling buffer from full-prompt K/V [B,Sp,KV,hd]: keep the
    last W positions at slot = position % W."""
    w = cache_k.shape[1]
    sp = k.shape[1]
    if sp <= w:
        ck = jax.lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype),
                                                 0, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype),
                                                 0, axis=1)
        return ck, cv
    positions = sp - w + jnp.arange(w)
    slots = positions % w
    ck = cache_k.at[:, slots].set(k[:, positions].astype(cache_k.dtype))
    cv = cache_v.at[:, slots].set(v[:, positions].astype(cache_v.dtype))
    return ck, cv


def cross_attention_cached(x, p, cfg: ModelConfig, cross_k, cross_v) -> Array:
    """Decoder cross-attention against precomputed encoder K/V (whisper)."""
    b, s, _ = x.shape
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ p["wq"].astype(x.dtype)).reshape(b, s, h, hd)
    g = h // kvh
    qg = q.reshape(b, s, kvh, g, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, cross_k.astype(x.dtype)).astype(jnp.float32)
    scores = scores / math.sqrt(hd)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, cross_v.astype(x.dtype)).reshape(b, s, h * hd)
    return out @ p["wo"].astype(x.dtype)
