"""Cached prefill / decode paths for every architecture family.

Cache layout (pytree):
  attention archs : [{"k": [reps, g, B, Smax, KV, hd], "v": ...} per segment]
  + whisper       : each segment dict also holds cross "ck"/"cv" [reps,g,B,enc,KV,hd]
  ssm archs       : {"conv": [L, B, K-1, conv_dim], "ssd": [L, B, nh, hd, state]}
  zamba2 (hybrid) : {"mamba": <ssm cache>, "shared": {"k": [apps, B, Smax, KV, hd], ...}}

``prefill(params, batch, cache)`` fills the cache for the prompt and returns the
last-position logits; ``decode_step(params, token, pos, cache)`` advances one
token.  Both scan over layer segments exactly like the training forward.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MAMBA, ModelConfig
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.common import find_segments, norm

Array = jax.Array


def _n_shared_apps(cfg: ModelConfig) -> int:
    return -(-cfg.num_layers // cfg.shared_attn_every) if cfg.shared_attn_every else 0


def build_decode_fns(cfg: ModelConfig, embed_inputs, run_encoder, logits_fn):
    segments = find_segments(cfg.layer_pattern)
    is_encdec = cfg.enc_layers > 0
    is_ssm = all(w == MAMBA for w in cfg.layer_pattern)

    # ------------------------------------------------------------------
    def init_cache(batch: int, max_len: int, dtype=None, window_cache: bool = False):
        """window_cache=True sizes local-attention layers' KV as rolling
        buffers of their window (§Perf it_windowed_kv, made real) — per-layer
        ``k_<j>`` keys since lengths differ within a scanned group."""
        dtype = dtype or cfg.act_dtype
        kv, hd = cfg.num_kv_heads, cfg.head_dim
        if is_ssm:
            mc = ssm_mod.mamba_init_cache(cfg, batch)
            cache: Dict[str, Any] = {"mamba": jax.tree.map(
                lambda a: jnp.zeros((cfg.num_layers,) + a.shape, a.dtype), mc)}
            if cfg.shared_attn_every:
                apps = _n_shared_apps(cfg)
                cache["shared"] = {
                    "k": jnp.zeros((apps, batch, max_len, kv, hd), dtype),
                    "v": jnp.zeros((apps, batch, max_len, kv, hd), dtype),
                }
            return cache
        segs = []
        for group, reps in segments:
            g = len(group)
            if window_cache:
                seg = {}
                for j, w in enumerate(group):
                    sj = min(w, max_len) if w else max_len
                    seg[f"k_{j}"] = jnp.zeros((reps, batch, sj, kv, hd), dtype)
                    seg[f"v_{j}"] = jnp.zeros((reps, batch, sj, kv, hd), dtype)
            else:
                seg = {
                    "k": jnp.zeros((reps, g, batch, max_len, kv, hd), dtype),
                    "v": jnp.zeros((reps, g, batch, max_len, kv, hd), dtype),
                }
            if is_encdec:
                seg["ck"] = jnp.zeros((reps, g, batch, cfg.enc_len, kv, hd), dtype)
                seg["cv"] = jnp.zeros((reps, g, batch, cfg.enc_len, kv, hd), dtype)
            segs.append(seg)
        return segs

    # ------------------------------------------------------------------
    # prefill
    # ------------------------------------------------------------------
    def _prefill_attn_layer(h, lp, window, enc_out, ck_slot, cv_slot):
        a, k, v = attn_mod.attention(
            norm(h, lp["ln1"], cfg.norm), lp["attn"], cfg,
            window=window, causal=True, return_kv=True)
        if cfg.post_norms:
            a = norm(a, lp["post_ln1"], cfg.norm)
        h = h + a
        new_cross = None
        if enc_out is not None and "cross" in lp:
            b, se, _ = enc_out.shape
            ek = (enc_out @ lp["cross"]["wk"].astype(h.dtype)).reshape(
                b, se, cfg.num_kv_heads, cfg.head_dim)
            ev = (enc_out @ lp["cross"]["wv"].astype(h.dtype)).reshape(
                b, se, cfg.num_kv_heads, cfg.head_dim)
            c = attn_mod.cross_attention_cached(
                norm(h, lp["ln_cross"], cfg.norm), lp["cross"], cfg, ek, ev)
            h = h + c
            new_cross = (ek.astype(ck_slot.dtype), ev.astype(cv_slot.dtype))
        mi = norm(h, lp["ln2"], cfg.norm)
        m = moe_mod.moe_ffn(mi, lp["moe"], cfg) if cfg.num_experts else \
            moe_mod.mlp(mi, lp["mlp"], cfg)
        if cfg.post_norms:
            m = norm(m, lp["post_ln2"], cfg.norm)
        return h + m, k, v, new_cross

    def prefill(params, batch, cache):
        h = embed_inputs(params, batch, cfg)
        enc_out = run_encoder(params, batch["frames"], cfg) if is_encdec else None
        sp = h.shape[1]
        if is_ssm:
            h, cache = _prefill_ssm(params, h, cache)
        else:
            new_segs = []
            for seg_params, seg_cache, (group, reps) in zip(
                    params["segments"], cache, segments):
                windowed_layout = "k_0" in seg_cache

                def body(carry, xs, group=group, windowed_layout=windowed_layout):
                    hh = carry
                    lps, sc = xs
                    upd = {k2: sc[k2] for k2 in sc}
                    for j, w in enumerate(group):
                        lp = jax.tree.map(lambda a: a[j], lps)
                        ckj = sc["ck"][j] if is_encdec else None
                        cvj = sc["cv"][j] if is_encdec else None
                        hh, k, v, cross = _prefill_attn_layer(hh, lp, w, enc_out, ckj, cvj)
                        if windowed_layout:
                            kk, vv = attn_mod.fill_windowed_cache(
                                sc[f"k_{j}"], sc[f"v_{j}"], k, v)
                            upd[f"k_{j}"] = kk
                            upd[f"v_{j}"] = vv
                        else:
                            kk = jax.lax.dynamic_update_slice_in_dim(
                                sc["k"][j], k.astype(sc["k"].dtype), 0, axis=1)
                            vv = jax.lax.dynamic_update_slice_in_dim(
                                sc["v"][j], v.astype(sc["v"].dtype), 0, axis=1)
                            upd["k"] = upd["k"].at[j].set(kk)
                            upd["v"] = upd["v"].at[j].set(vv)
                        if cross is not None:
                            upd["ck"] = upd["ck"].at[j].set(cross[0])
                            upd["cv"] = upd["cv"].at[j].set(cross[1])
                    return hh, upd

                h, new_cache = jax.lax.scan(body, h, (seg_params, seg_cache))
                new_segs.append(new_cache)
            cache = new_segs
        h = norm(h, params["final_norm"], cfg.norm)
        last = h[:, -1:, :]
        return logits_fn(params, last, cfg)[:, 0], cache

    def _prefill_ssm(params, h, cache):
        seg_params = params["segments"][0]
        mamba_cache = cache["mamba"]
        L, every = cfg.num_layers, cfg.shared_attn_every

        def body(carry, xs):
            hh = carry
            lps, mc = xs
            lp = jax.tree.map(lambda a: a[0], lps)
            xin = norm(hh, lp["ln1"], cfg.norm)
            y, st = _mamba_layer_with_state(xin, lp["mamba"])
            return hh + y, st

        if every:
            apps = _n_shared_apps(cfg)
            shared = cache["shared"]
            sk, sv = shared["k"], shared["v"]
            new_states = []
            for gi, start in enumerate(range(0, L, every)):
                hin = norm(h, params["shared_attn"]["ln1"], cfg.norm)
                a, k, v = attn_mod.attention(hin, params["shared_attn"]["attn"],
                                             cfg, window=0, return_kv=True)
                h = h + a
                h = h + moe_mod.mlp(norm(h, params["shared_attn"]["ln2"], cfg.norm),
                                    params["shared_attn"]["mlp"], cfg)
                sk = sk.at[gi, :, : k.shape[1]].set(k.astype(sk.dtype))
                sv = sv.at[gi, :, : v.shape[1]].set(v.astype(sv.dtype))
                stop = min(start + every, L)
                chunk = jax.tree.map(lambda a: a[start:stop], seg_params)
                mchunk = jax.tree.map(lambda a: a[start:stop], mamba_cache)
                h, states = jax.lax.scan(body, h, (chunk, mchunk))
                new_states.append(states)
            mamba_new = jax.tree.map(lambda *xs: jnp.concatenate(xs), *new_states)
            return h, {"mamba": mamba_new, "shared": {"k": sk, "v": sv}}
        h, states = jax.lax.scan(body, h, (seg_params, mamba_cache))
        return h, {"mamba": states}

    def _mamba_layer_with_state(xin, mp):
        """mamba_layer variant that also returns the decode cache entry."""
        b, s, d = xin.shape
        di, st, nh, hd = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
        xz = xin @ mp["w_in"].astype(xin.dtype)
        z, xi, B, C, dt = ssm_mod._split_proj(xz, cfg)
        conv_in = jnp.concatenate([xi, B, C], axis=-1)
        conv_out = jax.nn.silu(ssm_mod._causal_conv(
            conv_in, mp["conv_w"].astype(xin.dtype), mp["conv_b"].astype(xin.dtype)))
        xi2, B2, C2 = jnp.split(conv_out, [di, di + ssm_mod.NGROUPS * st], axis=-1)
        dtp = jax.nn.softplus(dt.astype(jnp.float32) + mp["dt_bias"][None, None, :])
        A = -jnp.exp(mp["A_log"].astype(jnp.float32))
        ck = min(256, s)
        y, final = ssm_mod.ssd_chunked(
            xi2.reshape(b, s, nh, hd).astype(jnp.float32), dtp, A,
            B2.reshape(b, s, ssm_mod.NGROUPS, st).astype(jnp.float32),
            C2.reshape(b, s, ssm_mod.NGROUPS, st).astype(jnp.float32), ck)
        y = y + xi2.reshape(b, s, nh, hd).astype(jnp.float32) * mp["D"][None, None, :, None]
        y = y.reshape(b, s, di).astype(xin.dtype)
        y = ssm_mod.rmsnorm(y * jax.nn.silu(z), mp["norm_w"])
        out = y @ mp["w_out"].astype(xin.dtype)
        # conv tail: last (K-1) conv inputs
        tail = conv_in[:, -(cfg.ssm_conv - 1):, :].astype(jnp.float32)
        return out, {"conv": tail, "ssd": final}

    # ------------------------------------------------------------------
    # decode
    # ------------------------------------------------------------------
    def _decode_attn_layer(h, lp, window, pos, k_c, v_c, ck=None, cv=None):
        a, k_c, v_c = attn_mod.decode_attention(
            norm(h, lp["ln1"], cfg.norm), lp["attn"], cfg, k_c, v_c, pos,
            window=window)
        if cfg.post_norms:
            a = norm(a, lp["post_ln1"], cfg.norm)
        h = h + a
        if ck is not None and "cross" in lp:
            c = attn_mod.cross_attention_cached(
                norm(h, lp["ln_cross"], cfg.norm), lp["cross"], cfg, ck, cv)
            h = h + c
        mi = norm(h, lp["ln2"], cfg.norm)
        m = moe_mod.moe_ffn(mi, lp["moe"], cfg) if cfg.num_experts else \
            moe_mod.mlp(mi, lp["mlp"], cfg)
        if cfg.post_norms:
            m = norm(m, lp["post_ln2"], cfg.norm)
        return h + m, k_c, v_c

    def _decode_windowed_layer(h, lp, window, pos, k_c, v_c):
        a, k_c, v_c = attn_mod.decode_attention_windowed(
            norm(h, lp["ln1"], cfg.norm), lp["attn"], cfg, k_c, v_c, pos,
            window=window)
        if cfg.post_norms:
            a = norm(a, lp["post_ln1"], cfg.norm)
        h = h + a
        mi = norm(h, lp["ln2"], cfg.norm)
        m = moe_mod.moe_ffn(mi, lp["moe"], cfg) if cfg.num_experts else \
            moe_mod.mlp(mi, lp["mlp"], cfg)
        if cfg.post_norms:
            m = norm(m, lp["post_ln2"], cfg.norm)
        return h + m, k_c, v_c

    def decode_step(params, token, pos, cache):
        """token [B,1] int32, pos scalar int32 → (logits [B,Vp], cache)."""
        h = params["embed"].astype(cfg.act_dtype)[token]
        if cfg.embed_scale:
            h = h * jnp.asarray(math.sqrt(cfg.d_model), h.dtype)
        if cfg.learned_pos:
            h = h + jax.lax.dynamic_slice_in_dim(
                params["pos_embed"], pos, 1, axis=0)[None].astype(h.dtype)
        if is_ssm:
            h, cache = _decode_ssm(params, h, pos, cache)
        else:
            new_segs = []
            for seg_params, seg_cache, (group, reps) in zip(
                    params["segments"], cache, segments):
                windowed_layout = "k_0" in seg_cache

                def body(carry, xs, group=group, windowed_layout=windowed_layout):
                    hh = carry
                    lps, sc = xs
                    upd = dict(sc)
                    for j, w in enumerate(group):
                        lp = jax.tree.map(lambda a: a[j], lps)
                        ckj = sc["ck"][j] if is_encdec else None
                        cvj = sc["cv"][j] if is_encdec else None
                        if windowed_layout:
                            kc, vc = sc[f"k_{j}"], sc[f"v_{j}"]
                            if w and kc.shape[1] <= w:  # rolling window buffer
                                hh, kk, vv = _decode_windowed_layer(
                                    hh, lp, w, pos, kc, vc)
                            else:
                                hh, kk, vv = _decode_attn_layer(
                                    hh, lp, w, pos, kc, vc, ckj, cvj)
                            upd[f"k_{j}"] = kk
                            upd[f"v_{j}"] = vv
                        else:
                            hh, kk, vv = _decode_attn_layer(
                                hh, lp, w, pos, sc["k"][j], sc["v"][j], ckj, cvj)
                            upd["k"] = upd["k"].at[j].set(kk)
                            upd["v"] = upd["v"].at[j].set(vv)
                    return hh, upd

                h, new_cache = jax.lax.scan(body, h, (seg_params, seg_cache))
                new_segs.append(new_cache)
            cache = new_segs
        h = norm(h, params["final_norm"], cfg.norm)
        from repro.models.transformer import _logits as logits_impl
        return logits_impl(params, h, cfg)[:, 0], cache

    def _decode_ssm(params, h, pos, cache):
        seg_params = params["segments"][0]
        mamba_cache = cache["mamba"]
        L, every = cfg.num_layers, cfg.shared_attn_every

        def body(carry, xs):
            hh = carry
            lps, mc = xs
            lp = jax.tree.map(lambda a: a[0], lps)
            y, new_mc = ssm_mod.mamba_decode_step(
                norm(hh, lp["ln1"], cfg.norm), lp["mamba"], cfg, mc)
            return hh + y, new_mc

        if every:
            shared = cache["shared"]
            sk, sv = shared["k"], shared["v"]
            new_states = []
            for gi, start in enumerate(range(0, L, every)):
                sp = params["shared_attn"]
                a, kk, vv = attn_mod.decode_attention(
                    norm(h, sp["ln1"], cfg.norm), sp["attn"], cfg,
                    sk[gi], sv[gi], pos, window=0)
                h = h + a
                h = h + moe_mod.mlp(norm(h, sp["ln2"], cfg.norm), sp["mlp"], cfg)
                sk = sk.at[gi].set(kk)
                sv = sv.at[gi].set(vv)
                stop = min(start + every, L)
                chunk = jax.tree.map(lambda a: a[start:stop], seg_params)
                mchunk = jax.tree.map(lambda a: a[start:stop], mamba_cache)
                h, states = jax.lax.scan(body, h, (chunk, mchunk))
                new_states.append(states)
            mamba_new = jax.tree.map(lambda *xs: jnp.concatenate(xs), *new_states)
            return h, {"mamba": mamba_new, "shared": {"k": sk, "v": sv}}
        h, states = jax.lax.scan(body, h, (seg_params, mamba_cache))
        return h, {"mamba": states}

    return init_cache, prefill, decode_step
