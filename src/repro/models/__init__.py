from repro.models.transformer import ModelApi, build_model, init_params

__all__ = ["ModelApi", "build_model", "init_params"]
