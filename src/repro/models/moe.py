"""Dense MLP and Mixture-of-Experts feed-forward.

MoE dispatch IS the paper's COO SpMM (DESIGN.md §4): the token→expert-slot
assignment is a sparse matrix with entries (dst = expert·capacity + rank,
src = token, val = gate weight); dispatch multiplies it against the dense
activation matrix, combine multiplies its transpose.  We implement it in
exactly that streaming form — sort tokens by expert (the dst-major ordering of
BlockedCOO), capacity-bounded slots (the packet padding), scatter/gather, and
the gate-weighted combine (the val multiply).

Per-batch-row dispatch keeps the sort local (S·k elements) and shards cleanly:
xe [B, E, C, D] with B→data, E→model (expert parallelism).
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import act_fn, dense_init, split_keys

Array = jax.Array


# ---------------------------------------------------------------------------
# dense MLP
# ---------------------------------------------------------------------------
def init_mlp(key, cfg: ModelConfig) -> Dict:
    d, f = cfg.d_model, cfg.d_ff
    if cfg.mlp == "glu":
        ks = split_keys(key, 3)
        return {
            "w_gate": dense_init(ks[0], (d, f), d),
            "w_up": dense_init(ks[1], (d, f), d),
            "w_down": dense_init(ks[2], (f, d), f),
        }
    ks = split_keys(key, 2)
    return {
        "w_fc": dense_init(ks[0], (d, f), d),
        "b_fc": jnp.zeros((f,), jnp.float32),
        "w_proj": dense_init(ks[1], (f, d), f),
        "b_proj": jnp.zeros((d,), jnp.float32),
    }


def mlp(x: Array, p: Dict, cfg: ModelConfig) -> Array:
    if cfg.mlp == "glu":
        h = act_fn(x @ p["w_gate"].astype(x.dtype), cfg.act) * (x @ p["w_up"].astype(x.dtype))
        return h @ p["w_down"].astype(x.dtype)
    h = act_fn(x @ p["w_fc"].astype(x.dtype) + p["b_fc"].astype(x.dtype), cfg.act)
    return h @ p["w_proj"].astype(x.dtype) + p["b_proj"].astype(x.dtype)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------
def init_moe(key, cfg: ModelConfig) -> Dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = split_keys(key, 4)
    return {
        "router": dense_init(ks[0], (d, e), d),
        "w_gate": dense_init(ks[1], (e, d, f), d),
        "w_up": dense_init(ks[2], (e, d, f), d),
        "w_down": dense_init(ks[3], (e, f, d), f),
    }


def _capacity(tokens: int, cfg: ModelConfig, capacity_factor: float) -> int:
    c = math.ceil(tokens * cfg.experts_per_token * capacity_factor / cfg.num_experts)
    return max(1, min(tokens, (c + 3) // 4 * 4))


def moe_ffn(x: Array, p: Dict, cfg: ModelConfig, capacity_factor: float = 0.0) -> Array:
    """x [B, S, D] → [B, S, D]; top-k routing with capacity, COO-form dispatch."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    cap = _capacity(s, cfg, capacity_factor or cfg.moe_capacity_factor)

    logits = x @ p["router"].astype(x.dtype)            # [B, S, E]
    gates = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_val, top_idx = jax.lax.top_k(gates, k)          # [B, S, k]
    top_val = top_val / jnp.maximum(top_val.sum(-1, keepdims=True), 1e-9)

    def dispatch_row(xr, ti, tv):
        # -- the COO build: entries (dst=slot, src=token, val=gate) ----------
        expert_flat = ti.reshape(-1)                    # [S*k] dst block ids
        token_flat = jnp.repeat(jnp.arange(s), k)       # [S*k] src ids
        gate_flat = tv.reshape(-1).astype(xr.dtype)     # [S*k] vals
        order = jnp.argsort(expert_flat)                # dst-major stream order
        es, ts, gs = expert_flat[order], token_flat[order], gate_flat[order]
        # rank within expert = position in sorted run (capacity = packet pad)
        rank = jnp.arange(s * k) - jnp.searchsorted(es, es, side="left")
        valid = rank < cap
        slot = jnp.where(valid, es * cap + rank, e * cap)   # overflow → dropped
        # dispatch: scatter tokens into [E*C, D] (padded COO packets)
        xe = jnp.zeros((e * cap + 1, d), xr.dtype).at[slot].set(xr[ts])
        xe = xe[:-1].reshape(e, cap, d)
        return xe, (slot, ts, gs)

    xe, meta = jax.vmap(dispatch_row)(x, top_idx, top_val)   # [B, E, C, D]

    # Explicit internal shardings (without them GSPMD replicates the dispatch
    # buffers — measured 171 GB/device/layer on mixtral, EXPERIMENTS.md §Perf):
    # EP mode: experts → "model";  TP mode (E ∤ axis): d_ff → "model".
    from jax.sharding import PartitionSpec as _P
    from repro.distributed.sharding import batch_axes, constrain, moe_mode
    mode = moe_mode(e)
    dp = batch_axes()
    if mode == "ep":
        xe = constrain(xe, _P(dp, "model", None, None))
    elif mode == "tp":
        xe = constrain(xe, _P(dp, None, None, None))
    h = act_fn(jnp.einsum("becd,edf->becf", xe, p["w_gate"].astype(x.dtype)), cfg.act)
    h = h * jnp.einsum("becd,edf->becf", xe, p["w_up"].astype(x.dtype))
    if mode == "ep":
        h = constrain(h, _P(dp, "model", None, None))
    elif mode == "tp":
        h = constrain(h, _P(dp, None, None, "model"))
    ye = jnp.einsum("becf,efd->becd", h, p["w_down"].astype(x.dtype))   # [B,E,C,D]
    if mode == "ep":
        ye = constrain(ye, _P(dp, "model", None, None))
    elif mode == "tp":
        ye = constrain(ye, _P(dp, None, None, None))

    def combine_row(yr, m):
        slot, ts, gs = m
        flat = yr.reshape(e * cap, d)
        flat = jnp.concatenate([flat, jnp.zeros((1, d), yr.dtype)], axis=0)
        contrib = flat[slot] * gs[:, None]              # val · gathered (SpMV form)
        return jnp.zeros((s, d), yr.dtype).at[ts].add(contrib)

    return jax.vmap(combine_row)(ye, meta)


def router_aux_loss(x: Array, p: Dict, cfg: ModelConfig) -> Array:
    """Load-balancing auxiliary loss (Switch-style): E · Σ_e f_e · P_e."""
    logits = x @ p["router"].astype(x.dtype)
    gates = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top1 = jnp.argmax(gates, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(top1, cfg.num_experts, dtype=jnp.float32), axis=(0, 1))
    prob = jnp.mean(gates, axis=(0, 1))
    return cfg.num_experts * jnp.sum(frac * prob)
