"""Shared building blocks for every architecture: norms, RoPE, activations,
initialization, and pattern→segment compression for scanned layers."""
from __future__ import annotations

import math
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array
PyTree = Any


# ---------------------------------------------------------------------------
# normalization
# ---------------------------------------------------------------------------
def rmsnorm(x: Array, w: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def layernorm(x: Array, w: Array, b: Array, eps: float = 1e-5) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def norm(x: Array, p: Dict[str, Array], kind: str) -> Array:
    if kind == "rmsnorm":
        return rmsnorm(x, p["w"])
    return layernorm(x, p["w"], p["b"])


def init_norm(d: int, kind: str) -> Dict[str, Array]:
    if kind == "rmsnorm":
        return {"w": jnp.ones((d,), jnp.float32)}
    return {"w": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}


# ---------------------------------------------------------------------------
# activations
# ---------------------------------------------------------------------------
def act_fn(x: Array, kind: str) -> Array:
    if kind == "silu":
        return jax.nn.silu(x)
    return jax.nn.gelu(x, approximate=True)


def softcap(x: Array, cap: float) -> Array:
    """gemma2 logit soft-capping: cap·tanh(x/cap)."""
    if cap <= 0.0:
        return x
    return jnp.tanh(x / cap) * cap


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------
def rope(x: Array, positions: Array, theta: float) -> Array:
    """x: [..., S, H, hd]; positions: broadcastable to [..., S]."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )  # [half]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(angles)[..., None, :]  # [..., S, 1, half]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    dt = x.dtype
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1
    ).astype(dt)


# ---------------------------------------------------------------------------
# initialization helpers
# ---------------------------------------------------------------------------
def dense_init(key, shape, in_dim) -> Array:
    return (jax.random.normal(key, shape, jnp.float32) / math.sqrt(in_dim))


def split_keys(key, n: int):
    return list(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# layer-pattern → (group, repeats) segments (DESIGN.md §7)
# ---------------------------------------------------------------------------
def find_segments(pattern: Tuple[int, ...], max_period: int = 8) -> List[Tuple[Tuple[int, ...], int]]:
    """Greedy compression of the per-layer pattern into periodic segments so
    that structural variation is STATIC inside each scanned body.

    gemma2  (4096,0)*23              → [((4096,0), 23)]
    gemma3  ((1024,)*5+(0,))*5+(1024,)*4 → [((1024,)*5+(0,), 5), ((1024,), 4)]
    uniform (0,)*L                   → [((0,), L)]
    """
    segs: List[Tuple[Tuple[int, ...], int]] = []
    i, n = 0, len(pattern)
    while i < n:
        best_p, best_r = 1, 1
        for p in range(1, min(max_period, n - i) + 1):
            group = pattern[i: i + p]
            r = 1
            while pattern[i + r * p: i + (r + 1) * p] == group:
                r += 1
            if p * r > best_p * best_r:
                best_p, best_r = p, r
        segs.append((pattern[i: i + best_p], best_r))
        i += best_p * best_r
    return segs


def tree_stack(trees: List[PyTree]) -> PyTree:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)
