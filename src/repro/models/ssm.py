"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) layer.

Chunked SSD algorithm: the sequence is split into chunks; within a chunk the
recurrence is computed in its dual quadratic-attention form (MXU-friendly),
and chunk-boundary states are passed with a lax.scan — O(S·chunk) compute,
O(1) recurrent state.  Matches the reference `ssd_minimal_discrete` from the
Mamba2 paper repo (validated in tests against a naive step-by-step scan).

Decode maintains (conv buffer, SSD state) and is a pure O(1) state update.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init, init_norm, rmsnorm, split_keys

Array = jax.Array

NGROUPS = 1  # B/C projection groups (mamba2 default 1 for these sizes)


def init_mamba(key, cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    di = cfg.ssm_d_inner
    st = cfg.ssm_state
    nh = cfg.ssm_heads
    conv_dim = di + 2 * NGROUPS * st
    ks = split_keys(key, 5)
    return {
        # in_proj → [z (di), x (di), B (g·st), C (g·st), dt (nh)]
        "w_in": dense_init(ks[0], (d, 2 * di + 2 * NGROUPS * st + nh), d),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv, conv_dim), cfg.ssm_conv),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm_w": jnp.ones((di,), jnp.float32),
        "w_out": dense_init(ks[4], (di, d), di),
    }


def _split_proj(xz: Array, cfg: ModelConfig):
    di, st, nh = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads
    z, x, B, C, dt = jnp.split(
        xz, [di, 2 * di, 2 * di + NGROUPS * st, 2 * di + 2 * NGROUPS * st], axis=-1
    )
    return z, x, B, C, dt


def _causal_conv(x: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv1d: x [B,S,C], w [K,C] → [B,S,C]."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    # windowed sum: Σ_j x[t-k+1+j] w[j]  — unrolled over the tiny kernel (k=4)
    out = sum(xp[:, j: j + x.shape[1], :] * w[j][None, None, :] for j in range(k))
    return out + b[None, None, :]


def _segsum(x: Array) -> Array:
    """Stable segment-sum: L[i,j] = Σ_{j<m≤i} x[m] (−inf above diagonal)."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int):
    """SSD forward.  x [b,s,h,p], dt [b,s,h] (post-softplus), A [h] (negative),
    B,C [b,s,g,n].  Returns y [b,s,h,p] and final state [b,h,p,n]."""
    b, s, h, p = x.shape
    g, n = B.shape[-2], B.shape[-1]
    nc = s // chunk
    # reshape into chunks
    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = B.reshape(b, nc, chunk, g, n)
    Cc = C.reshape(b, nc, chunk, g, n)
    dA = dtc * A[None, None, None, :]                     # [b,nc,l,h]
    dA = dA.transpose(0, 1, 3, 2)                         # [b,nc,h,l]
    dA_cs = jnp.cumsum(dA, axis=-1)
    # 1. intra-chunk (diagonal blocks): quadratic within chunk
    L = jnp.exp(_segsum(dA))                              # [b,nc,h,l,l]
    # scores: C_i · B_j  (group-broadcast over heads: h per group = h//g)
    CB = jnp.einsum("bclgn,bcsgn->bcgls", Cc, Bc)         # [b,nc,g,l,l]
    hpg = h // g
    CBh = jnp.repeat(CB, hpg, axis=2)                     # [b,nc,h,l,l]
    xdt = xc * dtc[..., None]                             # [b,nc,l,h,p]
    y_diag = jnp.einsum("bchls,bcshp->bclhp", CBh * L, xdt)
    # 2. chunk-boundary states
    decay_states = jnp.exp(dA_cs[..., -1:] - dA_cs)       # [b,nc,h,l]
    states = jnp.einsum("bclgn,bchl,bclhp->bchpn",
                        Bc, decay_states, xdt)            # [b,nc,h,p,n]
    # 3. inter-chunk recurrence (scan over chunks)
    chunk_decay = jnp.exp(dA_cs[..., -1])                 # [b,nc,h]

    def scan_body(prev, inp):
        st, dec = inp                                     # [b,h,p,n], [b,h]
        new = prev * dec[..., None, None] + st
        return new, prev                                  # emit state BEFORE chunk

    init = jnp.zeros((b, h, p, n), x.dtype)
    final, prev_states = jax.lax.scan(
        scan_body, init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)    # [b,nc,h,p,n]
    # 4. inter-chunk contribution to outputs
    state_decay = jnp.exp(dA_cs)                          # [b,nc,h,l]
    y_off = jnp.einsum("bclgn,bchpn,bchl->bclhp",
                       Cc, jnp.repeat(prev_states, 1, axis=2), state_decay)
    y = (y_diag + y_off).reshape(b, s, h, p)
    return y, final


def mamba_layer(x: Array, p: Dict, cfg: ModelConfig, chunk: int = 256) -> Array:
    """Full mamba2 block: in_proj → conv → SSD → gate·norm → out_proj."""
    b, s, d = x.shape
    di, st, nh, hd = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    xz = x @ p["w_in"].astype(x.dtype)
    z, xi, B, C, dt = _split_proj(xz, cfg)
    conv_in = jnp.concatenate([xi, B, C], axis=-1)
    conv_out = jax.nn.silu(_causal_conv(conv_in, p["conv_w"].astype(x.dtype),
                                        p["conv_b"].astype(x.dtype)))
    xi, B, C = jnp.split(conv_out, [di, di + NGROUPS * st], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    ck = min(chunk, s)
    y, _ = ssd_chunked(
        xi.reshape(b, s, nh, hd).astype(jnp.float32),
        dt, A,
        B.reshape(b, s, NGROUPS, st).astype(jnp.float32),
        C.reshape(b, s, NGROUPS, st).astype(jnp.float32),
        ck,
    )
    y = y + xi.reshape(b, s, nh, hd).astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(b, s, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_w"])
    return y @ p["w_out"].astype(x.dtype)


# ---------------------------------------------------------------------------
# decode (O(1) state update)
# ---------------------------------------------------------------------------
def mamba_init_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    di, st, nh, hd = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    conv_dim = di + 2 * NGROUPS * st
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
        "ssd": jnp.zeros((batch, nh, hd, st), jnp.float32),
    }


def mamba_decode_step(x, p, cfg: ModelConfig, cache: Dict) -> Tuple[Array, Dict]:
    """x [B, 1, D] → (y [B, 1, D], new cache)."""
    b = x.shape[0]
    di, st, nh, hd = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    xz = x[:, 0] @ p["w_in"].astype(x.dtype)              # [B, ...]
    z, xi, B, C, dt = _split_proj(xz, cfg)
    conv_in = jnp.concatenate([xi, B, C], axis=-1)        # [B, conv_dim]
    window = jnp.concatenate([cache["conv"], conv_in[:, None, :]], axis=1)  # [B,K,cd]
    w = p["conv_w"].astype(x.dtype)
    conv_out = jax.nn.silu((window * w[None]).sum(1) + p["conv_b"].astype(x.dtype))
    xi, B, C = jnp.split(conv_out, [di, di + NGROUPS * st], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, :])   # [B,nh]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xi.reshape(b, nh, hd).astype(jnp.float32)
    Bg = B.reshape(b, NGROUPS, st).astype(jnp.float32)
    Cg = C.reshape(b, NGROUPS, st).astype(jnp.float32)
    hpg = nh // NGROUPS
    Bh = jnp.repeat(Bg, hpg, axis=1)                      # [B,nh,st]
    Ch = jnp.repeat(Cg, hpg, axis=1)
    decay = jnp.exp(dt * A[None, :])                      # [B,nh]
    state = cache["ssd"] * decay[..., None, None] \
        + (dt[..., None] * xh)[..., None] * Bh[:, :, None, :]   # [B,nh,hd,st]
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch) + xh * p["D"][None, :, None]
    y = y.reshape(b, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_w"])
    out = (y @ p["w_out"].astype(x.dtype))[:, None, :]
    return out, {"conv": window[:, 1:], "ssd": state}
