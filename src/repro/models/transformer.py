"""Unified model assembly: every assigned architecture behind one API.

``build_model(cfg)`` returns a ``ModelApi`` of pure functions:

  init_params(key)                      → pytree (f32 master params)
  forward(params, batch)                → logits [B,S,Vp]           (train fwd)
  loss_fn(params, batch)                → scalar                     (train)
  init_cache(batch, dtype)              → decode cache pytree
  prefill(params, batch)                → (last_logits [B,Vp], cache)
  decode_step(params, token, pos, cache)→ (logits [B,Vp], cache)

Layer structure is compressed into periodic segments (models/common.find_segments)
so one lax.scan body covers each segment with *static* per-layer windows —
compile-time O(1) in depth, and local-attention layers get true sub-quadratic
compute (sliced K/V), not just masking.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MAMBA, ModelConfig
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.common import find_segments, init_norm, norm, split_keys

Array = jax.Array


class ModelApi(NamedTuple):
    cfg: ModelConfig
    init_params: Any
    forward: Any
    loss_fn: Any
    init_cache: Any
    prefill: Any
    decode_step: Any


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------
def _init_layer(key, cfg: ModelConfig, window: int, with_cross: bool) -> Dict:
    ks = split_keys(key, 6)
    if window == MAMBA:
        return {
            "ln1": init_norm(cfg.d_model, cfg.norm),
            "mamba": ssm_mod.init_mamba(ks[0], cfg),
        }
    p = {
        "ln1": init_norm(cfg.d_model, cfg.norm),
        "attn": attn_mod.init_attention(ks[0], cfg),
        "ln2": init_norm(cfg.d_model, cfg.norm),
    }
    if cfg.num_experts:
        p["moe"] = moe_mod.init_moe(ks[1], cfg)
    else:
        p["mlp"] = moe_mod.init_mlp(ks[1], cfg)
    if cfg.post_norms:
        p["post_ln1"] = init_norm(cfg.d_model, cfg.norm)
        p["post_ln2"] = init_norm(cfg.d_model, cfg.norm)
    if with_cross:
        p["ln_cross"] = init_norm(cfg.d_model, cfg.norm)
        p["cross"] = attn_mod.init_attention(ks[2], cfg)
    return p


def init_params(key, cfg: ModelConfig) -> Dict:
    segments = find_segments(cfg.layer_pattern)
    is_encdec = cfg.enc_layers > 0
    keys = split_keys(key, 16)
    d = cfg.d_model
    params: Dict[str, Any] = {
        "embed": jax.random.normal(keys[0], (cfg.padded_vocab, d), jnp.float32) * 0.02,
        "final_norm": init_norm(d, cfg.norm),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = jax.random.normal(keys[1], (d, cfg.padded_vocab), jnp.float32) * 0.02
    if cfg.learned_pos:
        # sized for the largest non-long decode/prefill shape (32k + headroom);
        # real whisper caps at 448 — extended for shape compliance (DESIGN §9)
        params["pos_embed"] = jax.random.normal(keys[2], (36864, d), jnp.float32) * 0.01
    # decoder segments (stacked [reps, g, ...])
    segs = []
    kseg = split_keys(keys[3], len(segments))
    for (group, reps), ks in zip(segments, kseg):
        layer_keys = split_keys(ks, reps * len(group))
        stacked = []
        for r in range(reps):
            row = [
                _init_layer(layer_keys[r * len(group) + j], cfg, w, is_encdec)
                for j, w in enumerate(group)
            ]
            stacked.append(jax.tree.map(lambda *xs: jnp.stack(xs), *row))
        segs.append(jax.tree.map(lambda *xs: jnp.stack(xs), *stacked))
    params["segments"] = segs
    if cfg.shared_attn_every:  # zamba2 shared attention block (weight-tied)
        shared_cfg = cfg
        params["shared_attn"] = {
            "ln1": init_norm(d, cfg.norm),
            "attn": attn_mod.init_attention(keys[4], shared_cfg),
            "ln2": init_norm(d, cfg.norm),
            "mlp": moe_mod.init_mlp(keys[5], shared_cfg),
        }
    if is_encdec:  # whisper encoder
        enc_keys = split_keys(keys[6], cfg.enc_layers)
        rows = [
            {
                "ln1": init_norm(d, cfg.norm),
                "attn": attn_mod.init_attention(k, cfg),
                "ln2": init_norm(d, cfg.norm),
                "mlp": moe_mod.init_mlp(jax.random.fold_in(k, 1), cfg),
            }
            for k in enc_keys
        ]
        params["encoder"] = jax.tree.map(lambda *xs: jnp.stack(xs), *rows)
        params["enc_pos"] = jax.random.normal(keys[7], (cfg.enc_len, d), jnp.float32) * 0.01
        params["enc_final_norm"] = init_norm(d, cfg.norm)
    if cfg.num_patches:  # phi-3-vision patch projector (stub frontend adapter)
        params["patch_proj"] = jax.random.normal(keys[8], (d, d), jnp.float32) / math.sqrt(d)
    return params


# ---------------------------------------------------------------------------
# layer application (forward)
# ---------------------------------------------------------------------------
def _apply_layer(h, lp, cfg: ModelConfig, window: int, enc_out, causal=True):
    if window == MAMBA:
        return h + ssm_mod.mamba_layer(norm(h, lp["ln1"], cfg.norm), lp["mamba"], cfg)
    a = attn_mod.attention(norm(h, lp["ln1"], cfg.norm), lp["attn"], cfg,
                           window=window, causal=causal)
    if cfg.post_norms:
        a = norm(a, lp["post_ln1"], cfg.norm)
    h = h + a
    if enc_out is not None and "cross" in lp:
        ek = (enc_out @ lp["cross"]["wk"].astype(h.dtype)).reshape(
            enc_out.shape[0], enc_out.shape[1], cfg.num_kv_heads, cfg.head_dim)
        ev = (enc_out @ lp["cross"]["wv"].astype(h.dtype)).reshape(
            enc_out.shape[0], enc_out.shape[1], cfg.num_kv_heads, cfg.head_dim)
        c = attn_mod.cross_attention_cached(
            norm(h, lp["ln_cross"], cfg.norm), lp["cross"], cfg, ek, ev)
        h = h + c
    mi = norm(h, lp["ln2"], cfg.norm)
    m = moe_mod.moe_ffn(mi, lp["moe"], cfg) if cfg.num_experts else \
        moe_mod.mlp(mi, lp["mlp"], cfg)
    if cfg.post_norms:
        m = norm(m, lp["post_ln2"], cfg.norm)
    return h + m


def _shared_attn_block(h, sp, cfg: ModelConfig):
    a = attn_mod.attention(norm(h, sp["ln1"], cfg.norm), sp["attn"], cfg,
                           window=0, causal=True)
    h = h + a
    return h + moe_mod.mlp(norm(h, sp["ln2"], cfg.norm), sp["mlp"], cfg)


def _run_decoder_stack(params, h, cfg: ModelConfig, enc_out=None, remat=False):
    """Apply all decoder layers to hidden h (shared-attn interleave for zamba)."""
    segments = find_segments(cfg.layer_pattern)
    if cfg.shared_attn_every:
        return _run_zamba_stack(params, h, cfg, remat)
    from repro.distributed.sharding import shard_activation

    for seg_params, (group, reps) in zip(params["segments"], segments):
        def body(carry, layer_slice, group=group):
            hh = carry
            for j, w in enumerate(group):
                lp = jax.tree.map(lambda a: a[j], layer_slice)
                hh = shard_activation(_apply_layer(hh, lp, cfg, w, enc_out))
            return hh, None

        scan_body = jax.checkpoint(body) if remat else body
        h, _ = jax.lax.scan(scan_body, h, seg_params)
    return h


def _run_zamba_stack(params, h, cfg: ModelConfig, remat=False):
    """zamba2: shared attention block every `shared_attn_every` mamba layers."""
    seg_params = params["segments"][0]  # [L, 1, ...] stacked mamba layers
    L = cfg.num_layers
    every = cfg.shared_attn_every

    def mamba_body(carry, layer_slice):
        lp = jax.tree.map(lambda a: a[0], layer_slice)
        return _apply_layer(carry, lp, cfg, MAMBA, None), None

    body = jax.checkpoint(mamba_body) if remat else mamba_body
    for start in range(0, L, every):
        h = _shared_attn_block(h, params["shared_attn"], cfg)
        stop = min(start + every, L)
        chunk = jax.tree.map(lambda a: a[start:stop], seg_params)
        h, _ = jax.lax.scan(body, h, chunk)
    return h


def _embed_inputs(params, batch, cfg: ModelConfig):
    """tokens (+ stub-frontend embeddings) → initial hidden states [B,S,D]."""
    tok = batch["tokens"]
    h = params["embed"].astype(cfg.act_dtype)[tok]
    if cfg.embed_scale:
        h = h * jnp.asarray(math.sqrt(cfg.d_model), h.dtype)
    if cfg.num_patches and "patches" in batch:
        patches = batch["patches"].astype(h.dtype) @ params["patch_proj"].astype(h.dtype)
        h = jnp.concatenate([patches, h], axis=1)
    if cfg.learned_pos:
        s = h.shape[1]
        h = h + params["pos_embed"][:s][None].astype(h.dtype)
    from repro.distributed.sharding import shard_activation
    return shard_activation(h)


def _run_encoder(params, frames, cfg: ModelConfig):
    """whisper encoder over precomputed frame embeddings (stub conv frontend)."""
    h = frames.astype(cfg.act_dtype) + params["enc_pos"][None, : frames.shape[1]].astype(cfg.act_dtype)

    def body(carry, lp):
        return _apply_layer(carry, lp, cfg, 0, None, causal=False), None

    h, _ = jax.lax.scan(body, h, params["encoder"])
    return norm(h, params["enc_final_norm"], cfg.norm)


def _logits(params, h, cfg: ModelConfig):
    w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = h.astype(jnp.float32) @ w.astype(jnp.float32)
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return logits


# ---------------------------------------------------------------------------
# public API builders
# ---------------------------------------------------------------------------
def build_model(cfg: ModelConfig, remat: bool = True) -> ModelApi:
    is_encdec = cfg.enc_layers > 0

    def forward(params, batch):
        h = _embed_inputs(params, batch, cfg)
        enc_out = _run_encoder(params, batch["frames"], cfg) if is_encdec else None
        h = _run_decoder_stack(params, h, cfg, enc_out, remat=remat)
        h = norm(h, params["final_norm"], cfg.norm)
        return _logits(params, h, cfg)

    def loss_fn(params, batch):
        logits = forward(params, batch)
        targets = batch["targets"]
        if cfg.num_patches and "patches" in batch:
            # patch positions carry no next-token loss
            logits = logits[:, cfg.num_patches:]
        valid = (targets >= 0)
        logz = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(
            logits, jnp.maximum(targets, 0)[..., None], axis=-1)[..., 0]
        nll = (logz - tgt) * valid
        return nll.sum() / jnp.maximum(valid.sum(), 1)

    from repro.models.decode import build_decode_fns  # late import (cycle)

    init_cache, prefill, decode_step = build_decode_fns(cfg, _embed_inputs,
                                                        _run_encoder, _logits)

    return ModelApi(
        cfg=cfg,
        init_params=functools.partial(init_params, cfg=cfg),
        forward=forward,
        loss_fn=loss_fn,
        init_cache=init_cache,
        prefill=prefill,
        decode_step=decode_step,
    )
