"""PPR query-serving subsystem — the paper's architecture as a service.

The paper motivates PPR as "a common building block of recommender systems"
and optimizes for query throughput, not exact convergence.  This package turns
the numeric core (repro.core: float / bit-exact fixed / Pallas / sharded SpMV
+ batched PPR) into that consumer-facing system: a multi-tenant query service
handling heavy traffic, the ROADMAP north star.

DESIGN — component ↔ paper section map
--------------------------------------
``service.py``    The facade.  Registers named graphs once (device placement,
                  packet padding, per-format quantization — the paper's §3
                  preprocessing, amortized across a graph's lifetime), accepts
                  ``PPRQuery(vertex, k, precision, deadline)`` and returns a
                  ``PPRFuture`` per ``submit`` that resolves to a ranked
                  ``Recommendation`` when its wave completes (``poll``/
                  ``flush`` drive launches; ``serve``/``pump``/``drain`` are
                  deprecated blocking wrappers).  Per-query ``precision`` is
                  the serving-side realization of §5.3's bit-width/accuracy
                  dial.
``engine/``       The pluggable datapath layer — the paper's own seam between
                  the host-side streaming front-end and interchangeable
                  reduced-precision SpMV datapaths.  ``WaveEngine.plan``
                  binds each wave to a backend ("float"/"fixed" single-device
                  or their mesh-sharded counterparts); new layouts plug in as
                  registered engines instead of service branches.
``futures.py``    ``PPRFuture``: done()/result()/add_done_callback(), resolved
                  by wave completion, rejected (``QueryRejected``) instead of
                  dangling when re-registration or a delta invalidates the
                  pending query.
``graphs.py``     Registered-graph state: host topology, packet padding, raw
                  quantization caches, and the host-side incremental delta
                  merge the engines refresh device state from.
``scheduler.py``  κ-batch admission waves (§5.1's κ-batching as an *admission
                  policy*): one wave amortizes a full edge-stream pass over up
                  to κ personalization columns.  Deadline-aware flush launches
                  partially-full waves so sparse traffic keeps bounded latency
                  — the occupancy/latency trade-off the FPGA design implies
                  but never had to schedule.
``topk.py``       Streaming top-K over the [V, κ] rank matrix (the authors'
                  Top-K SpMV follow-up, arXiv 2103.04808): dense ``lax.top_k``
                  path plus a padded-tile O(k)-state streaming merge that works
                  directly on the raw uint32 fixed-point domain (§4.1) — rank
                  order is monotone in the raw encoding, so results never need
                  dequantizing to be ranked.
``cache.py``      LRU result cache keyed (graph, vertex, precision, k): repeat
                  queries skip the §4 iteration pipeline entirely — the layer
                  a hardware paper omits but a service cannot.
``telemetry.py``  Wave latency, queries/s, batch occupancy, cache hit-rate —
                  the serving analogues of the paper's Table 2 / Fig. 3
                  throughput accounting.

The adaptive-precision subsystem (repro.autotune) plugs in here:
``precision="auto"`` queries are resolved to the cheapest Q format meeting a
per-query quality target before wave admission, waves early-exit at the
fixed-point absorbing state (paper Fig. 7), and a sampled fraction of served
auto queries is shadow-scored against a float32 reference to keep the
controller honest.

Multi-host sharded serving: ``register_graph(..., mesh=...)`` partitions the
edge stream by destination range over a ``jax.sharding.Mesh`` axis at
registration (``ShardedRegisteredGraph``) and routes the graph's waves through
the sharded step bodies of ``repro.core.ppr`` — wave keys are
``(graph, precision, mesh_key)``, so meshed and single-device traffic never
mix in one wave, and telemetry counts waves/queries per mesh layout.  The
fixed-point sharded path is bit-identical to single-device serving (raw-domain
accumulation is exact); the float path is numerically equal.

Dynamic graph updates (repro.graph_updates): ``PPRService.apply_delta`` merges
batched edge insertions/deletions and vertex growth into a live registered
graph — epoch-versioned, with *scoped* invalidation (only cache entries and
pending queries whose personalization vertex falls in the delta's affected
frontier are dropped; the rest are retagged to the new epoch and keep
serving), incremental requantization of only the changed edge values per
pre-registered Q format, per-bucket repartition on meshes, and warm-start
iteration seeding from each vertex's last converged column
(``warm_start=True``) so the convergence monitor exits waves early after an
update.

The HTTP serving tier (``repro.ppr_serving.http``) fronts the futures API
over a network: an asyncio pump drives ``poll()`` on deadline, ``POST
/v1/ppr`` maps onto ``submit()`` and awaits the ``PPRFuture``, and an
admission controller meters overload in escalating order — deepen κ
(backpressure batching), degrade ``precision="auto"`` quality targets
(SLO-aware: serve 0.93 instead of 0.95 while the queue is deep), then shed
with 429 + Retry-After past the high-water mark — every decision counted in
telemetry and surfaced by ``/v1/stats``.

``prefetch.py`` closes the ROADMAP's async-prefetch follow-on: during idle
polls the service issues synthetic queries for predicted-hot uncached
personalization vertices at the precision controller's currently resolved
format, and re-warms hot entries a delta's scoped invalidation dropped.
Demand counts decay exponentially under a configurable half-life
(``PrefetchConfig.half_life_s``), so hotness tracks recent traffic instead of
lifetime totals.
"""
from repro.ppr_serving.cache import LRUCache
from repro.ppr_serving.engine import (
    FixedEngine,
    FloatEngine,
    PallasFixedEngine,
    PallasFloatEngine,
    PallasRegisteredGraph,
    ShardedFixedEngine,
    ShardedFloatEngine,
    WaveEngine,
    WavePlan,
    engine_families,
    engine_for,
    engine_names,
    family_members,
    get_engine,
    register_engine,
)
from repro.ppr_serving.futures import PPRFuture, QueryRejected
from repro.ppr_serving.http import (
    AdmissionConfig,
    AdmissionController,
    PPRHTTPServer,
    ServingApp,
    WavePump,
)
from repro.ppr_serving.graphs import RegisteredGraph, ShardedRegisteredGraph
from repro.ppr_serving.prefetch import PrefetchConfig, Prefetcher
from repro.ppr_serving.scheduler import Wave, WaveScheduler
from repro.ppr_serving.service import (
    AUTO_KEY,
    FLOAT_KEY,
    PPRQuery,
    PPRService,
    Recommendation,
    normalize_precision,
    precision_key,
)
from repro.ppr_serving.telemetry import SINGLE_DEVICE_KEY, ServiceTelemetry
from repro.ppr_serving.topk import topk_dense, topk_streaming

__all__ = [
    "PPRService", "PPRQuery", "Recommendation", "PPRFuture", "QueryRejected",
    "PPRHTTPServer", "ServingApp", "AdmissionConfig", "AdmissionController",
    "WavePump",
    "RegisteredGraph", "ShardedRegisteredGraph", "PallasRegisteredGraph",
    "WaveEngine", "WavePlan",
    "register_engine", "get_engine", "engine_for", "family_members",
    "engine_names", "engine_families",
    "FloatEngine", "FixedEngine", "ShardedFloatEngine", "ShardedFixedEngine",
    "PallasFloatEngine", "PallasFixedEngine",
    "normalize_precision", "precision_key", "AUTO_KEY", "FLOAT_KEY",
    "SINGLE_DEVICE_KEY",
    "WaveScheduler", "Wave",
    "LRUCache", "ServiceTelemetry",
    "PrefetchConfig", "Prefetcher",
    "topk_dense", "topk_streaming",
]
