"""`WaveEngine` protocol + `WavePlan` + the engine registry.

An engine is the pluggable datapath behind the serving API: it owns how a
registered graph's device state is prepared (quantization, partitioning,
uploads), how one eq. (1) iteration steps, how a wave's iterations are driven
(fixed budget or early-exit), and how the rank matrix is reduced to top-K.
The service knows none of that — it asks the graph's engine for a
``WavePlan`` and runs it.

Engines are stateless singletons; all per-graph state (host arrays, device
uploads, shard buckets) lives on the ``RegisteredGraph`` they operate on, so
one engine instance serves every graph and the registry can hand out shared
instances.

Registry layout: every concrete engine registers under its own ``key``
("float", "fixed", "sharded_float", "sharded_fixed", ...) and into a *family*
("single", "sharded") with one float and one fixed member — a graph is
registered onto a family (``register_graph(..., engine="sharded")``) and each
wave resolves to the family's member for its precision, so float and fixed
traffic on one graph share host state but run their own datapaths.  New
backends (multi-channel layouts per arXiv 2103.04808, future Pallas kernels)
plug in as new families without touching the service.
"""
from __future__ import annotations

import abc
import dataclasses
from typing import Any, Callable, ClassVar, Dict, Optional, Tuple

from repro.autotune.convergence import ConvergencePolicy, run_until_converged
from repro.core.fixed_point import QFormat
from repro.ppr_serving.topk import topk_dense, topk_streaming

__all__ = [
    "WavePlan", "WaveEngine",
    "register_engine", "get_engine", "engine_for", "family_members",
    "engine_names", "engine_families",
]


@dataclasses.dataclass
class WavePlan:
    """Everything one wave needs, bound to device state by an engine.

    ``engine``   the concrete engine key (telemetry label).
    ``fixed``    raw uint32 domain (True) or float32 (False).
    ``scale``    ``fmt.scale`` for fixed plans (dequantization divisor), else None.
    ``initial``  pers [κ] int32 → P0 [V, κ] (one-hot personalization matrix).
    ``step``     (Vmat, P) → P_next, one eq. (1) iteration on the engine's
                 device arrays.
    ``iterate``  (step_closure, P0) → (P_final, iterations_run); drives the
                 wave's iterations, early-exiting when the engine was planned
                 with a convergence policy.
    ``topk``     (P, k_max, exclude) → (idx [κ, k], vals [κ, k]) ranked with
                 the query vertex excluded.
    """
    engine: str
    fixed: bool
    scale: Optional[int]
    initial: Callable[[Any], Any]
    step: Callable[[Any, Any], Any]
    iterate: Callable[[Callable[[Any], Any], Any], Tuple[Any, int]]
    topk: Callable[[Any, int, Optional[Any]], Tuple[Any, Any]]


class WaveEngine(abc.ABC):
    """One datapath backend: prepare device state, plan waves, absorb deltas.

    Subclasses set ``key`` (registry name), ``family`` (engine pair a graph
    registers onto) and ``fixed`` (which precision domain the engine serves),
    and implement ``prepare``/``plan``/``on_delta``.
    """

    key: ClassVar[str]
    family: ClassVar[str]
    fixed: ClassVar[bool]
    #: family needs a ``jax.sharding.Mesh`` at registration
    needs_mesh: ClassVar[bool] = False

    def make_graph(self, name: str, g, packet: int = 256,
                   mesh=None, mesh_axis: Optional[str] = None):
        """Construct the graph-state holder this engine family serves.

        The service calls the family's float member at registration, so a
        new family can carry its own ``RegisteredGraph`` subclass (extra host
        state, different partitioning) without a ``service.py`` edit — the
        same seam ``plan``/``on_delta`` provide for the datapath."""
        from repro.ppr_serving.graphs import (RegisteredGraph,
                                              ShardedRegisteredGraph)
        if self.needs_mesh:
            return ShardedRegisteredGraph(name, g, mesh, axis=mesh_axis,
                                          packet=packet)
        return RegisteredGraph(name, g, packet=packet)

    @abc.abstractmethod
    def prepare(self, rg, fmt: Optional[QFormat] = None) -> None:
        """Materialize the device state ``plan`` will bind (uploads,
        quantization, partitioning).  Called at registration for every
        pre-registered format and lazily from ``plan`` for late formats."""

    @abc.abstractmethod
    def plan(self, rg, fmt: Optional[QFormat] = None, *, alpha: float,
             iterations: int,
             convergence: Optional[ConvergencePolicy] = None,
             topk_tile: Optional[int] = None,
             trace_hook: Optional[Callable[[Dict[str, Any]], None]] = None
             ) -> WavePlan:
        """Bind a ``WavePlan`` to ``rg``'s current device state.

        ``trace_hook``, when given, receives one dict per ``iterate`` call
        with the convergence internals a trace wants (``iterations_run``,
        ``budget``, ``early_exit``, and the final per-iteration ``residual``
        when an early-exit policy is active).  Tracking residuals costs
        device syncs, so the hook — not the service — decides whether the
        monitor runs with ``track_deltas``; a hookless plan pays nothing."""

    @abc.abstractmethod
    def on_delta(self, rg, info) -> None:
        """Refresh the engine's device state after a host-side edge-delta
        merge (``rg.apply_delta``).  Must be idempotent — both members of a
        family are armed on most graphs and each gets the callback."""

    # ------------------------------------------------------------------
    # shared drivers
    def _make_iterate(self, iterations: int,
                      convergence: Optional[ConvergencePolicy],
                      fixed: bool, scale: Optional[int],
                      trace_hook=None):
        """Wave iteration driver: fixed budget, or early-exit under a policy.

        With a ``trace_hook``, convergence runs ``track_deltas=True`` (the
        per-iteration residuals cost host syncs — only a tracing wave pays
        them) and the hook receives the iterate's convergence internals."""
        if convergence is None:
            def iterate(step, P0):
                P = P0
                for _ in range(iterations):
                    P = step(P)
                if trace_hook is not None:
                    trace_hook({"iterations_run": iterations,
                                "budget": iterations, "early_exit": False})
                return P, iterations
            return iterate

        def iterate(step, P0):
            track = trace_hook is not None
            P, iters_run, deltas = run_until_converged(
                step, P0, iterations, convergence, fixed=fixed,
                scale=scale, track_deltas=track)  # hookless: skip the syncs
            if track:
                trace_hook({
                    "iterations_run": iters_run, "budget": iterations,
                    "early_exit": iters_run < iterations,
                    "residual": float(deltas[-1]) if deltas else None,
                })
            return P, iters_run
        return iterate

    def _make_topk(self, topk_tile: Optional[int]):
        if topk_tile is None:
            return lambda P, k, exclude: topk_dense(P, k, exclude=exclude)
        return lambda P, k, exclude: topk_streaming(P, k, v_tile=topk_tile,
                                                    exclude=exclude)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<{type(self).__name__} key={self.key!r} family={self.family!r}>"


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
_ENGINES: Dict[str, WaveEngine] = {}
_FAMILIES: Dict[str, Dict[bool, str]] = {}


def register_engine(cls):
    """Class decorator: instantiate and index the engine by key and family.

    Re-registering a key replaces the previous engine (deliberate: downstream
    code can swap a backend in tests or experiments)."""
    inst = cls()
    _ENGINES[cls.key] = inst
    _FAMILIES.setdefault(cls.family, {})[cls.fixed] = cls.key
    return cls


def get_engine(key: str) -> WaveEngine:
    """The concrete engine registered under ``key``."""
    if key not in _ENGINES:
        raise KeyError(f"no engine {key!r} registered "
                       f"(have {sorted(_ENGINES)})")
    return _ENGINES[key]


def engine_for(family: str, fixed: bool) -> WaveEngine:
    """The family member serving ``fixed`` (True) or float (False) waves."""
    if family not in _FAMILIES:
        raise KeyError(f"no engine family {family!r} registered "
                       f"(have {sorted(_FAMILIES)})")
    members = _FAMILIES[family]
    if fixed not in members:
        raise KeyError(f"engine family {family!r} has no "
                       f"{'fixed' if fixed else 'float'} member")
    return _ENGINES[members[fixed]]


def family_members(family: str) -> Tuple[WaveEngine, ...]:
    """The registered members of ``family``, float member first when present.

    Fixed-only families are legal (e.g. a Pallas fixed-point kernel backend
    with no float counterpart): the service resolves family-level metadata
    (``needs_mesh``, ``make_graph``) through any member and requires a float
    member only when float traffic or a shadow reference actually needs it."""
    if family not in _FAMILIES:
        raise KeyError(f"no engine family {family!r} registered "
                       f"(have {sorted(_FAMILIES)})")
    members = _FAMILIES[family]
    return tuple(_ENGINES[members[fixed]] for fixed in sorted(members))


def engine_names() -> Tuple[str, ...]:
    """All registered concrete engine keys, sorted."""
    return tuple(sorted(_ENGINES))


def engine_families() -> Tuple[str, ...]:
    """All registered engine families (what ``register_graph(engine=...)``
    selects by name), sorted."""
    return tuple(sorted(_FAMILIES))
