"""Single-device engines: the paper's datapaths on one device's edge stream.

``FloatEngine`` is the F32 reference architecture; ``FixedEngine`` is the
reduced-precision datapath (truncating Qm.f multiplies, raw uint32
accumulation — bit-exact against the FPGA model).  Both bind the full-layout
device arrays the registered graph uploads once per topology epoch.
"""
from __future__ import annotations

from typing import Optional

from repro.core.fixed_point import QFormat
from repro.core.ppr import (
    make_ppr_fixed_step,
    personalization_matrix,
    personalization_matrix_fixed,
    ppr_step_float,
)
from repro.ppr_serving.engine.base import WaveEngine, WavePlan, register_engine

__all__ = ["FloatEngine", "FixedEngine"]


@register_engine
class FloatEngine(WaveEngine):
    """float32 eq. (1) iterations over the full-layout edge stream."""

    key = "float"
    family = "single"
    fixed = False

    def prepare(self, rg, fmt: Optional[QFormat] = None) -> None:
        rg.device_full()

    def plan(self, rg, fmt: Optional[QFormat] = None, *, alpha: float,
             iterations: int, convergence=None,
             topk_tile: Optional[int] = None, trace_hook=None) -> WavePlan:
        x, y, val = rg.device_full()
        dangling = rg.dangling
        num_vertices = rg.num_vertices

        def step(Vmat, P):
            return ppr_step_float(x, y, val, dangling, Vmat, P,
                                  num_vertices=num_vertices, alpha=alpha)

        return WavePlan(
            engine=self.key, fixed=False, scale=None,
            initial=lambda pers: personalization_matrix(num_vertices, pers),
            step=step,
            iterate=self._make_iterate(iterations, convergence, False, None,
                                       trace_hook=trace_hook),
            topk=self._make_topk(topk_tile))

    def on_delta(self, rg, info) -> None:
        rg.refresh_device_base()


@register_engine
class FixedEngine(WaveEngine):
    """Bit-exact reduced-precision iterations in one Q format's raw domain."""

    key = "fixed"
    family = "single"
    fixed = True

    def prepare(self, rg, fmt: Optional[QFormat] = None) -> None:
        if fmt is None:
            raise ValueError(f"{self.key!r} engine needs a concrete Q format")
        rg.quantized(fmt)

    def plan(self, rg, fmt: Optional[QFormat] = None, *, alpha: float,
             iterations: int, convergence=None,
             topk_tile: Optional[int] = None, trace_hook=None) -> WavePlan:
        if fmt is None:
            raise ValueError(f"{self.key!r} engine needs a concrete Q format")
        body = make_ppr_fixed_step(fmt, rg.num_vertices, alpha)
        x, y, _ = rg.device_full()
        val_raw = rg.quantized(fmt)
        dangling = rg.dangling
        num_vertices = rg.num_vertices

        def step(Vmat, P):
            return body(x, y, val_raw, dangling, Vmat, P)

        return WavePlan(
            engine=self.key, fixed=True, scale=fmt.scale,
            initial=lambda pers: personalization_matrix_fixed(
                num_vertices, pers, fmt),
            step=step,
            iterate=self._make_iterate(iterations, convergence, True, fmt.scale,
                                       trace_hook=trace_hook),
            topk=self._make_topk(topk_tile))

    def on_delta(self, rg, info) -> None:
        rg.refresh_device_base()
