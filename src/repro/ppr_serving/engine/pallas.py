"""Pallas-fused engines: one kernel launch per eq. (1) iteration.

The family (``register_graph(..., engine="pallas")``) serves the same waves
as the "single" family but through ``repro.kernels.fused_ppr``: SpMV, the
eq. (1) axpy, the dangling-mass fold and the (L1, ∞, Σd²) residual reduction
execute as a single ``pallas_call`` over the dst-major packetized edge
stream.  The fixed member is bit-identical (raw uint32) to ``FixedEngine``;
the float member matches ``FloatEngine`` to f32 accumulation-order noise.

State layout (on ``PallasRegisteredGraph``): the packetized ``FusedLayout``
plus device uploads of its schedule/topology, the float value rows, and one
raw uint32 value row-set per prepared Q format.  ``on_delta`` re-packetizes
only the dst blocks an edge delta touched (``changed_dst // v_tile``) —
per-block rebuilds are deterministic, so the incremental layout is
array-equal to a fresh registration of the merged graph — behind a staleness
latch (both family members are armed and each gets the callback).

The early-exit driver reuses the kernel's residual output instead of
``ConvergenceMonitor``'s separate device reductions, with identical exit
decisions: a zero ∞-residual *is* the monitor's exact integer equality (the
minimum nonzero raw diff, 1.0, is exactly representable in f32), period-2
cycles are still caught by comparing against S_{t-2}, and the parity of the
remaining budget picks the bit-identical return state.

Off-TPU the kernels run under ``interpret=True`` (slow, bit-exact), so the
family stays correct — and testable in CI — on CPU-only hosts.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.autotune.convergence import ConvergencePolicy, states_equal
from repro.core.coo import COOGraph
from repro.core.fixed_point import QFormat
from repro.core.ppr import personalization_matrix, personalization_matrix_fixed
from repro.kernels.fused_ppr import (
    assemble_value_rows,
    build_fused_layout,
    default_interpret,
    fused_ppr_iteration,
    quantize_layout_rows,
)
from repro.ppr_serving.engine.base import WaveEngine, WavePlan, register_engine
from repro.ppr_serving.graphs import RegisteredGraph

__all__ = ["PallasRegisteredGraph", "PallasFloatEngine", "PallasFixedEngine"]

DEFAULT_V_TILE = 512


class PallasRegisteredGraph(RegisteredGraph):
    """Registered graph carrying the fused dst-major packetized layout.

    Defers the full-layout upload (fused waves never read it; it is still
    materialized lazily for shadow scoring through the base class) and owns
    the fused caches: the host ``FusedLayout``, its device schedule/topology,
    the float value rows, and per-format raw uint32 value rows."""

    engine_family = "pallas"

    _defer_full_upload = True

    def __init__(self, name: str, g: COOGraph, packet: int = 256,
                 v_tile: int = DEFAULT_V_TILE):
        self.v_tile = int(v_tile)
        self._fused_layout = None
        self._fused_dev = None                 # schedule + topology uploads
        self._fused_val_dev = {}               # None | QFormat → [rows, packet]
        self._fused_raw_rows = {}              # QFormat → per-dst-block rows
        self._fused_stale = False
        self._fused_full_rebuild = False
        self._fused_dirty: set = set()
        super().__init__(name, g, packet=packet)

    # ---- fused caches ------------------------------------------------------
    def fused_layout(self):
        if self._fused_layout is None:
            self._fused_layout = build_fused_layout(self.source, self.v_tile,
                                                    self.packet)
        return self._fused_layout

    def fused_topology(self):
        """Device uploads of the schedule + localized edge topology."""
        if self._fused_dev is None:
            lay = self.fused_layout()
            dang = np.zeros((lay.n_blk * lay.v_tile, 1), np.float32)
            dang[:self.num_vertices, 0] = np.asarray(self.graph.dangling,
                                                     np.float32)
            self._fused_dev = {
                "step_row": jnp.asarray(lay.step_row),
                "step_dst": jnp.asarray(lay.step_dst),
                "step_src": jnp.asarray(lay.step_src),
                "step_first": jnp.asarray(lay.step_first),
                "step_last": jnp.asarray(lay.step_last),
                "x2": jnp.asarray(lay.x2),
                "y2": jnp.asarray(lay.y2),
                "dang": jnp.asarray(dang),
            }
        return self._fused_dev

    def fused_values(self, fmt: Optional[QFormat] = None):
        """[num_rows, packet] value operand — f32 (fmt=None) or raw uint32."""
        if fmt not in self._fused_val_dev:
            lay = self.fused_layout()
            if fmt is None:
                self._fused_val_dev[fmt] = jnp.asarray(lay.val2)
            else:
                rows = quantize_layout_rows(lay, fmt)
                self._fused_raw_rows[fmt] = rows
                self._fused_val_dev[fmt] = jnp.asarray(
                    assemble_value_rows(rows, lay.packet))
        return self._fused_val_dev[fmt]

    # ---- delta ingestion ---------------------------------------------------
    def apply_delta(self, delta):
        """Host merge plus dirty-dst-block tracking for the fused layout.

        ``changed_dst`` covers every destination whose incident edge set or
        edge values moved (including removed edges' old rows); vertex growth
        that changes the block count forces a full re-packetization."""
        info = super().apply_delta(delta)
        if self._fused_layout is not None:
            n_blk = max(1, -(-self.num_vertices // self.v_tile))
            if n_blk != self._fused_layout.n_blk:
                self._fused_full_rebuild = True
            else:
                self._fused_dirty.update(
                    int(b) for b in np.unique(info.changed_dst // self.v_tile))
            self._fused_stale = True
        return info

    def refresh_fused(self) -> None:
        """Re-packetize dirty dst blocks and re-upload the fused caches.
        Idempotent across the family's two armed engines (staleness latch)."""
        if not self._fused_stale:
            return
        self._fused_stale = False
        old, dirty = self._fused_layout, self._fused_dirty
        self._fused_dirty = set()
        full = self._fused_full_rebuild or old is None
        self._fused_full_rebuild = False
        lay = build_fused_layout(self.source, self.v_tile, self.packet,
                                 reuse=None if full else old,
                                 dirty=None if full else dirty)
        self._fused_layout = lay
        self._fused_dev = None
        new_vals, new_rows = {}, {}
        for fmt, rows_old in self._fused_raw_rows.items():
            rows = quantize_layout_rows(lay, fmt,
                                        reuse_rows=None if full else rows_old,
                                        dirty=None if full else dirty)
            new_rows[fmt] = rows
            new_vals[fmt] = jnp.asarray(assemble_value_rows(rows, lay.packet))
        if None in self._fused_val_dev:
            new_vals[None] = jnp.asarray(lay.val2)
        self._fused_raw_rows = new_rows
        self._fused_val_dev = new_vals
        self.fused_topology()


# ---------------------------------------------------------------------------
# wave plumbing
# ---------------------------------------------------------------------------
def _bind_fused_step(rg: PallasRegisteredGraph, fmt: Optional[QFormat],
                     alpha: float, cell: dict):
    """Step closure over the graph's current fused device state.  Each launch
    parks the kernel's [3, K] residual in ``cell`` for the iterate driver."""
    lay = rg.fused_layout()
    dev = rg.fused_topology()
    val2 = rg.fused_values(fmt)
    statics = dict(v_tile=lay.v_tile, packet=lay.packet, n_blk=lay.n_blk,
                   num_steps=lay.num_steps, num_vertices=lay.num_vertices,
                   alpha=alpha, fmt=fmt, interpret=default_interpret())

    def step(Vmat, P):
        P_next, res = fused_ppr_iteration(
            dev["step_row"], dev["step_dst"], dev["step_src"],
            dev["step_first"], dev["step_last"],
            dev["x2"], dev["y2"], val2, dev["dang"], Vmat, P, **statics)
        cell["res"] = res
        return P_next

    return step


def _residual_delta(res, scale: Optional[int]) -> float:
    """max-over-columns L2 state change in value units (``wave_delta`` on the
    kernel's Σd² row — max ∘ sqrt = sqrt ∘ max)."""
    d = float(jnp.sqrt(res[2].max()))
    return d / scale if scale else d


def _make_fused_iterate(engine: WaveEngine, iterations: int,
                        convergence: Optional[ConvergencePolicy],
                        fixed: bool, scale: Optional[int], cell: dict,
                        trace_hook=None):
    """The ``run_until_converged`` contract driven off the kernel's fused
    residual: same check cadence, same exit conditions, same parity-correct
    return states as ``ConvergenceMonitor`` — without its per-check
    full-array device comparisons (the ∞-residual is already on device)."""
    if convergence is None:
        return engine._make_iterate(iterations, None, fixed, scale,
                                    trace_hook=trace_hook)
    pol = convergence
    track = trace_hook is not None

    def finish(P, t, deltas):
        if track:
            trace_hook({
                "iterations_run": t, "budget": iterations,
                "early_exit": t < iterations,
                "residual": float(deltas[-1]) if deltas else None,
            })
        return P, t

    def iterate(step, P0):
        deltas = []
        P, prev2 = P0, None
        for t in range(1, iterations + 1):
            P_next = step(P)
            res = cell["res"]
            checking = t % pol.check_every == 0
            prev2, prev2_at_check = (P, prev2) if fixed else (None, None)
            if checking:
                if fixed:
                    # zero ∞-residual ⇔ exact integer state equality: raw
                    # diffs are whole numbers, the smallest nonzero one (1.0)
                    # is exactly representable in f32 and a max never rounds
                    # a nonzero operand to zero.
                    strict = bool(res[1].max() == 0.0)
                    if track:
                        deltas.append(0.0 if strict else
                                      _residual_delta(res, scale))
                    if t >= pol.min_iterations:
                        if strict:
                            return finish(P_next, t, deltas)
                        if prev2_at_check is not None and states_equal(
                                P_next, prev2_at_check):
                            # period-2 absorbing cycle: parity of the
                            # remaining budget picks the bit-identical state
                            if (iterations - t) % 2 != 0:
                                return finish(P, t, deltas)
                            return finish(P_next, t, deltas)
                else:
                    delta = _residual_delta(res, scale)
                    deltas.append(delta)
                    if t >= pol.min_iterations and delta < pol.epsilon:
                        return finish(P_next, t, deltas)
            P = P_next
        return finish(P, iterations, deltas)

    return iterate


# ---------------------------------------------------------------------------
# the engines
# ---------------------------------------------------------------------------
@register_engine
class PallasFloatEngine(WaveEngine):
    """float32 fused-launch iterations over the packetized edge stream."""

    key = "pallas_float"
    family = "pallas"
    fixed = False

    def make_graph(self, name: str, g, packet: int = 256,
                   mesh=None, mesh_axis=None):
        return PallasRegisteredGraph(name, g, packet=packet)

    def prepare(self, rg, fmt: Optional[QFormat] = None) -> None:
        rg.fused_topology()
        rg.fused_values(None)

    def plan(self, rg, fmt: Optional[QFormat] = None, *, alpha: float,
             iterations: int, convergence=None,
             topk_tile: Optional[int] = None, trace_hook=None) -> WavePlan:
        self.prepare(rg)
        num_vertices = rg.num_vertices
        cell = {"res": None}
        return WavePlan(
            engine=self.key, fixed=False, scale=None,
            initial=lambda pers: personalization_matrix(num_vertices, pers),
            step=_bind_fused_step(rg, None, alpha, cell),
            iterate=_make_fused_iterate(self, iterations, convergence, False,
                                        None, cell, trace_hook=trace_hook),
            topk=self._make_topk(topk_tile))

    def on_delta(self, rg, info) -> None:
        rg.refresh_device_base()
        rg.refresh_fused()


@register_engine
class PallasFixedEngine(WaveEngine):
    """Bit-exact reduced-precision fused-launch iterations (raw uint32)."""

    key = "pallas_fixed"
    family = "pallas"
    fixed = True

    def make_graph(self, name: str, g, packet: int = 256,
                   mesh=None, mesh_axis=None):
        return PallasRegisteredGraph(name, g, packet=packet)

    def prepare(self, rg, fmt: Optional[QFormat] = None) -> None:
        if fmt is None:
            raise ValueError(f"{self.key!r} engine needs a concrete Q format")
        rg.fused_topology()
        rg.fused_values(fmt)

    def plan(self, rg, fmt: Optional[QFormat] = None, *, alpha: float,
             iterations: int, convergence=None,
             topk_tile: Optional[int] = None, trace_hook=None) -> WavePlan:
        if fmt is None:
            raise ValueError(f"{self.key!r} engine needs a concrete Q format")
        self.prepare(rg, fmt)
        num_vertices = rg.num_vertices
        cell = {"res": None}
        return WavePlan(
            engine=self.key, fixed=True, scale=fmt.scale,
            initial=lambda pers: personalization_matrix_fixed(
                num_vertices, pers, fmt),
            step=_bind_fused_step(rg, fmt, alpha, cell),
            iterate=_make_fused_iterate(self, iterations, convergence, True,
                                        fmt.scale, cell,
                                        trace_hook=trace_hook),
            topk=self._make_topk(topk_tile))

    def on_delta(self, rg, info) -> None:
        rg.refresh_device_base()
        rg.refresh_fused()
