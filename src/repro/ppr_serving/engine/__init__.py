"""Engine backends — the pluggable datapath layer behind `PPRService`.

DESIGN — why a backend protocol
-------------------------------
The paper's architecture is explicitly layered: a host-side streaming
front-end packages the edge stream, and interchangeable reduced-precision
SpMV datapaths iterate it (the CPU–FPGA synergy argument of arXiv
2004.13907).  This package is that seam in software: the serving front-end
(admission waves, futures, cache, telemetry) talks to a small ``WaveEngine``
protocol, and each datapath — float32 reference, bit-exact Qm.f fixed point,
and their mesh-sharded counterparts — is one backend behind it.

``WaveEngine.plan(graph, fmt) -> WavePlan`` binds a wave to device state: the
personalization-matrix builder, the one-iteration step over the engine's
device arrays, the iterate driver (fixed budget or early-exit), and the top-K
reduction.  ``prepare`` materializes device state at registration;
``on_delta`` refreshes it after an edge-delta merge (incremental
requantization upload, per-bucket repartition).

Engines register by name into *families* ("single", "sharded", "pallas")
with one float and one fixed member; ``PPRService.register_graph(...,
engine=...)`` selects a family, and every wave resolves to the member for
its precision.  The "pallas" family is the paper's fused single-launch
datapath (``repro.kernels.fused_ppr``); further datapaths — the
multi-channel layouts of arXiv 2103.04808, sharded top-K, P_t sharding —
plug in as new engines instead of new branches in the service.
"""
from repro.ppr_serving.engine.base import (
    WaveEngine,
    WavePlan,
    engine_families,
    engine_for,
    engine_names,
    family_members,
    get_engine,
    register_engine,
)
from repro.ppr_serving.engine.single import FixedEngine, FloatEngine
from repro.ppr_serving.engine.sharded import ShardedFixedEngine, ShardedFloatEngine
from repro.ppr_serving.engine.pallas import (
    PallasFixedEngine,
    PallasFloatEngine,
    PallasRegisteredGraph,
)

__all__ = [
    "WaveEngine", "WavePlan",
    "register_engine", "get_engine", "engine_for", "family_members",
    "engine_names", "engine_families",
    "FloatEngine", "FixedEngine",
    "ShardedFloatEngine", "ShardedFixedEngine",
    "PallasFloatEngine", "PallasFixedEngine", "PallasRegisteredGraph",
]
