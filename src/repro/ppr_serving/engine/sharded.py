"""Mesh-sharded engines: the paper's multi-channel edge partitioning scaled
to a ``jax.sharding.Mesh`` axis.

The host owns the partitioning/packaging step (the CPU–FPGA synergy argument
of arXiv 2004.13907): edges are bucketed by destination range once per
topology epoch — per quantized format too, through the same dtype-preserving
partitioner, so fixed-point shards stream the exact raw values the
single-device ``FixedEngine`` would.  Per-shard raw accumulation is exact and
each destination row lives on exactly one shard, so ``ShardedFixedEngine`` is
*bit-identical* to ``FixedEngine``; the float pair is numerically equal.

Delta ingestion re-buckets only the destination ranges a merge touched
(``refresh_partition_after_delta``), falling back to a full re-partition when
the delta moves the ceil-division layout itself (vertex growth changing
``ceil(V / n_shards)``) or an affected bucket outgrows its padding.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.fixed_point import QFormat
from repro.core.ppr import (
    make_ppr_sharded_fixed_step,
    make_ppr_sharded_float_step,
    personalization_matrix,
    personalization_matrix_fixed,
)
from repro.core.spmv import partition_edges_by_dst, sharded_vertex_layout
from repro.ppr_serving.engine.base import WaveEngine, WavePlan, register_engine

__all__ = ["ShardedFloatEngine", "ShardedFixedEngine"]


# ---------------------------------------------------------------------------
# partition state helpers — operate on a ShardedRegisteredGraph's buckets
# ---------------------------------------------------------------------------
def partition_topology(rg) -> None:
    """(Re-)bucket the *unpadded* edge stream by destination range; pad edges
    would only inflate shard 0 with zero slots the per-shard packet padding
    already provides.  Re-partitions every known quantized format through the
    same dtype-preserving partitioner."""
    sx, sy, sval = partition_edges_by_dst(
        rg.source.x, rg.source.y, rg.source.val,
        rg.num_vertices, rg.n_shards, packet=rg.packet)
    s = rg.n_shards
    rg._host_x = sx.reshape(s, -1)
    rg._host_y = sy.reshape(s, -1)
    rg._host_val = sval.reshape(s, -1)
    rg.sharded_x = jnp.asarray(sx)
    rg.sharded_y = jnp.asarray(sy)
    rg.sharded_val = jnp.asarray(sval)
    for fmt in set(rg._sharded_quantized) | set(rg._sharded_quant_host):
        _, _, sq = partition_edges_by_dst(
            rg.source.x, rg.source.y, rg._quantize_host(fmt),
            rg.num_vertices, rg.n_shards, packet=rg.packet)
        rg._sharded_quant_host[fmt] = sq.reshape(s, -1)
        rg._sharded_quantized[fmt] = jnp.asarray(sq)


def partition_format(rg, fmt: QFormat) -> jnp.ndarray:
    """Raw uint32 edge shard values in the partitioned layout (cached)."""
    if fmt not in rg._sharded_quantized:
        _, _, sval = partition_edges_by_dst(
            rg.source.x, rg.source.y, rg._quantize_host(fmt),
            rg.num_vertices, rg.n_shards, packet=rg.packet)
        rg._sharded_quant_host[fmt] = sval.reshape(rg.n_shards, -1)
        rg._sharded_quantized[fmt] = jnp.asarray(sval)
    return rg._sharded_quantized[fmt]


def refresh_partition_after_delta(rg, info) -> None:
    """Delta ingestion on a meshed graph: re-partition only the destination
    buckets that own a changed or removed edge.

    Falls back to a full re-partition when the delta moves the bucket
    geometry itself (vertex growth changing ``ceil(V / n_shards)``) or an
    affected bucket outgrows the current per-shard padding.  Idempotent per
    delta: both family members are armed on most graphs and each calls in."""
    if not rg._sharded_stale:
        return
    rg._sharded_stale = False
    old_v_local = rg._pre_delta_v_local
    v_local, _ = sharded_vertex_layout(rg.num_vertices, rg.n_shards)
    max_e = rg._host_x.shape[1]
    shard_of = rg.source.x // v_local
    counts = np.bincount(shard_of, minlength=rg.n_shards)
    affected = np.unique(info.changed_dst // v_local).astype(np.int64)
    if v_local != old_v_local or counts[affected].max(initial=0) > max_e:
        partition_topology(rg)
        return
    for s in affected:
        m = shard_of == s
        n = int(counts[s])
        for host in (rg._host_x, rg._host_y, rg._host_val):
            host[s, :] = 0
        rg._host_x[s, :n] = rg.source.x[m] % v_local
        rg._host_y[s, :n] = rg.source.y[m]
        rg._host_val[s, :n] = rg.source.val[m]
        for fmt, hq in rg._sharded_quant_host.items():
            hq[s, :] = 0
            hq[s, :n] = rg._quantized_host[fmt][m]
    rg.sharded_x = jnp.asarray(rg._host_x.reshape(-1))
    rg.sharded_y = jnp.asarray(rg._host_y.reshape(-1))
    rg.sharded_val = jnp.asarray(rg._host_val.reshape(-1))
    for fmt, hq in rg._sharded_quant_host.items():
        rg._sharded_quantized[fmt] = jnp.asarray(hq.reshape(-1))


# ---------------------------------------------------------------------------
# engines
# ---------------------------------------------------------------------------
@register_engine
class ShardedFloatEngine(WaveEngine):
    """float32 iterations whose SpMV streams mesh-partitioned edge shards."""

    key = "sharded_float"
    family = "sharded"
    fixed = False
    needs_mesh = True

    def prepare(self, rg, fmt: Optional[QFormat] = None) -> None:
        if not hasattr(rg, "_host_x"):
            partition_topology(rg)

    def plan(self, rg, fmt: Optional[QFormat] = None, *, alpha: float,
             iterations: int, convergence=None,
             topk_tile: Optional[int] = None, trace_hook=None) -> WavePlan:
        self.prepare(rg)
        body = make_ppr_sharded_float_step(rg.mesh, rg.axis,
                                           rg.num_vertices, alpha)
        x, y, val = rg.sharded_x, rg.sharded_y, rg.sharded_val
        dangling = rg.dangling
        num_vertices = rg.num_vertices

        def step(Vmat, P):
            return body(x, y, val, dangling, Vmat, P)

        return WavePlan(
            engine=self.key, fixed=False, scale=None,
            initial=lambda pers: personalization_matrix(num_vertices, pers),
            step=step,
            iterate=self._make_iterate(iterations, convergence, False, None,
                                       trace_hook=trace_hook),
            topk=self._make_topk(topk_tile))

    def on_delta(self, rg, info) -> None:
        rg.refresh_device_base()
        refresh_partition_after_delta(rg, info)


@register_engine
class ShardedFixedEngine(WaveEngine):
    """Bit-exact reduced-precision iterations over mesh-partitioned raw
    shards — bit-identical to ``FixedEngine`` on any V and shard count."""

    key = "sharded_fixed"
    family = "sharded"
    fixed = True
    needs_mesh = True

    def prepare(self, rg, fmt: Optional[QFormat] = None) -> None:
        if not hasattr(rg, "_host_x"):
            partition_topology(rg)
        if fmt is not None:
            partition_format(rg, fmt)

    def plan(self, rg, fmt: Optional[QFormat] = None, *, alpha: float,
             iterations: int, convergence=None,
             topk_tile: Optional[int] = None, trace_hook=None) -> WavePlan:
        if fmt is None:
            raise ValueError(f"{self.key!r} engine needs a concrete Q format")
        self.prepare(rg)
        body = make_ppr_sharded_fixed_step(fmt, rg.mesh, rg.axis,
                                           rg.num_vertices, alpha)
        x, y = rg.sharded_x, rg.sharded_y
        val_raw = partition_format(rg, fmt)
        dangling = rg.dangling
        num_vertices = rg.num_vertices

        def step(Vmat, P):
            return body(x, y, val_raw, dangling, Vmat, P)

        return WavePlan(
            engine=self.key, fixed=True, scale=fmt.scale,
            initial=lambda pers: personalization_matrix_fixed(
                num_vertices, pers, fmt),
            step=step,
            iterate=self._make_iterate(iterations, convergence, True, fmt.scale,
                                       trace_hook=trace_hook),
            topk=self._make_topk(topk_tile))

    def on_delta(self, rg, info) -> None:
        rg.refresh_device_base()
        refresh_partition_after_delta(rg, info)
