"""`PPRFuture` — the async result handle of the futures-based serving API.

``PPRService.submit`` returns one future per query.  A cache hit resolves the
future before ``submit`` even returns; a miss leaves it pending in the wave
scheduler until its wave launches (``poll``/``flush``, or the deadline-aware
admission policy) and the wave's completion resolves every occupant.

The service is single-process and synchronous, so ``result()`` does not block
on another thread — it *drives*: a pending future asks its service to launch
ready waves and, if still unresolved, to flush its own wave key.  ``result``
therefore always returns (or raises) in bounded time; ``timeout=0`` is the
non-blocking probe that raises ``TimeoutError`` instead of driving.

Futures reject instead of dangling: re-registering a graph or an edge delta
whose affected frontier covers a pending query's personalization vertex
rejects that future with a descriptive ``QueryRejected`` — a pending handle
is never silently dropped the way the legacy ``submit() -> None`` contract
allowed.
"""
from __future__ import annotations

from typing import Any, Callable, List, Optional

__all__ = ["PPRFuture", "QueryRejected"]


class QueryRejected(RuntimeError):
    """A pending query's future can never resolve (graph re-registered, or a
    delta invalidated the query's personalization vertex) — resubmit.

    ``code`` names the rejection class machine-readably so transports can map
    it without parsing the message: ``"graph-replaced"`` (re-registration —
    the HTTP tier serves 410 Gone) or ``"delta-invalidated"`` (epoch bump
    caught the pending vertex in its frontier — HTTP 409 Conflict, resubmit
    against the new topology).  The default ``"rejected"`` covers plug-in
    rejection paths."""

    def __init__(self, message: str, code: str = "rejected"):
        super().__init__(message)
        self.code = code


class PPRFuture:
    """Result handle for one submitted ``PPRQuery``.

    States: *pending* (queued for a wave) → *done* (holding either a
    ``Recommendation`` or an exception).  There is no cancelled state — the
    service rejects futures it cannot serve via ``QueryRejected``.
    """

    __slots__ = ("query", "_service", "_wave_key", "_result", "_exception",
                 "_done", "_callbacks", "_trace")

    def __init__(self, query, service=None):
        self.query = query
        self._service = service
        self._wave_key = None          # scheduler key while pending
        self._trace = None             # live obs trace when tracing is on
        self._result: Optional[Any] = None
        self._exception: Optional[BaseException] = None
        self._done = False
        self._callbacks: List[Callable[["PPRFuture"], None]] = []

    # ------------------------------------------------------------------
    def done(self) -> bool:
        """True once the future holds a result or an exception."""
        return self._done

    def result(self, timeout: Optional[float] = None):
        """The ``Recommendation``; drives the service if still pending.

        ``timeout=0`` never drives: it raises ``TimeoutError`` immediately
        when the future is pending (the non-blocking probe).  Any other
        timeout launches the service's ready waves and, if the future is
        still queued, flushes its wave — resolution is synchronous, so the
        timeout value itself is never waited out.
        """
        self._await(timeout)
        if self._exception is not None:
            raise self._exception
        return self._result

    def exception(self, timeout: Optional[float] = None
                  ) -> Optional[BaseException]:
        """The rejection exception, or None for a successful result.

        Drives the service exactly like ``result`` when pending."""
        self._await(timeout)
        return self._exception

    def _await(self, timeout: Optional[float]) -> None:
        """Shared pending-probe semantics of ``result``/``exception``:
        timeout<=0 is a non-blocking probe, otherwise drive the owning
        service; still-pending afterwards is a ``TimeoutError``."""
        if self._done:
            return
        vertex = getattr(self.query, "vertex", "?")
        if timeout is not None and timeout <= 0:
            raise TimeoutError(
                f"query for vertex {vertex} is still pending "
                f"(timeout=0 never drives the service)")
        if self._service is not None:
            self._service._drive(self)
        if not self._done:
            raise TimeoutError(
                f"query for vertex {vertex} could not be resolved "
                f"(no owning service to drive, or driving it never launched "
                f"this future's wave)")

    def add_done_callback(self, fn: Callable[["PPRFuture"], None]) -> None:
        """Run ``fn(self)`` when the future resolves (immediately if done).

        Callback exceptions are swallowed — a misbehaving callback must not
        poison the wave that is resolving its co-batched futures."""
        if self._done:
            try:
                fn(self)
            except Exception:
                pass
            return
        self._callbacks.append(fn)

    # ------------------------------------------------------------------
    # resolution — called by the owning service only
    def _resolve(self, result) -> None:
        self._result = result
        self._finish()

    def _reject(self, exc: BaseException) -> None:
        self._exception = exc
        self._finish()

    def _finish(self) -> None:
        self._done = True
        self._wave_key = None
        callbacks, self._callbacks = self._callbacks, []
        for fn in callbacks:
            try:
                fn(self)
            except Exception:
                pass

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        if not self._done:
            state = "pending"
        elif self._exception is not None:
            state = f"rejected: {self._exception!r}"
        else:
            state = "done"
        return f"<PPRFuture {getattr(self.query, 'vertex', '?')} {state}>"
