"""Registered-graph state holders — host topology + device upload caches.

These classes hold *state*, not datapath logic: the step construction,
quantized partitioning and top-K strategies that used to live here are owned
by the engine backends (``repro.ppr_serving.engine``).  A graph knows its
``engine_family`` ("single" / "sharded"); the service resolves each wave to
the family member for its precision and hands it this state.

What stays here is what every engine shares: the unpadded host graph (the
delta base), packet padding, the out-degree vector, the host-side raw
quantization cache, and the host-side incremental merge of edge deltas —
surviving edges keep their raw bits, only entries whose source out-degree
moved are requantized, bit-identical to quantizing the merged graph from
scratch.  ``epoch`` counts applied deltas; the service stamps it into cache
keys and wave keys so results computed on different topologies never alias.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.coo import COOGraph, EdgeMergeInfo, quantize_values
from repro.core.fixed_point import QFormat
from repro.core.spmv import sharded_vertex_layout
from repro.graph_updates.delta import EdgeDelta
from repro.ppr_serving.telemetry import SINGLE_DEVICE_KEY

__all__ = ["RegisteredGraph", "ShardedRegisteredGraph"]


class RegisteredGraph:
    """Host-side graph state prepared once at registration and patched in
    place by edge deltas, plus the full-layout device upload cache.

    The full-layout edge stream (``x``/``y``/``val``) is uploaded eagerly —
    every single-device wave reads it.  ``ShardedRegisteredGraph`` defers that
    upload: its waves read only the partitioned shards, and the full layout is
    materialized lazily iff something actually needs it — a meshed graph is
    registered precisely because one device's memory is tight."""

    mesh_key = SINGLE_DEVICE_KEY   # waves on this graph run single-device
    engine_family = "single"

    _defer_full_upload = False

    def __init__(self, name: str, g: COOGraph, packet: int = 256):
        self.name = name
        self.source = g                      # unpadded host graph (delta base)
        self.packet = packet
        self.epoch = 0
        self.graph = g.pad_to_packets(packet)
        self.num_vertices = g.num_vertices
        self.dangling = jnp.asarray(self.graph.dangling)
        self._outdeg = np.bincount(g.y, minlength=g.num_vertices).astype(np.int64)
        self._full_device: Optional[Tuple[jnp.ndarray, ...]] = None
        self._quantized: Dict[QFormat, jnp.ndarray] = {}
        self._quantized_host: Dict[QFormat, np.ndarray] = {}   # unpadded uint32
        self._stale_device_formats: set = set()
        self._full_was_materialized = False
        self._armed: Dict[str, object] = {}    # engine key → engine instance
        if not self._defer_full_upload:
            self.device_full()

    # ---- engine bookkeeping -----------------------------------------------
    def arm(self, engine) -> None:
        """Record an engine as serving this graph — armed engines get the
        ``on_delta`` device-refresh callback after each edge delta."""
        self._armed[engine.key] = engine

    def armed_engines(self):
        return tuple(self._armed.values())

    # ---- device upload caches ---------------------------------------------
    def device_full(self) -> Tuple[jnp.ndarray, ...]:
        """The full-layout (packet-padded) device arrays ``(x, y, val)``."""
        if self._full_device is None:
            self._full_device = (jnp.asarray(self.graph.x),
                                 jnp.asarray(self.graph.y),
                                 jnp.asarray(self.graph.val))
        return self._full_device

    @property
    def x(self) -> jnp.ndarray:
        return self.device_full()[0]

    @property
    def y(self) -> jnp.ndarray:
        return self.device_full()[1]

    @property
    def val(self) -> jnp.ndarray:
        return self.device_full()[2]

    def _quantize_host(self, fmt: QFormat) -> np.ndarray:
        """Raw uint32 values of the *unpadded* edge stream (host-side cache —
        the base incremental requantization patches on delta application)."""
        if fmt not in self._quantized_host:
            self._quantized_host[fmt] = self.source.quantized_val(fmt)
        return self._quantized_host[fmt]

    def quantized(self, fmt: QFormat) -> jnp.ndarray:
        """Padded raw uint32 device values for ``fmt`` (cached upload)."""
        if fmt not in self._quantized:
            raw = self._quantize_host(fmt)
            pad = self.graph.num_edges - raw.shape[0]
            if pad:
                raw = np.concatenate([raw, np.zeros(pad, np.uint32)])
            self._quantized[fmt] = jnp.asarray(raw)
        return self._quantized[fmt]

    # ---- delta ingestion --------------------------------------------------
    def apply_delta(self, delta: EdgeDelta) -> EdgeMergeInfo:
        """Merge an edge delta into the host state; bumps ``epoch``.

        Pre-registered Q formats are requantized incrementally: surviving
        edges keep their raw bits (copied through the merge's old→new index
        map), only ``changed_mask`` entries — edges of sources whose
        out-degree moved — go through the quantizer again.  The result is
        bit-identical to quantizing the merged graph from scratch.

        Device caches become stale here; the graph's armed engines refresh
        them through ``on_delta`` (the service drives that loop), so device
        costs are paid at delta time, not smeared over the next waves."""
        new_g, info = delta.apply(self.source, outdeg=self._outdeg)
        self._outdeg = info.new_outdeg
        self.source = new_g
        self.graph = new_g.pad_to_packets(self.packet)
        self.num_vertices = new_g.num_vertices
        self.dangling = jnp.asarray(self.graph.dangling)
        for fmt, old_raw in list(self._quantized_host.items()):
            new_raw = np.zeros(new_g.num_edges, np.uint32)
            new_raw[info.new_pos_of_kept] = old_raw[info.kept_old_idx]
            if info.changed_mask.any():
                new_raw[info.changed_mask] = quantize_values(
                    new_g.val[info.changed_mask], fmt)
            self._quantized_host[fmt] = new_raw
        self._stale_device_formats |= set(self._quantized)
        self._quantized.clear()
        self._full_was_materialized = self._full_device is not None
        self._full_device = None
        self.epoch += 1
        return info

    def refresh_device_base(self) -> None:
        """Re-upload the base device caches a delta invalidated — previously
        uploaded quantized formats, and the full layout if it was materialized
        (or this graph uploads eagerly).  Idempotent across armed engines."""
        for fmt in tuple(self._stale_device_formats):
            self.quantized(fmt)
        self._stale_device_formats.clear()
        if self._full_was_materialized or not self._defer_full_upload:
            self.device_full()


class ShardedRegisteredGraph(RegisteredGraph):
    """A registered graph whose edge stream is partitioned over a
    ``jax.sharding.Mesh`` axis (the paper's multi-channel partitioning, scaled
    to multi-device): waves on it run the sharded engines.

    Holds the bucketed host layout (``_host_x``/``_host_y``/``_host_val``,
    one row per shard) and per-format raw shard caches; the partitioning and
    per-bucket delta refresh that fill them live in
    ``repro.ppr_serving.engine.sharded``."""

    engine_family = "sharded"

    _defer_full_upload = True

    def __init__(self, name: str, g: COOGraph, mesh, axis: Optional[str] = None,
                 packet: int = 256):
        super().__init__(name, g, packet=packet)
        self.mesh = mesh
        self.axis = axis if axis is not None else mesh.axis_names[0]
        if self.axis not in mesh.axis_names:
            raise ValueError(f"mesh has no axis {self.axis!r} "
                             f"(axes: {mesh.axis_names})")
        self.n_shards = int(mesh.shape[self.axis])
        self.mesh_key = f"mesh:{self.axis}x{self.n_shards}"
        self._sharded_quantized: Dict[QFormat, jnp.ndarray] = {}
        self._sharded_quant_host: Dict[QFormat, np.ndarray] = {}  # [S, max_e]
        self._sharded_stale = False
        self._pre_delta_v_local = 0
        from repro.ppr_serving.engine.sharded import partition_topology
        partition_topology(self)

    def sharded_quantized(self, fmt: QFormat) -> jnp.ndarray:
        """Raw uint32 edge shard values in the partitioned layout (cached)."""
        from repro.ppr_serving.engine.sharded import partition_format
        return partition_format(self, fmt)

    def apply_delta(self, delta: EdgeDelta) -> EdgeMergeInfo:
        """Host merge plus the bookkeeping the sharded engines' per-bucket
        refresh needs: the pre-merge ceil-division layout (vertex growth may
        move it) and a staleness latch making the refresh idempotent across
        the family's two armed engines."""
        self._pre_delta_v_local, _ = sharded_vertex_layout(self.num_vertices,
                                                           self.n_shards)
        info = super().apply_delta(delta)
        self._sharded_stale = True
        return info
