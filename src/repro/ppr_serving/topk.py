"""Top-K extraction over the [V, κ] rank matrix (Parravicini et al.'s Top-K
SpMV follow-up, arXiv 2103.04808: recommender consumers want ranked top-K
results, not dense rank vectors).

Two paths, identical results:

1. ``topk_dense``      one ``lax.top_k`` over the full column — the XLA
                       production path when the dense rank matrix already
                       sits in device memory.
2. ``topk_streaming``  padded-tile variant: the matrix is consumed in
                       ``v_tile``-vertex tiles with an O(k) running buffer per
                       column, mirroring how an FPGA/TPU kernel fuses top-K
                       into the SpMV output stream without materializing dense
                       ranks in HBM.  V is padded to a whole number of tiles.

Both paths operate on float32 scores *or* on the raw uint32 fixed-point domain
directly: rank order is monotone in the raw encoding, so no dequantization is
needed (ties in raw are exactly ties after scaling).

Determinism: equal scores rank by ascending vertex id, matching
``repro.core.metrics.topk_indices``'s lexsort oracle — ``lax.top_k`` returns
the lower index first on ties, and the streaming merge keeps earlier-tile
candidates ahead of the current tile.  Integer-domain pad rows carry value 0
but the largest vertex ids, so real zero-score vertices win ties against them.

Self-exclusion: a recommender must not recommend the query vertex itself.
``exclude`` removes one vertex per column by *deletion*, not value-masking:
the merge runs with a k+1 buffer and the excluded vertex is dropped from the
result where present (value-masking to the domain minimum is wrong in the raw
uint32 domain — a masked vertex re-enters on zero-score ties when a column has
fewer than k nonzero ranks).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def _drop_excluded(idx: Array, vals: Array, exclude: Array, k: int
                   ) -> Tuple[Array, Array]:
    """Remove the (at most one) excluded entry per row of a top-(k+1) result,
    preserving order, and truncate to k."""
    is_ex = idx == exclude[:, None].astype(idx.dtype)
    order = jnp.argsort(is_ex, axis=1, stable=True)   # kept entries first, in order
    idx = jnp.take_along_axis(idx, order, axis=1)[:, :k]
    vals = jnp.take_along_axis(vals, order, axis=1)[:, :k]
    return idx, vals


@functools.partial(jax.jit, static_argnames=("k",))
def topk_dense(P: Array, k: int, exclude: Optional[Array] = None
               ) -> Tuple[Array, Array]:
    """(vertices [κ, k], scores [κ, k]) of the k highest-ranked per column,
    with ``exclude[j]`` (usually the query vertex) deleted from column j."""
    kk = k if exclude is None else k + 1
    if kk > P.shape[0]:
        raise ValueError(f"k={k} (+exclusion) exceeds num_vertices={P.shape[0]}")
    vals, idx = jax.lax.top_k(P.T, kk)                # [K, kk]
    idx = idx.astype(jnp.int32)
    if exclude is None:
        return idx, vals
    return _drop_excluded(idx, vals, jnp.asarray(exclude, jnp.int32), k)


@functools.partial(jax.jit, static_argnames=("k", "v_tile"))
def topk_streaming(P: Array, k: int, v_tile: int = 1024,
                   exclude: Optional[Array] = None) -> Tuple[Array, Array]:
    """Streaming merge over padded vertex tiles; == ``topk_dense`` bit-for-bit.

    Requires v_tile ≥ k+1 (the running buffer is seeded from the first tile).
    """
    kk = k if exclude is None else k + 1
    if v_tile < kk:
        raise ValueError(f"v_tile={v_tile} must be >= k(+exclusion)={kk}")
    if kk > P.shape[0]:
        raise ValueError(f"k={k} (+exclusion) exceeds num_vertices={P.shape[0]}")
    v, kappa = P.shape
    n_tiles = -(-v // v_tile)
    vp = n_tiles * v_tile
    if vp != v:
        pad_val = jnp.zeros((), P.dtype) if jnp.issubdtype(P.dtype, jnp.integer) \
            else jnp.asarray(-jnp.inf, P.dtype)
        P = jnp.concatenate(
            [P, jnp.full((vp - v, kappa), pad_val, P.dtype)], axis=0)
    tiles = P.reshape(n_tiles, v_tile, kappa)

    # seed the O(kk) running buffer from tile 0
    vals0, sel0 = jax.lax.top_k(tiles[0].T, kk)       # [K, kk]
    idx0 = sel0.astype(jnp.int32)

    def merge(carry, inp):
        cv, ci = carry                                # [K, kk]
        tile, base = inp                              # [v_tile, K], scalar
        tile_ids = jnp.broadcast_to(base + jnp.arange(v_tile, dtype=jnp.int32),
                                    (kappa, v_tile))
        cand_v = jnp.concatenate([cv, tile.T], axis=1)        # [K, kk+v_tile]
        cand_i = jnp.concatenate([ci, tile_ids], axis=1)
        nv, sel = jax.lax.top_k(cand_v, kk)
        ni = jnp.take_along_axis(cand_i, sel, axis=1)
        return (nv, ni), None

    bases = (jnp.arange(1, n_tiles, dtype=jnp.int32)) * v_tile
    (vals, idx), _ = jax.lax.scan(merge, (vals0, idx0), (tiles[1:], bases))
    if exclude is None:
        return idx, vals
    return _drop_excluded(idx, vals, jnp.asarray(exclude, jnp.int32), k)
