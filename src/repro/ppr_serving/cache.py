"""LRU result cache: repeat queries skip the PPR iteration entirely.

Keys are ``(graph, vertex, precision, k)`` — the full identity of a served
recommendation under a fixed service configuration (α and iteration count are
service-level constants; a service with different numerics should use a fresh
cache).  Hit/miss/eviction counters feed the telemetry hit-rate.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Callable, Dict, Hashable, Optional


class LRUCache:
    """Plain LRU over an OrderedDict; ``get`` refreshes recency."""

    def __init__(self, capacity: int):
        if capacity < 0:
            raise ValueError(f"capacity must be >= 0, got {capacity}")
        self.capacity = capacity
        self._store: "OrderedDict[Hashable, Any]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.invalidations = 0

    def __len__(self) -> int:
        return len(self._store)

    def __contains__(self, key: Hashable) -> bool:
        # membership probe only — does not touch counters or recency
        return key in self._store

    def get(self, key: Hashable) -> Optional[Any]:
        if key in self._store:
            self.hits += 1
            self._store.move_to_end(key)
            return self._store[key]
        self.misses += 1
        return None

    def put(self, key: Hashable, value: Any) -> None:
        if self.capacity == 0:
            return
        if key in self._store:
            self._store.move_to_end(key)
        self._store[key] = value
        while len(self._store) > self.capacity:
            self._store.popitem(last=False)
            self.evictions += 1

    def invalidate(self, predicate: Callable[[Hashable], bool]) -> int:
        """Drop every entry whose key satisfies ``predicate``; returns the
        count.  Used when a graph is re-registered under an existing name —
        its cached ranks describe the *old* topology and must not survive."""
        doomed = [k for k in self._store if predicate(k)]
        for k in doomed:
            del self._store[k]
        self.invalidations += len(doomed)
        return len(doomed)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> Dict[str, float]:
        return {
            "size": len(self._store),
            "capacity": self.capacity,
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "invalidations": self.invalidations,
            "hit_rate": self.hit_rate,
        }
