"""LRU result cache: repeat queries skip the PPR iteration entirely.

Keys are the service's ``_cache_key`` tuples — ``(graph, epoch, vertex,
precision, k, iterations, early_exit, warm)`` — the full identity of a served
recommendation, including the graph's delta epoch and the service numerics.
Scoped delta invalidation (``PPRService.apply_delta``) depends positionally
on that layout: its ``remap`` callback reads the epoch at index 1 and the
personalization vertex at index 2.  Hit/miss/eviction counters feed the
telemetry hit-rate.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Callable, Dict, Hashable, Optional


class LRUCache:
    """Plain LRU over an OrderedDict; ``get`` refreshes recency."""

    def __init__(self, capacity: int):
        if capacity < 0:
            raise ValueError(f"capacity must be >= 0, got {capacity}")
        self.capacity = capacity
        self._store: "OrderedDict[Hashable, Any]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.invalidations = 0

    def __len__(self) -> int:
        return len(self._store)

    def __contains__(self, key: Hashable) -> bool:
        # membership probe only — does not touch counters or recency
        return key in self._store

    def get(self, key: Hashable) -> Optional[Any]:
        if key in self._store:
            self.hits += 1
            self._store.move_to_end(key)
            return self._store[key]
        self.misses += 1
        return None

    def put(self, key: Hashable, value: Any) -> None:
        if self.capacity == 0:
            return
        if key in self._store:
            self._store.move_to_end(key)
        self._store[key] = value
        while len(self._store) > self.capacity:
            self._store.popitem(last=False)
            self.evictions += 1

    def remap(self, fn: Callable[[Hashable], Optional[Hashable]]
              ) -> "tuple[int, int]":
        """Rewrite every key through ``fn``: return a new key to retag the
        entry, the same key to keep it, or None to drop it.  Returns
        ``(dropped, retagged)``; drops count as invalidations.

        This is the scoped-invalidation primitive of delta ingestion: entries
        whose personalization vertex lies in a delta's affected frontier are
        dropped, everything else is retagged to the new epoch and keeps
        serving.  Recency order is preserved; if two keys collide after
        remapping, the more recently used entry wins (the older one counts as
        dropped)."""
        dropped = retagged = 0
        remapped: "OrderedDict[Hashable, Any]" = OrderedDict()
        for key, value in self._store.items():
            new_key = fn(key)
            if new_key is None:
                dropped += 1
                continue
            if new_key != key:
                retagged += 1
            if new_key in remapped:
                dropped += 1                 # older colliding entry gives way
                del remapped[new_key]        # re-insert at current recency
            remapped[new_key] = value
        self._store = remapped
        self.invalidations += dropped
        return dropped, retagged

    def invalidate(self, predicate: Callable[[Hashable], bool]) -> int:
        """Drop every entry whose key satisfies ``predicate``; returns the
        count.  Used when a graph is re-registered under an existing name —
        its cached ranks describe the *old* topology and must not survive."""
        doomed = [k for k in self._store if predicate(k)]
        for k in doomed:
            del self._store[k]
        self.invalidations += len(doomed)
        return len(doomed)

    def map_values(self, fn: Callable[[Hashable, Any], Any]) -> None:
        """Replace every entry's value with ``fn(key, value)`` in place —
        recency order and counters untouched.  Delta ingestion grows stored
        warm-start columns through this (repro.graph_updates.warmstart)."""
        for key in self._store:
            self._store[key] = fn(key, self._store[key])

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> Dict[str, float]:
        return {
            "size": len(self._store),
            "capacity": self.capacity,
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "invalidations": self.invalidations,
            "hit_rate": self.hit_rate,
        }
