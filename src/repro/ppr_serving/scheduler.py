"""κ-batch admission scheduler — the paper's batching as a serving policy.

Mirrors ``repro.serving.engine``'s slot batcher, specialized for PPR: one wave
amortizes a full edge-stream pass over up to κ personalization vertices, so
admission fills waves per (graph, precision, mesh, epoch) key — queries on
different graphs, Q formats, mesh layouts, or delta epochs cannot share a
stream and therefore never share a wave.

Flush policy (deadline-aware): a full wave of κ launches immediately; a
partially-full wave launches once *any* occupant has waited out its admission
budget — min(service ``max_wait``, the query's own ``deadline``) — so a
trickle of traffic still gets bounded latency at the cost of occupancy.
Time is injectable (``time_fn``) to keep the policy deterministic under test.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import time
from collections import OrderedDict
from typing import Any, Hashable, List, Optional, Tuple


@dataclasses.dataclass
class _Pending:
    item: Any
    enqueued_at: float
    deadline: Optional[float]      # max seconds this item may wait for batching

    def flush_at(self, max_wait: float) -> float:
        budget = max_wait if self.deadline is None else min(max_wait, self.deadline)
        return self.enqueued_at + budget


@dataclasses.dataclass
class Wave:
    """One κ-batched launch: all items share one (graph, precision, mesh,
    epoch) stream."""
    key: Hashable                  # (graph, precision, mesh_key, epoch) in the
    items: List[Any]               # PPR service (epoch = the graph's delta count)
    full: bool                     # False ⇒ deadline-flushed partial wave
    # per-item submit times (parallel to ``items``): launch time minus these
    # is each occupant's admission wait — the queue-time half of its latency,
    # which the launch path would otherwise lose the moment the wave forms
    enqueued_at: List[float] = dataclasses.field(default_factory=list)

    def __len__(self) -> int:
        return len(self.items)


class WaveScheduler:
    def __init__(self, kappa: int, max_wait: float = 0.0, time_fn=time.monotonic):
        if kappa < 1:
            raise ValueError(f"kappa must be >= 1, got {kappa}")
        self.kappa = kappa
        self.max_wait = max_wait
        self.time_fn = time_fn
        self._queues: "OrderedDict[Hashable, List[_Pending]]" = OrderedDict()
        self._depth = 0                # maintained by every mutation below
        # lazy min-heap of (head enqueue stamp, seq, key): each queue is FIFO
        # in enqueue time, so the globally oldest pending item is some queue's
        # head.  Mutations push a fresh entry whenever a queue's head changes;
        # reads pop entries that no longer describe a live head.  seq breaks
        # stamp ties without ever comparing (possibly heterogeneous) keys.
        self._heads: List[Tuple[float, int, Hashable]] = []
        self._head_seq = itertools.count()

    def _note_head(self, key: Hashable) -> None:
        """Record ``key``'s current queue head in the lazy heap (no-op for an
        empty/absent queue — reads skip stale entries)."""
        q = self._queues.get(key)
        if q:
            heapq.heappush(self._heads,
                           (q[0].enqueued_at, next(self._head_seq), key))

    # ------------------------------------------------------------------
    def submit(self, key: Hashable, item: Any,
               deadline: Optional[float] = None,
               now: Optional[float] = None) -> None:
        now = self.time_fn() if now is None else now
        q = self._queues.setdefault(key, [])
        q.append(_Pending(item, now, deadline))
        self._depth += 1
        if len(q) == 1:                # new head ⇒ new heap entry
            self._note_head(key)

    def pending(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def queue_depth(self) -> int:
        """Total pending queries across every wave key — O(1).

        The admission controller reads this on *every* arrival (shed/admit is
        a per-request decision), so it must not walk the pending dicts the way
        ``pending()`` does."""
        return self._depth

    def oldest_wait_s(self, now: Optional[float] = None) -> float:
        """Seconds the longest-waiting pending query has been queued (0.0
        when nothing is pending).

        Amortized O(1): the lazy head heap already orders the per-key queue
        heads by enqueue stamp, so a read peeks the top and only pops entries
        invalidated since they were pushed (each mutation creates at most one
        such entry, and each is discarded exactly once).  The pump reads this
        on every control tick and ``submit`` records it on every arrival —
        the previous every-key scan was per-arrival work proportional to the
        number of live (graph, precision, mesh, epoch) streams."""
        if not self._queues:
            return 0.0
        now = self.time_fn() if now is None else now
        while self._heads:
            stamp, _, key = self._heads[0]
            q = self._queues.get(key)
            if q is not None and q and q[0].enqueued_at == stamp:
                return max(0.0, now - stamp)
            heapq.heappop(self._heads)     # stale: head moved or queue died
        return 0.0

    def purge(self, key_predicate, item_predicate=None) -> int:
        """Drop pending queries whose wave key satisfies ``key_predicate``;
        returns the number dropped.  Used when a graph is re-registered: its
        queued queries were validated against the old topology (their vertices
        may not even exist in the new one) and must not launch.

        With ``item_predicate``, only matching items inside matching keys are
        dropped (delta ingestion's scoped purge: pending queries whose vertex
        falls in the affected frontier go, co-queued queries stay)."""
        dropped = 0
        for key in [k for k in self._queues if key_predicate(k)]:
            if item_predicate is None:
                dropped += len(self._queues.pop(key))
                continue
            q = self._queues[key]
            kept = [p for p in q if not item_predicate(p.item)]
            dropped += len(q) - len(kept)
            if kept:
                head_moved = kept[0] is not q[0]
                self._queues[key] = kept
                if head_moved:
                    self._note_head(key)
            else:
                del self._queues[key]
        self._depth -= dropped
        return dropped

    def extract(self, key_predicate) -> List[tuple]:
        """Pop every pending entry under matching keys, returning
        ``(key, item, enqueued_at, deadline)`` tuples in queue order.

        Delta ingestion uses this to move a graph's surviving pending queries
        onto new epoch-tagged wave keys: re-``submit`` with ``now=enqueued_at``
        preserves each query's admission budget across the move."""
        out: List[tuple] = []
        for key in [k for k in self._queues if key_predicate(k)]:
            for p in self._queues.pop(key):
                out.append((key, p.item, p.enqueued_at, p.deadline))
        self._depth -= len(out)
        return out

    def flush_keys(self, keys) -> List[Wave]:
        """Pop the named keys' queues as waves regardless of occupancy or
        deadline (κ-chunked like ``drain``).  The prefetcher uses this to
        launch its synthetic queries immediately during an idle pump instead
        of leaving them to age in the admission queue."""
        waves: List[Wave] = []
        for key in [k for k in self._queues if k in keys]:
            q = self._queues.pop(key)
            self._depth -= len(q)
            for i in range(0, len(q), self.kappa):
                chunk = q[i: i + self.kappa]
                waves.append(Wave(key, [p.item for p in chunk],
                                  full=len(chunk) == self.kappa,
                                  enqueued_at=[p.enqueued_at for p in chunk]))
        return waves

    # ------------------------------------------------------------------
    def ready_waves(self, now: Optional[float] = None) -> List[Wave]:
        """Pop every launchable wave: all full waves, plus partial waves in
        which *any* occupant's admission budget has expired (a late query with
        a tight deadline must not wait on the oldest occupant's looser one;
        the whole partial queue rides the flushed wave — that is the point of
        batching)."""
        now = self.time_fn() if now is None else now
        waves: List[Wave] = []
        for key in list(self._queues):
            q = self._queues[key]
            popped_full = False
            while len(q) >= self.kappa:
                waves.append(Wave(key, [p.item for p in q[: self.kappa]],
                                  full=True,
                                  enqueued_at=[p.enqueued_at
                                               for p in q[: self.kappa]]))
                del q[: self.kappa]
                self._depth -= self.kappa
                popped_full = True
            if q and now >= min(p.flush_at(self.max_wait) for p in q):
                waves.append(Wave(key, [p.item for p in q], full=False,
                                  enqueued_at=[p.enqueued_at for p in q]))
                self._depth -= len(q)
                q.clear()
            if not q:
                del self._queues[key]
            elif popped_full:          # survivors promoted: new queue head
                self._note_head(key)
        return waves

    def drain(self) -> List[Wave]:
        """Flush everything unconditionally (end-of-batch / shutdown path)."""
        return self.flush_keys(set(self._queues))
