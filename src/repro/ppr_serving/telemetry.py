"""Service telemetry: per-wave latency, throughput, batch occupancy, cache
hit-rate, and the adaptive-precision counters.

The occupancy counter is the serving-side view of the paper's κ-batching
economics: a wave amortizes one full edge-stream pass over its occupants, so
mean occupancy × κ is the effective amortization factor actually achieved
under real traffic (deadline flushes of partial waves lower it).

The autotune counters close the loop's observability: how many shadow
(float32 reference) evaluations were spent, what quality they measured, how
many iterations early-exit saved against the fixed budget (paper Fig. 7's
"additional 2x"), and which precisions traffic was actually served at — the
served-precision distribution is the live realization of Figs. 4-6's
quality/bit-width dial.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

# Mesh-layout key for waves on graphs registered without a mesh.  Defined here
# (the lowest layer that needs it) and re-exported by service.py; sharded
# graphs use "mesh:<axis>x<n_shards>" keys instead.
SINGLE_DEVICE_KEY = "single"


class ServiceTelemetry:
    def __init__(self) -> None:
        self.reset()

    def reset(self) -> None:
        """Zero every counter — e.g. after a jit warm-up pass, so measured
        telemetry reflects only the timed traffic without re-registering
        graphs (host-side partitioning and device uploads are not cheap)."""
        self.wave_latencies_s: List[float] = []
        self.wave_occupancies: List[float] = []
        self.wave_precisions: List[str] = []
        # engine-backend layer: which concrete engine served each wave, and
        # its latencies — the observability of the pluggable datapath seam
        self.wave_latencies_by_engine: Dict[str, List[float]] = {}
        self.queries_served = 0
        self.cache_hits = 0
        self.cache_misses = 0
        # multi-host sharded serving: which mesh layout served each wave
        self.waves_by_mesh: Dict[str, int] = {}
        self.queries_by_mesh: Dict[str, int] = {}
        # adaptive-precision subsystem (repro.autotune)
        self.served_by_precision: Dict[str, int] = {}
        self.auto_resolved: Dict[str, int] = {}
        self.shadow_scores: List[float] = []
        self.early_exit_waves = 0
        self.iterations_saved = 0
        # dynamic graph updates (repro.graph_updates)
        self.deltas_applied = 0
        self.edges_added = 0
        self.edges_removed = 0
        self.scoped_invalidations = 0      # cache entries + pending queries dropped
        self.scoped_cache_retained = 0     # entries a whole-graph flush would have lost
        self.warm_start_waves = 0
        self.warm_start_columns = 0
        self.warm_start_iterations_saved = 0
        # async prefetcher
        self.prefetch_issued = 0
        self.prefetch_suppressed = 0   # idle polls that skipped prefetch: queue deep
        # HTTP serving control plane (repro.ppr_serving.http): admission
        # queue gauges plus every shed / degrade / batching decision — the
        # issue of record for "was quality traded, and did it recover"
        self.queue_depth_last = 0
        self.queue_depth_peak = 0
        self.oldest_wait_last_s = 0.0
        self.oldest_wait_peak_s = 0.0
        self.queries_shed = 0          # rejected by admission (HTTP 429)
        self.shed_engaged_events = 0   # high-water crossings (entering shed)
        self.shed_recovered_events = 0 # low-water crossings (leaving shed)
        self.slo_degrade_events = 0    # quality-target ceiling imposed
        self.slo_recover_events = 0    # ceiling lifted (queue drained)
        self.slo_degraded_queries = 0  # auto queries resolved under a ceiling
        self.kappa_deepen_events = 0   # wave batch deepened under backpressure
        self.kappa_relax_events = 0    # batch depth restored toward base κ
        # per-(graph, vertex) demand — what the prefetcher ranks hotness by —
        # plus each vertex's most recent (k, resolved precision), so a
        # prefetched entry lands under the cache key real traffic actually
        # probes (auto traffic records its post-resolution format)
        self.query_vertex_counts: Dict[str, Dict[int, int]] = {}
        self.query_vertex_last: Dict[str, Dict[int, Tuple[int, str]]] = {}

    # ------------------------------------------------------------------
    def record_wave(self, n_queries: int, kappa: int, latency_s: float,
                    precision: str, mesh_key: str = SINGLE_DEVICE_KEY,
                    engine: Optional[str] = None) -> None:
        if engine is not None:
            self.wave_latencies_by_engine.setdefault(engine, []).append(
                float(latency_s))
        self.wave_latencies_s.append(float(latency_s))
        self.wave_occupancies.append(n_queries / float(kappa))
        self.wave_precisions.append(precision)
        self.queries_served += n_queries
        self.served_by_precision[precision] = \
            self.served_by_precision.get(precision, 0) + n_queries
        self.waves_by_mesh[mesh_key] = self.waves_by_mesh.get(mesh_key, 0) + 1
        self.queries_by_mesh[mesh_key] = \
            self.queries_by_mesh.get(mesh_key, 0) + n_queries

    def record_cache(self, hit: bool) -> None:
        if hit:
            self.cache_hits += 1
        else:
            self.cache_misses += 1

    def record_auto_resolution(self, resolved_precision: str) -> None:
        """One ``precision="auto"`` query resolved to a concrete format."""
        self.auto_resolved[resolved_precision] = \
            self.auto_resolved.get(resolved_precision, 0) + 1

    def record_shadow(self, score: float) -> None:
        """One shadow evaluation (float32 reference run + metric score)."""
        self.shadow_scores.append(float(score))

    def record_early_exit(self, iterations_saved: int) -> None:
        """A wave stopped ``iterations_saved`` iterations short of its budget."""
        self.early_exit_waves += 1
        self.iterations_saved += int(iterations_saved)

    #: per-graph demand entries above which counts are halved and pruned —
    #: bounds memory and ages out stale hotness (recency, not lifetime totals)
    DEMAND_COMPACT_THRESHOLD = 4096

    def record_query_vertex(self, graph: str, vertex: int,
                            k: Optional[int] = None,
                            pkey: Optional[str] = None) -> None:
        """One real (non-synthetic) query's demand for a personalization
        vertex — the frequency signal the prefetcher ranks."""
        counts = self.query_vertex_counts.setdefault(graph, {})
        counts[int(vertex)] = counts.get(int(vertex), 0) + 1
        if k is not None and pkey is not None:
            self.query_vertex_last.setdefault(graph, {})[int(vertex)] = \
                (int(k), pkey)
        if len(counts) > self.DEMAND_COMPACT_THRESHOLD:
            compacted = {v: n // 2 for v, n in counts.items() if n // 2}
            self.query_vertex_counts[graph] = compacted
            last = self.query_vertex_last.get(graph)
            if last is not None:
                self.query_vertex_last[graph] = \
                    {v: lk for v, lk in last.items() if v in compacted}

    def forget_graph_demand(self, graph: str) -> None:
        """Drop a graph's per-vertex demand signal (full re-registration:
        hotness measured on the dead topology must not steer the prefetcher)."""
        self.query_vertex_counts.pop(graph, None)
        self.query_vertex_last.pop(graph, None)

    def record_delta(self, edges_added: int, edges_removed: int,
                     cache_dropped: int, cache_retained: int,
                     pending_dropped: int) -> None:
        """One ``apply_delta``: scoped invalidation dropped ``cache_dropped``
        cache entries and ``pending_dropped`` pending queries, while
        ``cache_retained`` entries survived that a whole-graph flush (the old
        re-registration path) would have destroyed."""
        self.deltas_applied += 1
        self.edges_added += int(edges_added)
        self.edges_removed += int(edges_removed)
        self.scoped_invalidations += int(cache_dropped) + int(pending_dropped)
        self.scoped_cache_retained += int(cache_retained)

    def record_warm_start(self, columns: int, iterations_saved: int) -> None:
        """One wave seeded ``columns`` personalization columns from stored
        converged state; ``iterations_saved`` is measured against the last
        cold wave of the same (graph, precision) stream."""
        self.warm_start_waves += 1
        self.warm_start_columns += int(columns)
        self.warm_start_iterations_saved += int(iterations_saved)

    def record_prefetch(self, issued: int) -> None:
        """Synthetic cache-warming queries issued during an idle pump."""
        self.prefetch_issued += int(issued)

    def record_prefetch_suppressed(self) -> None:
        """An idle poll skipped prefetch because the wave queue was deep —
        idle-only warming yielding to live traffic."""
        self.prefetch_suppressed += 1

    # -- HTTP serving control plane ------------------------------------
    def record_queue_depth(self, depth: int, oldest_wait_s: float) -> None:
        """Admission-queue gauges (last + peak): sampled by the serving
        pump's control ticks, surfaced by ``/v1/stats``."""
        self.queue_depth_last = int(depth)
        self.queue_depth_peak = max(self.queue_depth_peak, int(depth))
        self.oldest_wait_last_s = float(oldest_wait_s)
        self.oldest_wait_peak_s = max(self.oldest_wait_peak_s,
                                      float(oldest_wait_s))

    def record_shed(self) -> None:
        """One arriving query rejected by admission control (HTTP 429)."""
        self.queries_shed += 1

    def record_shed_transition(self, engaged: bool) -> None:
        """Load shedding switched on (high-water crossed) or off (drained
        below the low-water mark)."""
        if engaged:
            self.shed_engaged_events += 1
        else:
            self.shed_recovered_events += 1

    def record_slo_transition(self, degraded: bool) -> None:
        """The SLO controller imposed (or lifted) the degraded quality-target
        ceiling on ``precision="auto"`` resolution."""
        if degraded:
            self.slo_degrade_events += 1
        else:
            self.slo_recover_events += 1

    def record_degraded_query(self) -> None:
        """One auto query resolved against a stepped-down quality target."""
        self.slo_degraded_queries += 1

    def record_kappa_change(self, deepened: bool) -> None:
        """Backpressure batching moved the wave depth: deepened under load,
        or relaxed back toward the base κ as the queue drained."""
        if deepened:
            self.kappa_deepen_events += 1
        else:
            self.kappa_relax_events += 1

    # ------------------------------------------------------------------
    @property
    def waves(self) -> int:
        return len(self.wave_latencies_s)

    @property
    def shadow_evaluations(self) -> int:
        return len(self.shadow_scores)

    def summary(self) -> Dict[str, float]:
        lat = np.asarray(self.wave_latencies_s, np.float64)
        total_s = float(lat.sum()) if lat.size else 0.0
        cache_total = self.cache_hits + self.cache_misses
        out = {
            "waves": self.waves,
            "queries_served": self.queries_served,
            "queries_per_s": self.queries_served / total_s if total_s else 0.0,
            "wave_latency_p50_s": float(np.percentile(lat, 50)) if lat.size else 0.0,
            "wave_latency_p95_s": float(np.percentile(lat, 95)) if lat.size else 0.0,
            "mean_occupancy": float(np.mean(self.wave_occupancies))
            if self.wave_occupancies else 0.0,
            "cache_hits": self.cache_hits,
            "cache_misses": self.cache_misses,
            "cache_hit_rate": self.cache_hits / cache_total if cache_total else 0.0,
            "shadow_evaluations": self.shadow_evaluations,
            "shadow_quality_mean": float(np.mean(self.shadow_scores))
            if self.shadow_scores else 0.0,
            "early_exit_waves": self.early_exit_waves,
            "iterations_saved": self.iterations_saved,
            "deltas_applied": self.deltas_applied,
            "edges_added": self.edges_added,
            "edges_removed": self.edges_removed,
            "scoped_invalidations": self.scoped_invalidations,
            "scoped_cache_retained": self.scoped_cache_retained,
            "warm_start_waves": self.warm_start_waves,
            "warm_start_columns": self.warm_start_columns,
            "warm_start_iterations_saved": self.warm_start_iterations_saved,
            "prefetch_issued": self.prefetch_issued,
            "prefetch_suppressed": self.prefetch_suppressed,
            "queue_depth": self.queue_depth_last,
            "queue_depth_peak": self.queue_depth_peak,
            "oldest_wait_s": self.oldest_wait_last_s,
            "oldest_wait_peak_s": self.oldest_wait_peak_s,
            "queries_shed": self.queries_shed,
            "shed_engaged_events": self.shed_engaged_events,
            "shed_recovered_events": self.shed_recovered_events,
            "slo_degrade_events": self.slo_degrade_events,
            "slo_recover_events": self.slo_recover_events,
            "slo_degraded_queries": self.slo_degraded_queries,
            "kappa_deepen_events": self.kappa_deepen_events,
            "kappa_relax_events": self.kappa_relax_events,
        }
        for pkey, n in sorted(self.served_by_precision.items()):
            out[f"served_{pkey}"] = n
        for pkey, n in sorted(self.auto_resolved.items()):
            out[f"auto_{pkey}"] = n
        for mkey, n in sorted(self.waves_by_mesh.items()):
            out[f"waves_{mkey}"] = n
        for mkey, n in sorted(self.queries_by_mesh.items()):
            out[f"queries_{mkey}"] = n
        for ekey, stats in sorted(self.engine_stats().items()):
            for stat, v in stats.items():
                out[f"engine_{ekey}_{stat}"] = v
        return out

    def engine_stats(self) -> Dict[str, Dict[str, float]]:
        """Per-engine wave-latency stats: count / mean / p95 per concrete
        engine key — the observability of the backend layer (which datapath
        served what, and how fast)."""
        out: Dict[str, Dict[str, float]] = {}
        for ekey, lats in self.wave_latencies_by_engine.items():
            a = np.asarray(lats, np.float64)
            out[ekey] = {
                "waves": int(a.size),
                "latency_mean_s": float(a.mean()) if a.size else 0.0,
                "latency_p95_s": float(np.percentile(a, 95)) if a.size else 0.0,
            }
        return out
