"""Service telemetry: per-wave latency, throughput, batch occupancy, cache
hit-rate.

The occupancy counter is the serving-side view of the paper's κ-batching
economics: a wave amortizes one full edge-stream pass over its occupants, so
mean occupancy × κ is the effective amortization factor actually achieved
under real traffic (deadline flushes of partial waves lower it).
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np


class ServiceTelemetry:
    def __init__(self) -> None:
        self.wave_latencies_s: List[float] = []
        self.wave_occupancies: List[float] = []
        self.wave_precisions: List[str] = []
        self.queries_served = 0
        self.cache_hits = 0
        self.cache_misses = 0

    # ------------------------------------------------------------------
    def record_wave(self, n_queries: int, kappa: int, latency_s: float,
                    precision: str) -> None:
        self.wave_latencies_s.append(float(latency_s))
        self.wave_occupancies.append(n_queries / float(kappa))
        self.wave_precisions.append(precision)
        self.queries_served += n_queries

    def record_cache(self, hit: bool) -> None:
        if hit:
            self.cache_hits += 1
        else:
            self.cache_misses += 1

    # ------------------------------------------------------------------
    @property
    def waves(self) -> int:
        return len(self.wave_latencies_s)

    def summary(self) -> Dict[str, float]:
        lat = np.asarray(self.wave_latencies_s, np.float64)
        total_s = float(lat.sum()) if lat.size else 0.0
        cache_total = self.cache_hits + self.cache_misses
        return {
            "waves": self.waves,
            "queries_served": self.queries_served,
            "queries_per_s": self.queries_served / total_s if total_s else 0.0,
            "wave_latency_p50_s": float(np.percentile(lat, 50)) if lat.size else 0.0,
            "wave_latency_p95_s": float(np.percentile(lat, 95)) if lat.size else 0.0,
            "mean_occupancy": float(np.mean(self.wave_occupancies))
            if self.wave_occupancies else 0.0,
            "cache_hits": self.cache_hits,
            "cache_misses": self.cache_misses,
            "cache_hit_rate": self.cache_hits / cache_total if cache_total else 0.0,
        }
