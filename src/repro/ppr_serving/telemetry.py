"""Service telemetry — the serving stack's counters, now on bounded storage.

Everything ``ServiceTelemetry`` records lives in a ``repro.obs``
``MetricsRegistry``: counters and gauges for the event/decision accounting,
exponential-bucket histograms for the latency/occupancy/quality
distributions (exact sums and counts → exact means), and fixed-size seeded
reservoirs for percentiles.  Memory is therefore O(1) in queries served —
the pre-PR unbounded per-wave lists (``wave_latencies_s``, ``shadow_scores``,
``wave_occupancies``, per-engine latency lists) leaked in any long-lived
server.  The one knob is ``reservoir_size`` (default 1024): while fewer
observations than that have arrived, a reservoir holds the *entire* history
and percentile summaries are exact; past it, percentiles degrade gracefully
to a deterministic uniform sample.

The legacy read surface is preserved: ``summary()`` emits the same keys with
the same values, and the old list/dict attributes (``wave_latencies_s``,
``shadow_scores``, ``served_by_precision``, ...) remain as read-only
properties reconstructed from the registry, exact for runs smaller than the
reservoir.  The registry itself is public (``telemetry.registry``) — it is
what ``GET /v1/metrics`` renders as Prometheus text exposition.

The occupancy counter is the serving-side view of the paper's κ-batching
economics: a wave amortizes one full edge-stream pass over its occupants, so
mean occupancy × κ is the effective amortization factor actually achieved
under real traffic (deadline flushes of partial waves lower it).

The autotune counters close the loop's observability: how many shadow
(float32 reference) evaluations were spent, what quality they measured, how
many iterations early-exit saved against the fixed budget (paper Fig. 7's
"additional 2x"), and which precisions traffic was actually served at — the
served-precision distribution is the live realization of Figs. 4-6's
quality/bit-width dial.

Per-stage wave timing (``record_stage``: plan / warm_start / iterate / topk
/ resolve, plus the pre-wave admission wait) is what finally says *where* a
query's milliseconds went rather than just how many there were — the
breakdown feeds ``summary()``'s ``stage_*`` keys, the bench JSON rows, and
``/v1/metrics``.
"""
from __future__ import annotations

from collections import deque
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.obs.metrics import MetricsRegistry, exponential_buckets

# Mesh-layout key for waves on graphs registered without a mesh.  Defined here
# (the lowest layer that needs it) and re-exported by service.py; sharded
# graphs use "mesh:<axis>x<n_shards>" keys instead.
SINGLE_DEVICE_KEY = "single"

#: unit-interval bounds for occupancy/quality distributions
_UNIT_BUCKETS = tuple(i / 20 for i in range(1, 21))
#: iteration-count bounds (1..256 in doublings)
_ITER_BUCKETS = exponential_buckets(1.0, 2.0, 9)

#: wave pipeline stages timed by the service (`record_stage` accepts exactly
#: these — a typo'd stage must fail loudly, not mint a metric series)
WAVE_STAGES = ("plan", "warm_start", "iterate", "topk", "resolve")


class ServiceTelemetry:
    def __init__(self, reservoir_size: int = 1024) -> None:
        """``reservoir_size`` bounds every percentile sample (wave latency,
        per-engine latency, occupancy, shadow quality): percentiles are exact
        until that many observations, then a deterministic uniform sample."""
        self.reservoir_size = reservoir_size
        self.reset()

    def reset(self) -> None:
        """Zero every counter — e.g. after a jit warm-up pass, so measured
        telemetry reflects only the timed traffic without re-registering
        graphs (host-side partitioning and device uploads are not cheap)."""
        r = self.registry = MetricsRegistry(reservoir_size=self.reservoir_size)
        # -- waves / queries / cache ----------------------------------------
        self._waves = r.counter("ppr_waves_total", "Waves launched.")
        # graph-labeled: on a shared instance, one graph's overload must be
        # attributable (pairs with per-graph admission, ROADMAP item 3)
        self._queries = r.counter("ppr_queries_served_total",
                                  "Queries resolved by waves, per graph.",
                                  labels=("graph",))
        self._cache_hits = r.counter("ppr_cache_hits_total",
                                     "Submit-path result-cache hits.")
        self._cache_misses = r.counter("ppr_cache_misses_total",
                                       "Submit-path result-cache misses.")
        self._wave_latency = r.histogram(
            "ppr_wave_latency_seconds", "Wave wall-clock latency.")
        self._wave_latency_q = r.reservoir(
            "ppr_wave_latency_seconds_quantiles",
            "Wave latency percentile sample.")
        self._engine_latency = r.histogram(
            "ppr_engine_wave_latency_seconds",
            "Wave latency per concrete engine backend.", labels=("engine",))
        self._engine_latency_q = r.reservoir(
            "ppr_engine_wave_latency_seconds_quantiles",
            "Per-engine wave latency percentile sample.", labels=("engine",))
        self._occupancy = r.histogram(
            "ppr_wave_occupancy", "Wave occupancy (queries / kappa).",
            bounds=_UNIT_BUCKETS)
        self._occupancy_q = r.reservoir(
            "ppr_wave_occupancy_quantiles", "Wave occupancy sample.")
        self._served_by_precision = r.counter(
            "ppr_served_queries_total", "Queries served per precision.",
            labels=("precision",))
        self._waves_by_mesh = r.counter(
            "ppr_mesh_waves_total", "Waves per mesh layout.", labels=("mesh",))
        self._queries_by_mesh = r.counter(
            "ppr_mesh_queries_total", "Queries per mesh layout.",
            labels=("mesh",))
        # bounded precision-history ring (legacy `wave_precisions` list)
        self._wave_precisions = deque(maxlen=self.reservoir_size)
        # -- per-stage wave timing + admission wait -------------------------
        self._stage = r.histogram(
            "ppr_wave_stage_seconds",
            "Wave pipeline stage timing (plan/warm_start/iterate/topk/"
            "resolve).", labels=("stage",))
        self._admission_wait = r.histogram(
            "ppr_admission_wait_seconds",
            "Queue time between submit and wave launch.")
        self._admission_wait_q = r.reservoir(
            "ppr_admission_wait_seconds_quantiles",
            "Admission-wait percentile sample.")
        self._wave_iterations = r.histogram(
            "ppr_wave_iterations", "Iterations actually run per wave.",
            bounds=_ITER_BUCKETS)
        # -- adaptive-precision subsystem (repro.autotune) -------------------
        self._auto_resolved = r.counter(
            "ppr_auto_resolved_total",
            'precision="auto" resolutions per concrete format.',
            labels=("precision",))
        self._shadow_quality = r.histogram(
            "ppr_shadow_quality", "Shadow-scored quality (NDCG vs float32).",
            bounds=_UNIT_BUCKETS)
        self._shadow_quality_q = r.reservoir(
            "ppr_shadow_quality_quantiles", "Shadow quality sample.")
        self._early_exit_waves = r.counter(
            "ppr_early_exit_waves_total",
            "Waves stopped before their iteration budget.")
        self._iterations_saved = r.counter(
            "ppr_iterations_saved_total",
            "Iterations early exit saved vs the fixed budget.")
        # -- dynamic graph updates (repro.graph_updates) ---------------------
        self._deltas_applied = r.counter("ppr_deltas_applied_total",
                                         "Edge deltas absorbed.")
        self._edges_added = r.counter("ppr_delta_edges_added_total",
                                      "Edges inserted by deltas.")
        self._edges_removed = r.counter("ppr_delta_edges_removed_total",
                                        "Edges removed by deltas.")
        self._scoped_invalidations = r.counter(
            "ppr_scoped_invalidations_total",
            "Cache entries + pending queries dropped by delta frontiers.")
        self._scoped_cache_retained = r.counter(
            "ppr_scoped_cache_retained_total",
            "Cache entries a whole-graph flush would have lost.")
        self._warm_start_waves = r.counter("ppr_warm_start_waves_total",
                                           "Waves seeded from stored columns.")
        self._warm_start_columns = r.counter("ppr_warm_start_columns_total",
                                             "Personalization columns seeded.")
        self._warm_start_saved = r.counter(
            "ppr_warm_start_iterations_saved_total",
            "Iterations saved vs the last cold wave.")
        # -- async prefetcher ------------------------------------------------
        self._prefetch_issued = r.counter(
            "ppr_prefetch_issued_total", "Synthetic cache-warming queries.")
        self._prefetch_suppressed = r.counter(
            "ppr_prefetch_suppressed_total",
            "Idle polls that skipped prefetch: queue deep.")
        # -- HTTP serving control plane (repro.ppr_serving.http): admission
        # queue gauges plus every shed / degrade / batching decision — the
        # issue of record for "was quality traded, and did it recover"
        self._queue_depth = r.gauge(
            "ppr_queue_depth", "Pending queries in the admission queue "
            "(recorded on control ticks and on every submit).")
        self._oldest_wait = r.gauge(
            "ppr_oldest_wait_seconds",
            "Age of the longest-waiting pending query.")
        self._queries_shed = r.counter(
            "ppr_queries_shed_total",
            "Arrivals rejected by admission (429), per graph.",
            labels=("graph",))
        self._queries_deadline_shed = r.counter(
            "ppr_queries_deadline_shed_total",
            "Queries dropped at wave launch: admission wait already past "
            "their deadline (504), per graph.", labels=("graph",))
        # end-to-end admitted-query latency (submit → resolution), the
        # distribution the latency SLO evaluates; cache hits land as ~0
        self._query_latency = r.histogram(
            "ppr_query_latency_seconds",
            "Admitted-query latency, submit to resolution, per graph.",
            labels=("graph",))
        self._slo_advisory = r.counter(
            "ppr_slo_advisory_total",
            "Admission-ladder moves advised by SLO burn rather than queue "
            "depth (deepen/degrade/veto).", labels=("action",))
        self._shed_engaged = r.counter("ppr_shed_engaged_total",
                                       "High-water crossings (entering shed).")
        self._shed_recovered = r.counter("ppr_shed_recovered_total",
                                         "Low-water crossings (leaving shed).")
        self._slo_degrade = r.counter("ppr_slo_degrade_total",
                                      "Quality-target ceiling imposed.")
        self._slo_recover = r.counter("ppr_slo_recover_total",
                                      "Quality-target ceiling lifted.")
        self._slo_degraded_queries = r.counter(
            "ppr_slo_degraded_queries_total",
            "Auto queries resolved under a ceiling, per graph.",
            labels=("graph",))
        self._kappa_deepen = r.counter("ppr_kappa_deepen_total",
                                       "Wave depth deepened under load.")
        self._kappa_relax = r.counter("ppr_kappa_relax_total",
                                      "Wave depth relaxed toward base kappa.")
        # per-(graph, vertex) demand — what the prefetcher ranks hotness by —
        # plus each vertex's most recent (k, resolved precision), so a
        # prefetched entry lands under the cache key real traffic actually
        # probes (auto traffic records its post-resolution format).  Bounded
        # by DEMAND_COMPACT_THRESHOLD compaction, not by the registry.
        self.query_vertex_counts: Dict[str, Dict[int, int]] = {}
        self.query_vertex_last: Dict[str, Dict[int, Tuple[int, str]]] = {}

    # ------------------------------------------------------------------
    #: label value when a caller cannot attribute an event to a graph
    UNATTRIBUTED = "unknown"

    def record_wave(self, n_queries: int, kappa: int, latency_s: float,
                    precision: str, mesh_key: str = SINGLE_DEVICE_KEY,
                    engine: Optional[str] = None,
                    graph: str = UNATTRIBUTED) -> None:
        if engine is not None:
            self._engine_latency.labels(engine=engine).observe(latency_s)
            self._engine_latency_q.labels(engine=engine).add(latency_s)
        self._waves.get().inc()
        self._wave_latency.get().observe(latency_s)
        self._wave_latency_q.get().add(latency_s)
        occ = n_queries / float(kappa)
        self._occupancy.get().observe(occ)
        self._occupancy_q.get().add(occ)
        self._wave_precisions.append(precision)
        self._queries.labels(graph=graph).inc(n_queries)
        self._served_by_precision.labels(precision=precision).inc(n_queries)
        self._waves_by_mesh.labels(mesh=mesh_key).inc()
        self._queries_by_mesh.labels(mesh=mesh_key).inc(n_queries)

    def record_stage(self, stage: str, seconds: float) -> None:
        """One wave pipeline stage's wall-clock cost (see ``WAVE_STAGES``)."""
        if stage not in WAVE_STAGES:
            raise ValueError(f"unknown wave stage {stage!r} "
                             f"(have {WAVE_STAGES})")
        self._stage.labels(stage=stage).observe(seconds)

    def record_admission_wait(self, seconds: float) -> None:
        """One query's submit → wave-launch queue time."""
        self._admission_wait.get().observe(seconds)
        self._admission_wait_q.get().add(seconds)

    def record_wave_iterations(self, n: int) -> None:
        """Iterations one wave actually ran (early exit shortens this)."""
        self._wave_iterations.get().observe(n)

    def record_cache(self, hit: bool) -> None:
        (self._cache_hits if hit else self._cache_misses).get().inc()

    def record_auto_resolution(self, resolved_precision: str) -> None:
        """One ``precision="auto"`` query resolved to a concrete format."""
        self._auto_resolved.labels(precision=resolved_precision).inc()

    def record_shadow(self, score: float) -> None:
        """One shadow evaluation (float32 reference run + metric score)."""
        self._shadow_quality.get().observe(score)
        self._shadow_quality_q.get().add(score)

    def record_early_exit(self, iterations_saved: int) -> None:
        """A wave stopped ``iterations_saved`` iterations short of its budget."""
        self._early_exit_waves.get().inc()
        self._iterations_saved.get().inc(int(iterations_saved))

    #: per-graph demand entries above which counts are halved and pruned —
    #: bounds memory and ages out stale hotness (recency, not lifetime totals)
    DEMAND_COMPACT_THRESHOLD = 4096

    def record_query_vertex(self, graph: str, vertex: int,
                            k: Optional[int] = None,
                            pkey: Optional[str] = None) -> None:
        """One real (non-synthetic) query's demand for a personalization
        vertex — the frequency signal the prefetcher ranks."""
        counts = self.query_vertex_counts.setdefault(graph, {})
        counts[int(vertex)] = counts.get(int(vertex), 0) + 1
        if k is not None and pkey is not None:
            self.query_vertex_last.setdefault(graph, {})[int(vertex)] = \
                (int(k), pkey)
        if len(counts) > self.DEMAND_COMPACT_THRESHOLD:
            compacted = {v: n // 2 for v, n in counts.items() if n // 2}
            self.query_vertex_counts[graph] = compacted
            last = self.query_vertex_last.get(graph)
            if last is not None:
                self.query_vertex_last[graph] = \
                    {v: lk for v, lk in last.items() if v in compacted}

    def forget_graph_demand(self, graph: str) -> None:
        """Drop a graph's per-vertex demand signal (full re-registration:
        hotness measured on the dead topology must not steer the prefetcher)."""
        self.query_vertex_counts.pop(graph, None)
        self.query_vertex_last.pop(graph, None)

    def record_delta(self, edges_added: int, edges_removed: int,
                     cache_dropped: int, cache_retained: int,
                     pending_dropped: int) -> None:
        """One ``apply_delta``: scoped invalidation dropped ``cache_dropped``
        cache entries and ``pending_dropped`` pending queries, while
        ``cache_retained`` entries survived that a whole-graph flush (the old
        re-registration path) would have destroyed."""
        self._deltas_applied.get().inc()
        self._edges_added.get().inc(int(edges_added))
        self._edges_removed.get().inc(int(edges_removed))
        self._scoped_invalidations.get().inc(
            int(cache_dropped) + int(pending_dropped))
        self._scoped_cache_retained.get().inc(int(cache_retained))

    def record_warm_start(self, columns: int, iterations_saved: int) -> None:
        """One wave seeded ``columns`` personalization columns from stored
        converged state; ``iterations_saved`` is measured against the last
        cold wave of the same (graph, precision) stream."""
        self._warm_start_waves.get().inc()
        self._warm_start_columns.get().inc(int(columns))
        self._warm_start_saved.get().inc(int(iterations_saved))

    def record_prefetch(self, issued: int) -> None:
        """Synthetic cache-warming queries issued during an idle pump."""
        self._prefetch_issued.get().inc(int(issued))

    def record_prefetch_suppressed(self) -> None:
        """An idle poll skipped prefetch because the wave queue was deep —
        idle-only warming yielding to live traffic."""
        self._prefetch_suppressed.get().inc()

    # -- HTTP serving control plane ------------------------------------
    def record_queue_depth(self, depth: int, oldest_wait_s: float) -> None:
        """Admission-queue gauges (last + peak): sampled by the serving
        pump's control ticks *and* on every ``submit`` — peaks between
        control ticks used to be invisible under bursty arrivals."""
        self._queue_depth.get().set(int(depth))
        self._oldest_wait.get().set(float(oldest_wait_s))

    def record_shed(self, graph: str = UNATTRIBUTED) -> None:
        """One arriving query rejected by admission control (HTTP 429)."""
        self._queries_shed.labels(graph=graph).inc()

    def record_deadline_shed(self, graph: str = UNATTRIBUTED) -> None:
        """One query dropped at wave launch because its admission wait had
        already exceeded its deadline (HTTP 504) — serving it late would
        burn compute on an answer the caller stopped waiting for."""
        self._queries_deadline_shed.labels(graph=graph).inc()

    def record_query_latency(self, graph: str, seconds: float) -> None:
        """One admitted query's submit → resolution latency (cache hits
        record ~0) — the distribution the latency SLO is evaluated over."""
        self._query_latency.labels(graph=graph).observe(seconds)

    def record_slo_advisory(self, action: str) -> None:
        """The SLO monitor steered the admission ladder: ``deepen`` /
        ``degrade`` pushed by burn, or ``veto`` (quality burning blocked a
        degrade that queue depth alone would have taken)."""
        self._slo_advisory.labels(action=action).inc()

    def record_shed_transition(self, engaged: bool) -> None:
        """Load shedding switched on (high-water crossed) or off (drained
        below the low-water mark)."""
        (self._shed_engaged if engaged else self._shed_recovered).get().inc()

    def record_slo_transition(self, degraded: bool) -> None:
        """The SLO controller imposed (or lifted) the degraded quality-target
        ceiling on ``precision="auto"`` resolution."""
        (self._slo_degrade if degraded else self._slo_recover).get().inc()

    def record_degraded_query(self, graph: str = UNATTRIBUTED) -> None:
        """One auto query resolved against a stepped-down quality target."""
        self._slo_degraded_queries.labels(graph=graph).inc()

    def record_kappa_change(self, deepened: bool) -> None:
        """Backpressure batching moved the wave depth: deepened under load,
        or relaxed back toward the base κ as the queue drained."""
        (self._kappa_deepen if deepened else self._kappa_relax).get().inc()

    # ------------------------------------------------------------------
    # legacy read surface (everything below is derived from the registry)
    # ------------------------------------------------------------------
    @staticmethod
    def _labeled(family, cast=int) -> Dict[str, float]:
        return {labels[0][1]: cast(inst.value)
                for labels, inst in family.series()}

    @staticmethod
    def _family_total(family) -> int:
        """Sum across a labeled family's series — the legacy scalar view of a
        now-per-graph counter (a family with no series yet totals 0)."""
        return int(sum(inst.value for _, inst in family.series()))

    @property
    def waves(self) -> int:
        return int(self._waves.get().value)

    @property
    def queries_served(self) -> int:
        return self._family_total(self._queries)

    @property
    def queries_served_by_graph(self) -> Dict[str, int]:
        return self._labeled(self._queries)

    @property
    def cache_hits(self) -> int:
        return int(self._cache_hits.get().value)

    @property
    def cache_misses(self) -> int:
        return int(self._cache_misses.get().value)

    @property
    def wave_latencies_s(self) -> List[float]:
        """Percentile sample of wave latencies (exact history while shorter
        than ``reservoir_size``) — the bounded heir of the legacy list."""
        return self._wave_latency_q.get().values()

    @property
    def wave_occupancies(self) -> List[float]:
        return self._occupancy_q.get().values()

    @property
    def wave_precisions(self) -> List[str]:
        return list(self._wave_precisions)

    @property
    def wave_latencies_by_engine(self) -> Dict[str, List[float]]:
        return {labels[0][1]: inst.values()
                for labels, inst in self._engine_latency_q.series()}

    @property
    def shadow_scores(self) -> List[float]:
        return self._shadow_quality_q.get().values()

    @property
    def served_by_precision(self) -> Dict[str, int]:
        return self._labeled(self._served_by_precision)

    @property
    def auto_resolved(self) -> Dict[str, int]:
        return self._labeled(self._auto_resolved)

    @property
    def waves_by_mesh(self) -> Dict[str, int]:
        return self._labeled(self._waves_by_mesh)

    @property
    def queries_by_mesh(self) -> Dict[str, int]:
        return self._labeled(self._queries_by_mesh)

    @property
    def shadow_evaluations(self) -> int:
        return self._shadow_quality.get().count

    @property
    def early_exit_waves(self) -> int:
        return int(self._early_exit_waves.get().value)

    @property
    def iterations_saved(self) -> int:
        return int(self._iterations_saved.get().value)

    @property
    def deltas_applied(self) -> int:
        return int(self._deltas_applied.get().value)

    @property
    def edges_added(self) -> int:
        return int(self._edges_added.get().value)

    @property
    def edges_removed(self) -> int:
        return int(self._edges_removed.get().value)

    @property
    def scoped_invalidations(self) -> int:
        return int(self._scoped_invalidations.get().value)

    @property
    def scoped_cache_retained(self) -> int:
        return int(self._scoped_cache_retained.get().value)

    @property
    def warm_start_waves(self) -> int:
        return int(self._warm_start_waves.get().value)

    @property
    def warm_start_columns(self) -> int:
        return int(self._warm_start_columns.get().value)

    @property
    def warm_start_iterations_saved(self) -> int:
        return int(self._warm_start_saved.get().value)

    @property
    def prefetch_issued(self) -> int:
        return int(self._prefetch_issued.get().value)

    @property
    def prefetch_suppressed(self) -> int:
        return int(self._prefetch_suppressed.get().value)

    @property
    def queue_depth_last(self) -> int:
        return int(self._queue_depth.get().value)

    @property
    def queue_depth_peak(self) -> int:
        return int(self._queue_depth.get().peak)

    @property
    def oldest_wait_last_s(self) -> float:
        return self._oldest_wait.get().value

    @property
    def oldest_wait_peak_s(self) -> float:
        return self._oldest_wait.get().peak

    @property
    def queries_shed(self) -> int:
        return self._family_total(self._queries_shed)

    @property
    def queries_shed_by_graph(self) -> Dict[str, int]:
        return self._labeled(self._queries_shed)

    @property
    def queries_deadline_shed(self) -> int:
        return self._family_total(self._queries_deadline_shed)

    @property
    def queries_deadline_shed_by_graph(self) -> Dict[str, int]:
        return self._labeled(self._queries_deadline_shed)

    @property
    def slo_advisories(self) -> Dict[str, int]:
        return self._labeled(self._slo_advisory)

    @property
    def shed_engaged_events(self) -> int:
        return int(self._shed_engaged.get().value)

    @property
    def shed_recovered_events(self) -> int:
        return int(self._shed_recovered.get().value)

    @property
    def slo_degrade_events(self) -> int:
        return int(self._slo_degrade.get().value)

    @property
    def slo_recover_events(self) -> int:
        return int(self._slo_recover.get().value)

    @property
    def slo_degraded_queries(self) -> int:
        return self._family_total(self._slo_degraded_queries)

    @property
    def slo_degraded_queries_by_graph(self) -> Dict[str, int]:
        return self._labeled(self._slo_degraded_queries)

    @property
    def kappa_deepen_events(self) -> int:
        return int(self._kappa_deepen.get().value)

    @property
    def kappa_relax_events(self) -> int:
        return int(self._kappa_relax.get().value)

    # ------------------------------------------------------------------
    def summary(self) -> Dict[str, float]:
        lat = np.asarray(self.wave_latencies_s, np.float64)
        # the histogram's sum/count cover *every* wave ever (the reservoir
        # may be a sample); totals and means stay exact under eviction
        total_s = self._wave_latency.get().sum
        cache_total = self.cache_hits + self.cache_misses
        occ = self._occupancy.get()
        shadow = self._shadow_quality.get()
        out = {
            "waves": self.waves,
            "queries_served": self.queries_served,
            "queries_per_s": self.queries_served / total_s if total_s else 0.0,
            "wave_latency_p50_s": float(np.percentile(lat, 50)) if lat.size else 0.0,
            "wave_latency_p95_s": float(np.percentile(lat, 95)) if lat.size else 0.0,
            "mean_occupancy": occ.mean,
            "cache_hits": self.cache_hits,
            "cache_misses": self.cache_misses,
            "cache_hit_rate": self.cache_hits / cache_total if cache_total else 0.0,
            "shadow_evaluations": self.shadow_evaluations,
            "shadow_quality_mean": shadow.mean,
            "early_exit_waves": self.early_exit_waves,
            "iterations_saved": self.iterations_saved,
            "deltas_applied": self.deltas_applied,
            "edges_added": self.edges_added,
            "edges_removed": self.edges_removed,
            "scoped_invalidations": self.scoped_invalidations,
            "scoped_cache_retained": self.scoped_cache_retained,
            "warm_start_waves": self.warm_start_waves,
            "warm_start_columns": self.warm_start_columns,
            "warm_start_iterations_saved": self.warm_start_iterations_saved,
            "prefetch_issued": self.prefetch_issued,
            "prefetch_suppressed": self.prefetch_suppressed,
            "queue_depth": self.queue_depth_last,
            "queue_depth_peak": self.queue_depth_peak,
            "oldest_wait_s": self.oldest_wait_last_s,
            "oldest_wait_peak_s": self.oldest_wait_peak_s,
            "queries_shed": self.queries_shed,
            "queries_deadline_shed": self.queries_deadline_shed,
            "shed_engaged_events": self.shed_engaged_events,
            "shed_recovered_events": self.shed_recovered_events,
            "slo_degrade_events": self.slo_degrade_events,
            "slo_recover_events": self.slo_recover_events,
            "slo_degraded_queries": self.slo_degraded_queries,
            "kappa_deepen_events": self.kappa_deepen_events,
            "kappa_relax_events": self.kappa_relax_events,
        }
        for pkey, n in sorted(self.served_by_precision.items()):
            out[f"served_{pkey}"] = n
        for pkey, n in sorted(self.auto_resolved.items()):
            out[f"auto_{pkey}"] = n
        for mkey, n in sorted(self.waves_by_mesh.items()):
            out[f"waves_{mkey}"] = n
        for mkey, n in sorted(self.queries_by_mesh.items()):
            out[f"queries_{mkey}"] = n
        for ekey, stats in sorted(self.engine_stats().items()):
            for stat, v in stats.items():
                out[f"engine_{ekey}_{stat}"] = v
        for stage, stats in sorted(self.stage_stats().items()):
            out[f"stage_{stage}_total_s"] = stats["total_s"]
            out[f"stage_{stage}_mean_s"] = stats["mean_s"]
        aw = self._admission_wait.get()
        if aw.count:
            out["admission_wait_mean_s"] = aw.mean
            out["admission_wait_p95_s"] = \
                self._admission_wait_q.get().percentile(95)
        return out

    def engine_stats(self) -> Dict[str, Dict[str, float]]:
        """Per-engine wave-latency stats: count / mean / p95 per concrete
        engine key — the observability of the backend layer (which datapath
        served what, and how fast).  Count and mean come from the histogram
        (exact forever); p95 from the bounded reservoir sample."""
        out: Dict[str, Dict[str, float]] = {}
        samples = {labels[0][1]: inst
                   for labels, inst in self._engine_latency_q.series()}
        for labels, hist in self._engine_latency.series():
            ekey = labels[0][1]
            sample = samples.get(ekey)
            vals = np.asarray(sample.values() if sample else [], np.float64)
            out[ekey] = {
                "waves": int(hist.count),
                "latency_mean_s": hist.mean,
                "latency_p95_s": float(np.percentile(vals, 95))
                if vals.size else 0.0,
            }
        return out

    def stage_stats(self) -> Dict[str, Dict[str, float]]:
        """Per-stage wave timing (count / total / mean) — where a wave's
        milliseconds go: plan vs iterate vs top-K vs resolve."""
        out: Dict[str, Dict[str, float]] = {}
        for labels, hist in self._stage.series():
            if not hist.count:
                continue
            out[labels[0][1]] = {
                "count": int(hist.count),
                "total_s": hist.sum,
                "mean_s": hist.mean,
            }
        return out
