"""Async result-cache prefetcher — warm predicted-hot vertices between waves.

The ROADMAP follow-on: the LRU result cache and wave telemetry were built so
that a prefetcher could be *measured*, not just bolted on.  ``Prefetcher``
ranks personalization vertices by recent real-query frequency (telemetry's
``query_vertex_counts``) and, during idle pumps (no wave was launchable), the
service issues synthetic ``PPRQuery``s for the hottest uncached vertices and
launches them immediately.  Their results land in the LRU exactly like real
wave results, so the warmed-hit-rate shows up in the existing ``lru_*``
counters: synthetic traffic never touches the submit-path ``cache_*`` /
``lru_*`` hit/miss stats (membership probes are counter-free), so every hit
they later absorb is a real query that skipped its wave.

Synthetic queries are issued under the cache key real traffic probes: each
vertex's last real (k, resolved precision) when telemetry has seen one —
``precision="auto"`` traffic records its post-resolution format, which is the
rung the controller would resolve next — falling back to the config's ``k``
at the controller's currently resolved format for the graph.

Composition with delta ingestion: ``PPRService.apply_delta`` reports the hot
vertices its scoped invalidation dropped; they enter the re-warm queue and are
re-issued ahead of merely-popular vertices on the next idle pump.
"""
from __future__ import annotations

import dataclasses
import heapq
import time
from collections import OrderedDict
from typing import Dict, Iterable, List, Mapping, MutableMapping, Optional


@dataclasses.dataclass(frozen=True)
class PrefetchConfig:
    """Policy for synthetic cache-warming traffic.

    ``top_n``        hottest vertices considered per graph per idle pump.
    ``k``            fallback top-k for synthetic queries; the service prefers
                     the vertex's last real-query k so the warmed cache key is
                     the one real traffic probes (clamped to the graph's V-1).
    ``max_per_pump`` global cap on synthetic queries issued per idle pump —
                     prefetch compute must never crowd out a real wave.
    ``min_count``    a vertex must have this many recent real queries to be
                     considered hot (and to earn a re-warm after a delta).
    ``half_life_s``  exponential half-life of the demand counts (seconds):
                     before each idle pump ranks candidates, every vertex's
                     count is scaled by ``0.5 ** (elapsed / half_life_s)`` —
                     a vertex hot an hour ago no longer ranks hot forever.
                     None (the default) keeps the legacy cumulative counts.
    ``suppress_depth`` admission-queue depth at which an otherwise-idle poll
                     skips prefetch entirely: pending live queries mean the
                     service is between waves, not idle, and synthetic warm-up
                     compute must yield.  None (the default) uses the
                     service's κ — a full wave's worth queued is traffic.
    """
    top_n: int = 16
    k: int = 10
    max_per_pump: int = 8
    min_count: int = 2
    half_life_s: Optional[float] = None
    suppress_depth: Optional[int] = None

    def __post_init__(self):
        if self.top_n < 1 or self.k < 1 or self.max_per_pump < 1:
            raise ValueError("top_n, k and max_per_pump must be >= 1")
        if self.min_count < 1:
            raise ValueError("min_count must be >= 1")
        if self.half_life_s is not None and not self.half_life_s > 0:
            raise ValueError(f"half_life_s must be > 0 (or None), "
                             f"got {self.half_life_s}")
        if self.suppress_depth is not None and self.suppress_depth < 1:
            raise ValueError(f"suppress_depth must be >= 1 (or None), "
                             f"got {self.suppress_depth}")


class Prefetcher:
    """Rank hot vertices; remember delta-invalidated ones for re-warming."""

    def __init__(self, config: PrefetchConfig = PrefetchConfig(),
                 time_fn=time.monotonic):
        self.config = config
        self.time_fn = time_fn           # injectable clock (demand decay)
        # graph → ordered set of delta-invalidated hot vertices (FIFO)
        self._rewarm: Dict[str, "OrderedDict[int, None]"] = {}
        # graph → last demand-decay timestamp; a graph never decayed before
        # falls back to the construction stamp, so demand accumulated during
        # a long poll-free stretch still ages on the *first* idle poll
        self._last_decay: Dict[str, float] = {}
        self._start = time_fn()
        self.issued = 0
        self.rewarms_queued = 0
        self.suppressed = 0            # idle polls skipped: live queue was deep

    def decay_demand(self, graph: str, counts: MutableMapping[int, float],
                     now: Optional[float] = None,
                     last_seen: Optional[MutableMapping[int, tuple]] = None
                     ) -> None:
        """Exponentially age ``counts`` in place by the time elapsed since the
        last decay of this graph (no-op without a configured half-life).

        Counts that cool below a small floor are pruned outright — they can
        never clear ``min_count`` again without fresh traffic, and pruning
        keeps the demand map from accumulating dead vertices.  ``last_seen``
        (telemetry's per-vertex (k, precision) map) is pruned in lockstep:
        its only other pruning path is the compaction threshold on the counts
        map, which decay keeps small enough to never fire — without this it
        would grow one entry per vertex ever queried."""
        hl = self.config.half_life_s
        if hl is None:
            return
        now = self.time_fn() if now is None else now
        last = self._last_decay.get(graph, self._start)
        if now <= last:
            return               # stamps only advance: an out-of-order `now`
        self._last_decay[graph] = now   # must not rewind and over-age later
        factor = 0.5 ** ((now - last) / hl)
        for v in list(counts):
            cooled = counts[v] * factor
            if cooled < 0.05:
                del counts[v]
                if last_seen is not None:
                    last_seen.pop(v, None)
            else:
                counts[v] = cooled

    def note_invalidated(self, graph: str, vertices: Iterable[int]) -> None:
        """Hot vertices whose cache entries a delta's scoped invalidation
        dropped: first in line at the next idle pump."""
        queue = self._rewarm.setdefault(graph, OrderedDict())
        for v in vertices:
            if int(v) not in queue:
                queue[int(v)] = None
                self.rewarms_queued += 1

    def drop_graph(self, graph: str) -> None:
        """Full re-registration: queued re-warms describe a dead topology."""
        self._rewarm.pop(graph, None)
        self._last_decay.pop(graph, None)

    def candidates(self, graph: str, counts: Mapping[int, int],
                   limit: Optional[int] = None) -> List[int]:
        """Up to ``limit`` vertices worth warming, most urgent first: the
        re-warm queue (consumed FIFO, but only as many as ``limit`` allows —
        the remainder stays queued for the next idle pump), then the
        ``top_n`` hottest by real-query count.  The caller filters out
        vertices that are already cached or out of range."""
        limit = self.config.max_per_pump if limit is None else limit
        out: List[int] = []
        queue = self._rewarm.get(graph)
        while queue and len(out) < limit:
            v, _ = queue.popitem(last=False)
            out.append(v)
        hot = heapq.nsmallest(
            self.config.top_n,
            (v for v, n in counts.items() if n >= self.config.min_count),
            key=lambda v: (-counts[v], v))
        for v in hot:
            if len(out) >= limit:
                break
            if v not in out:
                out.append(v)
        return out

    def stats(self) -> Dict[str, float]:
        return {
            "issued": self.issued,
            "suppressed": self.suppressed,
            "rewarms_queued": self.rewarms_queued,
            "rewarms_pending": sum(len(q) for q in self._rewarm.values()),
        }
