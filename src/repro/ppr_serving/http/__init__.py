"""HTTP serving tier over the futures API — the ROADMAP's "front door".

PR 5 left ``submit() → PPRFuture`` + ``poll()``/``flush()`` driven only by
in-process benchmark loops; this package serves them over a network with the
control plane a production tier needs:

``server.py``     ``ServingApp`` (transport-agnostic routes + status mapping)
                  behind ``AsyncioHTTPTransport`` (stdlib asyncio streams,
                  HTTP/1.1 keep-alive — no new runtime deps, tier-1 stays
                  hermetic); ``PPRHTTPServer`` assembles app + admission +
                  pump with one lifecycle.  The transport seam is where a
                  FastAPI/uvicorn adapter lands later.
``admission.py``  Bounded wave-queue admission with hysteretic load shedding
                  (429 + Retry-After past the high-water mark), backpressure-
                  aware κ-deepening, and SLO-aware quality degradation —
                  ``precision="auto"`` resolves against a stepped-down
                  quality target while the queue is deep, recovering when it
                  drains.  Every decision lands in ``ServiceTelemetry``.
``pump.py``       The asyncio heartbeat calling ``poll()`` on deadline —
                  waves launch, futures resolve, parked handlers respond.
``schemas.py``    stdlib-JSON request/response schemas (``SchemaError`` →
                  400), shaped for a later 1:1 pydantic mapping.
``client.py``     Keep-alive asyncio JSON client for benches/tests/examples.
"""
from repro.ppr_serving.http.admission import AdmissionConfig, AdmissionController
from repro.ppr_serving.http.client import AsyncHTTPClient, http_request
from repro.ppr_serving.http.pump import WavePump
from repro.ppr_serving.http.schemas import (PPRRequestSchema, SchemaError,
                                            error_payload,
                                            recommendation_payload)
from repro.ppr_serving.http.server import (AsyncioHTTPTransport, HTTPRequest,
                                           HTTPResponse, PPRHTTPServer,
                                           ServingApp)

__all__ = [
    "AdmissionConfig", "AdmissionController",
    "AsyncHTTPClient", "http_request",
    "WavePump",
    "PPRRequestSchema", "SchemaError",
    "error_payload", "recommendation_payload",
    "AsyncioHTTPTransport", "HTTPRequest", "HTTPResponse",
    "PPRHTTPServer", "ServingApp",
]
