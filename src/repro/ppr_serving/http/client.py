"""Tiny asyncio HTTP/1.1 JSON client — the load half of the serving tier.

Exists so the traffic generator (benchmarks/bench_serving_http.py), the e2e
tests and the example can drive the real server over real sockets without a
new runtime dependency.  One ``AsyncHTTPClient`` holds one keep-alive
connection — a closed-loop "user"; open N of them for N-way concurrency.
Not a general HTTP client: JSON bodies, Content-Length framing, no TLS.
"""
from __future__ import annotations

import asyncio
import json
from typing import Any, Dict, Optional, Tuple

__all__ = ["AsyncHTTPClient", "http_request"]


class AsyncHTTPClient:
    def __init__(self, host: str, port: int):
        self.host = host
        self.port = port
        self._reader: Optional[asyncio.StreamReader] = None
        self._writer: Optional[asyncio.StreamWriter] = None

    async def _connect(self) -> None:
        self._reader, self._writer = await asyncio.open_connection(
            self.host, self.port)

    async def close(self) -> None:
        if self._writer is not None:
            self._writer.close()
            try:
                await self._writer.wait_closed()
            except (ConnectionResetError, BrokenPipeError):
                pass
            self._reader = self._writer = None

    async def request(self, method: str, path: str,
                      body: Optional[Dict[str, Any]] = None
                      ) -> Tuple[int, Dict[str, str], Dict[str, Any]]:
        """One request/response on the keep-alive connection; reconnects
        once if the server closed it between requests.  Returns
        ``(status, headers, json_payload)``."""
        payload = b"" if body is None else json.dumps(body).encode("utf-8")
        for attempt in (0, 1):
            if self._writer is None:
                await self._connect()
            try:
                self._write_request(method, path, payload)
                await self._writer.drain()
                return await self._read_response()
            except (ConnectionResetError, BrokenPipeError,
                    asyncio.IncompleteReadError):
                await self.close()
                if attempt:
                    raise
        raise RuntimeError("unreachable")

    # ------------------------------------------------------------------
    def _write_request(self, method: str, path: str, payload: bytes) -> None:
        head = [f"{method} {path} HTTP/1.1",
                f"Host: {self.host}:{self.port}",
                "Content-Type: application/json",
                f"Content-Length: {len(payload)}"]
        self._writer.write(("\r\n".join(head) + "\r\n\r\n").encode("latin1")
                           + payload)

    async def _read_response(self
                             ) -> Tuple[int, Dict[str, str], Dict[str, Any]]:
        status_line = await self._reader.readline()
        if not status_line:
            raise asyncio.IncompleteReadError(b"", None)
        status = int(status_line.decode("latin1").split()[1])
        headers: Dict[str, str] = {}
        while True:
            h = await self._reader.readline()
            if h in (b"\r\n", b"\n", b""):
                break
            name, _, value = h.decode("latin1").partition(":")
            headers[name.strip().lower()] = value.strip()
        length = int(headers.get("content-length", "0") or 0)
        raw = await self._reader.readexactly(length) if length else b""
        if not raw:
            return status, headers, {}
        # /v1/metrics serves Prometheus text exposition, not JSON — hand
        # non-JSON bodies back as decoded text instead of crashing
        if "application/json" in headers.get("content-type",
                                             "application/json"):
            return status, headers, json.loads(raw)
        return status, headers, raw.decode("utf-8")


async def http_request(host: str, port: int, method: str, path: str,
                       body: Optional[Dict[str, Any]] = None
                       ) -> Tuple[int, Dict[str, str], Dict[str, Any]]:
    """One-shot convenience wrapper: connect, request, close."""
    client = AsyncHTTPClient(host, port)
    try:
        return await client.request(method, path, body)
    finally:
        await client.close()
