"""The HTTP front door: transport-agnostic app core + asyncio transport.

Layering (so a FastAPI adapter can land later without touching policy):

    AsyncioHTTPTransport        stdlib asyncio streams, HTTP/1.1 keep-alive
        │  HTTPRequest → HTTPResponse
    ServingApp                  routes + status mapping + future awaiting
        │  PPRQuery → PPRFuture
    AdmissionController         shed / degrade / deepen (admission.py)
    WavePump                    drives poll() on deadline (pump.py)
    PPRService                  the futures API (everything below is PR 1-5)

Endpoints:

    POST /v1/ppr      submit one query; 200 with ranked recommendations,
                      400 bad request, 404 unknown graph, 429 + Retry-After
                      shed, 409 delta-invalidated, 410 graph-replaced,
                      504 deadline-exceeded (dropped at wave launch)
    GET  /v1/healthz  liveness + registered graphs + queue depth
    GET  /v1/stats    full ServiceTelemetry summary + admission + pump stats
    GET  /v1/metrics  the metrics registry in Prometheus text exposition
                      format (0.0.4); ``?format=json`` for the JSON dump
    GET  /v1/slo      SLO monitor status: per-spec state + per-window burn
                      rates + recent alert transitions (404 when the
                      service runs without an SLO monitor)
    GET  /v1/debug/traces   flight-recorder snapshot (last completed traces
                      + control-plane events); ``?n=K`` bounds both lists

Status mapping is the rejection-path contract: a ``QueryRejected`` future is
a *client-actionable* outcome (resubmit), never a 500 — and the future is
consumed (its exception read) on every path, so rejected queries cannot leak
pending futures or "exception was never retrieved" noise.
"""
from __future__ import annotations

import asyncio
import dataclasses
from typing import Any, Dict, Optional, Tuple
from urllib.parse import parse_qs

from repro.obs import prometheus_text
from repro.ppr_serving.futures import QueryRejected
from repro.ppr_serving.http.admission import AdmissionConfig, AdmissionController
from repro.ppr_serving.http.pump import WavePump
from repro.ppr_serving.http.schemas import (PPRRequestSchema, SchemaError,
                                            dumps, error_payload,
                                            recommendation_payload)
from repro.ppr_serving.service import AUTO_KEY, PPRQuery

__all__ = ["HTTPRequest", "HTTPResponse", "ServingApp",
           "AsyncioHTTPTransport", "PPRHTTPServer"]

_REASONS = {200: "OK", 400: "Bad Request", 404: "Not Found",
            405: "Method Not Allowed", 409: "Conflict", 410: "Gone",
            429: "Too Many Requests", 500: "Internal Server Error",
            504: "Gateway Timeout"}

#: QueryRejected.code → HTTP status (the rejection-path contract)
_REJECT_STATUS = {"graph-replaced": 410, "delta-invalidated": 409,
                  "deadline-exceeded": 504}


@dataclasses.dataclass(frozen=True)
class HTTPRequest:
    method: str
    path: str
    headers: Dict[str, str]            # keys lower-cased
    body: bytes = b""


@dataclasses.dataclass(frozen=True)
class HTTPResponse:
    status: int
    payload: Dict[str, Any]            # JSON body (ignored when body is set)
    headers: Tuple[Tuple[str, str], ...] = ()
    # non-JSON responses (the Prometheus text exposition) set the raw body
    # and its content type; ``payload`` then goes unrendered
    body: Optional[bytes] = None
    content_type: str = "application/json"


class ServingApp:
    """Routes HTTP requests onto the futures API.  Transport-agnostic: any
    adapter that can build an ``HTTPRequest`` and render an ``HTTPResponse``
    (asyncio streams today, FastAPI/uvicorn later) serves the same policy."""

    def __init__(self, service, admission: Optional[AdmissionController] = None,
                 pump: Optional[WavePump] = None):
        self.service = service
        self.admission = admission
        self.pump = pump
        self.requests = 0

    # ------------------------------------------------------------------
    async def handle(self, req: HTTPRequest) -> HTTPResponse:
        self.requests += 1
        path, _, query_string = req.path.partition("?")
        params = {k: v[-1] for k, v in parse_qs(query_string).items()}
        route = (req.method.upper(), path)
        if route == ("POST", "/v1/ppr"):
            return await self._handle_ppr(req)
        if route == ("GET", "/v1/healthz"):
            return self._handle_healthz()
        if route == ("GET", "/v1/stats"):
            return self._handle_stats()
        if route == ("GET", "/v1/metrics"):
            return self._handle_metrics(params)
        if route == ("GET", "/v1/slo"):
            return self._handle_slo(params)
        if route == ("GET", "/v1/debug/traces"):
            return self._handle_traces(params)
        if path in ("/v1/ppr", "/v1/healthz", "/v1/stats", "/v1/metrics",
                    "/v1/slo", "/v1/debug/traces"):
            return HTTPResponse(405, error_payload(
                f"method {req.method} not allowed on {path}",
                "method-not-allowed"))
        return HTTPResponse(404, error_payload(
            f"no route {req.method} {path} "
            f"(have POST /v1/ppr, GET /v1/healthz, GET /v1/stats, "
            f"GET /v1/metrics, GET /v1/slo, GET /v1/debug/traces)",
            "unknown-route"))

    # ------------------------------------------------------------------
    async def _handle_ppr(self, req: HTTPRequest) -> HTTPResponse:
        try:
            spec = PPRRequestSchema.parse(req.body)
        except SchemaError as e:
            return HTTPResponse(400, error_payload(str(e), "bad-request"))

        if self.admission is not None:
            retry_after = self.admission.admit(graph=spec.graph)
            if retry_after is not None:
                return HTTPResponse(
                    429,
                    error_payload(
                        "admission queue is over its high-water mark — load "
                        "shed; retry after the hinted backoff",
                        "shed", retry_after_s=retry_after),
                    headers=(("Retry-After", f"{retry_after:.3f}"),))

        # the degradation decision the response reports: taken at submit
        # time, when resolution happens — not when the wave later runs
        ceiling = self.service.controller.target_ceiling
        degraded = False
        if spec.precision == AUTO_KEY and ceiling is not None:
            requested = (self.service.controller.config.default_target
                         if spec.quality_target is None
                         else float(spec.quality_target))
            degraded = ceiling < requested

        q = PPRQuery(graph=spec.graph, vertex=spec.vertex, k=spec.k,
                     precision=spec.precision,
                     quality_target=spec.quality_target,
                     deadline=spec.deadline_s)
        try:
            fut = self.service.submit(q)
        except KeyError as e:
            return HTTPResponse(404, error_payload(
                str(e).strip('"\''), "unknown-graph"))
        except ValueError as e:
            return HTTPResponse(400, error_payload(str(e), "bad-request"))

        try:
            rec = await self._await_future(fut)
        except QueryRejected as e:
            status = _REJECT_STATUS.get(e.code, 409)
            return HTTPResponse(status, error_payload(str(e), e.code))
        return HTTPResponse(200, recommendation_payload(rec, degraded=degraded))

    async def _await_future(self, fut):
        """Bridge a ``PPRFuture`` into the event loop: the pump resolves it
        from its poll cycles; this handler just parks until then."""
        loop = asyncio.get_running_loop()
        af: asyncio.Future = loop.create_future()

        def _done(f) -> None:
            def _transfer() -> None:
                if af.cancelled():
                    f.exception()      # consume: a gone client must not leak
                    return
                exc = f.exception()
                if exc is not None:
                    af.set_exception(exc)
                else:
                    af.set_result(f.result())
            # resolution happens inside pump/handler code already on this
            # loop, but threadsafe scheduling keeps an engine-thread future
            # resolution (a later offload) from corrupting the loop
            loop.call_soon_threadsafe(_transfer)

        fut.add_done_callback(_done)
        return await af

    # ------------------------------------------------------------------
    def _handle_healthz(self) -> HTTPResponse:
        svc = self.service
        return HTTPResponse(200, {
            "status": "ok",
            "graphs": list(svc.graphs),
            "queue_depth": svc.queue_depth(),
            "shedding": bool(self.admission.shedding) if self.admission else False,
            "degrading": bool(self.admission.degrading) if self.admission else False,
        })

    def _handle_stats(self) -> HTTPResponse:
        out: Dict[str, Any] = dict(self.service.telemetry_summary())
        if self.admission is not None:
            out.update({f"admission_{k}": v
                        for k, v in self.admission.stats().items()})
        if self.pump is not None:
            out["pump_cycles"] = self.pump.cycles
            out["pump_waves_launched"] = self.pump.waves_launched
        return HTTPResponse(200, out)

    def _handle_metrics(self, params: Dict[str, str]) -> HTTPResponse:
        """The bounded metrics registry — Prometheus text exposition by
        default (what a scraper ingests), ``?format=json`` for the flat
        JSON snapshot."""
        registry = self.service.telemetry.registry
        if params.get("format") == "json":
            return HTTPResponse(200, registry.as_dict())
        return HTTPResponse(
            200, {}, body=prometheus_text(registry).encode("utf-8"),
            content_type="text/plain; version=0.0.4; charset=utf-8")

    def _handle_slo(self, params: Dict[str, str]) -> HTTPResponse:
        """SLO monitor status: per-spec state, per-window burn rates, totals,
        plus the most recent alert transitions out of the flight recorder.
        Ticks the monitor first so a curl during a flood sees current burn,
        not the last heartbeat's."""
        slo = getattr(self.service, "slo", None)
        if slo is None:
            return HTTPResponse(404, error_payload(
                "this service runs without an SLO monitor — construct it "
                "with PPRService(slo=True) or pass --slo to ppr_run",
                "slo-monitoring-off"))
        slo.tick()
        out: Dict[str, Any] = slo.status()
        recorder = getattr(self.service, "recorder", None)
        if recorder is not None:
            n = 32
            if "n" in params:
                try:
                    n = max(0, int(params["n"]))
                except ValueError:
                    return HTTPResponse(400, error_payload(
                        f"n must be an integer, got {params['n']!r}",
                        "bad-request"))
            out["recent_events"] = recorder.events_of_kind(
                "slo_burning", "slo_recovered", "slo_advisory", n=n)
        return HTTPResponse(200, out)

    def _handle_traces(self, params: Dict[str, str]) -> HTTPResponse:
        """Flight-recorder snapshot: the last completed query/wave traces and
        control-plane events, ``?n=K`` limiting both lists."""
        recorder = getattr(self.service, "recorder", None)
        if recorder is None:
            return HTTPResponse(404, error_payload(
                "this service has no flight recorder", "no-recorder"))
        n: Optional[int] = None
        if "n" in params:
            try:
                n = max(0, int(params["n"]))
            except ValueError:
                return HTTPResponse(400, error_payload(
                    f"n must be an integer, got {params['n']!r}",
                    "bad-request"))
        snap = recorder.snapshot(n_traces=n, n_events=n)
        snap["tracing"] = getattr(self.service, "tracer", None) is not None
        return HTTPResponse(200, snap)


# ---------------------------------------------------------------------------
# asyncio streams transport
# ---------------------------------------------------------------------------
class AsyncioHTTPTransport:
    """Minimal HTTP/1.1 server over ``asyncio.start_server``: request-line +
    headers + Content-Length bodies, keep-alive by default, JSON responses.
    Deliberately small — the transport interface (``start``/``stop`` +
    ``host``/``port``) is the seam a production ASGI adapter replaces."""

    def __init__(self, app: ServingApp, host: str = "127.0.0.1",
                 port: int = 0):
        self.app = app
        self.host = host
        self.port = port               # 0 → ephemeral; real port after start()
        self._server: Optional[asyncio.AbstractServer] = None

    async def start(self) -> None:
        self._server = await asyncio.start_server(
            self._serve_connection, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]

    async def stop(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None

    # ------------------------------------------------------------------
    async def _serve_connection(self, reader: asyncio.StreamReader,
                                writer: asyncio.StreamWriter) -> None:
        try:
            while True:
                req = await self._read_request(reader)
                if req is None:
                    break
                try:
                    resp = await self.app.handle(req)
                except Exception as e:   # a handler bug must answer, not hang
                    resp = HTTPResponse(500, error_payload(
                        f"internal error: {e!r}", "internal"))
                self._write_response(writer, resp)
                await writer.drain()
                if req.headers.get("connection", "").lower() == "close":
                    break
        except (ConnectionResetError, asyncio.IncompleteReadError):
            pass                         # client went away mid-request
        finally:
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionResetError, BrokenPipeError):
                pass

    @staticmethod
    async def _read_request(reader: asyncio.StreamReader
                            ) -> Optional[HTTPRequest]:
        line = await reader.readline()
        if not line or line in (b"\r\n", b"\n"):
            return None
        parts = line.decode("latin1").split()
        if len(parts) < 2:
            return None
        method, path = parts[0], parts[1]
        headers: Dict[str, str] = {}
        while True:
            h = await reader.readline()
            if h in (b"\r\n", b"\n", b""):
                break
            name, _, value = h.decode("latin1").partition(":")
            headers[name.strip().lower()] = value.strip()
        length = int(headers.get("content-length", "0") or 0)
        body = await reader.readexactly(length) if length else b""
        return HTTPRequest(method=method, path=path, headers=headers,
                           body=body)

    @staticmethod
    def _write_response(writer: asyncio.StreamWriter,
                        resp: HTTPResponse) -> None:
        body = resp.body if resp.body is not None else dumps(resp.payload)
        reason = _REASONS.get(resp.status, "Unknown")
        head = [f"HTTP/1.1 {resp.status} {reason}",
                f"Content-Type: {resp.content_type}",
                f"Content-Length: {len(body)}"]
        head.extend(f"{k}: {v}" for k, v in resp.headers)
        writer.write(("\r\n".join(head) + "\r\n\r\n").encode("latin1") + body)


# ---------------------------------------------------------------------------
class PPRHTTPServer:
    """Batteries-included assembly: app + admission + pump + transport with
    one lifecycle.  ``port=0`` binds an ephemeral port (tests/benches read
    ``server.port`` after ``start``)."""

    def __init__(self, service, host: str = "127.0.0.1", port: int = 0,
                 admission: Optional[AdmissionConfig] = None,
                 pump_interval_s: float = 0.005):
        self.service = service
        self.admission = AdmissionController(service,
                                             admission or AdmissionConfig())
        self.pump = WavePump(service, self.admission,
                             interval_s=pump_interval_s)
        self.app = ServingApp(service, self.admission, self.pump)
        self.transport = AsyncioHTTPTransport(self.app, host=host, port=port)

    @property
    def host(self) -> str:
        return self.transport.host

    @property
    def port(self) -> int:
        return self.transport.port

    async def start(self) -> None:
        await self.transport.start()
        self.pump.start()

    async def stop(self) -> None:
        await self.transport.stop()    # stop accepting before final flush
        await self.pump.stop()

    async def serve_forever(self) -> None:
        await self.start()
        try:
            await asyncio.Event().wait()     # until cancelled (Ctrl-C)
        finally:
            await self.stop()
