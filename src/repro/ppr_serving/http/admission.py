"""Admission control for the HTTP serving tier — the load knobs, in order.

The service values low latency over exact convergence (the paper's whole
premise), so overload is met with *graceful degradation*, escalating as the
admission queue deepens:

1. **Deepen κ** (``deepen_water``): batch more personalization columns per
   wave before anything is refused — one edge-stream pass amortized over 2κ
   queries is the paper's own economics, bought at a modest per-wave latency
   cost.  Doublings only (each distinct κ compiles its own wave shapes),
   capped at ``kappa_max``; relaxes on the same thresholds going down.
2. **Degrade quality** (``degrade_water``): impose a quality-target ceiling
   on ``precision="auto"`` resolution (serve ``degraded_target`` — e.g. 0.93
   — instead of the requested 0.95), the serving-side turn of the paper's
   precision/quality dial.  Lifts at ``degrade_low_water`` (hysteresis).
3. **Shed** (``high_water``): reject new arrivals with HTTP 429 +
   ``Retry-After`` so admitted traffic keeps a bounded p95 instead of
   everyone timing out together.  Stops shedding only once the queue drains
   below ``low_water`` — the gap is what keeps shedding from flapping at the
   boundary.

Every decision is counted in ``ServiceTelemetry`` (the ``queries_shed`` /
``slo_*`` / ``kappa_*`` counters and the queue gauges), so ``/v1/stats`` is
the full audit trail of what quality was traded when, and whether it
recovered.

When the service carries an ``SLOMonitor`` (``PPRService(slo=...)``), the
controller closes the loop the monitor opens: each tick also advances the
monitor, and a *burning* latency or shed SLO pushes the same ladder —
κ deepens to at least its first rung and the quality ceiling engages even
while the queue alone looks healthy (burn is the leading indicator; depth
the trailing one).  A burning *quality* SLO does the opposite: it vetoes
the degrade step (and lifts an active ceiling), because trading more
quality while the quality objective is already out of budget digs the
hole deeper.  Every SLO-driven move is counted
(``ppr_slo_advisory_total{action=deepen|degrade|veto}``) and lands in the
flight recorder, so depth-driven and burn-driven decisions stay
distinguishable after the fact.

The controller is transport-independent: it only needs a ``PPRService`` (its
``queue_depth``/``set_kappa``/``degrade_quality``/``restore_quality`` hooks)
and a clock — unit tests drive it with a fake depth signal and no sockets.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional

__all__ = ["AdmissionConfig", "AdmissionController"]


@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    """Water marks are admission-queue depths (pending queries).  Defaults
    suit a κ=8 service; scale them with κ — the useful mental unit is
    "waves' worth of queries queued"."""
    high_water: int = 64           # shed new arrivals above this depth
    low_water: int = 16            # stop shedding once drained to this
    deepen_water: int = 16         # start deepening κ at this depth
    kappa_max: int = 64            # ceiling for deepened κ
    degrade_water: int = 32        # impose the quality ceiling above this
    degrade_low_water: int = 8     # lift it once drained to this
    degraded_target: float = 0.93  # the stepped-down quality target served
    retry_after_s: float = 0.1     # hint on 429 responses

    def __post_init__(self):
        if not 0 < self.low_water <= self.high_water:
            raise ValueError(
                f"need 0 < low_water <= high_water, got "
                f"{self.low_water}/{self.high_water}")
        if not 0 < self.degrade_low_water <= self.degrade_water:
            raise ValueError(
                f"need 0 < degrade_low_water <= degrade_water, got "
                f"{self.degrade_low_water}/{self.degrade_water}")
        if self.deepen_water < 1:
            raise ValueError(f"deepen_water must be >= 1, "
                             f"got {self.deepen_water}")
        if self.kappa_max < 1:
            raise ValueError(f"kappa_max must be >= 1, got {self.kappa_max}")
        if not 0.0 < self.degraded_target <= 1.0:
            raise ValueError(f"degraded_target must be in (0, 1], "
                             f"got {self.degraded_target}")
        if self.retry_after_s <= 0:
            raise ValueError(f"retry_after_s must be > 0, "
                             f"got {self.retry_after_s}")


class AdmissionController:
    """Hysteretic shed/degrade/deepen state machine over the service's
    queue-depth signal."""

    def __init__(self, service, config: AdmissionConfig = AdmissionConfig(),
                 slo=None):
        self.service = service
        self.config = config
        self.base_kappa = service.kappa
        if config.kappa_max < self.base_kappa:
            raise ValueError(
                f"kappa_max={config.kappa_max} is below the service's base "
                f"kappa={self.base_kappa} — the controller only deepens")
        # the burn-rate monitor feeding the advisory signal: explicit, or
        # the service's own (PPRService(slo=...)); None keeps the controller
        # purely depth-driven, bit-identical to the pre-SLO behavior
        self.slo = slo if slo is not None else getattr(service, "slo", None)
        self.shedding = False
        self.degrading = False
        self.admitted = 0
        self.shed = 0

    # ------------------------------------------------------------------
    def target_kappa(self, depth: int) -> int:
        """Pure policy: κ for a given queue depth — one doubling per
        doubling of depth past ``deepen_water``, so the set of compiled wave
        shapes stays logarithmic in the overload."""
        kappa, thresh = self.base_kappa, self.config.deepen_water
        while depth >= thresh and kappa * 2 <= self.config.kappa_max:
            kappa *= 2
            thresh *= 2
        return kappa

    def tick(self, now: Optional[float] = None) -> int:
        """One control cycle: read the depth, update the three knobs, record
        the gauges.  Called by the pump every cycle and by ``admit`` on every
        arrival (depth moves fastest exactly when decisions matter most).
        Returns the depth it acted on."""
        svc, cfg = self.service, self.config
        depth = svc.queue_depth()
        svc.telemetry.record_queue_depth(depth, svc.oldest_wait_s(now))

        # SLO advisory: a burning latency/shed SLO pushes the ladder ahead
        # of queue depth; a burning quality SLO vetoes further degradation.
        push = veto = False
        if self.slo is not None:
            self.slo.tick(now)
            kinds = self.slo.burning_kinds()
            push = bool(kinds & {"latency", "shed"})
            veto = "quality" in kinds

        # burn counts as if the queue had already reached the deepen mark —
        # the first κ doubling lands before depth alone would take it
        kappa = self.target_kappa(
            max(depth, cfg.deepen_water) if push else depth)
        if kappa != svc.kappa:
            if push and kappa > svc.kappa and depth < cfg.deepen_water:
                self._advise("deepen", now, depth=depth)
            svc.set_kappa(kappa)       # counts deepen/relax in telemetry

        want_degrade = depth > cfg.degrade_water or push
        if veto:
            # quality budget already burning: do not trade more quality, and
            # lift an active ceiling rather than hold it
            if self.degrading:
                self._advise("veto", now, depth=depth)
                self.degrading = False
                svc.restore_quality()
            elif want_degrade:
                self._advise("veto", now, depth=depth)
        elif not self.degrading and want_degrade:
            if push and depth <= cfg.degrade_water:
                self._advise("degrade", now, depth=depth)
            self.degrading = True
            svc.degrade_quality(cfg.degraded_target)
        elif self.degrading and depth <= cfg.degrade_low_water and not push:
            self.degrading = False
            svc.restore_quality()

        if not self.shedding and depth > cfg.high_water:
            self.shedding = True
            svc.telemetry.record_shed_transition(engaged=True)
            self._event("shed_engaged", now, depth=depth)
        elif self.shedding and depth <= cfg.low_water:
            self.shedding = False
            svc.telemetry.record_shed_transition(engaged=False)
            self._event("shed_recovered", now, depth=depth)
        return depth

    def _advise(self, action: str, now: Optional[float], **attrs) -> None:
        """Count + record one SLO-driven ladder move (``deepen`` /
        ``degrade`` / ``veto``) — what separates burn-driven decisions from
        plain depth-driven ones in the audit trail."""
        telemetry = getattr(self.service, "telemetry", None)
        if telemetry is not None and hasattr(telemetry, "record_slo_advisory"):
            telemetry.record_slo_advisory(action)
        self._event("slo_advisory", now, action=action, **attrs)

    def _event(self, kind: str, now: Optional[float], **attrs) -> None:
        """Shed transitions into the service's flight recorder, when it has
        one — unit tests drive this controller with bare stub services."""
        recorder = getattr(self.service, "recorder", None)
        if recorder is None:
            return
        if now is None:
            now = getattr(self.service, "time_fn", time.monotonic)()
        recorder.record_event(kind, now, **attrs)

    def admit(self, now: Optional[float] = None,
              graph: Optional[str] = None) -> Optional[float]:
        """Per-arrival decision: ``None`` admits; a float sheds, carrying the
        ``Retry-After`` hint in seconds.  ``graph`` attributes a shed to the
        graph whose traffic was rejected (the per-graph counter label)."""
        self.tick(now)
        if self.shedding:
            self.shed += 1
            if graph is None:
                self.service.telemetry.record_shed()
            else:
                self.service.telemetry.record_shed(graph=graph)
            return self.config.retry_after_s
        self.admitted += 1
        return None

    def stats(self) -> Dict[str, float]:
        out = {
            "admitted": self.admitted,
            "shed": self.shed,
            "shedding": self.shedding,
            "degrading": self.degrading,
            "kappa": self.service.kappa,
            "base_kappa": self.base_kappa,
        }
        if self.slo is not None:
            out["slo_burning"] = sorted(self.slo.burning())
        return out
