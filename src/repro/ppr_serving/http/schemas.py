"""Wire schemas of the HTTP serving tier — stdlib-JSON in, stdlib-JSON out.

No pydantic: tier-1 stays hermetic.  Each schema is a frozen dataclass with
an explicit ``parse`` that raises ``SchemaError`` (→ HTTP 400) with a message
naming the offending field, mirroring the descriptive-validation house style
of ``PPRService.submit``.  A FastAPI adapter can later map these 1:1 onto
pydantic models without touching the transport-agnostic app core.

``POST /v1/ppr`` request body::

    {"graph": "social", "vertex": 17, "k": 10,
     "precision": "auto",            # null/"f32" | bits | "Q1.25" | "auto"
     "quality_target": 0.95,         # only meaningful with "auto"
     "deadline_s": 0.05}             # admission-wait budget (optional)

Response body (200)::

    {"graph": ..., "vertex": ..., "k": ...,
     "precision": "Q1.25",           # resolved precision actually served
     "source": "wave" | "cache", "wave_id": ..., "latency_s": ...,
     "degraded": false,              # true ⇒ served under the SLO ceiling
     "recommendations": [{"vertex": 3, "score": 0.013}, ...]}

Errors are ``{"error": <message>, "code": <machine-readable>}`` with the code
mirroring ``QueryRejected.code`` where one exists.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Optional, Union

__all__ = ["SchemaError", "PPRRequestSchema", "recommendation_payload",
           "error_payload", "dumps"]


class SchemaError(ValueError):
    """Malformed request body — maps to HTTP 400."""


def _require(obj: Dict[str, Any], field: str, types, type_name: str):
    if field not in obj:
        raise SchemaError(f"missing required field {field!r}")
    v = obj[field]
    # bool is an int subclass; an explicit true/false vertex is a client bug
    if isinstance(v, bool) or not isinstance(v, types):
        raise SchemaError(f"field {field!r} must be {type_name}, "
                          f"got {type(v).__name__}")
    return v


def _optional(obj: Dict[str, Any], field: str, types, type_name: str,
              default=None):
    if field not in obj or obj[field] is None:
        return default
    v = obj[field]
    if isinstance(v, bool) or not isinstance(v, types):
        raise SchemaError(f"field {field!r} must be {type_name} or null, "
                          f"got {type(v).__name__}")
    return v


@dataclasses.dataclass(frozen=True)
class PPRRequestSchema:
    """Validated ``POST /v1/ppr`` body, still transport-side: precision stays
    the wire value (``submit`` owns format resolution and its errors)."""
    graph: str
    vertex: int
    k: int = 10
    precision: Union[None, int, str] = None
    quality_target: Optional[float] = None
    deadline_s: Optional[float] = None

    @classmethod
    def parse(cls, body: bytes) -> "PPRRequestSchema":
        if not body:
            raise SchemaError("empty request body (expected a JSON object)")
        try:
            obj = json.loads(body)
        except json.JSONDecodeError as e:
            raise SchemaError(f"request body is not valid JSON: {e}") from None
        if not isinstance(obj, dict):
            raise SchemaError(f"request body must be a JSON object, "
                              f"got {type(obj).__name__}")
        known = {"graph", "vertex", "k", "precision", "quality_target",
                 "deadline_s"}
        unknown = sorted(set(obj) - known)
        if unknown:
            raise SchemaError(f"unknown field(s) {unknown} "
                              f"(expected a subset of {sorted(known)})")
        return cls(
            graph=_require(obj, "graph", str, "a string"),
            vertex=_require(obj, "vertex", int, "an integer"),
            k=_optional(obj, "k", int, "an integer", default=10),
            precision=_optional(obj, "precision", (int, str),
                                "an integer bit-width or a string"),
            quality_target=_optional(obj, "quality_target", (int, float),
                                     "a number"),
            deadline_s=_optional(obj, "deadline_s", (int, float), "a number"),
        )


def recommendation_payload(rec, degraded: bool = False) -> Dict[str, Any]:
    """JSON-ready dict for a resolved ``Recommendation``."""
    return {
        "graph": rec.query.graph,
        "vertex": int(rec.query.vertex),
        "k": int(rec.query.k),
        "precision": rec.precision,
        "source": rec.source,
        "wave_id": int(rec.wave_id),
        "latency_s": float(rec.latency_s),
        "degraded": bool(degraded),
        "recommendations": [
            {"vertex": int(v), "score": float(s)}
            for v, s in zip(rec.vertices, rec.scores)
        ],
    }


def error_payload(message: str, code: str,
                  retry_after_s: Optional[float] = None) -> Dict[str, Any]:
    out: Dict[str, Any] = {"error": message, "code": code}
    if retry_after_s is not None:
        out["retry_after_s"] = float(retry_after_s)
    return out


def dumps(payload: Dict[str, Any]) -> bytes:
    return json.dumps(payload, separators=(",", ":")).encode("utf-8")
