"""The event-loop pump — what actually drives the futures API on deadline.

`PPRFuture` + ``poll()``/``flush()`` were designed to be driven by an event
loop; this is that loop's heartbeat.  A single asyncio task alternates

    admission.tick()  →  service.poll()  →  sleep(interval)

so deadline-expired partial waves launch within one interval of their
admission budget, full waves launch on the next cycle, and the admission
controller's shed/degrade/deepen state tracks the queue even when no
requests are arriving (recovery transitions happen *here*, as the queue
drains, not on the next arrival).

Wave compute is synchronous JAX and runs inside the tick, blocking the loop
for the wave's duration — the single-process cost of a no-new-runtime-deps
tier.  Arrivals buffer in the kernel meanwhile and flood the admission
controller when the loop resumes, which is exactly the depth spike the
controller exists to meter.  A process-pool engine offload is the natural
next step and slots in behind ``service.poll`` without touching this loop.
"""
from __future__ import annotations

import asyncio
from typing import Optional

__all__ = ["WavePump"]


class WavePump:
    """Owns the poll/tick task; start() is idempotent, stop() flushes."""

    def __init__(self, service, admission=None, interval_s: float = 0.005):
        if interval_s <= 0:
            raise ValueError(f"interval_s must be > 0, got {interval_s}")
        self.service = service
        self.admission = admission
        self.interval_s = interval_s
        self.cycles = 0
        self.waves_launched = 0
        self._task: Optional[asyncio.Task] = None
        # mirror the loop counters into the service's metrics registry so
        # /v1/metrics can answer "is the heartbeat alive" without /v1/stats
        registry = getattr(getattr(service, "telemetry", None),
                           "registry", None)
        if registry is not None:
            self._cycles_metric = registry.counter(
                "ppr_pump_cycles_total", "Pump heartbeat cycles run.")
            self._waves_metric = registry.counter(
                "ppr_pump_waves_launched_total",
                "Waves launched from pump cycles (incl. the stop flush).")
        else:
            self._cycles_metric = self._waves_metric = None

    # ------------------------------------------------------------------
    def start(self) -> None:
        if self._task is not None and not self._task.done():
            return
        self._task = asyncio.get_running_loop().create_task(
            self._run(), name="ppr-wave-pump")

    async def stop(self) -> None:
        """Cancel the heartbeat, then flush: every admitted future resolves
        (shutdown must not leak pending futures — in-flight HTTP handlers
        are awaiting them)."""
        if self._task is not None:
            self._task.cancel()
            try:
                await self._task
            except asyncio.CancelledError:
                pass
            self._task = None
        flushed = self.service.flush()
        self.waves_launched += flushed
        if self._waves_metric is not None and flushed:
            self._waves_metric.get().inc(flushed)
        if self.admission is not None:
            self.admission.tick()      # record the drained queue / recovery

    async def _run(self) -> None:
        while True:
            self.cycles += 1
            if self._cycles_metric is not None:
                self._cycles_metric.get().inc()
            if self.admission is not None:
                self.admission.tick()
            launched = self.service.poll()
            self.waves_launched += launched
            if self._waves_metric is not None and launched:
                self._waves_metric.get().inc(launched)
            # a launch may have unblocked more ready waves (κ changed, or a
            # deadline expired mid-wave) — loop immediately while productive,
            # yielding to the loop so handlers can run between waves
            if launched:
                await asyncio.sleep(0)
            else:
                await asyncio.sleep(self.interval_s)
