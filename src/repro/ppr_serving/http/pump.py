"""The event-loop pump — what actually drives the futures API on deadline.

`PPRFuture` + ``poll()``/``flush()`` were designed to be driven by an event
loop; this is that loop's heartbeat.  A single asyncio task alternates

    admission.tick()  →  service.poll()  →  sleep(interval)

so deadline-expired partial waves launch within one interval of their
admission budget, full waves launch on the next cycle, and the admission
controller's shed/degrade/deepen state tracks the queue even when no
requests are arriving (recovery transitions happen *here*, as the queue
drains, not on the next arrival).  The heartbeat also carries the
observability duties that need a clock: SLO burn-rate evaluation (through
``admission.tick`` when a controller is attached, directly otherwise) and
OTLP export cycles (span-batch drains + periodic delta metric pushes, run
off the loop thread like wave compute; the stop path flushes the exporter
so shutdown loses no queued telemetry).

Wave compute is synchronous JAX; by default it is offloaded to a dedicated
single worker thread (``offload=True``), so the event loop keeps admitting,
shedding, and answering health checks *during* a wave — the ROADMAP item-3
seam this docstring used to only mark.  One worker means at most one wave
pipeline runs at a time (JAX dispatch stays serialized, exactly as before);
``PPRService`` guards its scheduler/cache/controller mutations with an
internal lock so loop-thread ``submit()`` can interleave with worker-thread
``poll()``.  ``offload=False`` restores the old in-loop behavior for
single-threaded debugging.
"""
from __future__ import annotations

import asyncio
from concurrent.futures import ThreadPoolExecutor
from typing import Optional

__all__ = ["WavePump"]


class WavePump:
    """Owns the poll/tick task; start() is idempotent, stop() flushes."""

    def __init__(self, service, admission=None, interval_s: float = 0.005,
                 offload: bool = True):
        if interval_s <= 0:
            raise ValueError(f"interval_s must be > 0, got {interval_s}")
        self.service = service
        self.admission = admission
        self.interval_s = interval_s
        self.offload = offload
        self.cycles = 0
        self.waves_launched = 0
        self._task: Optional[asyncio.Task] = None
        self._executor: Optional[ThreadPoolExecutor] = None
        # mirror the loop counters into the service's metrics registry so
        # /v1/metrics can answer "is the heartbeat alive" without /v1/stats
        registry = getattr(getattr(service, "telemetry", None),
                           "registry", None)
        if registry is not None:
            self._cycles_metric = registry.counter(
                "ppr_pump_cycles_total", "Pump heartbeat cycles run.")
            self._waves_metric = registry.counter(
                "ppr_pump_waves_launched_total",
                "Waves launched from pump cycles (incl. the stop flush).")
        else:
            self._cycles_metric = self._waves_metric = None

    # ------------------------------------------------------------------
    def start(self) -> None:
        if self._task is not None and not self._task.done():
            return
        if self.offload and self._executor is None:
            # one worker: waves stay serialized, the stop() flush queues
            # behind any in-flight poll on the same thread
            self._executor = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="ppr-wave")
        self._task = asyncio.get_running_loop().create_task(
            self._run(), name="ppr-wave-pump")

    async def _drive(self, fn) -> int:
        """Run one service-driving call (poll/flush) off the loop thread."""
        if self._executor is None:
            # repro: allow[ASY303] offload=False is the explicit single-threaded debug mode; blocking is opted into
            return fn()
        return await asyncio.get_running_loop().run_in_executor(
            self._executor, fn)

    async def stop(self) -> None:
        """Cancel the heartbeat, then flush: every admitted future resolves
        (shutdown must not leak pending futures — in-flight HTTP handlers
        are awaiting them)."""
        if self._task is not None:
            self._task.cancel()
            try:
                await self._task
            except asyncio.CancelledError:
                pass
            self._task = None
        flushed = await self._drive(self.service.flush)
        self.waves_launched += flushed
        if self._waves_metric is not None and flushed:
            self._waves_metric.get().inc(flushed)
        if self.admission is not None:
            self.admission.tick()      # record the drained queue / recovery
        elif getattr(self.service, "slo", None) is not None:
            self.service.slo.tick()
        if getattr(self.service, "otlp", None) is not None:
            # final export: queued spans and the closing delta window must
            # not die with the process
            await self._drive(lambda: self.service.otlp.flush(
                self.service.telemetry.registry))
        if self._executor is not None:
            self._executor.shutdown(wait=True)
            self._executor = None

    async def _run(self) -> None:
        while True:
            self.cycles += 1
            if self._cycles_metric is not None:
                self._cycles_metric.get().inc()
            if self.admission is not None:
                self.admission.tick()
            elif getattr(self.service, "slo", None) is not None:
                # no admission controller to carry the monitor: evaluate the
                # SLOs on the heartbeat anyway (alerting without the ladder)
                self.service.slo.tick()
            launched = await self._drive(self.service.poll)
            self.waves_launched += launched
            if self._waves_metric is not None and launched:
                self._waves_metric.get().inc(launched)
            otlp = getattr(self.service, "otlp", None)
            if otlp is not None and otlp.due():
                # exporter I/O (HTTP POSTs) stays off the event loop, like
                # wave compute; an idle cycle pays only the due() check
                await self._drive(self.service.export_telemetry)
            # a launch may have unblocked more ready waves (κ changed, or a
            # deadline expired mid-wave) — loop immediately while productive,
            # yielding to the loop so handlers can run between waves
            if launched:
                await asyncio.sleep(0)
            else:
                await asyncio.sleep(self.interval_s)
