"""`PPRService` — the multi-tenant query-serving facade over the numeric core.

Lifecycle: graphs are registered once (host arrays moved to device, edge
stream padded to packets, per-format quantized values cached; with ``mesh=``
additionally partitioned by destination range over a mesh axis for
multi-device serving), then queries flow through

    submit → precision resolution ("auto" → controller) → result cache probe
           → κ-batch scheduler → wave launch → step-driven PPR iterations
           (early-exit on convergence) → streaming top-K → cache fill
           → shadow quality feedback

A wave shares one edge stream over up to κ personalization columns (the
paper's κ-batching); each wave is driven one eq. (1) iteration at a time via
``ppr_step_float`` / ``make_ppr_fixed_step``, which is what lets the
convergence monitor (repro.autotune.convergence, paper Fig. 7) stop a wave at
the fixed-point absorbing state instead of burning the full budget.  Results
are ranked ``Recommendation``s — the query vertex itself is always excluded
from its own top-k.

``precision="auto"`` queries are resolved to a concrete format *before wave
admission* by the adaptive-precision controller (repro.autotune.controller),
so auto traffic batches into the same waves as explicit same-format traffic.
After a fixed-precision wave, a sampled fraction of its auto queries is
shadow-scored against a float32 reference run to keep the controller's
quality estimates current (paper Figs. 4-6 measured online).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.autotune.controller import AutotuneConfig, PrecisionController
from repro.autotune.convergence import ConvergencePolicy, run_until_converged
from repro.core.coo import COOGraph
from repro.core.fixed_point import PAPER_FORMATS, QFormat, format_for_bits
from repro.core.metrics import ranking
from repro.core.ppr import (
    make_ppr_fixed_step,
    make_ppr_sharded_fixed_step,
    make_ppr_sharded_float_step,
    personalization_matrix,
    personalization_matrix_fixed,
    ppr_float,
    ppr_step_float,
)
from repro.core.spmv import partition_edges_by_dst
from repro.ppr_serving.cache import LRUCache
from repro.ppr_serving.scheduler import Wave, WaveScheduler
from repro.ppr_serving.telemetry import SINGLE_DEVICE_KEY, ServiceTelemetry
from repro.ppr_serving.topk import topk_dense, topk_streaming

Precision = Union[None, int, str, QFormat]

FLOAT_KEY = "f32"
AUTO_KEY = "auto"


def normalize_precision(precision: Precision) -> Optional[QFormat]:
    """None/"f32" → float32 path; int bits / "Q1.f" / QFormat → fixed path.

    ``"auto"`` is *not* a concrete precision — the service resolves it through
    the precision controller before anything needs a QFormat."""
    if precision == AUTO_KEY:
        raise ValueError('precision="auto" must be resolved by the service\'s '
                         'precision controller before normalization')
    if precision is None or precision == FLOAT_KEY:
        return None
    if isinstance(precision, QFormat):
        return precision
    if isinstance(precision, int):
        return format_for_bits(precision)
    if isinstance(precision, str):
        if precision in PAPER_FORMATS:
            return PAPER_FORMATS[precision]
        if precision.startswith("Q") and precision.count(".") == 1:
            i, f = precision[1:].split(".")
            try:
                return QFormat(int(i), int(f))
            except ValueError:
                pass   # malformed digits ("Q1.25x") → the descriptive error
    raise ValueError(f"unknown precision spec: {precision!r}")


def precision_key(precision: Precision) -> str:
    fmt = normalize_precision(precision)
    return FLOAT_KEY if fmt is None else fmt.name


@dataclasses.dataclass(frozen=True)
class PPRQuery:
    """One recommendation request.

    ``deadline`` bounds how long the query may wait in the admission queue for
    its wave to fill (seconds); it does not bound the iteration time itself.

    ``precision="auto"`` asks the service's precision controller for the
    cheapest Q format currently meeting ``quality_target`` (NDCG against the
    float32 reference; the controller's default target when None).
    ``quality_target`` is ignored for explicit precisions.
    """
    graph: str
    vertex: int
    k: int = 10
    precision: Precision = None
    deadline: Optional[float] = None
    quality_target: Optional[float] = None


@dataclasses.dataclass
class Recommendation:
    query: PPRQuery
    vertices: np.ndarray           # [k] ranked vertex ids (self excluded)
    scores: np.ndarray             # [k] float scores (dequantized for fixed)
    source: str                    # "wave" | "cache"
    wave_id: int = -1
    latency_s: float = 0.0
    precision: str = ""            # resolved precision key ("f32" / "Q1.f")


class RegisteredGraph:
    """Device-resident graph state, prepared once at registration.

    The full-layout edge stream (``x``/``y``/``val``) is uploaded eagerly —
    every single-device wave reads it.  ``ShardedRegisteredGraph`` defers that
    upload: its waves read only the partitioned shards, and the full layout is
    materialized lazily iff something actually needs it (the float32 shadow
    reference for sampled ``precision="auto"`` traffic) — a meshed graph is
    registered precisely because one device's memory is tight."""

    mesh_key = SINGLE_DEVICE_KEY   # waves on this graph run single-device

    _defer_full_upload = False

    def __init__(self, name: str, g: COOGraph, packet: int = 256):
        self.name = name
        self.graph = g.pad_to_packets(packet)
        self.num_vertices = g.num_vertices
        self.dangling = jnp.asarray(self.graph.dangling)
        self._full_device: Optional[Tuple[jnp.ndarray, ...]] = None
        self._quantized: Dict[QFormat, jnp.ndarray] = {}
        if not self._defer_full_upload:
            self._full()

    def _full(self) -> Tuple[jnp.ndarray, ...]:
        if self._full_device is None:
            self._full_device = (jnp.asarray(self.graph.x),
                                 jnp.asarray(self.graph.y),
                                 jnp.asarray(self.graph.val))
        return self._full_device

    @property
    def x(self) -> jnp.ndarray:
        return self._full()[0]

    @property
    def y(self) -> jnp.ndarray:
        return self._full()[1]

    @property
    def val(self) -> jnp.ndarray:
        return self._full()[2]

    def quantized(self, fmt: QFormat) -> jnp.ndarray:
        if fmt not in self._quantized:
            self._quantized[fmt] = jnp.asarray(self.graph.quantized_val(fmt))
        return self._quantized[fmt]

    # ---- wave step construction (overridden by the sharded variant) -------
    def float_step(self, alpha: float):
        """callable(Vmat, P) → P_next for one float32 eq. (1) iteration."""
        def step(Vmat, P):
            return ppr_step_float(self.x, self.y, self.val, self.dangling,
                                  Vmat, P, num_vertices=self.num_vertices,
                                  alpha=alpha)
        return step

    def fixed_step(self, fmt: QFormat, alpha: float):
        """callable(Vmat, P) → P_next, bit-exact in ``fmt``'s raw domain."""
        body = make_ppr_fixed_step(fmt, self.num_vertices, alpha)
        val_raw = self.quantized(fmt)

        def step(Vmat, P):
            return body(self.x, self.y, val_raw, self.dangling, Vmat, P)
        return step


class ShardedRegisteredGraph(RegisteredGraph):
    """A registered graph whose edge stream is partitioned over a
    ``jax.sharding.Mesh`` axis (the paper's multi-channel partitioning, scaled
    to multi-device): waves on it run the sharded step bodies of
    ``repro.core.ppr``.

    The host owns the partitioning/packaging step (the CPU–FPGA synergy
    argument of arXiv 2004.13907): edges are bucketed by destination range
    once at registration — per quantized format too, through the same
    dtype-preserving partitioner, so fixed-point shards are the exact raw
    values the single-device path would stream.  The base class's full-layout
    device arrays are deferred (see its docstring): only the float32 shadow
    reference materializes them, on first sampled auto query.
    """

    _defer_full_upload = True

    def __init__(self, name: str, g: COOGraph, mesh, axis: Optional[str] = None,
                 packet: int = 256):
        super().__init__(name, g, packet=packet)
        self.mesh = mesh
        self.axis = axis if axis is not None else mesh.axis_names[0]
        if self.axis not in mesh.axis_names:
            raise ValueError(f"mesh has no axis {self.axis!r} "
                             f"(axes: {mesh.axis_names})")
        self.n_shards = int(mesh.shape[self.axis])
        self.mesh_key = f"mesh:{self.axis}x{self.n_shards}"
        self._packet = packet
        sx, sy, sval = partition_edges_by_dst(
            self.graph.x, self.graph.y, self.graph.val,
            self.num_vertices, self.n_shards, packet=packet)
        self.sharded_x = jnp.asarray(sx)
        self.sharded_y = jnp.asarray(sy)
        self.sharded_val = jnp.asarray(sval)
        self._sharded_quantized: Dict[QFormat, jnp.ndarray] = {}

    def sharded_quantized(self, fmt: QFormat) -> jnp.ndarray:
        """Raw uint32 edge shard values in the partitioned layout (cached)."""
        if fmt not in self._sharded_quantized:
            _, _, sval = partition_edges_by_dst(
                self.graph.x, self.graph.y, self.graph.quantized_val(fmt),
                self.num_vertices, self.n_shards, packet=self._packet)
            self._sharded_quantized[fmt] = jnp.asarray(sval)
        return self._sharded_quantized[fmt]

    def float_step(self, alpha: float):
        body = make_ppr_sharded_float_step(self.mesh, self.axis,
                                           self.num_vertices, alpha)

        def step(Vmat, P):
            return body(self.sharded_x, self.sharded_y, self.sharded_val,
                        self.dangling, Vmat, P)
        return step

    def fixed_step(self, fmt: QFormat, alpha: float):
        body = make_ppr_sharded_fixed_step(fmt, self.mesh, self.axis,
                                           self.num_vertices, alpha)
        val_raw = self.sharded_quantized(fmt)

        def step(Vmat, P):
            return body(self.sharded_x, self.sharded_y, val_raw,
                        self.dangling, Vmat, P)
        return step


class PPRService:
    """Facade: named graphs, κ-batched admission, cached ranked results,
    adaptive precision (``precision="auto"``) and early-exit iterations."""

    def __init__(
        self,
        kappa: int = 8,
        iterations: int = 10,
        alpha: float = 0.85,
        max_wait: float = 0.0,
        cache_capacity: int = 4096,
        topk_tile: Optional[int] = None,
        autotune: Optional[AutotuneConfig] = None,
        early_exit: Union[None, bool, ConvergencePolicy] = None,
        time_fn=time.monotonic,
    ):
        self.kappa = kappa
        self.iterations = iterations
        self.alpha = alpha
        self.topk_tile = topk_tile
        self.time_fn = time_fn
        self.scheduler = WaveScheduler(kappa, max_wait=max_wait, time_fn=time_fn)
        self.cache = LRUCache(cache_capacity)
        self.telemetry = ServiceTelemetry()
        self.controller = PrecisionController(autotune or AutotuneConfig())
        if early_exit is True:
            self.convergence: Optional[ConvergencePolicy] = ConvergencePolicy()
        else:
            self.convergence = early_exit or None
        self._graphs: Dict[str, RegisteredGraph] = {}
        self._wave_counter = 0

    # ------------------------------------------------------------------
    def register_graph(self, name: str, g: COOGraph,
                       formats: Sequence[Precision] = (),
                       packet: int = 256,
                       mesh=None, mesh_axis: Optional[str] = None
                       ) -> RegisteredGraph:
        """Move a graph to the device; optionally pre-quantize for ``formats``.

        ``mesh`` (a ``jax.sharding.Mesh``) registers the graph *sharded*: the
        edge stream is partitioned by destination range over ``mesh_axis``
        (default: the mesh's first axis) at registration, and every wave on
        the graph runs the sharded step bodies — same results, multi-device
        bandwidth.  ``num_vertices`` need not divide the shard count.

        Re-registering an existing name invalidates that graph's cached
        results, drops its still-pending queries (they were validated against
        the old topology — their vertices may be out of range in the new one,
        which JAX's scatter would silently ignore, serving garbage), and
        resets its quality estimates — nothing from the old topology may be
        served or steer the precision ladder."""
        if name in self._graphs:
            self.cache.invalidate(lambda key: key[0] == name)
            self.scheduler.purge(lambda key: key[0] == name)
            self.controller.forget_graph(name)
        if mesh is None:
            rg: RegisteredGraph = RegisteredGraph(name, g, packet=packet)
        else:
            rg = ShardedRegisteredGraph(name, g, mesh, axis=mesh_axis,
                                        packet=packet)
        for p in formats:
            fmt = normalize_precision(p)
            if fmt is not None:
                # sharded waves read only the partitioned quantized values —
                # skip the full-layout device upload for meshed graphs
                if isinstance(rg, ShardedRegisteredGraph):
                    rg.sharded_quantized(fmt)
                else:
                    rg.quantized(fmt)
        self._graphs[name] = rg
        return rg

    @property
    def graphs(self) -> Tuple[str, ...]:
        return tuple(self._graphs)

    # ------------------------------------------------------------------
    def _resolve_precision(self, q: PPRQuery) -> str:
        """Concrete precision key for a query; "auto" goes through the ladder."""
        if q.precision == AUTO_KEY:
            fmt = self.controller.resolve(q.graph, q.quality_target)
            pkey = FLOAT_KEY if fmt is None else fmt.name
            self.telemetry.record_auto_resolution(pkey)
            return pkey
        return precision_key(q.precision)

    def _cache_key(self, q: PPRQuery, pkey: str) -> Tuple:
        # resolved precision + iteration budget + early-exit mode: an
        # auto-resolved or early-exited result must never alias an entry
        # computed under different numerics
        return (q.graph, int(q.vertex), pkey, int(q.k),
                int(self.iterations), self.convergence is not None)

    def submit(self, q: PPRQuery) -> Optional[Recommendation]:
        """Cache probe; on miss, enqueue for the next wave and return None.

        Validation happens *here*, not at wave launch: an invalid ``k`` that
        only surfaced inside the wave's top-K (``k+1 > V``) would crash
        ``pump()`` and lose every co-batched query's result — one bad query
        must never poison a wave."""
        if q.graph not in self._graphs:
            raise KeyError(f"graph {q.graph!r} is not registered "
                           f"(have {list(self._graphs)})")
        rg = self._graphs[q.graph]
        if not 0 <= q.vertex < rg.num_vertices:
            raise ValueError(f"vertex {q.vertex} out of range for {q.graph!r}")
        if q.k < 1:
            raise ValueError(f"k must be >= 1, got {q.k}")
        if q.k > rg.num_vertices - 1:
            # self-exclusion means at most V-1 recommendable vertices
            raise ValueError(
                f"k={q.k} exceeds the {rg.num_vertices - 1} recommendable "
                f"vertices of {q.graph!r} (|V|={rg.num_vertices}, the query "
                f"vertex excludes itself)")
        pkey = self._resolve_precision(q)
        hit = self.cache.get(self._cache_key(q, pkey))
        self.telemetry.record_cache(hit is not None)
        if hit is not None:
            verts, scores = hit
            return Recommendation(q, verts.copy(), scores.copy(),
                                  source="cache", precision=pkey)
        self.scheduler.submit((q.graph, pkey, rg.mesh_key), q,
                              deadline=q.deadline)
        return None

    def pump(self, now: Optional[float] = None) -> List[Recommendation]:
        """Launch every wave the admission policy considers ready."""
        recs: List[Recommendation] = []
        for wave in self.scheduler.ready_waves(now=now):
            recs.extend(self._run_wave(wave))
        return recs

    def drain(self) -> List[Recommendation]:
        """Flush all pending queries regardless of occupancy."""
        recs: List[Recommendation] = []
        for wave in self.scheduler.drain():
            recs.extend(self._run_wave(wave))
        return recs

    def serve(self, queries: Sequence[PPRQuery]) -> List[Recommendation]:
        """Synchronous batch entry point: results in submission order.

        Waves complete out of submission order when precisions or graphs mix
        (each (graph, precision) group fills independently), so results are
        matched back by query identity, not queue position.
        """
        from collections import defaultdict, deque

        out: Dict[int, Recommendation] = {}
        slot: Dict[int, deque] = defaultdict(deque)   # id(query) → indices FIFO
        # Admit the whole batch before pumping so full κ-waves form regardless
        # of max_wait (submit-then-pump per query would flush 1-query partials
        # whenever max_wait=0).
        for i, q in enumerate(queries):
            rec = self.submit(q)
            if rec is not None:
                out[i] = rec
            else:
                slot[id(q)].append(i)
        # Queries queued via submit() before this serve() call ride along in
        # the same waves; their results are cached/telemetered but belong to
        # no slot here, so route only our own.
        for rec in self.pump() + self.drain():
            idxs = slot.get(id(rec.query))
            if idxs:
                out[idxs.popleft()] = rec
        return [out[i] for i in range(len(queries))]

    def telemetry_summary(self) -> Dict[str, float]:
        """Telemetry counters (cache_* = submit-path view) plus the LRU's own
        stats under lru_* — the two diverge once anything touches the cache
        outside submit() (e.g. a future async prefetcher) — plus the precision
        controller's ladder counters under autotune_*."""
        s = self.telemetry.summary()
        s.update({f"lru_{k}": v for k, v in self.cache.stats().items()})
        s.update({f"autotune_{k}": v for k, v in self.controller.summary().items()})
        return s

    # ------------------------------------------------------------------
    def _iterate(self, step, P0, *, fixed: bool, scale: Optional[int]):
        """Drive one wave's iterations; early-exit when a policy is armed."""
        if self.convergence is None:
            P = P0
            for _ in range(self.iterations):
                P = step(P)
            return P, self.iterations
        P, iters_run, _ = run_until_converged(
            step, P0, self.iterations, self.convergence, fixed=fixed,
            scale=scale, track_deltas=False)   # trace unused: skip its syncs
        return P, iters_run

    def _run_wave(self, wave: Wave) -> List[Recommendation]:
        graph_name, pkey, mesh_key = wave.key
        rg = self._graphs[graph_name]
        fmt = None if pkey == FLOAT_KEY else normalize_precision(pkey)
        t0 = self.time_fn()
        self._wave_counter += 1
        wave_id = self._wave_counter

        verts = [int(q.vertex) for q in wave.items]
        pad = self.kappa - len(verts)
        padded = verts + [verts[0]] * pad           # pad columns are discarded
        pers = jnp.asarray(np.asarray(padded, np.int32))

        # the graph decides how its waves iterate: single-device or mesh-sharded
        if fmt is None:
            Vmat = personalization_matrix(rg.num_vertices, pers)
            step = rg.float_step(self.alpha)
            P, iters_run = self._iterate(
                lambda P_: step(Vmat, P_), Vmat, fixed=False, scale=None)
        else:
            Vmat = personalization_matrix_fixed(rg.num_vertices, pers, fmt)
            step = rg.fixed_step(fmt, self.alpha)
            P, iters_run = self._iterate(
                lambda P_: step(Vmat, P_), Vmat, fixed=True, scale=fmt.scale)
        if iters_run < self.iterations:
            self.telemetry.record_early_exit(self.iterations - iters_run)

        k_max = max(q.k for q in wave.items)
        if self.topk_tile is not None:
            idx, vals = topk_streaming(P, k_max, v_tile=self.topk_tile,
                                       exclude=pers)
        else:
            idx, vals = topk_dense(P, k_max, exclude=pers)
        idx = np.asarray(idx)                        # [κ, k_max]
        vals = np.asarray(vals)
        scores = vals.astype(np.float64) / fmt.scale if fmt is not None \
            else vals.astype(np.float64)
        latency = self.time_fn() - t0

        recs = []
        for col, q in enumerate(wave.items):
            v_top = idx[col, : q.k].copy()
            s_top = scores[col, : q.k].copy()
            # the cache keeps its own copies: callers may mutate their
            # Recommendation arrays without poisoning later hits
            self.cache.put(self._cache_key(q, pkey), (v_top.copy(), s_top.copy()))
            recs.append(Recommendation(q, v_top, s_top, source="wave",
                                       wave_id=wave_id, latency_s=latency,
                                       precision=pkey))
        self.telemetry.record_wave(len(wave.items), self.kappa, latency, pkey,
                                   mesh_key=mesh_key)
        self._shadow_feedback(wave, rg, fmt, pkey, P)
        return recs

    # ------------------------------------------------------------------
    def _shadow_feedback(self, wave: Wave, rg: RegisteredGraph,
                         fmt: Optional[QFormat], pkey: str, P) -> None:
        """Quality feedback for the wave's auto queries (sampled).

        Every auto query consumes exactly one sampling draw (in wave order),
        so a replayed query sequence under a seeded estimator makes identical
        shadow decisions regardless of how the ladder moved in between.
        Float32-served auto queries are perfect by definition: their sampled
        observations feed the ladder and telemetry as 1.0 without running a
        reference, so ``shadow_quality_mean`` reflects *all* sampled auto
        traffic, not just the fixed-point share.

        The float32 reference runs only over the sampled columns — shadow
        cost genuinely scales with ``sample_fraction`` rather than being paid
        per wave.  (Each distinct sampled-column count compiles its own
        ``ppr_float`` variant; there are at most κ of them.)
        """
        estimator = self.controller.estimator
        sampled = [(col, q) for col, q in enumerate(wave.items)
                   if q.precision == AUTO_KEY and estimator.should_sample()]
        if not sampled:
            return
        if fmt is None:
            for _, q in sampled:
                self.controller.observe_quality(rg.name, FLOAT_KEY, 1.0,
                                                target=q.quality_target)
                self.telemetry.record_shadow(1.0)
            return
        pers_sub = jnp.asarray(
            np.asarray([int(q.vertex) for _, q in sampled], np.int32))
        if isinstance(rg, ShardedRegisteredGraph):
            # keep the reference on the mesh: running it through the full
            # single-device stream would force the deferred full-layout
            # upload onto one device — the memory pressure mesh registration
            # exists to avoid.  The sharded float step is numerically equal
            # to ppr_float (tests/test_sharded_serving.py).
            Vref = personalization_matrix(rg.num_vertices, pers_sub)
            ref_step = rg.float_step(self.alpha)
            P_ref = Vref
            for _ in range(self.iterations):
                P_ref = ref_step(Vref, P_ref)
        else:
            P_ref, _ = ppr_float(rg.x, rg.y, rg.val, rg.dangling, pers_sub,
                                 num_vertices=rg.num_vertices,
                                 iterations=self.iterations, alpha=self.alpha)
        ref = np.asarray(P_ref, np.float64)
        approx = np.asarray(P, np.float64) / fmt.scale
        for j, (col, q) in enumerate(sampled):
            ref_col = ref[:, j]
            score = self.controller.observe_shadow(
                rg.name, pkey, approx[:, col], ref_col,
                target=q.quality_target, ref_order=ranking(ref_col))
            self.telemetry.record_shadow(score)
