"""`PPRService` — the futures-based query front-end over the engine backends.

Lifecycle: graphs are registered once onto an engine family (host arrays
moved to device, edge stream padded to packets, per-format quantized values
cached; the "sharded" family additionally partitions by destination range
over a mesh axis), then queries flow through

    submit → precision resolution ("auto" → controller) → result cache probe
           → PPRFuture (resolved immediately on a hit; else queued)
           → κ-batch scheduler → wave launch → engine plan (step + iterate +
             early-exit + top-K) → futures resolve → cache fill
           → shadow quality feedback

``submit`` returns a ``PPRFuture`` per query; ``poll``/``flush`` (or a
pending future's own ``result()``) drive wave launches, and each completed
wave resolves its occupants' futures.  The legacy blocking entry points —
``serve``/``pump``/``drain`` — remain as thin compatibility wrappers over the
futures path and emit ``DeprecationWarning``.

A wave shares one edge stream over up to κ personalization columns (the
paper's κ-batching).  *How* a wave iterates is the engine backend's business
(``repro.ppr_serving.engine``): the graph's engine family resolves each wave
to a concrete engine ("float"/"fixed"/"sharded_float"/"sharded_fixed"), whose
``WavePlan`` binds the device arrays, the eq. (1) step, the iterate driver
(early-exit per the convergence monitor, paper Fig. 7) and the top-K
reduction.  Results are ranked ``Recommendation``s — the query vertex itself
is always excluded from its own top-k.

``precision="auto"`` queries are resolved to a concrete format *before wave
admission* by the adaptive-precision controller (repro.autotune.controller),
so auto traffic batches into the same waves as explicit same-format traffic.
After a fixed-precision wave, a sampled fraction of its auto queries is
shadow-scored against a float32 reference run to keep the controller's
quality estimates current (paper Figs. 4-6 measured online).
"""
from __future__ import annotations

import dataclasses
import random
import threading
import time
import warnings
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.autotune.controller import AutotuneConfig, PrecisionController
from repro.autotune.convergence import ConvergencePolicy
from repro.core.fixed_point import PAPER_FORMATS, QFormat, format_for_bits
from repro.core.metrics import ranking
from repro.graph_updates.delta import EdgeDelta
from repro.graph_updates.warmstart import WarmStartStore
from repro.obs import FlightRecorder, Tracer, fanout_sink
from repro.obs.otlp import OTLPExporter
from repro.obs.slo import SLOMonitor, SLOSpec, default_slo_specs
from repro.ppr_serving.cache import LRUCache
from repro.ppr_serving.engine import engine_families, engine_for, family_members
from repro.ppr_serving.futures import PPRFuture, QueryRejected
from repro.ppr_serving.graphs import RegisteredGraph, ShardedRegisteredGraph
from repro.ppr_serving.prefetch import PrefetchConfig, Prefetcher
from repro.ppr_serving.scheduler import Wave, WaveScheduler
from repro.ppr_serving.telemetry import SINGLE_DEVICE_KEY, ServiceTelemetry

Precision = Union[None, int, str, QFormat]

FLOAT_KEY = "f32"
AUTO_KEY = "auto"


def normalize_precision(precision: Precision) -> Optional[QFormat]:
    """None/"f32" → float32 path; int bits / "Q1.f" / QFormat → fixed path.

    ``"auto"`` is *not* a concrete precision — the service resolves it through
    the precision controller before anything needs a QFormat."""
    if precision == AUTO_KEY:
        raise ValueError('precision="auto" must be resolved by the service\'s '
                         'precision controller before normalization')
    if precision is None or precision == FLOAT_KEY:
        return None
    if isinstance(precision, QFormat):
        return precision
    if isinstance(precision, int):
        return format_for_bits(precision)
    if isinstance(precision, str):
        if precision in PAPER_FORMATS:
            return PAPER_FORMATS[precision]
        if precision.startswith("Q") and precision.count(".") == 1:
            i, f = precision[1:].split(".")
            try:
                return QFormat(int(i), int(f))
            except ValueError:
                pass   # malformed digits ("Q1.25x") → the descriptive error
    raise ValueError(f"unknown precision spec: {precision!r}")


def precision_key(precision: Precision) -> str:
    fmt = normalize_precision(precision)
    return FLOAT_KEY if fmt is None else fmt.name


@dataclasses.dataclass(frozen=True)
class PPRQuery:
    """One recommendation request.

    ``deadline`` bounds how long the query may wait in the admission queue for
    its wave to fill (seconds); it does not bound the iteration time itself.

    ``precision="auto"`` asks the service's precision controller for the
    cheapest Q format currently meeting ``quality_target`` (NDCG against the
    float32 reference; the controller's default target when None).
    ``quality_target`` is ignored for explicit precisions.
    """
    graph: str
    vertex: int
    k: int = 10
    precision: Precision = None
    deadline: Optional[float] = None
    quality_target: Optional[float] = None
    # synthetic cache-warming query issued by the prefetcher: computed and
    # cached like real traffic, but never returned from pump()/drain() and
    # never counted in the submit-path demand/cache telemetry
    prefetch: bool = False


@dataclasses.dataclass
class Recommendation:
    query: PPRQuery
    vertices: np.ndarray           # [k] ranked vertex ids (self excluded)
    scores: np.ndarray             # [k] float scores (dequantized for fixed)
    source: str                    # "wave" | "cache"
    wave_id: int = -1
    latency_s: float = 0.0
    precision: str = ""            # resolved precision key ("f32" / "Q1.f")


def _deprecated(old: str, new: str) -> None:
    warnings.warn(
        f"PPRService.{old}() is deprecated and will be removed once the "
        f"futures API has settled; use {new} instead",
        DeprecationWarning, stacklevel=3)


class PPRService:
    """Facade: named graphs on engine backends, κ-batched admission,
    futures-based results, an LRU result cache, adaptive precision
    (``precision="auto"``) and early-exit iterations."""

    def __init__(
        self,
        kappa: int = 8,
        iterations: int = 10,
        alpha: float = 0.85,
        max_wait: float = 0.0,
        cache_capacity: int = 4096,
        topk_tile: Optional[int] = None,
        autotune: Optional[AutotuneConfig] = None,
        early_exit: Union[None, bool, ConvergencePolicy] = None,
        warm_start: Union[bool, int] = False,
        prefetch: Union[None, bool, PrefetchConfig] = None,
        tracing: Union[bool, float] = False,
        reservoir_size: int = 1024,
        time_fn=time.monotonic,
        slo: Union[None, bool, Sequence[SLOSpec], SLOMonitor] = None,
        otlp: Optional[OTLPExporter] = None,
    ):
        """``warm_start`` seeds wave iterations from each personalization
        vertex's last converged column (True, or an int store capacity per
        graph) — pair it with ``early_exit`` so the shorter convergence
        distance actually saves iterations.  ``prefetch`` arms the idle-poll
        cache warmer (True, or a ``PrefetchConfig``).

        ``tracing`` arms per-query/per-wave span traces (completed traces
        land in ``self.recorder``, the flight recorder); off by default —
        the hot path then pays one ``is None`` check per instrumentation
        point.  ``tracing=True`` traces everything (byte-compatible with
        the pre-sampling behavior); a float in (0, 1) head-samples that
        fraction of queries with a seeded RNG so tracing can stay armed in
        production — a sampled-out query costs exactly one RNG draw, and
        sampled traces carry the rate as a ``sample_rate`` root attribute
        so an exporter backend can re-weight.  Wave traces are kept
        whenever any occupant is sampled.  The flight recorder itself is
        always on: control-plane events (deltas, κ moves, shed/SLO
        transitions) are cheap and are exactly what an incident postmortem
        needs.  ``reservoir_size`` bounds every telemetry percentile sample
        (see ``ServiceTelemetry``).

        ``slo`` arms the burn-rate monitor (``repro.obs.slo``): ``True``
        for the default spec set, a spec sequence, or a prebuilt
        ``SLOMonitor`` (its registry must be this service's telemetry
        registry).  ``otlp`` attaches an ``OTLPExporter``: completed traces
        fan out to it *beside* the flight recorder, and
        ``export_telemetry()`` (driven by the serving pump) pushes
        delta-temporality metrics.  Both default off and keep the zero-cost
        property — a ``None`` check per query.
        """
        self.kappa = kappa
        self.iterations = iterations
        self.alpha = alpha
        self.topk_tile = topk_tile
        self.time_fn = time_fn
        self.scheduler = WaveScheduler(kappa, max_wait=max_wait, time_fn=time_fn)
        self.cache = LRUCache(cache_capacity)
        self.telemetry = ServiceTelemetry(reservoir_size=reservoir_size)
        self.recorder = FlightRecorder()
        # tracing=True → rate 1.0 (byte-compatible full tracing); a float is
        # a head-sampling rate.  bool checked first: True/False are ints.
        rate = (1.0 if tracing is True else
                0.0 if tracing is False else float(tracing))
        if not 0.0 <= rate <= 1.0:
            raise ValueError(f"tracing rate must be in [0, 1], got {tracing}")
        self._trace_rate = rate
        # seeded: a replayed run samples the same queries (and the golden
        # OTLP snapshot stays stable)
        self._trace_rng = random.Random(0)
        self.otlp = otlp
        if otlp is not None and otlp._mirror is None:
            otlp.bind_registry(self.telemetry.registry)
        sink = self.recorder.record_trace if otlp is None else \
            fanout_sink(self.recorder.record_trace, otlp.record_trace)
        self.tracer: Optional[Tracer] = (
            Tracer(time_fn=time_fn, sink=sink) if rate > 0.0 else None)
        if slo is None or slo is False:
            self.slo: Optional[SLOMonitor] = None
        elif isinstance(slo, SLOMonitor):
            self.slo = slo
        else:
            specs = default_slo_specs() if slo is True else tuple(slo)
            self.slo = SLOMonitor(self.telemetry.registry, specs,
                                  time_fn=time_fn, recorder=self.recorder)
        self.controller = PrecisionController(autotune or AutotuneConfig())
        if early_exit is True:
            self.convergence: Optional[ConvergencePolicy] = ConvergencePolicy()
        else:
            self.convergence = early_exit or None
        if warm_start is True:
            self._warm: Optional[WarmStartStore] = WarmStartStore()
        elif warm_start:
            self._warm = WarmStartStore(capacity_per_graph=int(warm_start))
        else:
            self._warm = None
        if prefetch is True:
            self.prefetcher: Optional[Prefetcher] = Prefetcher(time_fn=time_fn)
        elif prefetch:
            self.prefetcher = Prefetcher(prefetch, time_fn=time_fn)
        else:
            self.prefetcher = None
        self._graphs: Dict[str, RegisteredGraph] = {}
        self._wave_counter = 0
        # Guards the quick mutation sections — scheduler pops/submits, cache,
        # controller and wave bookkeeping — so the HTTP pump can drive
        # poll()/flush() on a worker thread while the event-loop thread keeps
        # calling submit().  Engine compute (the long part of a wave) runs
        # OUTSIDE the lock; the pump's single worker already serializes waves.
        # RLock: PPRFuture.result() re-enters through _drive on the same
        # thread in the synchronous (no-pump) path.
        self._lock = threading.RLock()
        # last cold (unseeded) iteration count per (graph, precision): the
        # baseline warm_start_iterations_saved is measured against
        self._cold_iters: Dict[Tuple[str, str], int] = {}

    # ------------------------------------------------------------------
    def register_graph(self, name: str, g, formats: Sequence[Precision] = (),
                       packet: int = 256,
                       mesh=None, mesh_axis: Optional[str] = None,
                       engine: Optional[str] = None) -> RegisteredGraph:
        """Register a graph onto an engine family; optionally pre-quantize.

        ``engine`` names the backend family serving the graph's waves
        (``repro.ppr_serving.engine.engine_families()``): "single" iterates
        the full edge stream on one device, "sharded" partitions it by
        destination range over ``mesh``/``mesh_axis`` at registration (same
        results — bit-identical on the fixed path — multi-device bandwidth;
        ``num_vertices`` need not divide the shard count).  Default: "sharded"
        when a mesh is given, else "single".

        Re-registering an existing name invalidates that graph's cached
        results, rejects its still-pending futures (they were validated
        against the old topology — their vertices may be out of range in the
        new one, which JAX's scatter would silently ignore, serving garbage),
        and resets its quality estimates — nothing from the old topology may
        be served or steer the precision ladder."""
        with self._lock:  # a registration must not race a worker-thread wave launch
            return self._register_graph_locked(name, g, formats, packet,
                                               mesh, mesh_axis, engine)

    def _register_graph_locked(self, name, g, formats, packet, mesh,
                               mesh_axis, engine) -> RegisteredGraph:
        family = engine if engine is not None else \
            ("sharded" if mesh is not None else "single")
        if family not in engine_families():
            raise ValueError(f"unknown engine family {family!r} "
                             f"(have {list(engine_families())})")
        # family-level metadata resolves through any member: fixed-only
        # plug-in families are legal and must be able to register
        members = family_members(family)
        needs_mesh = members[0].needs_mesh
        if needs_mesh and mesh is None:
            raise ValueError(f"engine {family!r} needs a mesh= at registration")
        if not needs_mesh and mesh is not None:
            raise ValueError(f"engine {family!r} runs single-device — drop "
                             f"mesh= or pick a sharded family "
                             f"(have {list(engine_families())})")
        if name in self._graphs:
            self.cache.invalidate(lambda key: key[0] == name)
            for _key, fut, _t, _d in self.scheduler.extract(
                    lambda k: k[0] == name):
                fut._reject(QueryRejected(
                    f"graph {name!r} was re-registered: the pending query for "
                    f"vertex {fut.query.vertex} was validated against the old "
                    f"topology and cannot be served — resubmit it against the "
                    f"new graph", code="graph-replaced"))
                self._finish_rejected(fut, "graph-replaced")
            self.recorder.record_event("graph_replaced", self.time_fn(),
                                       graph=name)
            self.controller.forget_graph(name)
            if self._warm is not None:
                self._warm.drop_graph(name)
            if self.prefetcher is not None:
                self.prefetcher.drop_graph(name)
            self.telemetry.forget_graph_demand(name)
        rg: RegisteredGraph = members[0].make_graph(
            name, g, packet=packet, mesh=mesh, mesh_axis=mesh_axis)
        rg.engine_family = family
        if not members[0].fixed:          # float member present: prepare it
            members[0].prepare(rg)
            rg.arm(members[0])
        for p in formats:
            fmt = normalize_precision(p)
            if fmt is not None:
                fixed_engine = engine_for(family, True)
                fixed_engine.prepare(rg, fmt)
                rg.arm(fixed_engine)
        self._graphs[name] = rg
        return rg

    @property
    def graphs(self) -> Tuple[str, ...]:
        return tuple(self._graphs)

    def registered_graph(self, name: str) -> RegisteredGraph:
        """The live registered-graph state (its ``.source`` is the current
        host ``COOGraph`` — the base external drivers synthesize deltas
        against)."""
        if name not in self._graphs:
            raise KeyError(f"graph {name!r} is not registered "
                           f"(have {list(self._graphs)})")
        return self._graphs[name]

    # ------------------------------------------------------------------
    def apply_delta(self, name: str, delta: EdgeDelta) -> Dict[str, float]:
        """Absorb an edge delta into a live registered graph — no
        stop-the-world re-registration.

        The graph's epoch is bumped (cache keys and wave keys are
        epoch-tagged), and invalidation is *scoped*: only cache entries and
        pending futures whose personalization vertex falls in the delta's
        affected frontier (touched vertices plus their in-neighbors — the
        one-hop, α-weighted blast radius) are dropped.  Everything else is
        retagged to the new epoch and keeps serving: entries outside the
        frontier see only multi-hop, α²-damped rank shifts, a bounded
        staleness the shadow quality estimator keeps scoring.  Surviving
        pending futures move to the new epoch's wave keys with their
        admission budgets intact — they resolve against the new topology.
        Frontier futures are *rejected* with a descriptive ``QueryRejected``
        (never left forever-pending).  Autotune quality windows decay (soft
        evidence) rather than reset.  The host merge is followed by each
        armed engine's device refresh (incremental requantization upload,
        per-bucket repartition), so the delta pays its device cost here.

        Returns a report dict (also folded into telemetry): epoch, edge
        counts, scoped-invalidation accounting, apply latency."""
        with self._lock:  # a delta must not race a worker-thread wave launch
            return self._apply_delta_locked(name, delta)

    def _apply_delta_locked(self, name: str, delta: EdgeDelta) -> Dict[str, float]:
        if name not in self._graphs:
            raise KeyError(f"graph {name!r} is not registered "
                           f"(have {list(self._graphs)})")
        rg = self._graphs[name]
        t0 = self.time_fn()
        frontier = delta.affected_frontier(rg.source)
        fr = frozenset(int(v) for v in frontier)
        info = rg.apply_delta(delta)
        for eng in rg.armed_engines():
            eng.on_delta(rg, info)
        epoch = rg.epoch

        dropped_vertices: List[int] = []

        def retag(key):
            if key[0] != name:
                return key
            if int(key[2]) in fr:
                dropped_vertices.append(int(key[2]))
                return None
            return (key[0], epoch) + tuple(key[2:])

        cache_dropped, cache_retained = self.cache.remap(retag)
        moved = self.scheduler.extract(lambda k: k[0] == name)
        pending_dropped = pending_requeued = 0
        for key, fut, enqueued_at, deadline in moved:
            if int(fut.query.vertex) in fr:
                pending_dropped += 1
                fut._reject(QueryRejected(
                    f"pending query for vertex {fut.query.vertex} on graph "
                    f"{name!r} was invalidated by an edge delta (epoch "
                    f"{epoch}): its personalization vertex is inside the "
                    f"delta's affected frontier — resubmit to recompute on "
                    f"the new topology", code="delta-invalidated"))
                self._finish_rejected(fut, "delta-invalidated")
            else:
                new_key = (key[0], key[1], key[2], epoch)
                fut._wave_key = new_key
                self.scheduler.submit(new_key, fut, deadline=deadline,
                                      now=enqueued_at)
                pending_requeued += 1
        if self._warm is not None:
            self._warm.grow(name, rg.num_vertices)
        self.controller.decay_graph(name)
        if self.prefetcher is not None:
            counts = self.telemetry.query_vertex_counts.get(name, {})
            hot = [v for v in dropped_vertices
                   if counts.get(v, 0) >= self.prefetcher.config.min_count]
            self.prefetcher.note_invalidated(name, hot)
        self.telemetry.record_delta(delta.num_added, delta.num_removed,
                                    cache_dropped, cache_retained,
                                    pending_dropped)
        self.recorder.record_event(
            "delta", self.time_fn(), graph=name, epoch=epoch,
            edges_added=delta.num_added, edges_removed=delta.num_removed,
            cache_dropped=cache_dropped, pending_dropped=pending_dropped)
        return {
            "epoch": epoch,
            "edges_added": delta.num_added,
            "edges_removed": delta.num_removed,
            "num_vertices": rg.num_vertices,
            "frontier_size": len(fr),
            "cache_dropped": cache_dropped,
            "cache_retained": cache_retained,
            "pending_dropped": pending_dropped,
            "pending_requeued": pending_requeued,
            "apply_s": self.time_fn() - t0,
        }

    # ------------------------------------------------------------------
    # load-control hooks (driven by repro.ppr_serving.http's admission
    # controller, but meaningful to any external control loop)
    # ------------------------------------------------------------------
    def queue_depth(self) -> int:
        """Pending queries across every wave key — O(1); the admission
        controller's shed/degrade/deepen signal."""
        return self.scheduler.queue_depth()

    def oldest_wait_s(self, now: Optional[float] = None) -> float:
        """Seconds the longest-waiting pending query has been queued."""
        return self.scheduler.oldest_wait_s(now)

    def set_kappa(self, kappa: int) -> None:
        """Retune the wave batch depth in place (backpressure-aware batching:
        deepen κ under load to amortize one edge-stream pass over more
        queries *before* resorting to shedding; relax it as the queue
        drains).  Applies to waves formed after the call — already-queued
        queries launch at the new depth.  Each distinct κ compiles its own
        wave shapes, so callers should move in doublings of the base κ."""
        if kappa < 1:
            raise ValueError(f"kappa must be >= 1, got {kappa}")
        with self._lock:
            if kappa == self.kappa:
                return
            self.telemetry.record_kappa_change(deepened=kappa > self.kappa)
            self.recorder.record_event(
                "kappa", self.time_fn(), kappa=kappa,
                deepened=kappa > self.kappa, previous=self.kappa)
            self.kappa = kappa
            self.scheduler.kappa = kappa

    def degrade_quality(self, target: float) -> None:
        """Impose the SLO-degradation ceiling: until ``restore_quality``,
        every ``precision="auto"`` query resolves against
        ``min(its target, target)`` — serving 0.93 instead of 0.95 when the
        admission queue is deep buys wave latency at a measured, recorded
        quality cost (each capped resolution counts in telemetry)."""
        with self._lock:
            if self.controller.target_ceiling == float(target):
                return
            self.controller.set_target_ceiling(target)
            self.telemetry.record_slo_transition(degraded=True)
            self.recorder.record_event("slo_degrade", self.time_fn(),
                                       target=float(target))

    def restore_quality(self) -> None:
        """Lift the degradation ceiling (queue drained) — auto traffic
        resumes its requested quality targets."""
        with self._lock:
            if self.controller.target_ceiling is None:
                return
            self.controller.set_target_ceiling(None)
            self.telemetry.record_slo_transition(degraded=False)
            self.recorder.record_event("slo_recover", self.time_fn())

    # ------------------------------------------------------------------
    def _trace_sampled(self) -> bool:
        """Head-sampling decision for one query — exactly one seeded RNG
        draw at rates below 1.0 (the entire per-query cost of a sampled-out
        query), no draw at full tracing."""
        return self._trace_rate >= 1.0 or \
            self._trace_rng.random() < self._trace_rate

    def export_telemetry(self) -> int:
        """Drive the attached OTLP exporter one cycle (queued span batches +
        a delta metrics push when due); returns POSTs made, 0 with no
        exporter.  The serving pump calls this off the event loop; a
        pump-less embedding can call it from any maintenance loop."""
        if self.otlp is None:
            return 0
        return self.otlp.tick(self.telemetry.registry)

    # ------------------------------------------------------------------
    def _resolve_precision(self, q: PPRQuery) -> str:
        """Concrete precision key for a query; "auto" goes through the ladder."""
        if q.precision == AUTO_KEY:
            ceiling = self.controller.target_ceiling
            if ceiling is not None:
                requested = (self.controller.config.default_target
                             if q.quality_target is None
                             else float(q.quality_target))
                if ceiling < requested:
                    self.telemetry.record_degraded_query(graph=q.graph)
            fmt = self.controller.resolve(q.graph, q.quality_target)
            pkey = FLOAT_KEY if fmt is None else fmt.name
            self.telemetry.record_auto_resolution(pkey)
            return pkey
        return precision_key(q.precision)

    def _cache_key(self, q: PPRQuery, pkey: str,
                   epoch: Optional[int] = None) -> Tuple:
        # graph epoch + resolved precision + iteration budget + early-exit +
        # warm-start mode: a result computed on an older topology or under
        # different numerics must never alias a current entry.  Scoped delta
        # invalidation relies on this layout (epoch at [1], vertex at [2]).
        # Wave resolution passes the wave's own epoch explicitly: with the
        # pump offload a delta can land mid-wave, and reading the *current*
        # epoch here would file the stale wave's results under the new one.
        if epoch is None:
            epoch = getattr(self._graphs.get(q.graph), "epoch", 0)
        return (q.graph, epoch, int(q.vertex), pkey,
                int(q.k), int(self.iterations), self.convergence is not None,
                self._warm is not None)

    # ------------------------------------------------------------------
    # futures API
    # ------------------------------------------------------------------
    def submit(self, q: PPRQuery) -> PPRFuture:
        """One query in, one ``PPRFuture`` out.

        A cache hit resolves the future before this returns (the fast path
        skips the iteration pipeline entirely); a miss queues the future for
        the next wave on its (graph, precision, mesh, epoch) stream — it
        resolves when ``poll``/``flush`` (or the future's own ``result()``)
        launches that wave.

        Validation happens *here*, not at wave launch, and raises
        synchronously: an invalid ``k`` that only surfaced inside the wave's
        top-K (``k+1 > V``) would crash the wave and lose every co-batched
        query's result — one bad query must never poison a wave."""
        if q.graph not in self._graphs:
            raise KeyError(f"graph {q.graph!r} is not registered "
                           f"(have {list(self._graphs)})")
        rg = self._graphs[q.graph]
        if not 0 <= q.vertex < rg.num_vertices:
            raise ValueError(f"vertex {q.vertex} out of range for {q.graph!r}")
        if q.k < 1:
            raise ValueError(f"k must be >= 1, got {q.k}")
        if q.k > rg.num_vertices - 1:
            # self-exclusion means at most V-1 recommendable vertices
            raise ValueError(
                f"k={q.k} exceeds the {rg.num_vertices - 1} recommendable "
                f"vertices of {q.graph!r} (|V|={rg.num_vertices}, the query "
                f"vertex excludes itself)")
        with self._lock:
            tracer = self.tracer
            tr = None
            if tracer is not None and self._trace_sampled():
                tr = tracer.start("query", "query", graph=q.graph,
                                  vertex=int(q.vertex), k=int(q.k),
                                  requested=str(q.precision))
                if self._trace_rate < 1.0:
                    # recorded on the span so an exporter backend can
                    # re-weight sampled traces back to traffic rates
                    tr.attrs["sample_rate"] = self._trace_rate
                sp = tr.span("resolve_precision", self.time_fn())
            pkey = self._resolve_precision(q)
            if tr is not None:
                sp.end(self.time_fn(), precision=pkey)
            self.telemetry.record_query_vertex(q.graph, int(q.vertex),
                                               k=q.k, pkey=pkey)
            fut = PPRFuture(q, self)
            if tr is not None:
                fut._trace = tr
                sp = tr.span("cache_probe", self.time_fn())
            hit = self.cache.get(self._cache_key(q, pkey))
            self.telemetry.record_cache(hit is not None)
            if tr is not None:
                sp.end(self.time_fn(), hit=hit is not None)
            if hit is not None:
                verts, scores = hit
                # submit-path resolution: the admitted-latency SLO sees the
                # fast path as (effectively) zero, which it is
                if not q.prefetch:
                    self.telemetry.record_query_latency(q.graph, 0.0)
                fut._resolve(Recommendation(q, verts.copy(), scores.copy(),
                                            source="cache", precision=pkey))
                if tr is not None:
                    tracer.finish(tr, outcome="resolved", source="cache",
                                  precision=pkey)
                    fut._trace = None
                return fut
            key = (q.graph, pkey, rg.mesh_key, rg.epoch)
            fut._wave_key = key
            now = self.time_fn()
            self.scheduler.submit(key, fut, deadline=q.deadline, now=now)
            # gauge at *submit* time, not just on control ticks: a burst's
            # peak depth between ticks used to be invisible in
            # queue_depth_peak
            self.telemetry.record_queue_depth(self.scheduler.queue_depth(),
                                              self.scheduler.oldest_wait_s(now))
            return fut

    def poll(self, now: Optional[float] = None) -> int:
        """Launch every wave the admission policy considers ready; resolved
        futures fire their callbacks.  Returns the number of waves launched.

        An *idle* poll (nothing launchable) with a prefetcher armed instead
        issues synthetic queries for predicted-hot uncached vertices and
        launches them immediately; their results fill the cache but resolve
        no caller-visible futures."""
        waves, _ = self._launch_ready(now, allow_prefetch=True)
        return waves

    def run_batch(self, queries: Sequence[PPRQuery]) -> List[Recommendation]:
        """Futures-native synchronous batch: submit every query first (so
        full κ-waves form regardless of ``max_wait``), flush, and gather the
        results in submission order.  The supported replacement for the
        deprecated ``serve()`` when a caller wants blocking batch semantics
        rather than holding the futures itself."""
        futures = [self.submit(q) for q in queries]
        self.flush()
        return [f.result() for f in futures]

    def flush(self) -> int:
        """Launch everything pending regardless of occupancy (end-of-batch /
        shutdown path); every pending future resolves.  Returns the number of
        waves launched."""
        with self._lock:
            popped = self.scheduler.drain()
        waves = 0
        for wave in popped:
            self._run_wave(wave)
            waves += 1
        return waves

    def _drive(self, fut: PPRFuture) -> None:
        """Resolve one pending future synchronously: launch the ready waves,
        then flush the future's own wave if it is still queued."""
        self._launch_ready(None, allow_prefetch=False)
        if fut.done():
            return
        key = fut._wave_key
        if key is not None:
            with self._lock:
                popped = self.scheduler.flush_keys({key})
            for wave in popped:
                self._run_wave(wave)

    def _launch_ready(self, now: Optional[float],
                      allow_prefetch: bool) -> Tuple[int, List[Recommendation]]:
        recs: List[Recommendation] = []
        waves = 0
        with self._lock:
            popped = self.scheduler.ready_waves(now=now)
        for wave in popped:
            recs.extend(self._run_wave(wave))
            waves += 1
        if not waves and allow_prefetch and self.prefetcher is not None:
            # "idle" must mean idle: a deep queue with nothing launchable yet
            # (partial waves still inside their admission budgets) is live
            # traffic between waves, and synthetic warm-up compute would add
            # latency right where the admission controller is fighting it
            cfg = self.prefetcher.config
            suppress_at = (cfg.suppress_depth if cfg.suppress_depth is not None
                           else self.kappa)
            if self.scheduler.queue_depth() >= suppress_at:
                self.prefetcher.suppressed += 1
                self.telemetry.record_prefetch_suppressed()
            else:
                pw, pr = self._prefetch_pump(now)
                waves += pw
                recs.extend(pr)
        return waves, recs

    # ------------------------------------------------------------------
    # deprecated blocking wrappers (kept working over the futures path)
    # ------------------------------------------------------------------
    def serve(self, queries: Sequence[PPRQuery]) -> List[Recommendation]:
        """Deprecated synchronous batch entry point: results in submission
        order.  Thin wrapper over the futures-native ``run_batch``."""
        _deprecated("serve", "run_batch() (or submit() + flush() + "
                             "PPRFuture.result() to hold the futures)")
        return self.run_batch(queries)

    def pump(self, now: Optional[float] = None) -> List[Recommendation]:
        """Deprecated: ``poll()`` with the launched waves' real (non-prefetch)
        recommendations returned as a list."""
        _deprecated("pump", "poll() + PPRFuture.add_done_callback()")
        _, recs = self._launch_ready(now, allow_prefetch=True)
        return [r for r in recs if not r.query.prefetch]

    def drain(self) -> List[Recommendation]:
        """Deprecated: ``flush()`` with the launched waves' real recommendations
        returned as a list."""
        _deprecated("drain", "flush() + PPRFuture.result()")
        recs: List[Recommendation] = []
        with self._lock:
            popped = self.scheduler.drain()
        for wave in popped:
            recs.extend(self._run_wave(wave))
        return [r for r in recs if not r.query.prefetch]

    # ------------------------------------------------------------------
    def _prefetch_pump(self, now: Optional[float]
                       ) -> Tuple[int, List[Recommendation]]:
        """Issue + immediately launch synthetic queries for hot uncached
        vertices, under the cache key real traffic probes: each vertex's last
        real (k, resolved precision) when known — auto traffic records its
        post-resolution format, so that matches what the controller would
        resolve next — else the config's k at the controller's current rung."""
        with self._lock:
            cfg = self.prefetcher.config
            now_s = self.time_fn() if now is None else now
            keys = set()
            issued = 0
            for name, rg in self._graphs.items():
                if issued >= cfg.max_per_pump:
                    break
                counts = self.telemetry.query_vertex_counts.get(name, {})
                last = self.telemetry.query_vertex_last.get(name, {})
                self.prefetcher.decay_demand(name, counts, now=now_s,
                                             last_seen=last)
                for v in self.prefetcher.candidates(name, counts,
                                                    cfg.max_per_pump - issued):
                    if not 0 <= v < rg.num_vertices:
                        continue              # stale demand from a dead topology
                    k_v, pkey = last.get(v, (cfg.k, None))
                    if pkey is None:
                        fmt = self.controller.resolve(name)
                        pkey = FLOAT_KEY if fmt is None else fmt.name
                    q = PPRQuery(name, int(v),
                                 k=min(k_v, rg.num_vertices - 1),
                                 precision=pkey, prefetch=True)
                    if self._cache_key(q, pkey) in self.cache:
                        continue              # membership probe: counter-free
                    key = (name, pkey, rg.mesh_key, rg.epoch)
                    fut = PPRFuture(q, self)
                    fut._wave_key = key
                    self.scheduler.submit(key, fut, now=now)
                    keys.add(key)
                    issued += 1
            if not issued:
                return 0, []
            self.prefetcher.issued += issued
            self.telemetry.record_prefetch(issued)
            popped = self.scheduler.flush_keys(keys)
        recs: List[Recommendation] = []
        waves = 0
        for wave in popped:
            recs.extend(self._run_wave(wave))
            waves += 1
        return waves, recs

    def telemetry_summary(self) -> Dict[str, float]:
        """Telemetry counters (cache_* = submit-path view) plus the LRU's own
        stats under lru_* — the two diverge once anything touches the cache
        outside submit() (e.g. the prefetcher) — plus the precision
        controller's ladder counters under autotune_* and per-engine wave
        latency stats under engine_*."""
        s = self.telemetry.summary()
        s.update({f"lru_{k}": v for k, v in self.cache.stats().items()})
        s.update({f"autotune_{k}": v for k, v in self.controller.summary().items()})
        if self._warm is not None:
            s.update({f"warm_{k}": v for k, v in self._warm.stats().items()})
        if self.prefetcher is not None:
            s.update({f"prefetch_{k}": v
                      for k, v in self.prefetcher.stats().items()})
        return s

    # ------------------------------------------------------------------
    def _warm_seed(self, rg: RegisteredGraph, wave: Wave, pkey: str,
                   Vmat) -> Tuple[jnp.ndarray, int]:
        """``(P0, warm columns)``: the wave's start state, with each column
        whose personalization vertex has a stored converged column seeded from
        it instead of the one-hot restart."""
        seeds = []
        for col, fut in enumerate(wave.items):
            s = self._warm.get(rg.name, int(fut.query.vertex), pkey)
            if s is not None and s.shape[0] == rg.num_vertices:
                seeds.append((col, s))
        if not seeds:
            return Vmat, 0
        P0 = np.asarray(Vmat).copy()
        for col, s in seeds:
            P0[:, col] = s
        # pad columns duplicate column 0's personalization vertex; mirror its
        # seed too, or a cold pad column gates the wave's (global) early exit
        P0[:, len(wave.items):] = P0[:, :1]
        return jnp.asarray(P0), len(seeds)

    def _finish_rejected(self, fut: PPRFuture, code: str) -> None:
        """Close a rejected future's live trace (if tracing is armed)."""
        if self.tracer is not None and fut._trace is not None:
            self.tracer.finish(fut._trace, outcome="rejected", code=code)
            fut._trace = None

    def _run_wave(self, wave: Wave) -> List[Recommendation]:
        graph_name, pkey, mesh_key, _epoch = wave.key
        rg = self._graphs[graph_name]
        fmt = None if pkey == FLOAT_KEY else normalize_precision(pkey)
        t0 = self.time_fn()

        # deadline-aware shed (before any compute is spent): a query whose
        # admission wait already exceeds its deadline gets a prompt 504, not
        # a late answer the caller stopped waiting for.  Strictly past-
        # deadline only (>): a deadline-flushed partial wave launches *at*
        # the budget boundary and must still serve its occupants.
        if any(f.query.deadline is not None for f in wave.items):
            live: List[PPRFuture] = []
            live_enq: List[float] = []
            for col, fut in enumerate(wave.items):
                q = fut.query
                enq = (wave.enqueued_at[col]
                       if col < len(wave.enqueued_at) else t0)
                if q.deadline is not None and t0 - enq > q.deadline:
                    self.telemetry.record_admission_wait(max(0.0, t0 - enq))
                    self.telemetry.record_deadline_shed(graph=q.graph)
                    fut._reject(QueryRejected(
                        f"query for vertex {q.vertex} on graph {q.graph!r} "
                        f"waited {t0 - enq:.4f}s in admission, past its "
                        f"{q.deadline:.4f}s deadline — dropped at wave "
                        f"launch rather than served late",
                        code="deadline-exceeded"))
                    self._finish_rejected(fut, "deadline-exceeded")
                else:
                    live.append(fut)
                    live_enq.append(enq)
            if not live:
                return []              # the whole wave expired in the queue
            wave = dataclasses.replace(wave, items=live, enqueued_at=live_enq)

        self._wave_counter += 1
        wave_id = self._wave_counter

        tracer = self.tracer
        iterate_info: Dict[str, object] = {}
        wtr = None
        # under head-sampling, a wave trace is kept iff any occupant was
        # sampled — an unsampled wave must not leak whole-traffic traces
        if tracer is not None and (
                self._trace_rate >= 1.0
                or any(f._trace is not None for f in wave.items)):
            wtr = tracer.start(
                "wave", "wave", t=t0, wave_id=wave_id, graph=graph_name,
                precision=pkey, mesh=mesh_key, full=wave.full,
                n_queries=len(wave.items),
                occupancy=len(wave.items) / self.kappa,
                member_traces=[f._trace.trace_id for f in wave.items
                               if f._trace is not None])
        # queue time is half of each occupant's latency story — account it
        # per member at launch, where it stops accruing
        for enq in wave.enqueued_at:
            self.telemetry.record_admission_wait(max(0.0, t0 - enq))

        # the graph's engine family decides how its waves iterate; arming
        # keeps late-bound engines in the delta device-refresh loop
        engine = engine_for(rg.engine_family, fmt is not None)
        rg.arm(engine)
        plan = engine.plan(rg, fmt, alpha=self.alpha,
                           iterations=self.iterations,
                           convergence=self.convergence,
                           topk_tile=self.topk_tile,
                           trace_hook=iterate_info.update
                           if tracer is not None else None)

        queries = [fut.query for fut in wave.items]
        verts = [int(q.vertex) for q in queries]
        pad = self.kappa - len(verts)
        padded = verts + [verts[0]] * pad           # pad columns are discarded
        pers = jnp.asarray(np.asarray(padded, np.int32))

        Vmat = plan.initial(pers)
        t_plan = self.time_fn()
        self.telemetry.record_stage("plan", t_plan - t0)
        P0, warm_cols = (self._warm_seed(rg, wave, pkey, Vmat)
                         if self._warm is not None else (Vmat, 0))
        t_warm = self.time_fn()
        self.telemetry.record_stage("warm_start", t_warm - t_plan)
        P, iters_run = plan.iterate(lambda P_: plan.step(Vmat, P_), P0)
        if iters_run < self.iterations:
            self.telemetry.record_early_exit(self.iterations - iters_run)
        self.telemetry.record_wave_iterations(iters_run)
        warm_saved = 0
        if self._warm is not None:
            P_host = np.asarray(P)
            for col, q in enumerate(queries):
                self._warm.put(graph_name, int(q.vertex), pkey,
                               P_host[:, col].copy())
            if warm_cols:
                base = self._cold_iters.get((graph_name, pkey))
                warm_saved = max(0, base - iters_run) if base is not None else 0
                self.telemetry.record_warm_start(warm_cols, warm_saved)
            else:
                self._cold_iters[(graph_name, pkey)] = iters_run
        t_iter = self.time_fn()
        self.telemetry.record_stage("iterate", t_iter - t_warm)

        k_max = max(q.k for q in queries)
        idx, vals = plan.topk(P, k_max, pers)
        idx = np.asarray(idx)                        # [κ, k_max]
        vals = np.asarray(vals)
        scores = vals.astype(np.float64) / plan.scale if plan.fixed \
            else vals.astype(np.float64)
        t_topk = self.time_fn()
        self.telemetry.record_stage("topk", t_topk - t_iter)
        latency = t_topk - t0

        recs = []
        # the cache fill + counters are the wave's shared-state tail: take the
        # service lock so a concurrent loop-thread submit() sees either no
        # entry or a complete one (engine compute above ran unlocked — that is
        # the whole point of the pump offload)
        with self._lock:
            for col, fut in enumerate(wave.items):
                q = fut.query
                v_top = idx[col, : q.k].copy()
                s_top = scores[col, : q.k].copy()
                # the cache keeps its own copies: callers may mutate their
                # Recommendation arrays without poisoning later hits
                self.cache.put(self._cache_key(q, pkey, epoch=_epoch),
                               (v_top.copy(), s_top.copy()))
                recs.append(Recommendation(q, v_top, s_top, source="wave",
                                           wave_id=wave_id, latency_s=latency,
                                           precision=pkey))
            t_resolve = self.time_fn()
            self.telemetry.record_stage("resolve", t_resolve - t_topk)
            # per-occupant end-to-end latency (submit → resolution): the
            # distribution the latency SLO evaluates.  Synthetic prefetch
            # queries are cache warming, not traffic — they don't count.
            for col, fut in enumerate(wave.items):
                if not fut.query.prefetch:
                    enq = (wave.enqueued_at[col]
                           if col < len(wave.enqueued_at) else t0)
                    self.telemetry.record_query_latency(
                        graph_name, max(0.0, t_resolve - enq))
            self.telemetry.record_wave(len(wave.items), self.kappa, latency,
                                       pkey, mesh_key=mesh_key,
                                       engine=plan.engine, graph=graph_name)
        self._shadow_feedback(wave, rg, fmt, pkey, P)
        if wtr is not None:
            wtr.span("plan", t0).end(t_plan, engine=plan.engine)
            wtr.span("warm_start", t_plan).end(
                t_warm, warm_cols=warm_cols, iterations_saved=warm_saved)
            wtr.span("iterate", t_warm).end(t_iter, **iterate_info)
            wtr.span("topk", t_iter).end(t_topk, k_max=k_max)
            wtr.span("resolve", t_topk).end(t_resolve)
            tracer.finish(wtr, latency_s=latency, engine=plan.engine)
        # resolve futures LAST: with the pump offload a waiter wakes the
        # moment its future resolves (the loop-thread bridge), and must then
        # observe the wave's *completed* accounting — counters, traces and
        # cache fills all land before any caller can see the result
        for col, fut in enumerate(wave.items):
            fut._resolve(recs[col])
            if tracer is not None and fut._trace is not None:
                tr = fut._trace
                enq = (wave.enqueued_at[col]
                       if col < len(wave.enqueued_at) else t0)
                tr.span("admission_wait", enq).end(t0)
                tr.span("wave_execute", t0, wave_id=wave_id,
                        engine=plan.engine,
                        **iterate_info).end(self.time_fn())
                tracer.finish(tr, outcome="resolved", source="wave",
                              precision=pkey,
                              wave_trace=wtr.trace_id if wtr else None)
                fut._trace = None
        return recs

    # ------------------------------------------------------------------
    def _shadow_feedback(self, wave: Wave, rg: RegisteredGraph,
                         fmt: Optional[QFormat], pkey: str, P) -> None:
        """Quality feedback for the wave's auto queries (sampled).

        Every auto query consumes exactly one sampling draw (in wave order),
        so a replayed query sequence under a seeded estimator makes identical
        shadow decisions regardless of how the ladder moved in between.
        Float32-served auto queries are perfect by definition: their sampled
        observations feed the ladder and telemetry as 1.0 without running a
        reference, so ``shadow_quality_mean`` reflects *all* sampled auto
        traffic, not just the fixed-point share.

        The float32 reference runs through the graph's own float engine —
        on a sharded graph it stays on the mesh (the deferred full-layout
        upload is the memory pressure mesh registration exists to avoid; the
        sharded float step is numerically equal to the single-device one,
        tests/test_sharded_serving.py) — and only over the sampled columns,
        so shadow cost genuinely scales with ``sample_fraction`` rather than
        being paid per wave.
        """
        estimator = self.controller.estimator
        sampled = [(col, fut.query) for col, fut in enumerate(wave.items)
                   if fut.query.precision == AUTO_KEY
                   and estimator.should_sample()]
        if not sampled:
            return
        if fmt is None:
            with self._lock:   # controller state is shared with submit-time resolution
                for _, q in sampled:
                    self.controller.observe_quality(rg.name, FLOAT_KEY, 1.0,
                                                    target=q.quality_target)
                    self.telemetry.record_shadow(1.0)
            return
        pers_sub = jnp.asarray(
            np.asarray([int(q.vertex) for _, q in sampled], np.int32))
        try:
            float_engine = engine_for(rg.engine_family, False)
        except KeyError:
            return      # fixed-only family: no float datapath for a reference
        rg.arm(float_engine)
        ref_plan = float_engine.plan(rg, None, alpha=self.alpha,
                                     iterations=self.iterations)
        Vref = ref_plan.initial(pers_sub)
        P_ref = Vref
        for _ in range(self.iterations):
            P_ref = ref_plan.step(Vref, P_ref)
        ref = np.asarray(P_ref, np.float64)
        approx = np.asarray(P, np.float64) / fmt.scale
        with self._lock:   # the reference compute above ran unlocked
            for j, (col, q) in enumerate(sampled):
                ref_col = ref[:, j]
                score = self.controller.observe_shadow(
                    rg.name, pkey, approx[:, col], ref_col,
                    target=q.quality_target, ref_order=ranking(ref_col))
                self.telemetry.record_shadow(score)
