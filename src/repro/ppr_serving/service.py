"""`PPRService` — the multi-tenant query-serving facade over the numeric core.

Lifecycle: graphs are registered once (host arrays moved to device, edge
stream padded to packets, per-format quantized values cached; with ``mesh=``
additionally partitioned by destination range over a mesh axis for
multi-device serving), then queries flow through

    submit → precision resolution ("auto" → controller) → result cache probe
           → κ-batch scheduler → wave launch → step-driven PPR iterations
           (early-exit on convergence) → streaming top-K → cache fill
           → shadow quality feedback

A wave shares one edge stream over up to κ personalization columns (the
paper's κ-batching); each wave is driven one eq. (1) iteration at a time via
``ppr_step_float`` / ``make_ppr_fixed_step``, which is what lets the
convergence monitor (repro.autotune.convergence, paper Fig. 7) stop a wave at
the fixed-point absorbing state instead of burning the full budget.  Results
are ranked ``Recommendation``s — the query vertex itself is always excluded
from its own top-k.

``precision="auto"`` queries are resolved to a concrete format *before wave
admission* by the adaptive-precision controller (repro.autotune.controller),
so auto traffic batches into the same waves as explicit same-format traffic.
After a fixed-precision wave, a sampled fraction of its auto queries is
shadow-scored against a float32 reference run to keep the controller's
quality estimates current (paper Figs. 4-6 measured online).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.autotune.controller import AutotuneConfig, PrecisionController
from repro.autotune.convergence import ConvergencePolicy, run_until_converged
from repro.core.coo import COOGraph, EdgeMergeInfo, quantize_values
from repro.core.fixed_point import PAPER_FORMATS, QFormat, format_for_bits
from repro.core.metrics import ranking
from repro.graph_updates.delta import EdgeDelta
from repro.graph_updates.warmstart import WarmStartStore
from repro.core.ppr import (
    make_ppr_fixed_step,
    make_ppr_sharded_fixed_step,
    make_ppr_sharded_float_step,
    personalization_matrix,
    personalization_matrix_fixed,
    ppr_float,
    ppr_step_float,
)
from repro.core.spmv import partition_edges_by_dst, sharded_vertex_layout
from repro.ppr_serving.cache import LRUCache
from repro.ppr_serving.prefetch import PrefetchConfig, Prefetcher
from repro.ppr_serving.scheduler import Wave, WaveScheduler
from repro.ppr_serving.telemetry import SINGLE_DEVICE_KEY, ServiceTelemetry
from repro.ppr_serving.topk import topk_dense, topk_streaming

Precision = Union[None, int, str, QFormat]

FLOAT_KEY = "f32"
AUTO_KEY = "auto"


def normalize_precision(precision: Precision) -> Optional[QFormat]:
    """None/"f32" → float32 path; int bits / "Q1.f" / QFormat → fixed path.

    ``"auto"`` is *not* a concrete precision — the service resolves it through
    the precision controller before anything needs a QFormat."""
    if precision == AUTO_KEY:
        raise ValueError('precision="auto" must be resolved by the service\'s '
                         'precision controller before normalization')
    if precision is None or precision == FLOAT_KEY:
        return None
    if isinstance(precision, QFormat):
        return precision
    if isinstance(precision, int):
        return format_for_bits(precision)
    if isinstance(precision, str):
        if precision in PAPER_FORMATS:
            return PAPER_FORMATS[precision]
        if precision.startswith("Q") and precision.count(".") == 1:
            i, f = precision[1:].split(".")
            try:
                return QFormat(int(i), int(f))
            except ValueError:
                pass   # malformed digits ("Q1.25x") → the descriptive error
    raise ValueError(f"unknown precision spec: {precision!r}")


def precision_key(precision: Precision) -> str:
    fmt = normalize_precision(precision)
    return FLOAT_KEY if fmt is None else fmt.name


@dataclasses.dataclass(frozen=True)
class PPRQuery:
    """One recommendation request.

    ``deadline`` bounds how long the query may wait in the admission queue for
    its wave to fill (seconds); it does not bound the iteration time itself.

    ``precision="auto"`` asks the service's precision controller for the
    cheapest Q format currently meeting ``quality_target`` (NDCG against the
    float32 reference; the controller's default target when None).
    ``quality_target`` is ignored for explicit precisions.
    """
    graph: str
    vertex: int
    k: int = 10
    precision: Precision = None
    deadline: Optional[float] = None
    quality_target: Optional[float] = None
    # synthetic cache-warming query issued by the prefetcher: computed and
    # cached like real traffic, but never returned from pump()/drain() and
    # never counted in the submit-path demand/cache telemetry
    prefetch: bool = False


@dataclasses.dataclass
class Recommendation:
    query: PPRQuery
    vertices: np.ndarray           # [k] ranked vertex ids (self excluded)
    scores: np.ndarray             # [k] float scores (dequantized for fixed)
    source: str                    # "wave" | "cache"
    wave_id: int = -1
    latency_s: float = 0.0
    precision: str = ""            # resolved precision key ("f32" / "Q1.f")


class RegisteredGraph:
    """Device-resident graph state, prepared once at registration and patched
    in place by edge deltas.

    The full-layout edge stream (``x``/``y``/``val``) is uploaded eagerly —
    every single-device wave reads it.  ``ShardedRegisteredGraph`` defers that
    upload: its waves read only the partitioned shards, and the full layout is
    materialized lazily iff something actually needs it (the float32 shadow
    reference for sampled ``precision="auto"`` traffic) — a meshed graph is
    registered precisely because one device's memory is tight.

    ``epoch`` counts applied deltas; the service stamps it into cache keys and
    wave keys so results computed on different topologies never alias.
    ``apply_delta`` refreshes device state *incrementally*: only changed
    ``val`` entries are requantized per pre-registered Q format (the host
    keeps the raw arrays and the out-degree vector for exactly this)."""

    mesh_key = SINGLE_DEVICE_KEY   # waves on this graph run single-device

    _defer_full_upload = False

    def __init__(self, name: str, g: COOGraph, packet: int = 256):
        self.name = name
        self.source = g                      # unpadded host graph (delta base)
        self.packet = packet
        self.epoch = 0
        self.graph = g.pad_to_packets(packet)
        self.num_vertices = g.num_vertices
        self.dangling = jnp.asarray(self.graph.dangling)
        self._outdeg = np.bincount(g.y, minlength=g.num_vertices).astype(np.int64)
        self._full_device: Optional[Tuple[jnp.ndarray, ...]] = None
        self._quantized: Dict[QFormat, jnp.ndarray] = {}
        self._quantized_host: Dict[QFormat, np.ndarray] = {}   # unpadded uint32
        if not self._defer_full_upload:
            self._full()

    def _full(self) -> Tuple[jnp.ndarray, ...]:
        if self._full_device is None:
            self._full_device = (jnp.asarray(self.graph.x),
                                 jnp.asarray(self.graph.y),
                                 jnp.asarray(self.graph.val))
        return self._full_device

    @property
    def x(self) -> jnp.ndarray:
        return self._full()[0]

    @property
    def y(self) -> jnp.ndarray:
        return self._full()[1]

    @property
    def val(self) -> jnp.ndarray:
        return self._full()[2]

    def _quantize_host(self, fmt: QFormat) -> np.ndarray:
        """Raw uint32 values of the *unpadded* edge stream (host-side cache —
        the base incremental requantization patches on delta application)."""
        if fmt not in self._quantized_host:
            self._quantized_host[fmt] = self.source.quantized_val(fmt)
        return self._quantized_host[fmt]

    def quantized(self, fmt: QFormat) -> jnp.ndarray:
        if fmt not in self._quantized:
            raw = self._quantize_host(fmt)
            pad = self.graph.num_edges - raw.shape[0]
            if pad:
                raw = np.concatenate([raw, np.zeros(pad, np.uint32)])
            self._quantized[fmt] = jnp.asarray(raw)
        return self._quantized[fmt]

    # ---- delta ingestion --------------------------------------------------
    def apply_delta(self, delta: EdgeDelta) -> EdgeMergeInfo:
        """Merge an edge delta and refresh device state; bumps ``epoch``.

        Pre-registered Q formats are requantized incrementally: surviving
        edges keep their raw bits (copied through the merge's old→new index
        map), only ``changed_mask`` entries — edges of sources whose
        out-degree moved — go through the quantizer again.  The result is
        bit-identical to quantizing the merged graph from scratch."""
        new_g, info = delta.apply(self.source, outdeg=self._outdeg)
        self._outdeg = info.new_outdeg
        self.source = new_g
        self.graph = new_g.pad_to_packets(self.packet)
        self.num_vertices = new_g.num_vertices
        self.dangling = jnp.asarray(self.graph.dangling)
        for fmt, old_raw in list(self._quantized_host.items()):
            new_raw = np.zeros(new_g.num_edges, np.uint32)
            new_raw[info.new_pos_of_kept] = old_raw[info.kept_old_idx]
            if info.changed_mask.any():
                new_raw[info.changed_mask] = quantize_values(
                    new_g.val[info.changed_mask], fmt)
            self._quantized_host[fmt] = new_raw
        for fmt in list(self._quantized):
            del self._quantized[fmt]
            self.quantized(fmt)                  # re-upload from patched host raw
        materialized = self._full_device is not None
        self._full_device = None
        if materialized or not self._defer_full_upload:
            self._full()
        self.epoch += 1
        return info

    # ---- wave step construction (overridden by the sharded variant) -------
    def float_step(self, alpha: float):
        """callable(Vmat, P) → P_next for one float32 eq. (1) iteration."""
        def step(Vmat, P):
            return ppr_step_float(self.x, self.y, self.val, self.dangling,
                                  Vmat, P, num_vertices=self.num_vertices,
                                  alpha=alpha)
        return step

    def fixed_step(self, fmt: QFormat, alpha: float):
        """callable(Vmat, P) → P_next, bit-exact in ``fmt``'s raw domain."""
        body = make_ppr_fixed_step(fmt, self.num_vertices, alpha)
        val_raw = self.quantized(fmt)

        def step(Vmat, P):
            return body(self.x, self.y, val_raw, self.dangling, Vmat, P)
        return step


class ShardedRegisteredGraph(RegisteredGraph):
    """A registered graph whose edge stream is partitioned over a
    ``jax.sharding.Mesh`` axis (the paper's multi-channel partitioning, scaled
    to multi-device): waves on it run the sharded step bodies of
    ``repro.core.ppr``.

    The host owns the partitioning/packaging step (the CPU–FPGA synergy
    argument of arXiv 2004.13907): edges are bucketed by destination range
    once at registration — per quantized format too, through the same
    dtype-preserving partitioner, so fixed-point shards are the exact raw
    values the single-device path would stream.  The base class's full-layout
    device arrays are deferred (see its docstring): only the float32 shadow
    reference materializes them, on first sampled auto query.
    """

    _defer_full_upload = True

    def __init__(self, name: str, g: COOGraph, mesh, axis: Optional[str] = None,
                 packet: int = 256):
        super().__init__(name, g, packet=packet)
        self.mesh = mesh
        self.axis = axis if axis is not None else mesh.axis_names[0]
        if self.axis not in mesh.axis_names:
            raise ValueError(f"mesh has no axis {self.axis!r} "
                             f"(axes: {mesh.axis_names})")
        self.n_shards = int(mesh.shape[self.axis])
        self.mesh_key = f"mesh:{self.axis}x{self.n_shards}"
        self._packet = packet
        self._sharded_quantized: Dict[QFormat, jnp.ndarray] = {}
        self._sharded_quant_host: Dict[QFormat, np.ndarray] = {}  # [S, max_e]
        self._partition_all()

    def _partition_all(self) -> None:
        """(Re-)bucket the *unpadded* edge stream by destination range; pad
        edges would only inflate shard 0 with zero slots the per-shard packet
        padding already provides."""
        sx, sy, sval = partition_edges_by_dst(
            self.source.x, self.source.y, self.source.val,
            self.num_vertices, self.n_shards, packet=self._packet)
        s = self.n_shards
        self._host_x = sx.reshape(s, -1)
        self._host_y = sy.reshape(s, -1)
        self._host_val = sval.reshape(s, -1)
        self.sharded_x = jnp.asarray(sx)
        self.sharded_y = jnp.asarray(sy)
        self.sharded_val = jnp.asarray(sval)
        for fmt in set(self._sharded_quantized) | set(self._sharded_quant_host):
            _, _, sq = partition_edges_by_dst(
                self.source.x, self.source.y, self._quantize_host(fmt),
                self.num_vertices, self.n_shards, packet=self._packet)
            self._sharded_quant_host[fmt] = sq.reshape(s, -1)
            self._sharded_quantized[fmt] = jnp.asarray(sq)

    def sharded_quantized(self, fmt: QFormat) -> jnp.ndarray:
        """Raw uint32 edge shard values in the partitioned layout (cached)."""
        if fmt not in self._sharded_quantized:
            _, _, sval = partition_edges_by_dst(
                self.source.x, self.source.y, self._quantize_host(fmt),
                self.num_vertices, self.n_shards, packet=self._packet)
            self._sharded_quant_host[fmt] = sval.reshape(self.n_shards, -1)
            self._sharded_quantized[fmt] = jnp.asarray(sval)
        return self._sharded_quantized[fmt]

    def apply_delta(self, delta: EdgeDelta) -> EdgeMergeInfo:
        """Delta ingestion on a meshed graph: re-partition only the
        destination buckets that own a changed or removed edge.

        Falls back to a full re-partition when the delta moves the bucket
        geometry itself (vertex growth changing ``ceil(V / n_shards)``) or an
        affected bucket outgrows the current per-shard padding."""
        old_v_local, _ = sharded_vertex_layout(self.num_vertices, self.n_shards)
        info = super().apply_delta(delta)     # merge + epoch + quantized host
        v_local, _ = sharded_vertex_layout(self.num_vertices, self.n_shards)
        max_e = self._host_x.shape[1]
        shard_of = self.source.x // v_local
        counts = np.bincount(shard_of, minlength=self.n_shards)
        affected: Optional[np.ndarray] = \
            np.unique(info.changed_dst // v_local).astype(np.int64)
        if v_local != old_v_local or counts[affected].max(initial=0) > max_e:
            self._partition_all()
            return info
        for s in affected:
            m = shard_of == s
            n = int(counts[s])
            for host in (self._host_x, self._host_y, self._host_val):
                host[s, :] = 0
            self._host_x[s, :n] = self.source.x[m] % v_local
            self._host_y[s, :n] = self.source.y[m]
            self._host_val[s, :n] = self.source.val[m]
            for fmt, hq in self._sharded_quant_host.items():
                hq[s, :] = 0
                hq[s, :n] = self._quantized_host[fmt][m]
        self.sharded_x = jnp.asarray(self._host_x.reshape(-1))
        self.sharded_y = jnp.asarray(self._host_y.reshape(-1))
        self.sharded_val = jnp.asarray(self._host_val.reshape(-1))
        for fmt, hq in self._sharded_quant_host.items():
            self._sharded_quantized[fmt] = jnp.asarray(hq.reshape(-1))
        return info

    def float_step(self, alpha: float):
        body = make_ppr_sharded_float_step(self.mesh, self.axis,
                                           self.num_vertices, alpha)

        def step(Vmat, P):
            return body(self.sharded_x, self.sharded_y, self.sharded_val,
                        self.dangling, Vmat, P)
        return step

    def fixed_step(self, fmt: QFormat, alpha: float):
        body = make_ppr_sharded_fixed_step(fmt, self.mesh, self.axis,
                                           self.num_vertices, alpha)
        val_raw = self.sharded_quantized(fmt)

        def step(Vmat, P):
            return body(self.sharded_x, self.sharded_y, val_raw,
                        self.dangling, Vmat, P)
        return step


class PPRService:
    """Facade: named graphs, κ-batched admission, cached ranked results,
    adaptive precision (``precision="auto"``) and early-exit iterations."""

    def __init__(
        self,
        kappa: int = 8,
        iterations: int = 10,
        alpha: float = 0.85,
        max_wait: float = 0.0,
        cache_capacity: int = 4096,
        topk_tile: Optional[int] = None,
        autotune: Optional[AutotuneConfig] = None,
        early_exit: Union[None, bool, ConvergencePolicy] = None,
        warm_start: Union[bool, int] = False,
        prefetch: Union[None, bool, PrefetchConfig] = None,
        time_fn=time.monotonic,
    ):
        """``warm_start`` seeds wave iterations from each personalization
        vertex's last converged column (True, or an int store capacity per
        graph) — pair it with ``early_exit`` so the shorter convergence
        distance actually saves iterations.  ``prefetch`` arms the idle-pump
        cache warmer (True, or a ``PrefetchConfig``)."""
        self.kappa = kappa
        self.iterations = iterations
        self.alpha = alpha
        self.topk_tile = topk_tile
        self.time_fn = time_fn
        self.scheduler = WaveScheduler(kappa, max_wait=max_wait, time_fn=time_fn)
        self.cache = LRUCache(cache_capacity)
        self.telemetry = ServiceTelemetry()
        self.controller = PrecisionController(autotune or AutotuneConfig())
        if early_exit is True:
            self.convergence: Optional[ConvergencePolicy] = ConvergencePolicy()
        else:
            self.convergence = early_exit or None
        if warm_start is True:
            self._warm: Optional[WarmStartStore] = WarmStartStore()
        elif warm_start:
            self._warm = WarmStartStore(capacity_per_graph=int(warm_start))
        else:
            self._warm = None
        if prefetch is True:
            self.prefetcher: Optional[Prefetcher] = Prefetcher()
        elif prefetch:
            self.prefetcher = Prefetcher(prefetch)
        else:
            self.prefetcher = None
        self._graphs: Dict[str, RegisteredGraph] = {}
        self._wave_counter = 0
        # last cold (unseeded) iteration count per (graph, precision): the
        # baseline warm_start_iterations_saved is measured against
        self._cold_iters: Dict[Tuple[str, str], int] = {}

    # ------------------------------------------------------------------
    def register_graph(self, name: str, g: COOGraph,
                       formats: Sequence[Precision] = (),
                       packet: int = 256,
                       mesh=None, mesh_axis: Optional[str] = None
                       ) -> RegisteredGraph:
        """Move a graph to the device; optionally pre-quantize for ``formats``.

        ``mesh`` (a ``jax.sharding.Mesh``) registers the graph *sharded*: the
        edge stream is partitioned by destination range over ``mesh_axis``
        (default: the mesh's first axis) at registration, and every wave on
        the graph runs the sharded step bodies — same results, multi-device
        bandwidth.  ``num_vertices`` need not divide the shard count.

        Re-registering an existing name invalidates that graph's cached
        results, drops its still-pending queries (they were validated against
        the old topology — their vertices may be out of range in the new one,
        which JAX's scatter would silently ignore, serving garbage), and
        resets its quality estimates — nothing from the old topology may be
        served or steer the precision ladder."""
        if name in self._graphs:
            self.cache.invalidate(lambda key: key[0] == name)
            self.scheduler.purge(lambda key: key[0] == name)
            self.controller.forget_graph(name)
            if self._warm is not None:
                self._warm.drop_graph(name)
            if self.prefetcher is not None:
                self.prefetcher.drop_graph(name)
            self.telemetry.forget_graph_demand(name)
        if mesh is None:
            rg: RegisteredGraph = RegisteredGraph(name, g, packet=packet)
        else:
            rg = ShardedRegisteredGraph(name, g, mesh, axis=mesh_axis,
                                        packet=packet)
        for p in formats:
            fmt = normalize_precision(p)
            if fmt is not None:
                # sharded waves read only the partitioned quantized values —
                # skip the full-layout device upload for meshed graphs
                if isinstance(rg, ShardedRegisteredGraph):
                    rg.sharded_quantized(fmt)
                else:
                    rg.quantized(fmt)
        self._graphs[name] = rg
        return rg

    @property
    def graphs(self) -> Tuple[str, ...]:
        return tuple(self._graphs)

    def registered_graph(self, name: str) -> RegisteredGraph:
        """The live registered-graph state (its ``.source`` is the current
        host ``COOGraph`` — the base external drivers synthesize deltas
        against)."""
        if name not in self._graphs:
            raise KeyError(f"graph {name!r} is not registered "
                           f"(have {list(self._graphs)})")
        return self._graphs[name]

    # ------------------------------------------------------------------
    def apply_delta(self, name: str, delta: EdgeDelta) -> Dict[str, float]:
        """Absorb an edge delta into a live registered graph — no
        stop-the-world re-registration.

        The graph's epoch is bumped (cache keys and wave keys are
        epoch-tagged), and invalidation is *scoped*: only cache entries and
        pending queries whose personalization vertex falls in the delta's
        affected frontier (touched vertices plus their in-neighbors — the
        one-hop, α-weighted blast radius) are dropped.  Everything else is
        retagged to the new epoch and keeps serving: entries outside the
        frontier see only multi-hop, α²-damped rank shifts, a bounded
        staleness the shadow quality estimator keeps scoring.  Surviving
        pending queries move to the new epoch's wave keys with their
        admission budgets intact — they launch against the new topology.
        Autotune quality windows decay (soft evidence) rather than reset.

        Returns a report dict (also folded into telemetry): epoch, edge
        counts, scoped-invalidation accounting, apply latency."""
        if name not in self._graphs:
            raise KeyError(f"graph {name!r} is not registered "
                           f"(have {list(self._graphs)})")
        rg = self._graphs[name]
        t0 = self.time_fn()
        frontier = delta.affected_frontier(rg.source)
        fr = frozenset(int(v) for v in frontier)
        rg.apply_delta(delta)
        epoch = rg.epoch

        dropped_vertices: List[int] = []

        def retag(key):
            if key[0] != name:
                return key
            if int(key[2]) in fr:
                dropped_vertices.append(int(key[2]))
                return None
            return (key[0], epoch) + tuple(key[2:])

        cache_dropped, cache_retained = self.cache.remap(retag)
        moved = self.scheduler.extract(lambda k: k[0] == name)
        pending_dropped = pending_requeued = 0
        for key, item, enqueued_at, deadline in moved:
            if int(item.vertex) in fr:
                pending_dropped += 1
            else:
                self.scheduler.submit((key[0], key[1], key[2], epoch), item,
                                      deadline=deadline, now=enqueued_at)
                pending_requeued += 1
        if self._warm is not None:
            self._warm.grow(name, rg.num_vertices)
        self.controller.decay_graph(name)
        if self.prefetcher is not None:
            counts = self.telemetry.query_vertex_counts.get(name, {})
            hot = [v for v in dropped_vertices
                   if counts.get(v, 0) >= self.prefetcher.config.min_count]
            self.prefetcher.note_invalidated(name, hot)
        self.telemetry.record_delta(delta.num_added, delta.num_removed,
                                    cache_dropped, cache_retained,
                                    pending_dropped)
        return {
            "epoch": epoch,
            "edges_added": delta.num_added,
            "edges_removed": delta.num_removed,
            "num_vertices": rg.num_vertices,
            "frontier_size": len(fr),
            "cache_dropped": cache_dropped,
            "cache_retained": cache_retained,
            "pending_dropped": pending_dropped,
            "pending_requeued": pending_requeued,
            "apply_s": self.time_fn() - t0,
        }

    # ------------------------------------------------------------------
    def _resolve_precision(self, q: PPRQuery) -> str:
        """Concrete precision key for a query; "auto" goes through the ladder."""
        if q.precision == AUTO_KEY:
            fmt = self.controller.resolve(q.graph, q.quality_target)
            pkey = FLOAT_KEY if fmt is None else fmt.name
            self.telemetry.record_auto_resolution(pkey)
            return pkey
        return precision_key(q.precision)

    def _cache_key(self, q: PPRQuery, pkey: str) -> Tuple:
        # graph epoch + resolved precision + iteration budget + early-exit +
        # warm-start mode: a result computed on an older topology or under
        # different numerics must never alias a current entry.  Scoped delta
        # invalidation relies on this layout (epoch at [1], vertex at [2]).
        epoch = getattr(self._graphs.get(q.graph), "epoch", 0)
        return (q.graph, epoch, int(q.vertex), pkey,
                int(q.k), int(self.iterations), self.convergence is not None,
                self._warm is not None)

    def submit(self, q: PPRQuery) -> Optional[Recommendation]:
        """Cache probe; on miss, enqueue for the next wave and return None.

        Validation happens *here*, not at wave launch: an invalid ``k`` that
        only surfaced inside the wave's top-K (``k+1 > V``) would crash
        ``pump()`` and lose every co-batched query's result — one bad query
        must never poison a wave."""
        if q.graph not in self._graphs:
            raise KeyError(f"graph {q.graph!r} is not registered "
                           f"(have {list(self._graphs)})")
        rg = self._graphs[q.graph]
        if not 0 <= q.vertex < rg.num_vertices:
            raise ValueError(f"vertex {q.vertex} out of range for {q.graph!r}")
        if q.k < 1:
            raise ValueError(f"k must be >= 1, got {q.k}")
        if q.k > rg.num_vertices - 1:
            # self-exclusion means at most V-1 recommendable vertices
            raise ValueError(
                f"k={q.k} exceeds the {rg.num_vertices - 1} recommendable "
                f"vertices of {q.graph!r} (|V|={rg.num_vertices}, the query "
                f"vertex excludes itself)")
        pkey = self._resolve_precision(q)
        self.telemetry.record_query_vertex(q.graph, int(q.vertex),
                                           k=q.k, pkey=pkey)
        hit = self.cache.get(self._cache_key(q, pkey))
        self.telemetry.record_cache(hit is not None)
        if hit is not None:
            verts, scores = hit
            return Recommendation(q, verts.copy(), scores.copy(),
                                  source="cache", precision=pkey)
        self.scheduler.submit((q.graph, pkey, rg.mesh_key, rg.epoch), q,
                              deadline=q.deadline)
        return None

    def pump(self, now: Optional[float] = None) -> List[Recommendation]:
        """Launch every wave the admission policy considers ready.

        An *idle* pump (nothing launchable) with a prefetcher armed instead
        issues synthetic queries for predicted-hot uncached vertices and
        launches them immediately; their results fill the cache but are never
        returned — only real queries riding along in a prefetch wave are."""
        return self._pump(now, allow_prefetch=True)

    def _pump(self, now: Optional[float],
              allow_prefetch: bool) -> List[Recommendation]:
        # serve() passes allow_prefetch=False: a synchronous batch whose
        # queries all hit the cache must not pay a prefetch wave's latency —
        # prefetch compute belongs to explicit (poll-loop) pump() calls
        recs: List[Recommendation] = []
        for wave in self.scheduler.ready_waves(now=now):
            recs.extend(self._run_wave(wave))
        if not recs and allow_prefetch and self.prefetcher is not None:
            recs.extend(self._prefetch_pump(now))
        return [r for r in recs if not r.query.prefetch]

    def drain(self) -> List[Recommendation]:
        """Flush all pending queries regardless of occupancy."""
        recs: List[Recommendation] = []
        for wave in self.scheduler.drain():
            recs.extend(self._run_wave(wave))
        return [r for r in recs if not r.query.prefetch]

    def _prefetch_pump(self, now: Optional[float]) -> List[Recommendation]:
        """Issue + immediately launch synthetic queries for hot uncached
        vertices, under the cache key real traffic probes: each vertex's last
        real (k, resolved precision) when known — auto traffic records its
        post-resolution format, so that matches what the controller would
        resolve next — else the config's k at the controller's current rung."""
        cfg = self.prefetcher.config
        keys = set()
        issued = 0
        for name, rg in self._graphs.items():
            if issued >= cfg.max_per_pump:
                break
            counts = self.telemetry.query_vertex_counts.get(name, {})
            last = self.telemetry.query_vertex_last.get(name, {})
            for v in self.prefetcher.candidates(name, counts,
                                                cfg.max_per_pump - issued):
                if not 0 <= v < rg.num_vertices:
                    continue                  # stale demand from a dead topology
                k_v, pkey = last.get(v, (cfg.k, None))
                if pkey is None:
                    fmt = self.controller.resolve(name)
                    pkey = FLOAT_KEY if fmt is None else fmt.name
                q = PPRQuery(name, int(v), k=min(k_v, rg.num_vertices - 1),
                             precision=pkey, prefetch=True)
                if self._cache_key(q, pkey) in self.cache:
                    continue                  # membership probe: counter-free
                key = (name, pkey, rg.mesh_key, rg.epoch)
                self.scheduler.submit(key, q, now=now)
                keys.add(key)
                issued += 1
        if not issued:
            return []
        self.prefetcher.issued += issued
        self.telemetry.record_prefetch(issued)
        recs: List[Recommendation] = []
        for wave in self.scheduler.flush_keys(keys):
            recs.extend(self._run_wave(wave))
        return recs

    def serve(self, queries: Sequence[PPRQuery]) -> List[Recommendation]:
        """Synchronous batch entry point: results in submission order.

        Waves complete out of submission order when precisions or graphs mix
        (each (graph, precision) group fills independently), so results are
        matched back by query identity, not queue position.
        """
        from collections import defaultdict, deque

        out: Dict[int, Recommendation] = {}
        slot: Dict[int, deque] = defaultdict(deque)   # id(query) → indices FIFO
        # Admit the whole batch before pumping so full κ-waves form regardless
        # of max_wait (submit-then-pump per query would flush 1-query partials
        # whenever max_wait=0).
        for i, q in enumerate(queries):
            rec = self.submit(q)
            if rec is not None:
                out[i] = rec
            else:
                slot[id(q)].append(i)
        # Queries queued via submit() before this serve() call ride along in
        # the same waves; their results are cached/telemetered but belong to
        # no slot here, so route only our own.
        for rec in self._pump(None, allow_prefetch=False) + self.drain():
            idxs = slot.get(id(rec.query))
            if idxs:
                out[idxs.popleft()] = rec
        return [out[i] for i in range(len(queries))]

    def telemetry_summary(self) -> Dict[str, float]:
        """Telemetry counters (cache_* = submit-path view) plus the LRU's own
        stats under lru_* — the two diverge once anything touches the cache
        outside submit() (e.g. a future async prefetcher) — plus the precision
        controller's ladder counters under autotune_*."""
        s = self.telemetry.summary()
        s.update({f"lru_{k}": v for k, v in self.cache.stats().items()})
        s.update({f"autotune_{k}": v for k, v in self.controller.summary().items()})
        if self._warm is not None:
            s.update({f"warm_{k}": v for k, v in self._warm.stats().items()})
        if self.prefetcher is not None:
            s.update({f"prefetch_{k}": v
                      for k, v in self.prefetcher.stats().items()})
        return s

    # ------------------------------------------------------------------
    def _iterate(self, step, P0, *, fixed: bool, scale: Optional[int]):
        """Drive one wave's iterations; early-exit when a policy is armed."""
        if self.convergence is None:
            P = P0
            for _ in range(self.iterations):
                P = step(P)
            return P, self.iterations
        P, iters_run, _ = run_until_converged(
            step, P0, self.iterations, self.convergence, fixed=fixed,
            scale=scale, track_deltas=False)   # trace unused: skip its syncs
        return P, iters_run

    def _warm_seed(self, rg: RegisteredGraph, wave: Wave, pkey: str,
                   Vmat) -> Tuple[jnp.ndarray, int]:
        """``(P0, warm columns)``: the wave's start state, with each column
        whose personalization vertex has a stored converged column seeded from
        it instead of the one-hot restart."""
        seeds = []
        for col, q in enumerate(wave.items):
            s = self._warm.get(rg.name, int(q.vertex), pkey)
            if s is not None and s.shape[0] == rg.num_vertices:
                seeds.append((col, s))
        if not seeds:
            return Vmat, 0
        P0 = np.asarray(Vmat).copy()
        for col, s in seeds:
            P0[:, col] = s
        # pad columns duplicate column 0's personalization vertex; mirror its
        # seed too, or a cold pad column gates the wave's (global) early exit
        P0[:, len(wave.items):] = P0[:, :1]
        return jnp.asarray(P0), len(seeds)

    def _run_wave(self, wave: Wave) -> List[Recommendation]:
        graph_name, pkey, mesh_key, _epoch = wave.key
        rg = self._graphs[graph_name]
        fmt = None if pkey == FLOAT_KEY else normalize_precision(pkey)
        t0 = self.time_fn()
        self._wave_counter += 1
        wave_id = self._wave_counter

        verts = [int(q.vertex) for q in wave.items]
        pad = self.kappa - len(verts)
        padded = verts + [verts[0]] * pad           # pad columns are discarded
        pers = jnp.asarray(np.asarray(padded, np.int32))

        # the graph decides how its waves iterate: single-device or mesh-sharded
        if fmt is None:
            Vmat = personalization_matrix(rg.num_vertices, pers)
            step = rg.float_step(self.alpha)
        else:
            Vmat = personalization_matrix_fixed(rg.num_vertices, pers, fmt)
            step = rg.fixed_step(fmt, self.alpha)
        P0, warm_cols = (self._warm_seed(rg, wave, pkey, Vmat)
                         if self._warm is not None else (Vmat, 0))
        P, iters_run = self._iterate(
            lambda P_: step(Vmat, P_), P0, fixed=fmt is not None,
            scale=None if fmt is None else fmt.scale)
        if iters_run < self.iterations:
            self.telemetry.record_early_exit(self.iterations - iters_run)
        if self._warm is not None:
            P_host = np.asarray(P)
            for col, q in enumerate(wave.items):
                self._warm.put(graph_name, int(q.vertex), pkey,
                               P_host[:, col].copy())
            if warm_cols:
                base = self._cold_iters.get((graph_name, pkey))
                saved = max(0, base - iters_run) if base is not None else 0
                self.telemetry.record_warm_start(warm_cols, saved)
            else:
                self._cold_iters[(graph_name, pkey)] = iters_run

        k_max = max(q.k for q in wave.items)
        if self.topk_tile is not None:
            idx, vals = topk_streaming(P, k_max, v_tile=self.topk_tile,
                                       exclude=pers)
        else:
            idx, vals = topk_dense(P, k_max, exclude=pers)
        idx = np.asarray(idx)                        # [κ, k_max]
        vals = np.asarray(vals)
        scores = vals.astype(np.float64) / fmt.scale if fmt is not None \
            else vals.astype(np.float64)
        latency = self.time_fn() - t0

        recs = []
        for col, q in enumerate(wave.items):
            v_top = idx[col, : q.k].copy()
            s_top = scores[col, : q.k].copy()
            # the cache keeps its own copies: callers may mutate their
            # Recommendation arrays without poisoning later hits
            self.cache.put(self._cache_key(q, pkey), (v_top.copy(), s_top.copy()))
            recs.append(Recommendation(q, v_top, s_top, source="wave",
                                       wave_id=wave_id, latency_s=latency,
                                       precision=pkey))
        self.telemetry.record_wave(len(wave.items), self.kappa, latency, pkey,
                                   mesh_key=mesh_key)
        self._shadow_feedback(wave, rg, fmt, pkey, P)
        return recs

    # ------------------------------------------------------------------
    def _shadow_feedback(self, wave: Wave, rg: RegisteredGraph,
                         fmt: Optional[QFormat], pkey: str, P) -> None:
        """Quality feedback for the wave's auto queries (sampled).

        Every auto query consumes exactly one sampling draw (in wave order),
        so a replayed query sequence under a seeded estimator makes identical
        shadow decisions regardless of how the ladder moved in between.
        Float32-served auto queries are perfect by definition: their sampled
        observations feed the ladder and telemetry as 1.0 without running a
        reference, so ``shadow_quality_mean`` reflects *all* sampled auto
        traffic, not just the fixed-point share.

        The float32 reference runs only over the sampled columns — shadow
        cost genuinely scales with ``sample_fraction`` rather than being paid
        per wave.  (Each distinct sampled-column count compiles its own
        ``ppr_float`` variant; there are at most κ of them.)
        """
        estimator = self.controller.estimator
        sampled = [(col, q) for col, q in enumerate(wave.items)
                   if q.precision == AUTO_KEY and estimator.should_sample()]
        if not sampled:
            return
        if fmt is None:
            for _, q in sampled:
                self.controller.observe_quality(rg.name, FLOAT_KEY, 1.0,
                                                target=q.quality_target)
                self.telemetry.record_shadow(1.0)
            return
        pers_sub = jnp.asarray(
            np.asarray([int(q.vertex) for _, q in sampled], np.int32))
        if isinstance(rg, ShardedRegisteredGraph):
            # keep the reference on the mesh: running it through the full
            # single-device stream would force the deferred full-layout
            # upload onto one device — the memory pressure mesh registration
            # exists to avoid.  The sharded float step is numerically equal
            # to ppr_float (tests/test_sharded_serving.py).
            Vref = personalization_matrix(rg.num_vertices, pers_sub)
            ref_step = rg.float_step(self.alpha)
            P_ref = Vref
            for _ in range(self.iterations):
                P_ref = ref_step(Vref, P_ref)
        else:
            P_ref, _ = ppr_float(rg.x, rg.y, rg.val, rg.dangling, pers_sub,
                                 num_vertices=rg.num_vertices,
                                 iterations=self.iterations, alpha=self.alpha)
        ref = np.asarray(P_ref, np.float64)
        approx = np.asarray(P, np.float64) / fmt.scale
        for j, (col, q) in enumerate(sampled):
            ref_col = ref[:, j]
            score = self.controller.observe_shadow(
                rg.name, pkey, approx[:, col], ref_col,
                target=q.quality_target, ref_order=ranking(ref_col))
            self.telemetry.record_shadow(score)
