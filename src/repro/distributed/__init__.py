from repro.distributed.collectives import compressed_psum, make_compressed_grad_allreduce
from repro.distributed.sharding import (
    batch_shardings,
    cache_shardings,
    param_shardings,
    set_sharding_context,
    shard_activation,
)

__all__ = [
    "param_shardings", "batch_shardings", "cache_shardings",
    "set_sharding_context", "shard_activation",
    "compressed_psum", "make_compressed_grad_allreduce",
]
