"""Logical-axis sharding rules → NamedSharding (MaxText-style, DESIGN.md §6).

Parameter rules are path-based over the params pytree; activation/cache rules
are small helpers.  Everything is a *global-view* pjit sharding: the model code
stays mesh-agnostic, and an optional sharding context lets the forward pass
pin residual-stream activations (sequence parallelism).

Mesh axes: ("data", "model") single-pod, ("pod", "data", "model") multi-pod.
"data" composes with "pod" for batch parallelism.
"""
from __future__ import annotations

import re
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# global sharding context (set by launch/train/serve; no-op when unset)
# ---------------------------------------------------------------------------
_CTX: dict = {"mesh": None, "batch_axes": None, "seq_axis": None}


def set_sharding_context(mesh: Optional[Mesh], *, sequence_parallel: bool = True):
    if mesh is None:
        _CTX.update(mesh=None, batch_axes=None, seq_axis=None)
        return
    batch = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    _CTX.update(
        mesh=mesh,
        batch_axes=batch if batch else None,
        seq_axis="model" if sequence_parallel and "model" in mesh.axis_names else None,
    )


def shard_activation(x, kind: str = "residual"):
    """Constraint for [B, S, D] activations: batch→(pod,data), seq→model (SP)."""
    mesh = _CTX["mesh"]
    if mesh is None:
        return x
    spec = P(_CTX["batch_axes"], _CTX["seq_axis"], None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def constrain(x, spec: P):
    """Raw with_sharding_constraint under the context mesh (no-op unset)."""
    mesh = _CTX["mesh"]
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def batch_axes():
    return _CTX["batch_axes"]


def moe_mode(num_experts: int) -> Optional[str]:
    """'ep' when experts divide the model axis, else 'tp' (shard d_ff)."""
    mesh = _CTX["mesh"]
    if mesh is None or "model" not in mesh.axis_names:
        return None
    m = mesh.shape["model"]
    return "ep" if num_experts % m == 0 else "tp"


# ---------------------------------------------------------------------------
# parameter shardings (path-pattern rules)
# ---------------------------------------------------------------------------
# (regex over the flattened key path, PartitionSpec applied to the LAST dims;
#  leading stacked dims [reps, g] are always unsharded)
_PARAM_RULES = [
    (r"embed$", P("model", None)),                 # vocab-sharded table
    (r"unembed$", P(None, "model")),
    (r"pos_embed$|enc_pos$", P(None, None)),
    (r"patch_proj$", P(None, None)),
    # attention projections (tail dims after the stacked prefix)
    (r"(attn|cross)/wq$", P(None, "model")),
    (r"(attn|cross)/wk$", P(None, "model")),
    (r"(attn|cross)/wv$", P(None, "model")),
    (r"(attn|cross)/wo$", P("model", None)),
    (r"(attn|cross)/(q_norm|k_norm)$", P(None)),
    # dense MLP
    (r"mlp/w_gate$|mlp/w_up$|mlp/w_fc$", P(None, "model")),
    (r"mlp/w_down$|mlp/w_proj$", P("model", None)),
    (r"mlp/b_fc$", P("model")),
    (r"mlp/b_proj$", P(None)),
    # MoE (expert parallelism over "model")
    (r"moe/router$", P(None, None)),
    (r"moe/w_gate$|moe/w_up$", P("model", None, None)),
    (r"moe/w_down$", P("model", None, None)),
    # mamba2
    (r"mamba/w_in$", P(None, "model")),
    (r"mamba/conv_w$", P(None, "model")),
    (r"mamba/conv_b$", P("model")),
    (r"mamba/(A_log|D|dt_bias)$", P("model")),
    (r"mamba/norm_w$", P("model")),
    (r"mamba/w_out$", P("model", None)),
    # norms & everything else: replicated
    (r".*", P()),
]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _spec_for(path_s: str, ndim: int) -> P:
    for pat, spec in _PARAM_RULES:
        if re.search(pat, path_s):
            tail = tuple(spec)
            if len(tail) > ndim:  # scalar-ish params
                tail = tail[-ndim:] if ndim else ()
            pad = (None,) * (ndim - len(tail))
            return P(*(pad + tail))
    return P()


def param_shardings(params_shape: Any, mesh: Mesh, cfg=None):
    """Pytree of NamedSharding matching an (eval_shape) params pytree.

    MoE rule is config-dependent: experts→"model" (EP) when num_experts divides
    the model axis; otherwise TP inside each expert (shard d_ff) — e.g. mixtral
    E=8 on a 16-way axis."""
    model_size = mesh.shape.get("model", 1)
    moe_tp = bool(cfg and cfg.num_experts and cfg.num_experts % model_size != 0)

    def one(path, leaf):
        ps = _path_str(path)
        nd = len(leaf.shape)
        if moe_tp and re.search(r"moe/(w_gate|w_up)$", ps):
            return NamedSharding(mesh, P(*([None] * (nd - 1) + ["model"])))   # F
        if moe_tp and re.search(r"moe/w_down$", ps):
            spec = [None] * nd
            spec[-2] = "model"                                                # F
            return NamedSharding(mesh, P(*spec))
        return NamedSharding(mesh, _spec_for(ps, nd))

    return jax.tree_util.tree_map_with_path(one, params_shape)


# ---------------------------------------------------------------------------
# batch / cache shardings
# ---------------------------------------------------------------------------
def _batch_axes(mesh) -> Any:
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return axes if axes else None


def batch_shardings(batch_shape: Any, mesh: Mesh, batch_divisible: bool = True):
    """tokens/targets [B,S] → batch over (pod,data); stub embeddings likewise."""
    dp = _batch_axes(mesh) if batch_divisible else None

    def one(leaf):
        nd = len(leaf.shape)
        if nd == 0:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, P(dp, *([None] * (nd - 1))))

    return jax.tree.map(one, batch_shape)


def cache_shardings(cache_shape: Any, mesh: Mesh, batch: int):
    """Decode cache rule (DESIGN.md §6): batch→(pod,data) when divisible,
    cache sequence→"model" (uniform rule that works for every kv_heads count,
    including MQA kv=1; head-sharding is the §Perf alternative)."""
    dp = _batch_axes(mesh)
    n_dp = 1
    for a in (dp or ()):
        n_dp *= mesh.shape[a]
    dp = dp if (dp and batch % n_dp == 0) else None

    model_size = mesh.shape.get("model", 1)

    def one(path, leaf):
        nd = len(leaf.shape)
        ps = _path_str(path)
        if re.search(r"(^|/)(ck|cv)$", ps) and nd >= 4:
            # cross-attention cache [..., B, enc, KV, hd]: enc_len (1500) does
            # not divide the axis — shard kv-heads instead (whisper kv=16)
            spec = [None] * nd
            spec[-4] = dp
            spec[-2] = "model" if leaf.shape[-2] % model_size == 0 else None
            return NamedSharding(mesh, P(*spec))
        if re.search(r"(^|/)(k|v)$", ps) and nd >= 4:
            # [..., B, S, KV, hd] — batch and sequence are dims -4/-3
            spec = [None] * nd
            spec[-4] = dp
            spec[-3] = "model"
            return NamedSharding(mesh, P(*spec))
        if ps.endswith("conv") and nd == 4:      # [L, B, K-1, conv_dim]
            return NamedSharding(mesh, P(None, dp, None, "model"))
        if ps.endswith("ssd") and nd == 5:       # [L, B, nh, hd, state]
            return NamedSharding(mesh, P(None, dp, "model", None, None))
        return NamedSharding(mesh, P(*([None] * nd)))

    return jax.tree_util.tree_map_with_path(one, cache_shape)
