"""Distributed-optimization collectives.

``compressed_psum`` — the paper's truncation quantizer applied to the
data-parallel gradient all-reduce, with error feedback (DESIGN.md §4.3):

  on each device:  c = trunc_grid(g + r);  r' = (g + r) - c
  all-reduce:      G = psum(c) / n

Wire bytes drop from 32-bit to (1 + int_bits + frac_bits) per element; the
residual r carries the truncation error into the next step so the long-run
update is unbiased (error-feedback SGD).  Validated in tests against exact
psum (bounded error per step; identical convergence on a quadratic).

``make_compressed_grad_allreduce`` wraps it over a pytree via shard_map for a
pure-DP training loop; in the hybrid pjit train step the same quantizer can be
applied per-shard before XLA's automatic reduction.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.core.quantization import truncate_to_grid


def compressed_psum(g, residual, axis: str, frac_bits: int = 12):
    """Quantized all-reduce of one array with error feedback.  Returns
    (mean-reduced gradient, new residual)."""
    corrected = g + residual
    q = truncate_to_grid(corrected, frac_bits)
    new_residual = corrected - q
    reduced = jax.lax.pmean(q, axis)
    return reduced, new_residual


def make_compressed_grad_allreduce(mesh: Mesh, axis: str, frac_bits: int = 12):
    """shard_map pytree gradient all-reduce with per-leaf error feedback."""

    def allreduce(grads, residuals):
        def one(g, r):
            return compressed_psum(g, r, axis, frac_bits)

        pairs = jax.tree.map(one, grads, residuals)
        red = jax.tree.map(lambda t: t[0], pairs,
                           is_leaf=lambda t: isinstance(t, tuple))
        res = jax.tree.map(lambda t: t[1], pairs,
                           is_leaf=lambda t: isinstance(t, tuple))
        return red, res

    def wrapped(grads, residuals):
        specs = jax.tree.map(lambda _: P(axis), grads)  # grads sharded on data
        rspecs = jax.tree.map(lambda _: P(axis), residuals)
        return shard_map(
            allreduce, mesh=mesh,
            in_specs=(specs, rspecs),
            out_specs=(jax.tree.map(lambda _: P(axis), grads), rspecs),
        )(grads, residuals)

    return wrapped


def collective_bytes_saved(n_params: int, frac_bits: int, int_bits: int = 2) -> float:
    """Wire-format reduction factor vs f32 ring all-reduce (for §Perf napkin math)."""
    return 32.0 / (1 + int_bits + frac_bits)
