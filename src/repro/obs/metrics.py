"""Bounded metrics registry — counters, gauges, histograms, reservoirs.

The serving stack's telemetry kept unbounded per-wave lists (every wave
latency, every shadow score, forever); a long-lived server leaks.  This
module is the bounded replacement: every instrument here holds O(1) state in
the number of observations —

``Counter``     monotone float/int total.
``Gauge``       last value + running peak (the admission-queue gauges need
                "what is it now" *and* "how bad did it get").
``Histogram``   exponential (or explicit) bucket counts + exact sum/count.
                Sum and count make means exact; the buckets bound the tail's
                memory at the cost of percentile resolution.
``Reservoir``   fixed-size uniform sample (Vitter's Algorithm R) with a
                *seeded* RNG, so percentile estimates are deterministic under
                replayed traffic.  While fewer observations than ``size``
                have arrived the reservoir holds all of them, so small runs
                (every test, every bench warm-up) report *exact* percentiles
                — only a long-lived server degrades gracefully to a sample.

Instruments live in a ``MetricsRegistry`` keyed by metric name; a metric may
carry label dimensions (``registry.counter("served", labels=("precision",))``
then ``.labels(precision="f32").inc()``), and the per-family series count is
capped (``max_series``) so a label-cardinality bug degrades into one overflow
series instead of an unbounded map — the registry itself obeys the bound it
exists to enforce.

The registry is exporter-agnostic: ``collect()`` yields plain sample tuples
that repro.obs.export renders as Prometheus text exposition or JSON.
"""
from __future__ import annotations

import bisect
import math
import random
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

__all__ = [
    "Counter", "Gauge", "Histogram", "Reservoir", "MetricsRegistry",
    "exponential_buckets",
]

#: label-values key of the unlabeled (single-series) child of a family
_NO_LABELS: Tuple[str, ...] = ()

#: the series every over-cardinality observation collapses into
OVERFLOW_LABEL = "_overflow"


def exponential_buckets(start: float, factor: float, count: int) -> Tuple[float, ...]:
    """``count`` upper bounds ``start, start*factor, ...`` (no +Inf — every
    histogram implicitly owns the overflow bucket)."""
    if start <= 0 or factor <= 1 or count < 1:
        raise ValueError(
            f"need start > 0, factor > 1, count >= 1; "
            f"got {start}/{factor}/{count}")
    return tuple(start * factor ** i for i in range(count))


#: default latency bounds: 1 µs .. ~137 s in doublings (28 buckets)
LATENCY_BUCKETS = exponential_buckets(1e-6, 2.0, 28)


class Counter:
    """Monotone total; ``inc`` only goes up."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counters only go up, got inc({n})")
        self.value += n


class Gauge:
    """Last-written value plus its running peak."""

    __slots__ = ("value", "peak")

    def __init__(self) -> None:
        self.value = 0.0
        self.peak = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)
        if v > self.peak:
            self.peak = float(v)


class Histogram:
    """Cumulative-bucket histogram with exact ``sum``/``count``.

    ``bounds`` are upper bounds in ascending order; observations above the
    last bound land in the implicit overflow bucket (rendered ``le="+Inf"``).
    """

    __slots__ = ("bounds", "bucket_counts", "sum", "count")

    def __init__(self, bounds: Sequence[float] = LATENCY_BUCKETS):
        bounds = tuple(float(b) for b in bounds)
        if not bounds or any(b2 <= b1 for b1, b2 in zip(bounds, bounds[1:])):
            raise ValueError(f"bounds must be non-empty ascending, got {bounds}")
        self.bounds = bounds
        self.bucket_counts = [0] * (len(bounds) + 1)   # +1: overflow
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        v = float(v)
        self.bucket_counts[bisect.bisect_left(self.bounds, v)] += 1
        self.sum += v
        self.count += 1

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def cumulative(self) -> List[Tuple[float, int]]:
        """``(le, cumulative_count)`` per bound, ending with (+inf, count)."""
        out: List[Tuple[float, int]] = []
        running = 0
        for b, n in zip(self.bounds, self.bucket_counts):
            running += n
            out.append((b, running))
        out.append((math.inf, self.count))
        return out


class Reservoir:
    """Fixed-size uniform sample of an unbounded observation stream.

    Algorithm R with a seeded ``random.Random`` — two services replaying the
    same traffic hold identical reservoirs, which keeps percentile-based
    assertions and benches deterministic.  ``values()`` returns observations
    in arrival order (evictions replace in place), so while ``n_seen <= size``
    it is exactly the full history.
    """

    __slots__ = ("size", "n_seen", "sum", "_values", "_rng")

    def __init__(self, size: int = 1024, seed: int = 0):
        if size < 1:
            raise ValueError(f"reservoir size must be >= 1, got {size}")
        self.size = size
        self.n_seen = 0
        self.sum = 0.0                     # over every observation ever seen
        self._values: List[float] = []
        self._rng = random.Random(seed)

    def add(self, v: float) -> None:
        self.n_seen += 1
        self.sum += float(v)
        if len(self._values) < self.size:
            self._values.append(float(v))
            return
        j = self._rng.randrange(self.n_seen)
        if j < self.size:
            self._values[j] = float(v)

    def values(self) -> List[float]:
        return list(self._values)

    def percentile(self, q: float) -> float:
        """Linear-interpolated percentile of the held sample (0 when empty)."""
        if not self._values:
            return 0.0
        vals = sorted(self._values)
        pos = (len(vals) - 1) * (q / 100.0)
        lo = int(math.floor(pos))
        hi = min(lo + 1, len(vals) - 1)
        frac = pos - lo
        return vals[lo] * (1.0 - frac) + vals[hi] * frac


_KINDS = {"counter": Counter, "gauge": Gauge}


class _Family:
    """One named metric and its labeled children (bounded)."""

    def __init__(self, name: str, kind: str, help: str,
                 labels: Tuple[str, ...], max_series: int,
                 make_child) -> None:
        self.name = name
        self.kind = kind
        self.help = help
        self.label_names = labels
        self.max_series = max_series
        self._make_child = make_child
        self._children: Dict[Tuple[str, ...], object] = {}
        if not labels:                    # unlabeled: materialize eagerly so
            self._children[_NO_LABELS] = make_child()   # zero values export

    def labels(self, **kv: str):
        if tuple(sorted(kv)) != tuple(sorted(self.label_names)):
            raise ValueError(
                f"metric {self.name!r} takes labels {self.label_names}, "
                f"got {tuple(sorted(kv))}")
        key = tuple(str(kv[ln]) for ln in self.label_names)
        child = self._children.get(key)
        if child is None:
            if len(self._children) >= self.max_series:
                # cardinality bug containment: collapse into one series
                key = tuple(OVERFLOW_LABEL for _ in self.label_names)
                child = self._children.get(key)
                if child is None:
                    child = self._children[key] = self._make_child()
                return child
            child = self._children[key] = self._make_child()
        return child

    def get(self):
        """The unlabeled child (only valid on label-less families)."""
        if self.label_names:
            raise ValueError(f"metric {self.name!r} is labeled "
                             f"({self.label_names}) — use .labels()")
        return self._children[_NO_LABELS]

    def series(self) -> Iterable[Tuple[Tuple[Tuple[str, str], ...], object]]:
        """``((label, value), ...) → instrument`` pairs, label-sorted."""
        for key in sorted(self._children):
            yield tuple(zip(self.label_names, key)), self._children[key]


class MetricsRegistry:
    """Name → family index; get-or-create, type-checked, bounded.

    ``reservoir_size`` is the percentile sample bound every ``reservoir()``
    defaults to — the one knob that trades percentile fidelity for memory.
    """

    def __init__(self, reservoir_size: int = 1024, max_series: int = 256):
        if reservoir_size < 1:
            raise ValueError(f"reservoir_size must be >= 1, "
                             f"got {reservoir_size}")
        self.reservoir_size = reservoir_size
        self.max_series = max_series
        self._families: Dict[str, _Family] = {}

    # ------------------------------------------------------------------
    def _family(self, name: str, kind: str, help: str,
                labels: Tuple[str, ...], make_child) -> _Family:
        fam = self._families.get(name)
        if fam is not None:
            if fam.kind != kind or fam.label_names != labels:
                raise ValueError(
                    f"metric {name!r} already registered as {fam.kind} with "
                    f"labels {fam.label_names}; asked for {kind}/{labels}")
            return fam
        fam = _Family(name, kind, help, labels, self.max_series, make_child)
        self._families[name] = fam
        return fam

    def counter(self, name: str, help: str = "",
                labels: Sequence[str] = ()) -> _Family:
        return self._family(name, "counter", help, tuple(labels), Counter)

    def gauge(self, name: str, help: str = "",
              labels: Sequence[str] = ()) -> _Family:
        return self._family(name, "gauge", help, tuple(labels), Gauge)

    def histogram(self, name: str, help: str = "",
                  labels: Sequence[str] = (),
                  bounds: Sequence[float] = LATENCY_BUCKETS) -> _Family:
        return self._family(name, "histogram", help, tuple(labels),
                            lambda: Histogram(bounds))

    def reservoir(self, name: str, help: str = "",
                  labels: Sequence[str] = (),
                  size: Optional[int] = None, seed: int = 0) -> _Family:
        n = self.reservoir_size if size is None else size
        return self._family(name, "reservoir", help, tuple(labels),
                            lambda: Reservoir(n, seed=seed))

    # ------------------------------------------------------------------
    def names(self) -> List[str]:
        return sorted(self._families)

    def collect(self) -> List[Tuple[str, str, str, List[Tuple[Tuple[Tuple[str, str], ...], object]]]]:
        """``(name, kind, help, [(labels, instrument), ...])`` per family,
        name-sorted — the exporter contract."""
        return [(name, fam.kind, fam.help, list(fam.series()))
                for name, fam in sorted(self._families.items())]

    def as_dict(self) -> Dict[str, object]:
        """Flat JSON-ready snapshot: scalar instruments become numbers,
        histograms/reservoirs become summary dicts.  Labeled series append
        ``{label=value,...}`` to the key, Prometheus-style."""
        out: Dict[str, object] = {}
        for name, kind, _help, series in self.collect():
            for labels, inst in series:
                key = name
                if labels:
                    key += "{" + ",".join(f"{k}={v}" for k, v in labels) + "}"
                if kind == "counter":
                    out[key] = inst.value
                elif kind == "gauge":
                    out[key] = inst.value
                    out[key + "_peak"] = inst.peak
                elif kind == "histogram":
                    out[key] = {"count": inst.count, "sum": inst.sum,
                                "mean": inst.mean}
                else:                                   # reservoir
                    out[key] = {"n_seen": inst.n_seen,
                                "p50": inst.percentile(50),
                                "p95": inst.percentile(95),
                                "p99": inst.percentile(99)}
        return out
