"""Observability layer for the serving stack — tracing, metrics, exporters.

The paper's claim is a latency/quality trade measured end-to-end; the serving
stack realizing it (HTTP admission → futures → κ-waves → engines →
fixed-point iteration) could only report lifetime aggregates.  This package
is the time-resolved counterpart, with memory O(1) in queries served:

``metrics.py``   bounded instruments (Counter/Gauge/Histogram/Reservoir) in
                 a ``MetricsRegistry`` with label support and a series cap —
                 what ``ServiceTelemetry`` stores its state in.
``trace.py``     span-based tracer with injected clocks: every query carries
                 a trace (submit → cache probe → admission wait → wave
                 execute → resolution) cross-linked with a per-wave trace
                 (plan → iterate w/ early-exit residual → top-K → resolve).
``recorder.py``  flight recorder: ring buffers of the last N completed
                 traces and admission-control transitions, so a shed/degrade
                 incident can be reconstructed after the fact.
``export.py``    Prometheus text exposition (``GET /v1/metrics``), JSON
                 dumps, and terminal-friendly trace/SLO rendering.
``slo.py``       declarative SLO specs (latency / shed rate / shadow
                 quality) evaluated over sliding windows by an
                 injected-clock ``SLOMonitor`` with multi-window error-budget
                 burn-rate alerting — the layer that makes the instruments
                 actionable.
``otlp.py``      stdlib-only OTLP/HTTP-JSON exporter: spans via a fan-out
                 ``Tracer`` sink beside the flight recorder, metrics via a
                 periodic delta-temporality push.

Everything is clock-injected and deterministic under test; nothing here
imports jax — the observability layer must never be the thing that makes
the hot path slow or the test suite heavy.
"""
from repro.obs.export import (
    format_event,
    format_slo,
    format_trace,
    prometheus_text,
)
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    Reservoir,
    exponential_buckets,
)
from repro.obs.otlp import OTLPExporter
from repro.obs.recorder import FlightRecorder
from repro.obs.slo import SLOMonitor, SLOSpec, default_slo_specs
from repro.obs.trace import Span, Trace, Tracer, fanout_sink

__all__ = [
    "Counter", "Gauge", "Histogram", "Reservoir", "MetricsRegistry",
    "exponential_buckets",
    "Span", "Trace", "Tracer", "fanout_sink",
    "FlightRecorder",
    "SLOSpec", "SLOMonitor", "default_slo_specs",
    "OTLPExporter",
    "prometheus_text", "format_trace", "format_event", "format_slo",
]
