"""OTLP/HTTP JSON exporter — traces and metrics leave the process, stdlib-only.

The flight recorder answers "what just happened *here*"; a fleet needs the
same spans and counters in a collector.  This module speaks the
OpenTelemetry Protocol over HTTP/JSON (``POST <endpoint>/v1/traces`` and
``/v1/metrics``) with nothing but ``urllib`` — no OpenTelemetry SDK, no new
runtime dependency, per the repo's no-new-deps rule.

Span path: ``OTLPExporter.record_trace`` is a ``Tracer`` sink.  The service
composes it *beside* the flight recorder via ``repro.obs.trace.fanout_sink``
— export augments the local record, never replaces it.  Completed traces are
converted to OTLP span dicts immediately (no live service objects are
pinned) and held in a bounded queue; ``tick()`` drains the queue in batches.
Span/trace ids derive deterministically from the tracer's monotone trace ids
(32-hex traceId, 16-hex spanId = trace id ⊕ preorder index), so a replayed
run exports byte-identical payloads — the golden snapshot test relies on it.

Metric path: ``tick()`` periodically pushes the registry in **delta
temporality** — counters and histograms report the change since the last
push (a restart-safe stream for a collector), gauges report current value
(plus a ``_peak`` sibling, matching the Prometheus rendering), reservoirs
report as summaries.  Timestamps are the injected clock scaled to
nanoseconds; with the default ``time.monotonic`` they are process-relative,
which OTLP permits for delta streams (collectors align on arrival).

Failure policy: bounded queue (oldest spans dropped past ``queue_capacity``),
``max_retries`` sends with exponential backoff, then the batch is dropped
and counted — the exporter must degrade by losing telemetry, never by
blocking the pump thread indefinitely or growing without bound.  Every
decision is visible: internal counters (``stats()``) are mirrored as
``otlp_*`` families in the bound registry so ``/v1/metrics`` reports on the
exporter itself.
"""
from __future__ import annotations

import json
import time
import urllib.request
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Tuple

from repro.obs.trace import Span, Trace

__all__ = ["OTLPExporter"]

_QUANTILES = (0.5, 0.95, 0.99)
_ID64 = (1 << 64) - 1
_ID128 = (1 << 128) - 1


def _attr_value(v: Any) -> Dict[str, Any]:
    """One attribute value in OTLP AnyValue JSON (int64 renders as string,
    per the protobuf-JSON mapping; bool checked before int — bool is int)."""
    if isinstance(v, bool):
        return {"boolValue": v}
    if isinstance(v, int):
        return {"intValue": str(v)}
    if isinstance(v, float):
        return {"doubleValue": v}
    if isinstance(v, (list, tuple)):
        return {"arrayValue": {"values": [_attr_value(x) for x in v]}}
    return {"stringValue": str(v)}


def _attrs(mapping: Dict[str, Any]) -> List[Dict[str, Any]]:
    return [{"key": k, "value": _attr_value(mapping[k])}
            for k in sorted(mapping)]


def _ns(t_s: float) -> str:
    return str(max(0, int(t_s * 1e9)))


def _http_post(url: str, body: bytes, timeout_s: float) -> None:
    req = urllib.request.Request(
        url, data=body, method="POST",
        headers={"Content-Type": "application/json"})
    with urllib.request.urlopen(req, timeout=timeout_s) as resp:
        resp.read()


class OTLPExporter:
    """Pushes spans and delta metrics to an OTLP/HTTP collector.

    ``transport`` is the injectable send seam — any
    ``(url, body_bytes) -> None`` raising on failure; the default posts with
    ``urllib``.  ``registry=None`` defers the self-metric mirror to
    ``bind_registry`` (the service binds its telemetry registry).  All time
    comes from ``time_fn``; retries back off via ``sleep_fn`` (both injected
    so tests run instantly and deterministically)."""

    def __init__(self, endpoint: str, *, service_name: str = "repro-ppr",
                 flush_interval_s: float = 5.0, max_batch: int = 128,
                 queue_capacity: int = 2048, max_retries: int = 2,
                 backoff_s: float = 0.05, timeout_s: float = 2.0,
                 transport=None, registry=None, time_fn=time.monotonic,
                 sleep_fn=time.sleep):
        if flush_interval_s <= 0:
            raise ValueError(
                f"flush_interval_s must be > 0, got {flush_interval_s}")
        if max_batch < 1 or queue_capacity < 1:
            raise ValueError(
                f"max_batch/queue_capacity must be >= 1, got "
                f"{max_batch}/{queue_capacity}")
        if max_retries < 0 or backoff_s < 0:
            raise ValueError(
                f"max_retries/backoff_s must be >= 0, got "
                f"{max_retries}/{backoff_s}")
        base = endpoint.rstrip("/")
        self.endpoint = base
        self.traces_url = base + "/v1/traces"
        self.metrics_url = base + "/v1/metrics"
        self.service_name = service_name
        self.flush_interval_s = flush_interval_s
        self.max_batch = max_batch
        self.queue_capacity = queue_capacity
        self.max_retries = max_retries
        self.backoff_s = backoff_s
        self.timeout_s = timeout_s
        self.transport = transport if transport is not None else (
            lambda url, body: _http_post(url, body, self.timeout_s))
        self.time_fn = time_fn
        self.sleep_fn = sleep_fn
        self._spans: Deque[Dict[str, Any]] = deque()
        self._last_push_t: Optional[float] = None
        self._window_start_t = time_fn()
        # delta snapshots: (family, label_key) -> last cumulative state
        self._counter_last: Dict[Tuple[str, Tuple[str, ...]], float] = {}
        self._hist_last: Dict[Tuple[str, Tuple[str, ...]],
                              Tuple[Tuple[int, ...], float, int]] = {}
        # authoritative internal counters (survive a telemetry reset);
        # mirrored as otlp_* families once a registry is bound
        self._counts = {"spans_queued": 0, "spans_exported": 0,
                        "spans_dropped": 0, "span_batches_sent": 0,
                        "metric_pushes": 0, "send_failures": 0,
                        "send_retries": 0}
        self._mirror = None
        if registry is not None:
            self.bind_registry(registry)

    # ------------------------------------------------------------------
    def bind_registry(self, registry) -> None:
        """Mirror the exporter's own counters as ``otlp_*`` families in
        ``registry`` (the service's telemetry registry), so a scrape of
        ``/v1/metrics`` reports on the export pipeline itself."""
        self._mirror = {
            "spans_queued": registry.counter(
                "otlp_spans_queued_total", "Spans accepted from the tracer."),
            "spans_exported": registry.counter(
                "otlp_spans_exported_total", "Spans delivered in sent batches."),
            "spans_dropped": registry.counter(
                "otlp_spans_dropped_total",
                "Spans lost to queue overflow or exhausted retries."),
            "span_batches_sent": registry.counter(
                "otlp_batches_sent_total", "Span batches POSTed."),
            "metric_pushes": registry.counter(
                "otlp_metric_pushes_total", "Delta metric payloads POSTed."),
            "send_failures": registry.counter(
                "otlp_send_failures_total",
                "POSTs that failed after every retry."),
            "send_retries": registry.counter(
                "otlp_send_retries_total", "Individual send attempts retried."),
        }

    def _count(self, key: str, n: int = 1) -> None:
        self._counts[key] += n
        if self._mirror is not None:
            self._mirror[key].get().inc(n)

    def stats(self) -> Dict[str, int]:
        out = dict(self._counts)
        out["queue_depth"] = len(self._spans)
        return out

    # ------------------------------------------------------------------
    # span path (Tracer sink)
    # ------------------------------------------------------------------
    def record_trace(self, trace: Trace) -> None:
        """Tracer sink: convert the completed trace to OTLP spans and queue
        them.  Bounded — past ``queue_capacity`` the *oldest* spans drop
        (fresh telemetry beats stale during an incident)."""
        spans = self._otlp_spans(trace)
        self._count("spans_queued", len(spans))
        self._spans.extend(spans)
        overflow = len(self._spans) - self.queue_capacity
        if overflow > 0:
            for _ in range(overflow):
                self._spans.popleft()
            self._count("spans_dropped", overflow)

    def _otlp_spans(self, trace: Trace) -> List[Dict[str, Any]]:
        trace_hex = f"{trace.trace_id & _ID128:032x}"
        out: List[Dict[str, Any]] = []

        def walk(span: Span, parent_hex: str, index: int) -> int:
            span_hex = f"{((trace.trace_id << 16) | index) & _ID64:016x}"
            attrs = dict(span.attrs)
            if parent_hex == "":
                attrs.setdefault("trace.kind", trace.kind)
            end_s = span.end_s if span.end_s is not None else span.start_s
            rec: Dict[str, Any] = {
                "traceId": trace_hex,
                "spanId": span_hex,
                "name": span.name,
                "kind": 1,                     # SPAN_KIND_INTERNAL
                "startTimeUnixNano": _ns(span.start_s),
                "endTimeUnixNano": _ns(end_s),
                "status": {"code": 0},
            }
            if parent_hex:
                rec["parentSpanId"] = parent_hex
            if attrs:
                rec["attributes"] = _attrs(attrs)
            out.append(rec)
            nxt = index + 1
            for child in span.children:
                nxt = walk(child, span_hex, nxt)
            return nxt

        walk(trace.root, "", 0)
        return out

    def _span_payload(self, spans: List[Dict[str, Any]]) -> Dict[str, Any]:
        return {"resourceSpans": [{
            "resource": {"attributes": _attrs(
                {"service.name": self.service_name})},
            "scopeSpans": [{
                "scope": {"name": "repro.obs", "version": "1"},
                "spans": spans,
            }],
        }]}

    # ------------------------------------------------------------------
    # metric path (delta temporality)
    # ------------------------------------------------------------------
    def _metric_payload(self, registry, now: float) -> Dict[str, Any]:
        start_ns, now_ns = _ns(self._window_start_t), _ns(now)
        metrics: List[Dict[str, Any]] = []
        for name, kind, help_text, series in registry.collect():
            dps_main: List[Dict[str, Any]] = []
            dps_peak: List[Dict[str, Any]] = []
            for labels, inst in series:
                attrs = _attrs(dict(labels))
                lkey = tuple(v for _, v in labels)
                base: Dict[str, Any] = {"timeUnixNano": now_ns}
                if attrs:
                    base["attributes"] = attrs
                if kind == "counter":
                    prev = self._counter_last.get((name, lkey), 0.0)
                    self._counter_last[(name, lkey)] = inst.value
                    dps_main.append({**base, "startTimeUnixNano": start_ns,
                                     "asDouble": inst.value - prev})
                elif kind == "gauge":
                    dps_main.append({**base, "asDouble": inst.value})
                    dps_peak.append({**base, "asDouble": inst.peak})
                elif kind == "histogram":
                    buckets = tuple(inst.bucket_counts)
                    prev_b, prev_sum, prev_n = self._hist_last.get(
                        (name, lkey),
                        ((0,) * len(buckets), 0.0, 0))
                    self._hist_last[(name, lkey)] = \
                        (buckets, inst.sum, inst.count)
                    dps_main.append({
                        **base,
                        "startTimeUnixNano": start_ns,
                        "count": str(inst.count - prev_n),
                        "sum": inst.sum - prev_sum,
                        "bucketCounts": [str(b - p) for b, p
                                         in zip(buckets, prev_b)],
                        "explicitBounds": list(inst.bounds),
                    })
                else:                                       # reservoir
                    dps_main.append({
                        **base,
                        "count": str(inst.n_seen),
                        "sum": inst.sum,
                        "quantileValues": [
                            {"quantile": q,
                             "value": inst.percentile(q * 100.0)}
                            for q in _QUANTILES],
                    })
            entry: Dict[str, Any] = {"name": name}
            if help_text:
                entry["description"] = help_text
            if kind == "counter":
                entry["sum"] = {"dataPoints": dps_main,
                                "aggregationTemporality": 1,  # DELTA
                                "isMonotonic": True}
                metrics.append(entry)
            elif kind == "gauge":
                entry["gauge"] = {"dataPoints": dps_main}
                metrics.append(entry)
                metrics.append({"name": name + "_peak",
                                "description": f"Running peak of {name}.",
                                "gauge": {"dataPoints": dps_peak}})
            elif kind == "histogram":
                entry["histogram"] = {"dataPoints": dps_main,
                                      "aggregationTemporality": 1}
                metrics.append(entry)
            else:
                entry["summary"] = {"dataPoints": dps_main}
                metrics.append(entry)
        return {"resourceMetrics": [{
            "resource": {"attributes": _attrs(
                {"service.name": self.service_name})},
            "scopeMetrics": [{
                "scope": {"name": "repro.obs", "version": "1"},
                "metrics": metrics,
            }],
        }]}

    # ------------------------------------------------------------------
    # sending
    # ------------------------------------------------------------------
    def _send(self, url: str, payload: Dict[str, Any]) -> bool:
        """POST with retry/backoff; True on delivery, False once dropped.
        ``sort_keys`` keeps payload bytes deterministic (golden snapshots)."""
        body = json.dumps(payload, sort_keys=True).encode("utf-8")
        for attempt in range(self.max_retries + 1):
            try:
                self.transport(url, body)
                return True
            except Exception:
                if attempt == self.max_retries:
                    break
                self._count("send_retries")
                if self.backoff_s:
                    self.sleep_fn(self.backoff_s * (2 ** attempt))
        self._count("send_failures")
        return False

    def _drain_spans(self) -> int:
        posts = 0
        while self._spans:
            batch = [self._spans.popleft()
                     for _ in range(min(self.max_batch, len(self._spans)))]
            posts += 1
            if self._send(self.traces_url, self._span_payload(batch)):
                self._count("span_batches_sent")
                self._count("spans_exported", len(batch))
            else:
                self._count("spans_dropped", len(batch))
        return posts

    def _push_metrics(self, registry, now: float) -> int:
        payload = self._metric_payload(registry, now)
        delivered = self._send(self.metrics_url, payload)
        if delivered:
            self._count("metric_pushes")
        # the delta window advances either way: a dropped push loses its
        # window (counted above) rather than double-reporting the next one
        self._window_start_t = now
        self._last_push_t = now
        return 1

    # ------------------------------------------------------------------
    def due(self, now: Optional[float] = None) -> bool:
        """True when a periodic metrics push is owed or spans are queued."""
        now = self.time_fn() if now is None else now
        if self._spans:
            return True
        return (self._last_push_t is None or
                now - self._last_push_t >= self.flush_interval_s)

    def tick(self, registry=None, now: Optional[float] = None) -> int:
        """One export cycle: drain queued span batches; push delta metrics
        when the flush interval has elapsed.  Returns POSTs made.  Safe to
        call every pump heartbeat — idle ticks cost two comparisons."""
        now = self.time_fn() if now is None else now
        posts = self._drain_spans()
        if registry is not None and (
                self._last_push_t is None or
                now - self._last_push_t >= self.flush_interval_s):
            posts += self._push_metrics(registry, now)
        return posts

    def flush(self, registry=None, now: Optional[float] = None) -> int:
        """Shutdown/final export: drain every span and force a metrics push
        regardless of the interval."""
        now = self.time_fn() if now is None else now
        posts = self._drain_spans()
        if registry is not None:
            posts += self._push_metrics(registry, now)
        return posts
