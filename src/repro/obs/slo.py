"""SLO monitoring — declarative objectives + multi-window burn-rate alerting.

The paper's premise is trading exact convergence for latency/throughput under
a quality floor; in a serving system those are literally SLOs on three axes
the stack already measures:

``latency``   admitted-query latency ≤ ``objective`` seconds for at least
              ``1 - budget`` of queries (budget=0.05 ⇒ "p95 ≤ objective"),
              read from the ``ppr_query_latency_seconds`` histogram.
``shed``      shed arrivals ≤ ``budget`` of all arrivals, read from the
              served / shed / deadline-shed counters.
``quality``   shadow-scored NDCG ≥ ``objective`` for at least ``1 - budget``
              of sampled auto queries, read from ``ppr_shadow_quality``.

All three reduce to the same error-budget algebra: a *bad fraction* measured
over a sliding window, divided by the allowed ``budget``, is the **burn
rate** — 1.0 burns the budget exactly at the sustainable pace, 14 exhausts a
5%% budget in hours.  ``SLOMonitor`` evaluates each spec with the
SRE-workbook multi-window scheme: alert when *both* windows of the fast pair
(default 5m/1h) exceed ``fast_burn``, or both of the slow pair (1h/6h) exceed
``slow_burn``; recover with hysteresis once the short windows drop below
``recover_burn`` — the wide gap between engage (≥14) and recover (<1)
thresholds is what keeps the alert from flapping at the boundary.

The monitor never observes events itself: it periodically *samples*
cumulative (good, bad) totals from the ``MetricsRegistry`` families the
service already maintains, holds a bounded ring of those snapshots, and
differences them against window baselines.  Histogram-backed SLOs
(latency/quality) resolve objectives at bucket granularity — an objective
between bounds is effectively rounded down to the nearest bucket bound, so
pick objectives on the bucket grid (latency buckets are doublings of 1 µs;
quality buckets are the 0.05 grid).  With no samples older than a window yet
(startup, tests), the window is evaluated from the oldest sample available —
a flood right after boot alerts without waiting an hour for history.

Alert transitions land three ways: a ``slo_burning``/``slo_recovered``
control-plane event in the flight recorder, the ``slo_state`` gauge +
``slo_transitions_total`` counter in the registry (so ``GET /v1/metrics``
carries them), and ``status()`` — what ``GET /v1/slo`` serves.
``burning_kinds()`` is the advisory read the admission controller closes the
loop with: latency/shed burn pushes the deepen-κ → degrade ladder, quality
burn vetoes degradation (degrading further would burn it harder).

Clock-injected and stdlib-only, like everything in ``repro.obs``.
"""
from __future__ import annotations

import bisect
import dataclasses
import time
from collections import deque
from typing import Deque, Dict, FrozenSet, List, Optional, Sequence, Tuple

__all__ = ["SLO_KINDS", "SLOSpec", "SLOMonitor", "default_slo_specs"]

SLO_KINDS = ("latency", "shed", "quality")

#: registry families the monitor samples (created get-or-create, so a bare
#: registry under test works; in the service they already exist with help)
LATENCY_FAMILY = "ppr_query_latency_seconds"
SERVED_FAMILY = "ppr_queries_served_total"
SHED_FAMILY = "ppr_queries_shed_total"
DEADLINE_SHED_FAMILY = "ppr_queries_deadline_shed_total"
QUALITY_FAMILY = "ppr_shadow_quality"

#: unit-interval bounds of the shadow-quality histogram (must match
#: ServiceTelemetry's — duplicated here because obs must not import serving)
_UNIT_BUCKETS = tuple(i / 20 for i in range(1, 21))


@dataclasses.dataclass(frozen=True)
class SLOSpec:
    """One objective: what fraction of events may be bad, over which windows.

    ``objective`` is the latency bound in seconds (kind="latency") or the
    quality floor in NDCG (kind="quality"); unused for kind="shed", where
    every shed arrival is bad by definition.  ``budget`` is the allowed bad
    fraction (0.05 ⇒ 95%% compliance).  ``graph=None`` aggregates across
    every graph; naming one scopes the SLO to that graph's series."""
    name: str
    kind: str
    objective: float = 0.0
    budget: float = 0.05
    graph: Optional[str] = None
    fast_windows: Tuple[float, float] = (300.0, 3600.0)
    slow_windows: Tuple[float, float] = (3600.0, 21600.0)
    fast_burn: float = 14.0
    slow_burn: float = 6.0
    recover_burn: float = 1.0
    #: windows with fewer events than this report burn 0 (no evidence)
    min_events: int = 1

    def __post_init__(self):
        if not self.name:
            raise ValueError("SLOSpec needs a non-empty name")
        if self.kind not in SLO_KINDS:
            raise ValueError(f"unknown SLO kind {self.kind!r} "
                             f"(have {SLO_KINDS})")
        if not 0.0 < self.budget <= 1.0:
            raise ValueError(f"budget must be in (0, 1], got {self.budget}")
        if self.kind == "latency" and self.objective <= 0.0:
            raise ValueError(f"latency objective must be > 0 seconds, "
                             f"got {self.objective}")
        if self.kind == "quality" and not 0.0 < self.objective <= 1.0:
            raise ValueError(f"quality floor must be in (0, 1], "
                             f"got {self.objective}")
        for pair, label in ((self.fast_windows, "fast_windows"),
                            (self.slow_windows, "slow_windows")):
            if len(pair) != 2 or not 0 < pair[0] < pair[1]:
                raise ValueError(f"{label} must be (short, long) with "
                                 f"0 < short < long, got {pair}")
        if not self.fast_burn >= self.slow_burn > self.recover_burn > 0:
            raise ValueError(
                f"need fast_burn >= slow_burn > recover_burn > 0, got "
                f"{self.fast_burn}/{self.slow_burn}/{self.recover_burn}")
        if self.min_events < 1:
            raise ValueError(f"min_events must be >= 1, got {self.min_events}")

    @property
    def windows(self) -> Tuple[float, ...]:
        """Every distinct window length, ascending (the pairs may share)."""
        return tuple(sorted(set(self.fast_windows) | set(self.slow_windows)))


def default_slo_specs(latency_objective_s: float = 0.262144,
                      latency_budget: float = 0.05,
                      shed_budget: float = 0.05,
                      quality_floor: float = 0.90,
                      quality_budget: float = 0.10,
                      graph: Optional[str] = None) -> Tuple[SLOSpec, ...]:
    """The house spec set: p95 latency, shed rate, shadow-quality floor.

    The default latency objective sits exactly on a histogram bucket bound
    (1e-6 * 2^18 s ≈ 262 ms) so the bad-fraction read is exact."""
    return (
        SLOSpec("latency_p95", "latency", objective=latency_objective_s,
                budget=latency_budget, graph=graph),
        SLOSpec("shed_rate", "shed", budget=shed_budget, graph=graph),
        SLOSpec("shadow_quality", "quality", objective=quality_floor,
                budget=quality_budget),
    )


@dataclasses.dataclass
class _SpecState:
    """Mutable per-spec evaluation state inside the monitor."""
    spec: SLOSpec
    state: str = "ok"                       # "ok" | "burning"
    # (t, good_cum, bad_cum) snapshots, oldest first, pruned past the
    # longest window — O(window / resolution) memory, not O(queries)
    samples: Deque[Tuple[float, float, float]] = \
        dataclasses.field(default_factory=deque)
    good_total: float = 0.0
    bad_total: float = 0.0
    # last tick's per-window evaluation, what status() serves
    windows: Dict[float, Dict[str, float]] = \
        dataclasses.field(default_factory=dict)
    transitions: int = 0


class SLOMonitor:
    """Evaluates a spec set against a registry on an injected clock.

    ``tick(now)`` is the only mutation: sample totals, difference against
    window baselines, run the alert state machine.  The serving tier ticks it
    from the admission controller (every arrival *and* every pump heartbeat),
    so burn is evaluated exactly when load moves; anything else may call
    ``tick`` too — it is idempotent within a ``resolution_s`` bucket."""

    def __init__(self, registry, specs: Sequence[SLOSpec],
                 time_fn=time.monotonic, recorder=None,
                 resolution_s: float = 1.0):
        if not specs:
            raise ValueError("SLOMonitor needs at least one SLOSpec")
        names = [s.name for s in specs]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate SLO names: {sorted(names)}")
        if resolution_s < 0.0:
            raise ValueError(f"resolution_s must be >= 0, got {resolution_s}")
        self.registry = registry
        self.specs = tuple(specs)
        self.time_fn = time_fn
        self.recorder = recorder
        self.resolution_s = resolution_s
        self._states = {s.name: _SpecState(s) for s in self.specs}
        # slo_* families live beside the ppr_* ones so one scrape carries both
        self._burn = registry.gauge(
            "slo_burn_rate", "Error-budget burn rate per SLO and window "
            "(1.0 = budget consumed exactly at the sustainable pace).",
            labels=("slo", "window"))
        self._state_g = registry.gauge(
            "slo_state", "SLO alert state (0 = ok, 1 = burning).",
            labels=("slo",))
        self._transitions = registry.counter(
            "slo_transitions_total", "Alert state-machine transitions.",
            labels=("slo", "state"))
        self._ticks = registry.counter(
            "slo_ticks_total", "Monitor evaluation cycles.")
        for s in self.specs:
            self._state_g.labels(slo=s.name).set(0.0)

    # ------------------------------------------------------------------
    # cumulative (good, bad) totals per kind, read from the registry
    # ------------------------------------------------------------------
    def _series(self, family, graph: Optional[str]):
        for labels, inst in family.series():
            if graph is not None and any(
                    k == "graph" and v != graph for k, v in labels):
                continue
            yield inst

    @staticmethod
    def _hist_below(hist, threshold: float, inclusive: bool) -> int:
        """Observations ≤ the largest bound ≤ threshold (inclusive) or
        < threshold (exclusive) — bucket-granular, never over-counting."""
        cut = bisect.bisect_right(hist.bounds, threshold) if inclusive \
            else bisect.bisect_left(hist.bounds, threshold)
        return sum(hist.bucket_counts[:cut])

    def _totals(self, spec: SLOSpec) -> Tuple[float, float]:
        if spec.kind == "latency":
            fam = self.registry.histogram(LATENCY_FAMILY, labels=("graph",))
            good = bad = 0.0
            for hist in self._series(fam, spec.graph):
                g = self._hist_below(hist, spec.objective, inclusive=True)
                good += g
                bad += hist.count - g
            return good, bad
        if spec.kind == "shed":
            served = self.registry.counter(SERVED_FAMILY, labels=("graph",))
            shed = self.registry.counter(SHED_FAMILY, labels=("graph",))
            late = self.registry.counter(DEADLINE_SHED_FAMILY,
                                         labels=("graph",))
            good = sum(c.value for c in self._series(served, spec.graph))
            bad = (sum(c.value for c in self._series(shed, spec.graph)) +
                   sum(c.value for c in self._series(late, spec.graph)))
            return good, bad
        # quality: scores below the floor are the bad events; the shadow
        # histogram is unlabeled, so a graph-scoped quality spec still reads
        # the global distribution
        fam = self.registry.histogram(QUALITY_FAMILY, bounds=_UNIT_BUCKETS)
        hist = fam.get()
        bad = float(self._hist_below(hist, spec.objective, inclusive=False))
        return hist.count - bad, bad

    # ------------------------------------------------------------------
    def tick(self, now: Optional[float] = None) -> None:
        """One evaluation cycle: sample, window, alert."""
        now = self.time_fn() if now is None else now
        self._ticks.get().inc()
        for st in self._states.values():
            spec = st.spec
            good, bad = self._totals(spec)
            st.good_total, st.bad_total = good, bad
            samples = st.samples
            if not samples or now - samples[-1][0] >= self.resolution_s:
                samples.append((now, good, bad))
            horizon = now - spec.windows[-1]
            # keep one sample at/older than the horizon: it is the longest
            # window's baseline
            while len(samples) >= 2 and samples[1][0] <= horizon:
                samples.popleft()
            burns: Dict[float, float] = {}
            st.windows = {}
            for w in spec.windows:
                base = samples[0]
                for s in samples:
                    if s[0] <= now - w:
                        base = s
                    else:
                        break
                d_bad = bad - base[2]
                events = (good - base[1]) + d_bad
                if events < spec.min_events:
                    frac = burn = 0.0
                else:
                    frac = d_bad / events
                    burn = frac / spec.budget
                burns[w] = burn
                st.windows[w] = {"burn_rate": burn, "bad_fraction": frac,
                                 "events": events}
                self._burn.labels(slo=spec.name, window=f"{w:g}").set(burn)
            self._advance(st, burns, now)

    def _advance(self, st: _SpecState, burns: Dict[float, float],
                 now: float) -> None:
        spec = st.spec
        engage = ((burns[spec.fast_windows[0]] >= spec.fast_burn and
                   burns[spec.fast_windows[1]] >= spec.fast_burn) or
                  (burns[spec.slow_windows[0]] >= spec.slow_burn and
                   burns[spec.slow_windows[1]] >= spec.slow_burn))
        if st.state == "ok" and engage:
            self._transition(st, "burning", 1.0, "slo_burning", burns, now)
        elif st.state == "burning" and not engage and \
                burns[spec.fast_windows[0]] < spec.recover_burn and \
                burns[spec.slow_windows[0]] < spec.recover_burn:
            self._transition(st, "ok", 0.0, "slo_recovered", burns, now)

    def _transition(self, st: _SpecState, state: str, gauge: float,
                    event: str, burns: Dict[float, float],
                    now: float) -> None:
        spec = st.spec
        st.state = state
        st.transitions += 1
        self._state_g.labels(slo=spec.name).set(gauge)
        self._transitions.labels(slo=spec.name, state=state).inc()
        if self.recorder is not None:
            self.recorder.record_event(
                event, now, slo=spec.name, slo_kind=spec.kind,
                burn_fast=burns[spec.fast_windows[0]],
                burn_slow=burns[spec.slow_windows[0]],
                bad_total=st.bad_total, good_total=st.good_total)

    # ------------------------------------------------------------------
    def states(self) -> Dict[str, str]:
        return {name: st.state for name, st in self._states.items()}

    def burning(self) -> List[str]:
        return sorted(name for name, st in self._states.items()
                      if st.state == "burning")

    def burning_kinds(self) -> FrozenSet[str]:
        """The kinds currently burning — the admission controller's advisory
        signal (latency/shed push the degradation ladder; quality vetoes)."""
        return frozenset(st.spec.kind for st in self._states.values()
                         if st.state == "burning")

    def any_burning(self) -> bool:
        return any(st.state == "burning" for st in self._states.values())

    def status(self) -> Dict[str, object]:
        """JSON-ready evaluation snapshot — what ``GET /v1/slo`` serves.
        Reflects the last ``tick``; tick first for a fresh read."""
        specs = []
        for spec in self.specs:
            st = self._states[spec.name]
            specs.append({
                "name": spec.name,
                "kind": spec.kind,
                "graph": spec.graph,
                "objective": spec.objective,
                "budget": spec.budget,
                "state": st.state,
                "transitions": st.transitions,
                "good_total": st.good_total,
                "bad_total": st.bad_total,
                "windows": {f"{w:g}": dict(info)
                            for w, info in sorted(st.windows.items())},
            })
        return {
            "specs": specs,
            "burning": self.burning(),
            "ticks": int(self._ticks.get().value),
        }
