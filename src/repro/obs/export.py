"""Exporters: Prometheus text exposition, JSON snapshots, trace pretty-print.

``prometheus_text`` renders a ``MetricsRegistry`` in the text exposition
format (version 0.0.4) a Prometheus scraper ingests from ``GET /v1/metrics``:

    # HELP ppr_waves_total Waves launched.
    # TYPE ppr_waves_total counter
    ppr_waves_total 5
    ppr_wave_latency_seconds_bucket{le="0.001"} 2
    ...

Mapping choices:

- counters/gauges render 1:1; a gauge's running peak renders as a sibling
  ``<name>_peak`` gauge (Prometheus has no native peak — and the peak *is*
  the point of the admission-queue gauges).
- histograms render canonically (``_bucket``/``_sum``/``_count`` with a
  ``+Inf`` bucket).
- reservoirs render as summaries (``quantile`` series + ``_sum``/``_count``)
  — quantiles come from the bounded sample, sum/count are exact lifetime.

``format_trace`` renders one flight-recorder trace dict as an indented span
tree for terminals (``launch/ppr_run.py --dump-traces``, the HTTP example).
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Mapping

from repro.obs.metrics import MetricsRegistry

__all__ = ["prometheus_text", "format_trace", "format_event", "format_slo"]

_QUANTILES = (("0.5", 50.0), ("0.95", 95.0), ("0.99", 99.0))


def _escape(value: str) -> str:
    return (str(value).replace("\\", r"\\").replace('"', r'\"')
            .replace("\n", r"\n"))


def _labels(pairs) -> str:
    if not pairs:
        return ""
    inner = ",".join(f'{k}="{_escape(v)}"' for k, v in pairs)
    return "{" + inner + "}"


def _num(v: float) -> str:
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    f = float(v)
    return str(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


def prometheus_text(registry: MetricsRegistry) -> str:
    """The registry in Prometheus text exposition format (0.0.4)."""
    lines: List[str] = []

    def head(name: str, kind: str, help: str) -> None:
        if help:
            lines.append(f"# HELP {name} {_escape(help)}")
        lines.append(f"# TYPE {name} {kind}")

    for name, kind, help, series in registry.collect():
        if kind == "counter":
            head(name, "counter", help)
            for labels, c in series:
                lines.append(f"{name}{_labels(labels)} {_num(c.value)}")
        elif kind == "gauge":
            head(name, "gauge", help)
            for labels, g in series:
                lines.append(f"{name}{_labels(labels)} {_num(g.value)}")
            head(f"{name}_peak", "gauge", f"Running peak of {name}.")
            for labels, g in series:
                lines.append(f"{name}_peak{_labels(labels)} {_num(g.peak)}")
        elif kind == "histogram":
            head(name, "histogram", help)
            for labels, h in series:
                for le, cum in h.cumulative():
                    lines.append(
                        f"{name}_bucket"
                        f"{_labels(tuple(labels) + (('le', _num(le)),))} "
                        f"{cum}")
                lines.append(f"{name}_sum{_labels(labels)} {_num(h.sum)}")
                lines.append(f"{name}_count{_labels(labels)} {h.count}")
        else:                                               # reservoir
            head(name, "summary", help)
            for labels, r in series:
                for q_label, q in _QUANTILES:
                    lines.append(
                        f"{name}"
                        f"{_labels(tuple(labels) + (('quantile', q_label),))} "
                        f"{_num(r.percentile(q))}")
                lines.append(f"{name}_sum{_labels(labels)} {_num(r.sum)}")
                lines.append(f"{name}_count{_labels(labels)} {r.n_seen}")
    return "\n".join(lines) + "\n"


# ---------------------------------------------------------------------------
# human-readable trace dumps
# ---------------------------------------------------------------------------
def _fmt_attrs(attrs: Mapping[str, Any]) -> str:
    if not attrs:
        return ""
    inner = ", ".join(
        f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
        for k, v in attrs.items())
    return f"  [{inner}]"


def _fmt_span(span: Dict[str, Any], indent: int, lines: List[str]) -> None:
    dur = span.get("duration_s") or 0.0
    lines.append(f"{'  ' * indent}{span['name']:<20s} "
                 f"{dur * 1e3:8.3f} ms{_fmt_attrs(span.get('attrs', {}))}")
    for child in span.get("children", ()):
        _fmt_span(child, indent + 1, lines)


def format_trace(trace: Dict[str, Any]) -> str:
    """One flight-recorder trace dict as an indented span tree."""
    root = trace["root"]
    lines: List[str] = [f"trace {trace['trace_id']} ({trace['kind']})"]
    _fmt_span(root, 1, lines)
    return "\n".join(lines)


def format_event(event: Mapping[str, Any]) -> str:
    """One flight-recorder control-plane event as a single line."""
    extra = {k: v for k, v in event.items() if k not in ("t_s", "kind")}
    return f"t={event['t_s']:.4f}s {event['kind']}{_fmt_attrs(extra)}"


def format_slo(status: Mapping[str, Any]) -> str:
    """An ``SLOMonitor.status()`` dict as a terminal table — one line per
    spec with its state and per-window burn rates."""
    burning = ", ".join(status.get("burning", [])) or "none"
    lines: List[str] = [f"slo status  ({status.get('ticks', 0)} ticks, "
                        f"burning: {burning})"]
    for spec in status.get("specs", ()):
        windows = spec.get("windows", {})
        burns = "  ".join(
            f"{w}s={info.get('burn_rate', 0.0):.2f}"
            for w, info in sorted(windows.items(), key=lambda kv: float(kv[0])))
        lines.append(f"  {spec['name']:<16s} {spec['kind']:<8s} "
                     f"{spec['state']:<8s} {burns}")
    return "\n".join(lines)
