"""Flight recorder — the last N completed traces and control-plane events.

Lifetime counters say a shed event happened; reconstructing *the incident*
(queue built up → κ deepened → quality degraded → arrivals shed → drained →
recovered, and what the queries in flight experienced meanwhile) needs a
time-resolved record.  The recorder is two ring buffers:

``traces``   the last ``trace_capacity`` completed ``Trace``s (query and
             wave kinds interleaved in completion order), stored as plain
             dicts so a dump is JSON-ready and holds no live object graphs.
``events``   admission-control transitions and other control-plane moments
             (shed engage/recover, SLO degrade/recover, κ moves, deltas,
             graph replacement), each ``{t_s, kind, ...attrs}``.

Both are ``deque(maxlen=...)`` — O(1) memory in queries served, the same
bound the metrics registry enforces.  ``GET /v1/debug/traces`` and
``launch/ppr_run.py --dump-traces`` serve ``snapshot()`` verbatim.
"""
from __future__ import annotations

from collections import deque
from typing import Any, Dict, List, Optional

from repro.obs.trace import Trace

__all__ = ["FlightRecorder"]


class FlightRecorder:
    def __init__(self, trace_capacity: int = 256, event_capacity: int = 1024):
        if trace_capacity < 1 or event_capacity < 1:
            raise ValueError(
                f"capacities must be >= 1, got {trace_capacity}/"
                f"{event_capacity}")
        self.trace_capacity = trace_capacity
        self.event_capacity = event_capacity
        self._traces: "deque[Dict[str, Any]]" = deque(maxlen=trace_capacity)
        self._events: "deque[Dict[str, Any]]" = deque(maxlen=event_capacity)
        self.traces_recorded = 0
        self.events_recorded = 0

    # ------------------------------------------------------------------
    def record_trace(self, trace: Trace) -> None:
        """Sink for ``Tracer`` — stores the trace's dict form, so the ring
        never pins service objects (futures, arrays) against GC."""
        self._traces.append(trace.to_dict())
        self.traces_recorded += 1

    def record_event(self, kind: str, t_s: float, **attrs: Any) -> None:
        ev: Dict[str, Any] = {"t_s": float(t_s), "kind": kind}
        ev.update(attrs)
        self._events.append(ev)
        self.events_recorded += 1

    # ------------------------------------------------------------------
    def traces(self, n: Optional[int] = None) -> List[Dict[str, Any]]:
        """The most recent ``n`` completed traces, oldest first."""
        out = list(self._traces)
        return out if n is None else out[-n:]

    def events(self, n: Optional[int] = None) -> List[Dict[str, Any]]:
        out = list(self._events)
        return out if n is None else out[-n:]

    def events_of_kind(self, *kinds: str,
                       n: Optional[int] = None) -> List[Dict[str, Any]]:
        """The most recent ``n`` events whose kind is in ``kinds``, oldest
        first — how ``/v1/slo`` pulls just the alert transitions out of the
        shared control-plane ring."""
        out = [ev for ev in self._events if ev["kind"] in kinds]
        return out if n is None else out[-n:]

    def snapshot(self, n_traces: Optional[int] = None,
                 n_events: Optional[int] = None) -> Dict[str, Any]:
        """JSON-ready dump: what ``/v1/debug/traces`` serves."""
        return {
            "trace_capacity": self.trace_capacity,
            "event_capacity": self.event_capacity,
            "traces_recorded": self.traces_recorded,
            "events_recorded": self.events_recorded,
            "traces": self.traces(n_traces),
            "events": self.events(n_events),
        }
