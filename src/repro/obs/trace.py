"""Span-based query tracing with injected clocks.

The serving stack's lifetime aggregates can say *that* p95 moved; a trace
says where one query's milliseconds went.  A ``Trace`` is a tree of
``Span``s under one root, carrying the stages a query (or a wave) passes
through:

    query trace:  submit → resolve_precision → cache_probe
                  → admission_wait → wave_execute → (resolved | rejected)
    wave trace:   plan → warm_start → iterate (iterations run, early-exit,
                  residual) → topk → resolve, plus member-trace links

Waves are the unit of compute and queries the unit of latency, so the two
trace kinds cross-link instead of nesting: every member query trace records
its ``wave_trace`` id and the wave trace lists ``member_traces`` — a flight
recorder dump can be re-joined into the full picture after the fact.

Time is injected (``time_fn``) exactly like the scheduler's: tests drive
traces with a fake clock and assert whole span trees deterministically.
The tracer itself holds no history — completed traces go to a sink (the
flight recorder); a tracing-off service simply has no tracer and pays only
an ``is None`` check per instrumentation point.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Any, Callable, Dict, List, Optional

__all__ = ["Span", "Trace", "Tracer", "fanout_sink"]


def fanout_sink(*sinks: Callable[["Trace"], None]
                ) -> Callable[["Trace"], None]:
    """Compose tracer sinks: every completed trace goes to each sink in
    order.  The flight recorder stays the first, authoritative sink; an
    exporter rides beside it — export augments the local record, never
    replaces it.  ``None`` entries are skipped so callers can pass optional
    sinks unconditionally."""
    live = tuple(s for s in sinks if s is not None)
    if len(live) == 1:
        return live[0]

    def sink(trace: "Trace") -> None:
        for s in live:
            s(trace)

    return sink


@dataclasses.dataclass
class Span:
    """One timed stage; children are sub-stages."""
    name: str
    start_s: float
    end_s: Optional[float] = None
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    children: List["Span"] = dataclasses.field(default_factory=list)

    @property
    def duration_s(self) -> float:
        return 0.0 if self.end_s is None else self.end_s - self.start_s

    def end(self, t: float, **attrs: Any) -> "Span":
        self.end_s = t
        if attrs:
            self.attrs.update(attrs)
        return self

    def child(self, name: str, t: float, **attrs: Any) -> "Span":
        sp = Span(name, t, attrs=dict(attrs))
        self.children.append(sp)
        return sp

    def to_dict(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {"name": self.name, "start_s": self.start_s,
                               "end_s": self.end_s,
                               "duration_s": self.duration_s}
        if self.attrs:
            out["attrs"] = dict(self.attrs)
        if self.children:
            out["children"] = [c.to_dict() for c in self.children]
        return out


@dataclasses.dataclass
class Trace:
    """One query's (or one wave's) span tree plus identity/link attributes."""
    trace_id: int
    kind: str                              # "query" | "wave"
    root: Span
    done: bool = False

    @property
    def attrs(self) -> Dict[str, Any]:
        return self.root.attrs

    def span(self, name: str, t: float, **attrs: Any) -> Span:
        return self.root.child(name, t, **attrs)

    def to_dict(self) -> Dict[str, Any]:
        return {"trace_id": self.trace_id, "kind": self.kind,
                "root": self.root.to_dict()}


class Tracer:
    """Mints traces against one clock; finished traces flow to ``sink``.

    ``sink`` is any callable taking a completed ``Trace`` — in the service
    it is the flight recorder's ``record_trace``.  Trace ids are a process-
    local monotone counter: unique within a service lifetime, cheap, and
    stable under replay."""

    def __init__(self, time_fn: Callable[[], float] = time.monotonic,
                 sink: Optional[Callable[[Trace], None]] = None):
        self.time_fn = time_fn
        self.sink = sink
        self._ids = itertools.count(1)
        self.started = 0
        self.finished = 0

    def start(self, kind: str, name: str,
              t: Optional[float] = None, **attrs: Any) -> Trace:
        t = self.time_fn() if t is None else t
        self.started += 1
        return Trace(next(self._ids), kind,
                     Span(name, t, attrs=dict(attrs)))

    def finish(self, trace: Trace, t: Optional[float] = None,
               **attrs: Any) -> Trace:
        """End the root span, mark done, hand to the sink.  Idempotent —
        a trace that raced two completion paths records only the first."""
        if trace.done:
            return trace
        trace.root.end(self.time_fn() if t is None else t, **attrs)
        trace.done = True
        self.finished += 1
        if self.sink is not None:
            self.sink(trace)
        return trace
