"""IR ranking metrics for PPR accuracy (paper §5.3.1, Figs. 4-6).

All metrics compare an approximate ranking (fixed-point FPGA analogue) against a
converged reference ranking (the CPU float64 oracle).

- num_errors@N  : vertices whose position in the top-N differs (coarse; the
                  paper's example {2,4,8,6} vs {4,8,6,2} → 4 errors).
- edit_distance@N : Levenshtein distance between top-N sequences.
- NDCG          : rel_i = |V| − i (paper's relevance), log2 discount, normalized
                  by the reference's ideal DCG.
- precision@N   : |topN_approx ∩ topN_ref| / N (order-insensitive).
- kendall_tau@N : pairwise order agreement on the reference top-N.
- MAE           : mean |score_approx − score_ref| over all vertices.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np


def topk_indices(scores: np.ndarray, k: int) -> np.ndarray:
    """Indices of the k largest scores, ties broken by vertex id (deterministic)."""
    scores = np.asarray(scores)
    # argsort on (-score, idx): stable deterministic ranking
    order = np.lexsort((np.arange(scores.shape[0]), -scores))
    return order[:k]


def num_errors(approx: np.ndarray, ref: np.ndarray, n: int) -> int:
    ta = topk_indices(approx, n)
    tr = topk_indices(ref, n)
    return int((ta != tr).sum())


def edit_distance(approx: np.ndarray, ref: np.ndarray, n: int) -> int:
    """Levenshtein distance between the two top-N vertex sequences."""
    a = topk_indices(approx, n).tolist()
    b = topk_indices(ref, n).tolist()
    la, lb = len(a), len(b)
    prev = list(range(lb + 1))
    for i in range(1, la + 1):
        cur = [i] + [0] * lb
        for j in range(1, lb + 1):
            cost = 0 if a[i - 1] == b[j - 1] else 1
            cur[j] = min(prev[j] + 1, cur[j - 1] + 1, prev[j - 1] + cost)
        prev = cur
    return int(prev[lb])


def ndcg(approx: np.ndarray, ref: np.ndarray, n: int | None = None) -> float:
    """Paper's NDCG: rel of vertex = |V| − (its reference rank); DCG over the
    approx ordering; normalized by the reference (ideal) DCG."""
    v = ref.shape[0]
    n = n or v
    ref_order = topk_indices(ref, v)
    rel = np.empty(v, np.float64)
    rel[ref_order] = v - np.arange(v)          # rel_i = |V| - rank_i
    approx_order = topk_indices(approx, n)
    discounts = 1.0 / np.log2(np.arange(1, n + 1) + 1)
    dcg = float((rel[approx_order] * discounts).sum())
    idcg = float((rel[ref_order[:n]] * discounts).sum())
    return dcg / idcg if idcg > 0 else 1.0


def precision_at(approx: np.ndarray, ref: np.ndarray, n: int) -> float:
    ta = set(topk_indices(approx, n).tolist())
    tr = set(topk_indices(ref, n).tolist())
    return len(ta & tr) / float(n)


def kendall_tau(approx: np.ndarray, ref: np.ndarray, n: int) -> float:
    """Kendall's τ-b restricted to the reference top-N vertices."""
    import scipy.stats as st

    idx = topk_indices(ref, n)
    tau, _ = st.kendalltau(ref[idx], approx[idx])
    return float(tau) if np.isfinite(tau) else 1.0


def mae(approx: np.ndarray, ref: np.ndarray) -> float:
    return float(np.abs(np.asarray(approx, np.float64) - np.asarray(ref, np.float64)).mean())


def full_report(approx: np.ndarray, ref: np.ndarray,
                ns: Sequence[int] = (10, 20, 50)) -> dict:
    """All paper metrics for one (approx, ref) score-vector pair."""
    rep = {"mae": mae(approx, ref), "ndcg": ndcg(approx, ref, max(ns))}
    for n in ns:
        rep[f"errors@{n}"] = num_errors(approx, ref, n)
        rep[f"edit@{n}"] = edit_distance(approx, ref, n)
        rep[f"precision@{n}"] = precision_at(approx, ref, n)
        rep[f"kendall@{n}"] = kendall_tau(approx, ref, n)
    return rep


def aggregate_reports(reports: Sequence[dict]) -> dict:
    """Mean of each metric over a batch of personalization vertices."""
    keys = reports[0].keys()
    return {k: float(np.mean([r[k] for r in reports])) for k in keys}
