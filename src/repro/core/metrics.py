"""IR ranking metrics for PPR accuracy (paper §5.3.1, Figs. 4-6).

All metrics compare an approximate ranking (fixed-point FPGA analogue) against a
converged reference ranking (the CPU float64 oracle).

- num_errors@N  : vertices whose position in the top-N differs (coarse; the
                  paper's example {2,4,8,6} vs {4,8,6,2} → 4 errors).
- edit_distance@N : Levenshtein distance between top-N sequences.
- NDCG          : rel_i = |V| − i (paper's relevance), log2 discount, normalized
                  by the reference's ideal DCG.
- precision@N   : |topN_approx ∩ topN_ref| / N (order-insensitive).
- kendall_tau@N : pairwise order agreement on the reference top-N.
- MAE           : mean |score_approx − score_ref| over all vertices.

Every top-N metric accepts precomputed ``approx_order`` / ``ref_order`` full
rankings (from :func:`ranking`) so hot-path callers — ``full_report`` itself and
the serving-side shadow quality estimator (repro.autotune.quality), which scores
a sampled fraction of *all served queries* — sort each score vector once instead
of once per metric.  N larger than |V| is clamped to |V| everywhere.

``kendall_tau`` uses scipy when available and falls back to a pure-numpy τ-b
(O(N²) pairwise, fine for top-N sizes) so a scipy-less environment never loses
``full_report``.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

try:  # scipy is optional: the tier-1 env may not ship it
    from scipy.stats import kendalltau as _scipy_kendalltau
except Exception:  # pragma: no cover - exercised only in scipy-less envs
    _scipy_kendalltau = None


def ranking(scores: np.ndarray) -> np.ndarray:
    """Full deterministic ranking: indices by descending score, ties broken by
    ascending vertex id.  ``topk_indices(s, k) == ranking(s)[:k]``."""
    scores = np.asarray(scores)
    # argsort on (-score, idx): stable deterministic ranking
    return np.lexsort((np.arange(scores.shape[0]), -scores))


def topk_indices(scores: np.ndarray, k: int) -> np.ndarray:
    """Indices of the k largest scores, ties broken by vertex id (deterministic).
    k beyond |V| returns all |V| indices."""
    return ranking(scores)[:k]


def _order(scores: np.ndarray, precomputed: Optional[np.ndarray]) -> np.ndarray:
    return ranking(scores) if precomputed is None else np.asarray(precomputed)


def num_errors(approx: np.ndarray, ref: np.ndarray, n: int, *,
               approx_order: Optional[np.ndarray] = None,
               ref_order: Optional[np.ndarray] = None) -> int:
    ta = _order(approx, approx_order)[:n]
    tr = _order(ref, ref_order)[:n]
    return int((ta != tr).sum())


def edit_distance(approx: np.ndarray, ref: np.ndarray, n: int, *,
                  approx_order: Optional[np.ndarray] = None,
                  ref_order: Optional[np.ndarray] = None) -> int:
    """Levenshtein distance between the two top-N vertex sequences."""
    a = _order(approx, approx_order)[:n].tolist()
    b = _order(ref, ref_order)[:n].tolist()
    la, lb = len(a), len(b)
    prev = list(range(lb + 1))
    for i in range(1, la + 1):
        cur = [i] + [0] * lb
        for j in range(1, lb + 1):
            cost = 0 if a[i - 1] == b[j - 1] else 1
            cur[j] = min(prev[j] + 1, cur[j - 1] + 1, prev[j - 1] + cost)
        prev = cur
    return int(prev[lb])


def ndcg(approx: np.ndarray, ref: np.ndarray, n: int | None = None, *,
         approx_order: Optional[np.ndarray] = None,
         ref_order: Optional[np.ndarray] = None) -> float:
    """Paper's NDCG: rel of vertex = |V| − (its reference rank); DCG over the
    approx ordering; normalized by the reference (ideal) DCG."""
    v = ref.shape[0]
    n = min(n or v, v)
    ref_order = _order(ref, ref_order)
    rel = np.empty(v, np.float64)
    rel[ref_order] = v - np.arange(v)          # rel_i = |V| - rank_i
    approx_top = _order(approx, approx_order)[:n]
    discounts = 1.0 / np.log2(np.arange(1, n + 1) + 1)
    dcg = float((rel[approx_top] * discounts).sum())
    idcg = float((rel[ref_order[:n]] * discounts).sum())
    return dcg / idcg if idcg > 0 else 1.0


def precision_at(approx: np.ndarray, ref: np.ndarray, n: int, *,
                 approx_order: Optional[np.ndarray] = None,
                 ref_order: Optional[np.ndarray] = None) -> float:
    n = min(n, np.asarray(ref).shape[0])
    ta = set(_order(approx, approx_order)[:n].tolist())
    tr = set(_order(ref, ref_order)[:n].tolist())
    return len(ta & tr) / float(n) if n else 1.0


def _kendall_tau_b(x: np.ndarray, y: np.ndarray) -> float:
    """Pure-numpy Kendall τ-b: (C − D) / √((n₀ − ties_x)(n₀ − ties_y)) over all
    pairs.  O(N²) memory/time — intended for top-N slices, not full graphs."""
    x = np.asarray(x, np.float64)
    y = np.asarray(y, np.float64)
    n = x.shape[0]
    if n < 2:
        return float("nan")
    iu = np.triu_indices(n, 1)
    dx = np.sign(x[:, None] - x[None, :])[iu]
    dy = np.sign(y[:, None] - y[None, :])[iu]
    num = float((dx * dy).sum())               # C − D (tied pairs contribute 0)
    n0 = dx.shape[0]
    denom = np.sqrt(float(n0 - (dx == 0).sum()) * float(n0 - (dy == 0).sum()))
    return num / denom if denom > 0 else float("nan")


def kendall_tau(approx: np.ndarray, ref: np.ndarray, n: int, *,
                ref_order: Optional[np.ndarray] = None) -> float:
    """Kendall's τ-b restricted to the reference top-N vertices."""
    idx = _order(ref, ref_order)[:n]
    if _scipy_kendalltau is not None:
        tau, _ = _scipy_kendalltau(ref[idx], approx[idx])
    else:
        tau = _kendall_tau_b(ref[idx], approx[idx])
    return float(tau) if np.isfinite(tau) else 1.0


def mae(approx: np.ndarray, ref: np.ndarray) -> float:
    return float(np.abs(np.asarray(approx, np.float64) - np.asarray(ref, np.float64)).mean())


def full_report(approx: np.ndarray, ref: np.ndarray,
                ns: Sequence[int] = (10, 20, 50), *,
                ref_order: Optional[np.ndarray] = None) -> dict:
    """All paper metrics for one (approx, ref) score-vector pair.

    Both score vectors are ranked exactly once; pass ``ref_order=ranking(ref)``
    when scoring many approximations against one fixed reference (the shadow
    estimator's hot path) to skip even that sort.
    """
    approx_order = ranking(approx)
    ref_order = _order(ref, ref_order)
    kw = {"approx_order": approx_order, "ref_order": ref_order}
    rep = {"mae": mae(approx, ref), "ndcg": ndcg(approx, ref, max(ns), **kw)}
    for n in ns:
        rep[f"errors@{n}"] = num_errors(approx, ref, n, **kw)
        rep[f"edit@{n}"] = edit_distance(approx, ref, n, **kw)
        rep[f"precision@{n}"] = precision_at(approx, ref, n, **kw)
        rep[f"kendall@{n}"] = kendall_tau(approx, ref, n, ref_order=ref_order)
    return rep


def aggregate_reports(reports: Sequence[dict]) -> dict:
    """Mean of each metric over a batch of personalization vertices."""
    keys = reports[0].keys()
    return {k: float(np.mean([r[k] for r in reports])) for k in keys}
