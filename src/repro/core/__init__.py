# The paper's primary contribution: reduced-precision streaming COO SpMV + PPR.
from repro.core.coo import (
    BlockedCOO,
    COOGraph,
    EdgeMergeInfo,
    merge_edge_delta,
    quantize_values,
)
from repro.core.fixed_point import (
    BITWIDTH_TO_FORMAT,
    PAPER_FORMATS,
    Q1_19,
    Q1_21,
    Q1_23,
    Q1_25,
    QFormat,
    format_for_bits,
)
from repro.core.ppr import (
    PPRConfig,
    batched_ppr,
    make_ppr_fixed,
    make_ppr_fixed_step,
    make_ppr_sharded_fixed_step,
    make_ppr_sharded_float_step,
    personalization_matrix,
    personalization_matrix_fixed,
    ppr_float,
    ppr_step_float,
    run_ppr,
)
from repro.core.spmv import (
    make_sharded_spmv,
    make_sharded_spmv_fixed,
    partition_edges_by_dst,
    sharded_vertex_layout,
    spmv_fixed,
    spmv_float,
    spmv_pallas,
)

__all__ = [
    "COOGraph", "BlockedCOO", "EdgeMergeInfo", "merge_edge_delta",
    "quantize_values", "QFormat", "format_for_bits",
    "Q1_19", "Q1_21", "Q1_23", "Q1_25", "PAPER_FORMATS", "BITWIDTH_TO_FORMAT",
    "PPRConfig", "run_ppr", "batched_ppr", "ppr_float", "make_ppr_fixed",
    "ppr_step_float", "make_ppr_fixed_step",
    "make_ppr_sharded_float_step", "make_ppr_sharded_fixed_step",
    "personalization_matrix", "personalization_matrix_fixed",
    "spmv_float", "spmv_fixed", "spmv_pallas",
    "make_sharded_spmv", "make_sharded_spmv_fixed",
    "partition_edges_by_dst", "sharded_vertex_layout",
]
