"""The paper's truncation quantizer lifted to LM weights/activations/gradients.

Three framework features derive from the paper's reduced-precision insight:

1. ``quantize_weights`` — per-channel symmetric int8 (or Qm.f) weight
   quantization for the serving path (feeds kernels/fixed_matmul).
2. ``truncate_to_grid`` — the exact paper quantizer (floor to 2^-f grid) as a
   reusable activation op.
3. ``ErrorFeedbackQuantizer`` — gradient compression for the data-parallel
   all-reduce: q = trunc(g + residual); residual' = (g + residual) − q.  The
   residual carries the truncation error to the next step, so the compressed
   SGD trajectory stays unbiased in the long run (error-feedback SGD).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.fixed_point import QFormat

Array = jax.Array


def truncate_to_grid(x: Array, frac_bits: int) -> Array:
    """Signed truncation-toward-zero to the 2^-f grid (paper policy, signed ext)."""
    scale = jnp.asarray(float(1 << frac_bits), x.dtype)
    return jnp.trunc(x * scale) / scale


class QuantizedTensor(NamedTuple):
    """Per-channel symmetric quantized tensor: w ≈ q * scale[None, :]."""

    q: Array       # int8 [in, out]
    scale: Array   # f32 [out]


def quantize_weights(w: Array, bits: int = 8) -> QuantizedTensor:
    """Per-output-channel symmetric quantization with truncation rounding."""
    maxq = float(2 ** (bits - 1) - 1)
    absmax = jnp.max(jnp.abs(w), axis=0)
    scale = jnp.where(absmax > 0, absmax / maxq, 1.0).astype(jnp.float32)
    q = jnp.trunc(w / scale[None, :])
    q = jnp.clip(q, -maxq - 1, maxq).astype(jnp.int8)
    return QuantizedTensor(q=q, scale=scale)


def dequantize(qt: QuantizedTensor, dtype=jnp.float32) -> Array:
    return qt.q.astype(dtype) * qt.scale[None, :].astype(dtype)


@dataclasses.dataclass(frozen=True)
class ErrorFeedbackQuantizer:
    """Gradient compressor: truncate to f fractional bits with residual feedback.

    Used inside the DP all-reduce: devices quantize their local gradient shard,
    all-reduce the cheap representation, and keep the truncation error locally
    to add back next step.  With f bits the wire format is (f + int_bits + sign)
    bits vs 32 — e.g. f=12 → ~2.4x collective-bytes reduction (§Perf).
    """

    frac_bits: int = 12

    def init_state(self, grads):
        return jax.tree.map(jnp.zeros_like, grads)

    def compress(self, grads, residuals) -> Tuple:
        def one(g, r):
            corrected = g + r
            q = truncate_to_grid(corrected, self.frac_bits)
            return q, corrected - q

        flat = jax.tree.map(one, grads, residuals)
        q = jax.tree.map(lambda t: t[0], flat,
                         is_leaf=lambda t: isinstance(t, tuple) and len(t) == 2)
        new_res = jax.tree.map(lambda t: t[1], flat,
                               is_leaf=lambda t: isinstance(t, tuple) and len(t) == 2)
        return q, new_res
