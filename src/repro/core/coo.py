"""COO graph container + packetization (paper §3, §4.1).

The paper streams the graph as three equal arrays (x=dst, y=src, val) in packets of
B edges.  On TPU we additionally 2-D block the matrix by (dst_tile, src_tile) so the
Pallas kernel keeps one P_t source slice and one accumulator slice in VMEM — the
URAM analogue (see the kernel mapping table in ``repro.kernels.coo_spmv``).

Padding discipline: sentinel edges have val=0 and x=y=0 inside their block, so they
contribute nothing while keeping every block a whole number of packets.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.core.fixed_point import QFormat


@dataclasses.dataclass
class COOGraph:
    """A directed graph as the transposed transition matrix X = (D^-1 A)^T in COO.

    x[e] = destination row of X (the vertex receiving rank),
    y[e] = source column (the vertex sending rank),
    val[e] = 1/outdeg(y[e]).
    ``dangling`` marks vertices with no outgoing edges.
    """

    num_vertices: int
    x: np.ndarray          # int32 [E]
    y: np.ndarray          # int32 [E]
    val: np.ndarray        # float32 [E]
    dangling: np.ndarray   # bool [V]

    @property
    def num_edges(self) -> int:
        return int(self.x.shape[0])

    @property
    def sparsity(self) -> float:
        v = self.num_vertices
        return self.num_edges / float(v * v)

    # ------------------------------------------------------------------
    @staticmethod
    def from_edges(src: np.ndarray, dst: np.ndarray, num_vertices: int) -> "COOGraph":
        """Build X = (D^-1 A)^T from raw (src → dst) edge list.

        X[dst, src] = 1/outdeg(src): entry (x=dst, y=src).
        """
        src = np.asarray(src, np.int64)
        dst = np.asarray(dst, np.int64)
        if src.shape != dst.shape:
            raise ValueError("src/dst length mismatch")
        outdeg = np.bincount(src, minlength=num_vertices).astype(np.int64)
        dangling = outdeg == 0
        val = (1.0 / outdeg[src]).astype(np.float32)
        # Sort by destination (x), then source — the streaming order the paper uses
        # (their FSM requires x to be monotone within the stream).
        order = np.lexsort((src, dst))
        return COOGraph(
            num_vertices=num_vertices,
            x=dst[order].astype(np.int32),
            y=src[order].astype(np.int32),
            val=val[order],
            dangling=dangling,
        )

    # ------------------------------------------------------------------
    def quantized_val(self, fmt: QFormat) -> np.ndarray:
        """Edge values truncated into the Q format (raw uint32)."""
        return quantize_values(self.val, fmt)

    def pad_to_packets(self, packet: int) -> "COOGraph":
        """Pad the edge stream to a whole number of B-edge packets (val=0 sentinels)."""
        e = self.num_edges
        pe = (e + packet - 1) // packet * packet
        if pe == e:
            return self
        pad = pe - e
        return COOGraph(
            num_vertices=self.num_vertices,
            x=np.concatenate([self.x, np.zeros(pad, np.int32)]),
            y=np.concatenate([self.y, np.zeros(pad, np.int32)]),
            val=np.concatenate([self.val, np.zeros(pad, np.float32)]),
            dangling=self.dangling,
        )


@dataclasses.dataclass
class EdgeMergeInfo:
    """Bookkeeping from ``merge_edge_delta`` for incremental downstream refresh.

    The merged graph is bit-identical to a from-scratch ``from_edges`` build,
    but consumers holding per-edge derived state (quantized raw values, shard
    partitions) should not recompute it wholesale: ``kept_old_idx`` /
    ``new_pos_of_kept`` map surviving edges old→new so untouched derived
    entries are copied, and ``changed_mask`` marks exactly the merged entries
    whose ``val`` differs from the pre-merge arrays (every edge of a touched
    source, which includes every added edge) — only those need requantizing.
    """

    kept_old_idx: np.ndarray      # int64 [n_kept]  surviving old edge ids
    new_pos_of_kept: np.ndarray   # int64 [n_kept]  their slots in the merged arrays
    changed_mask: np.ndarray      # bool  [E_new]   merged entries with a new val
    touched_sources: np.ndarray   # int64           sources whose out-degree changed
    changed_dst: np.ndarray       # int64           dsts owning a changed or removed edge
    new_outdeg: np.ndarray        # int64 [V_new]   post-merge out-degrees
    num_added: int
    num_removed: int


def merge_edge_delta(
    g: COOGraph,
    add_src: np.ndarray,
    add_dst: np.ndarray,
    remove_src: np.ndarray,
    remove_dst: np.ndarray,
    new_num_vertices: Optional[int] = None,
    outdeg: Optional[np.ndarray] = None,
) -> Tuple[COOGraph, EdgeMergeInfo]:
    """Apply an edge delta host-side, renormalizing only touched sources.

    Returns a merged ``COOGraph`` whose arrays are **bit-identical** to
    ``COOGraph.from_edges`` on the post-delta edge list (same (dst, src)
    streaming order, same ``1/outdeg`` float32 values), without resorting the
    whole stream or recomputing untouched values: surviving edges keep their
    position order and their ``val`` bits; only edges whose source gained or
    lost an out-edge are renormalized (``val`` is a pure function of the
    source's out-degree).

    ``remove_*`` must name existing edges; each request removes one instance
    (multi-edges carry multiplicity).  ``new_num_vertices`` may only grow the
    vertex space — new vertices are dangling until the delta wires them.
    ``outdeg`` (int64 [V]) lets a caller that tracks out-degrees skip the
    ``bincount`` over the old stream.
    """
    v_old = g.num_vertices
    v_new = v_old if new_num_vertices is None else int(new_num_vertices)
    if v_new < v_old:
        raise ValueError(
            f"new_num_vertices={v_new} shrinks the graph (|V|={v_old}); "
            f"vertex removal is not supported")
    add_src = np.atleast_1d(np.asarray(add_src, np.int64))
    add_dst = np.atleast_1d(np.asarray(add_dst, np.int64))
    remove_src = np.atleast_1d(np.asarray(remove_src, np.int64))
    remove_dst = np.atleast_1d(np.asarray(remove_dst, np.int64))
    if add_src.shape != add_dst.shape or remove_src.shape != remove_dst.shape:
        raise ValueError("src/dst length mismatch in edge delta")
    for name, arr, bound in (("add", add_src, v_new), ("add", add_dst, v_new),
                             ("remove", remove_src, v_old),
                             ("remove", remove_dst, v_old)):
        if arr.size and (arr.min() < 0 or arr.max() >= bound):
            raise ValueError(f"{name} edge endpoint out of range [0, {bound})")

    if outdeg is None:
        outdeg = np.bincount(g.y, minlength=v_old).astype(np.int64)
    new_outdeg = np.zeros(v_new, np.int64)
    new_outdeg[:v_old] = outdeg
    np.add.at(new_outdeg, add_src, 1)
    np.subtract.at(new_outdeg, remove_src, 1)
    if new_outdeg.min(initial=0) < 0:
        raise ValueError("delta removes more out-edges than some vertex has")

    # ---- removal: locate one stream slot per requested (src, dst) ---------
    # the stream is lexsorted by (dst=x, src=y), so x·M + y is sorted
    M = np.int64(max(v_new, 1))
    keys = g.x.astype(np.int64) * M + g.y.astype(np.int64)
    keep = np.ones(g.num_edges, bool)
    if remove_src.size:
        rem_keys, rem_counts = np.unique(remove_dst * M + remove_src,
                                         return_counts=True)
        lo = np.searchsorted(keys, rem_keys, side="left")
        hi = np.searchsorted(keys, rem_keys, side="right")
        short = rem_counts > (hi - lo)
        if short.any():
            k = rem_keys[short.argmax()]
            raise ValueError(
                f"delta removes edge ({k % M} -> {k // M}) more times than it "
                f"exists in the graph")
        for a, c in zip(lo, rem_counts):
            keep[a:a + c] = False
    kept_old_idx = np.nonzero(keep)[0]
    n_kept = kept_old_idx.shape[0]

    # ---- order-preserving merge of kept stream + sorted additions ---------
    add_order = np.lexsort((add_src, add_dst))
    add_src, add_dst = add_src[add_order], add_dst[add_order]
    add_keys = add_dst * M + add_src
    kept_keys = keys[kept_old_idx]
    # equal keys: kept edges first (ties are identical tuples either way)
    new_pos_of_add = np.searchsorted(kept_keys, add_keys, side="right") \
        + np.arange(add_keys.shape[0], dtype=np.int64)
    new_pos_of_kept = np.arange(n_kept, dtype=np.int64) \
        + np.searchsorted(add_keys, kept_keys, side="left")
    e_new = n_kept + add_keys.shape[0]
    x_new = np.empty(e_new, np.int32)
    y_new = np.empty(e_new, np.int32)
    val_new = np.empty(e_new, np.float32)
    x_new[new_pos_of_kept] = g.x[kept_old_idx]
    y_new[new_pos_of_kept] = g.y[kept_old_idx]
    val_new[new_pos_of_kept] = g.val[kept_old_idx]
    x_new[new_pos_of_add] = add_dst.astype(np.int32)
    y_new[new_pos_of_add] = add_src.astype(np.int32)

    # ---- renormalize touched sources only (val is 1/outdeg of the source) -
    touched = np.unique(np.concatenate([add_src, remove_src]))
    changed = np.isin(y_new, touched) if touched.size else np.zeros(e_new, bool)
    if changed.any():
        # same formula as from_edges: float64 reciprocal, then float32 cast
        val_new[changed] = (1.0 / new_outdeg[y_new[changed]]).astype(np.float32)

    dangling = np.zeros(v_new, bool)
    dangling[:v_old] = g.dangling
    dangling[v_old:] = new_outdeg[v_old:] == 0
    if touched.size:
        dangling[touched] = new_outdeg[touched] == 0

    changed_dst = np.unique(np.concatenate(
        [x_new[changed].astype(np.int64), remove_dst]))
    merged = COOGraph(num_vertices=v_new, x=x_new, y=y_new, val=val_new,
                      dangling=dangling)
    info = EdgeMergeInfo(
        kept_old_idx=kept_old_idx, new_pos_of_kept=new_pos_of_kept,
        changed_mask=changed, touched_sources=touched,
        changed_dst=changed_dst, new_outdeg=new_outdeg,
        num_added=int(add_src.size), num_removed=int(remove_src.size))
    return merged, info


def quantize_values(val: np.ndarray, fmt: QFormat) -> np.ndarray:
    """Truncate edge values into ``fmt`` (raw uint32) — the elementwise body of
    ``COOGraph.quantized_val``, exposed so delta ingestion can requantize only
    the ``changed_mask`` slice instead of the whole stream."""
    raw = np.floor(np.clip(np.asarray(val, np.float64), 0.0, None) * fmt.scale)
    return np.minimum(raw, fmt.max_raw).astype(np.uint32)


@dataclasses.dataclass
class BlockedCOO:
    """2-D (dst_tile × src_tile) blocking of a COOGraph for the Pallas kernel.

    Edges are bucketed by (x // v_tile, y // v_tile); each bucket is padded to a
    whole number of ``packet`` edges.  Buckets are concatenated in dst-major order
    with a CSR-like ``block_starts`` index (in packets).  Inside a bucket indices
    are *local* to the tile, matching the kernel's VMEM addressing.
    """

    num_vertices: int
    v_tile: int
    packet: int
    n_dst: int
    n_src: int
    x_local: np.ndarray       # int32 [Ep]  (padded total edges)
    y_local: np.ndarray       # int32 [Ep]
    val: np.ndarray           # float32 [Ep]
    block_starts: np.ndarray  # int32 [n_dst*n_src + 1] in packets
    num_real_edges: int

    @property
    def num_packets(self) -> int:
        return int(self.block_starts[-1])

    @property
    def pad_overhead(self) -> float:
        tot = self.num_packets * self.packet
        return tot / max(1, self.num_real_edges)

    @property
    def index_dtype(self):
        """Block-local indices fit 16 bits whenever v_tile ≤ 65536 — a
        beyond-paper compression the 2-D blocking enables: the edge stream
        drops from 8 B to 4 B of indices per edge (halving the streaming
        bandwidth term in the roofline note of ``repro.kernels.coo_spmv``)."""
        return np.uint16 if self.v_tile <= (1 << 16) else np.int32

    def packed_indices(self):
        """(x_local, y_local) in the narrowest dtype the tiling allows."""
        dt = self.index_dtype
        return self.x_local.astype(dt), self.y_local.astype(dt)

    def edge_stream_bytes(self, value_bits: int = 32) -> int:
        """HBM bytes of one full pass over the packed edge stream."""
        e = self.num_packets * self.packet
        idx = 2 if self.index_dtype == np.uint16 else 4
        return e * (2 * idx + value_bits // 8)

    @staticmethod
    def build(g: COOGraph, v_tile: int, packet: int) -> "BlockedCOO":
        v = g.num_vertices
        n_dst = (v + v_tile - 1) // v_tile
        n_src = (v + v_tile - 1) // v_tile
        bx = g.x // v_tile
        by = g.y // v_tile
        block_id = bx.astype(np.int64) * n_src + by
        order = np.argsort(block_id, kind="stable")
        xb, yb, vb, bid = g.x[order], g.y[order], g.val[order], block_id[order]
        counts = np.bincount(bid, minlength=n_dst * n_src)
        pad_counts = (counts + packet - 1) // packet * packet
        block_starts = np.zeros(n_dst * n_src + 1, np.int64)
        np.cumsum(pad_counts // packet, out=block_starts[1:])
        total = int(pad_counts.sum())
        x_local = np.zeros(total, np.int32)
        y_local = np.zeros(total, np.int32)
        val = np.zeros(total, np.float32)
        # scatter each block's edges into its padded slot
        src_off = np.zeros(n_dst * n_src + 1, np.int64)
        np.cumsum(counts, out=src_off[1:])
        dst_off = block_starts * packet
        for b in np.nonzero(counts)[0]:
            s0, s1 = src_off[b], src_off[b + 1]
            d0 = dst_off[b]
            n = s1 - s0
            x_local[d0:d0 + n] = xb[s0:s1] % v_tile
            y_local[d0:d0 + n] = yb[s0:s1] % v_tile
            val[d0:d0 + n] = vb[s0:s1]
        return BlockedCOO(
            num_vertices=v, v_tile=v_tile, packet=packet,
            n_dst=n_dst, n_src=n_src,
            x_local=x_local, y_local=y_local, val=val,
            block_starts=block_starts.astype(np.int32),
            num_real_edges=g.num_edges,
        )
