"""COO graph container + packetization (paper §3, §4.1).

The paper streams the graph as three equal arrays (x=dst, y=src, val) in packets of
B edges.  On TPU we additionally 2-D block the matrix by (dst_tile, src_tile) so the
Pallas kernel keeps one P_t source slice and one accumulator slice in VMEM — the
URAM analogue (DESIGN.md §2).

Padding discipline: sentinel edges have val=0 and x=y=0 inside their block, so they
contribute nothing while keeping every block a whole number of packets.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.core.fixed_point import QFormat


@dataclasses.dataclass
class COOGraph:
    """A directed graph as the transposed transition matrix X = (D^-1 A)^T in COO.

    x[e] = destination row of X (the vertex receiving rank),
    y[e] = source column (the vertex sending rank),
    val[e] = 1/outdeg(y[e]).
    ``dangling`` marks vertices with no outgoing edges.
    """

    num_vertices: int
    x: np.ndarray          # int32 [E]
    y: np.ndarray          # int32 [E]
    val: np.ndarray        # float32 [E]
    dangling: np.ndarray   # bool [V]

    @property
    def num_edges(self) -> int:
        return int(self.x.shape[0])

    @property
    def sparsity(self) -> float:
        v = self.num_vertices
        return self.num_edges / float(v * v)

    # ------------------------------------------------------------------
    @staticmethod
    def from_edges(src: np.ndarray, dst: np.ndarray, num_vertices: int) -> "COOGraph":
        """Build X = (D^-1 A)^T from raw (src → dst) edge list.

        X[dst, src] = 1/outdeg(src): entry (x=dst, y=src).
        """
        src = np.asarray(src, np.int64)
        dst = np.asarray(dst, np.int64)
        if src.shape != dst.shape:
            raise ValueError("src/dst length mismatch")
        outdeg = np.bincount(src, minlength=num_vertices).astype(np.int64)
        dangling = outdeg == 0
        val = (1.0 / outdeg[src]).astype(np.float32)
        # Sort by destination (x), then source — the streaming order the paper uses
        # (their FSM requires x to be monotone within the stream).
        order = np.lexsort((src, dst))
        return COOGraph(
            num_vertices=num_vertices,
            x=dst[order].astype(np.int32),
            y=src[order].astype(np.int32),
            val=val[order],
            dangling=dangling,
        )

    # ------------------------------------------------------------------
    def quantized_val(self, fmt: QFormat) -> np.ndarray:
        """Edge values truncated into the Q format (raw uint32)."""
        raw = np.floor(np.clip(self.val.astype(np.float64), 0.0, None) * fmt.scale)
        return np.minimum(raw, fmt.max_raw).astype(np.uint32)

    def pad_to_packets(self, packet: int) -> "COOGraph":
        """Pad the edge stream to a whole number of B-edge packets (val=0 sentinels)."""
        e = self.num_edges
        pe = (e + packet - 1) // packet * packet
        if pe == e:
            return self
        pad = pe - e
        return COOGraph(
            num_vertices=self.num_vertices,
            x=np.concatenate([self.x, np.zeros(pad, np.int32)]),
            y=np.concatenate([self.y, np.zeros(pad, np.int32)]),
            val=np.concatenate([self.val, np.zeros(pad, np.float32)]),
            dangling=self.dangling,
        )


@dataclasses.dataclass
class BlockedCOO:
    """2-D (dst_tile × src_tile) blocking of a COOGraph for the Pallas kernel.

    Edges are bucketed by (x // v_tile, y // v_tile); each bucket is padded to a
    whole number of ``packet`` edges.  Buckets are concatenated in dst-major order
    with a CSR-like ``block_starts`` index (in packets).  Inside a bucket indices
    are *local* to the tile, matching the kernel's VMEM addressing.
    """

    num_vertices: int
    v_tile: int
    packet: int
    n_dst: int
    n_src: int
    x_local: np.ndarray       # int32 [Ep]  (padded total edges)
    y_local: np.ndarray       # int32 [Ep]
    val: np.ndarray           # float32 [Ep]
    block_starts: np.ndarray  # int32 [n_dst*n_src + 1] in packets
    num_real_edges: int

    @property
    def num_packets(self) -> int:
        return int(self.block_starts[-1])

    @property
    def pad_overhead(self) -> float:
        tot = self.num_packets * self.packet
        return tot / max(1, self.num_real_edges)

    @property
    def index_dtype(self):
        """Block-local indices fit 16 bits whenever v_tile ≤ 65536 — a
        beyond-paper compression the 2-D blocking enables: the edge stream
        drops from 8 B to 4 B of indices per edge (EXPERIMENTS.md §Perf)."""
        return np.uint16 if self.v_tile <= (1 << 16) else np.int32

    def packed_indices(self):
        """(x_local, y_local) in the narrowest dtype the tiling allows."""
        dt = self.index_dtype
        return self.x_local.astype(dt), self.y_local.astype(dt)

    def edge_stream_bytes(self, value_bits: int = 32) -> int:
        """HBM bytes of one full pass over the packed edge stream."""
        e = self.num_packets * self.packet
        idx = 2 if self.index_dtype == np.uint16 else 4
        return e * (2 * idx + value_bits // 8)

    @staticmethod
    def build(g: COOGraph, v_tile: int, packet: int) -> "BlockedCOO":
        v = g.num_vertices
        n_dst = (v + v_tile - 1) // v_tile
        n_src = (v + v_tile - 1) // v_tile
        bx = g.x // v_tile
        by = g.y // v_tile
        block_id = bx.astype(np.int64) * n_src + by
        order = np.argsort(block_id, kind="stable")
        xb, yb, vb, bid = g.x[order], g.y[order], g.val[order], block_id[order]
        counts = np.bincount(bid, minlength=n_dst * n_src)
        pad_counts = (counts + packet - 1) // packet * packet
        block_starts = np.zeros(n_dst * n_src + 1, np.int64)
        np.cumsum(pad_counts // packet, out=block_starts[1:])
        total = int(pad_counts.sum())
        x_local = np.zeros(total, np.int32)
        y_local = np.zeros(total, np.int32)
        val = np.zeros(total, np.float32)
        # scatter each block's edges into its padded slot
        src_off = np.zeros(n_dst * n_src + 1, np.int64)
        np.cumsum(counts, out=src_off[1:])
        dst_off = block_starts * packet
        for b in np.nonzero(counts)[0]:
            s0, s1 = src_off[b], src_off[b + 1]
            d0 = dst_off[b]
            n = s1 - s0
            x_local[d0:d0 + n] = xb[s0:s1] % v_tile
            y_local[d0:d0 + n] = yb[s0:s1] % v_tile
            val[d0:d0 + n] = vb[s0:s1]
        return BlockedCOO(
            num_vertices=v, v_tile=v_tile, packet=packet,
            n_dst=n_dst, n_src=n_src,
            x_local=x_local, y_local=y_local, val=val,
            block_starts=block_starts.astype(np.int32),
            num_real_edges=g.num_edges,
        )
