"""Quantifying the paper's §3 format argument: COO streams at full utilization
on power-law graphs; row-oriented CSR/CSC lane-gangs stall on degree skew.

The paper: "CSC-based designs often fail to handle graphs with exponential
distribution, especially if stream-like processing is demanded... COO
simplifies array partitioning, enables burst reads... as entries are
independent and the architecture is not bound to knowing the degree of each
vertex."

Model (matches both an FPGA lane-gang and a TPU vectorized-rows design):
a row-oriented engine processes G rows per wave across lanes; each wave costs
max(deg) cycles among its rows while lanes with shorter rows idle.  A COO
engine costs ceil(E/packet) waves at full width regardless of degrees.

  csr_utilization  = Σ deg / (Σ_waves G · max_deg_in_wave)
  coo_utilization  = E / (packets · packet_size)   (= 1/pad_overhead)
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core.coo import BlockedCOO, COOGraph


def csr_gang_utilization(g: COOGraph, gang: int = 8) -> float:
    """Lane utilization of a row-gang engine (rows sorted by id, G per wave)."""
    deg = np.bincount(g.x, minlength=g.num_vertices).astype(np.int64)
    pad = (-len(deg)) % gang
    if pad:
        deg = np.concatenate([deg, np.zeros(pad, np.int64)])
    waves = deg.reshape(-1, gang)
    cost = waves.max(axis=1).sum() * gang
    return float(deg.sum()) / max(1.0, float(cost))


def csr_gang_utilization_sorted(g: COOGraph, gang: int = 8) -> float:
    """Same engine with degree-sorted rows (the best case for CSR gangs —
    requires a full-graph sort + permutation, which breaks streaming)."""
    deg = np.sort(np.bincount(g.x, minlength=g.num_vertices).astype(np.int64))
    pad = (-len(deg)) % gang
    if pad:
        deg = np.concatenate([np.zeros(pad, np.int64), deg])
    waves = deg.reshape(-1, gang)
    cost = waves.max(axis=1).sum() * gang
    return float(deg.sum()) / max(1.0, float(cost))


def coo_utilization(g: COOGraph, v_tile: int = 4096, packet: int = 256) -> float:
    b = BlockedCOO.build(g, v_tile=v_tile, packet=packet)
    return 1.0 / b.pad_overhead


def format_comparison(g: COOGraph, gang: int = 8) -> Dict[str, float]:
    return {
        "coo_utilization": coo_utilization(g),
        "csr_gang_utilization": csr_gang_utilization(g, gang),
        "csr_sorted_utilization": csr_gang_utilization_sorted(g, gang),
    }
