"""Reduced-precision unsigned fixed-point (Qm.f) arithmetic — the paper's §4.1 datapath.

The paper stores PPR values as unsigned fixed-point Q1.25 / Q1.23 / Q1.21 / Q1.19
(1 integer bit, f fractional bits) and *truncates* towards zero on quantization
("Other policies (e.g. rounding to the closest representable value) resulted in
numerical instability").

Two computation paths, bit-identical by construction (tested in
tests/test_fixed_point.py):

1. **Exact integer path** (`FixedMul` via 16-bit limbs).  TPU VPUs have no 64-bit
   multiplier, so a Q1.f × Q1.f product (needs 2(1+f) ≤ 52 bits) is decomposed into
   16×16→32-bit limb products in uint32 — every intermediate fits.  This is the
   bit-exact oracle and also what the Pallas kernel executes.

2. **Float-grid fast path** (`quantize_f32`).  f32 compute followed by truncation to
   the 2^-f grid.  Exactly equal to (1) while products stay inside the 24-bit f32
   mantissa; used for wide-κ batched SpMM where the MXU (which is f32/bf16-only)
   does the aggregation.  For f > 23 the integer path is authoritative.

All ops are jittable and shape-polymorphic.
"""
from __future__ import annotations

import dataclasses
import operator
from typing import Union

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

_U32 = jnp.uint32
_MASK16 = np.uint32(0xFFFF)


@dataclasses.dataclass(frozen=True)
class QFormat:
    """Unsigned Qm.f fixed point: ``int_bits`` integer bits, ``frac_bits`` fractional."""

    int_bits: int
    frac_bits: int

    def __post_init__(self):
        if self.int_bits < 1 or self.frac_bits < 0:
            raise ValueError(f"bad QFormat({self.int_bits},{self.frac_bits})")
        if self.total_bits > 32:
            raise ValueError("QFormat wider than 32 bits is not supported")

    @property
    def total_bits(self) -> int:
        return self.int_bits + self.frac_bits

    @property
    def scale(self) -> int:
        return 1 << self.frac_bits

    @property
    def max_raw(self) -> int:
        return (1 << self.total_bits) - 1

    @property
    def resolution(self) -> float:
        return 1.0 / self.scale

    @property
    def name(self) -> str:
        return f"Q{self.int_bits}.{self.frac_bits}"

    # ---- conversions -------------------------------------------------------
    def from_float(self, x: Union[Array, np.ndarray, float]) -> Array:
        """Encode float → raw uint32, truncating towards zero (paper's policy)."""
        x = jnp.asarray(x, jnp.float64 if jax.config.jax_enable_x64 else jnp.float32)
        raw = jnp.floor(jnp.clip(x, 0.0, None) * self.scale)
        raw = jnp.minimum(raw, float(self.max_raw))
        return raw.astype(_U32)

    def to_float(self, raw: Array, dtype=jnp.float32) -> Array:
        return raw.astype(dtype) / jnp.asarray(self.scale, dtype)

    # ---- arithmetic on raw uint32 ------------------------------------------
    def mul(self, a: Array, b: Array) -> Array:
        """Bit-exact (a*b) >> f using 16-bit limb decomposition in uint32.

        a,b < 2^total_bits with total_bits ≤ 32.  Write a = a1·2^16 + a0:
          a·b = a1b1·2^32 + (a1b0 + a0b1)·2^16 + a0b0
        Each limb product is a 16×16→32 multiply (fits uint32); the f-bit right
        shift is applied per partial product with the cross-term carries folded
        in explicitly.  Matches Python's ``(a*b) >> f`` for all inputs (hypothesis
        tested) as long as the true product fits in 64 bits — always true here.
        """
        a = a.astype(_U32)
        b = b.astype(_U32)
        f = self.frac_bits
        a0 = a & _MASK16
        a1 = a >> 16
        b0 = b & _MASK16
        b1 = b >> 16
        ll = a0 * b0                      # bits [0, 32)
        lh = a0 * b1                      # bits [16, 48)
        hl = a1 * b0                      # bits [16, 48)
        hh = a1 * b1                      # bits [32, 64)
        # mid = lh + hl may carry into bit 33: track the carry explicitly.
        mid = lh + hl
        mid_carry = (mid < lh).astype(_U32)         # 1 iff wrapped
        # Accumulate low 64 bits as (hi, lo) pair of uint32.
        # repro: allow[FXP002] carry-tracked — bits >=32 of mid<<16 re-enter via mid>>16 (+ mid_carry) in hi
        lo = ll + (mid << 16)
        carry_lo = (lo < ll).astype(_U32)
        hi = hh + (mid >> 16) + (mid_carry << 16) + carry_lo
        # result = (hi·2^32 + lo) >> f ; result must fit 32 bits (guaranteed when
        # inputs are in-format: product < 2^(2·total) and 2·total − f ≤ 32+int_bits).
        if f == 0:
            return lo
        if f < 32:
            return (lo >> f) | (hi << (32 - f))
        if f == 32:  # pragma: no cover - unreachable for ≤32-bit formats
            return hi
        return hi >> (f - 32)

    def add(self, a: Array, b: Array) -> Array:
        """Saturating add on raw values."""
        s = a.astype(_U32) + b.astype(_U32)
        wrapped = s < a.astype(_U32)
        over = wrapped | (s > np.uint32(self.max_raw))
        return jnp.where(over, np.uint32(self.max_raw), s)

    def quantize_raw(self, raw_wide_float: Array) -> Array:
        """Clamp an f32/f64 'raw-units' value into the format (truncate)."""
        r = jnp.floor(jnp.clip(raw_wide_float, 0.0, float(self.max_raw)))
        return r.astype(_U32)

    # ---- float-grid fast path ------------------------------------------------
    def quantize_f32(self, x: Array) -> Array:
        """Truncate an f32 value to the Qm.f grid (the paper's quantizer).

        quantize(x) = floor(x · 2^f) / 2^f, clipped into [0, max].  Matches the
        integer path bit-for-bit while values are exactly representable in f32.
        """
        scale = jnp.asarray(self.scale, x.dtype)
        q = jnp.floor(jnp.clip(x, 0.0, None) * scale)
        q = jnp.minimum(q, jnp.asarray(float(self.max_raw), x.dtype))
        return q / scale


# The paper's four evaluated formats plus the f32 reference label.
Q1_25 = QFormat(1, 25)
Q1_23 = QFormat(1, 23)
Q1_21 = QFormat(1, 21)
Q1_19 = QFormat(1, 19)

PAPER_FORMATS = {
    "Q1.25": Q1_25,  # "26 bits"
    "Q1.23": Q1_23,  # "24 bits"
    "Q1.21": Q1_21,  # "22 bits"
    "Q1.19": Q1_19,  # "20 bits"
}

BITWIDTH_TO_FORMAT = {26: Q1_25, 24: Q1_23, 22: Q1_21, 20: Q1_19}


def format_for_bits(bits: int) -> QFormat:
    """Paper convention: 'b bits' = Q1.(b-1) unsigned.

    ``bits`` must leave at least the 1 integer bit and 1 fractional bit —
    anything narrower cannot represent the paper's [0, 1] rank values.
    """
    if isinstance(bits, bool):
        raise ValueError(f"bit-width must be an int, got {bits!r}")
    try:
        bits = int(operator.index(bits))   # accept numpy ints, reject floats
    except TypeError:
        raise ValueError(f"bit-width must be an int, got {bits!r}") from None
    if bits < 2:
        raise ValueError(
            f"bit-width must be >= 2 (1 integer + >=1 fractional bit), got {bits}")
    return BITWIDTH_TO_FORMAT.get(bits, QFormat(1, bits - 1))
