"""Batched Personalized PageRank (paper Alg. 1 / eq. 1) in float and fixed point.

P_{t+1} = α·X·P_t + α/|V|·(d̄ᵀP_t)·1 + (1−α)·V̄       (eq. 1)

κ personalization vertices are batched as columns of P (the paper's key
throughput optimization: every edge read is amortized over κ problems).
The fixed-point variant reproduces the FPGA datapath bit-for-bit:
truncating multiplies, raw-domain accumulation, truncating scale-by-α.

The single-iteration bodies are exposed as ``ppr_step_float`` and
``make_ppr_fixed_step`` so external drivers (repro.ppr_serving's wave
scheduler) can advance one eq. (1) iteration at a time — e.g. to abort on a
deadline or interleave waves — while the ``lax.scan`` drivers below stay the
fast path for fixed iteration counts.  Both drivers share the same body
functions, so step-driven and scanned results are bit-identical.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.coo import COOGraph
from repro.core.fixed_point import QFormat
from repro.core.spmv import (
    make_sharded_spmv,
    make_sharded_spmv_fixed,
    spmv_fixed,
    spmv_float,
)

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class PPRConfig:
    alpha: float = 0.85
    iterations: int = 10          # paper: 10 iterations suffice (§5.1)
    kappa: int = 8                # personalization vertices per pass (paper: 8–16)
    track_convergence: bool = True


def personalization_matrix(num_vertices: int, pers: Array, dtype=jnp.float32) -> Array:
    """V̄ of eq. (1): one-hot column per personalization vertex, [V, κ]."""
    k = pers.shape[0]
    V = jnp.zeros((num_vertices, k), dtype)
    return V.at[pers, jnp.arange(k)].set(jnp.ones((k,), dtype))


def personalization_matrix_fixed(num_vertices: int, pers: Array, fmt: QFormat) -> Array:
    """V̄ in the raw uint32 domain (1.0 is exactly representable in Q1.f)."""
    one_raw = np.uint32(fmt.scale)
    V = jnp.zeros((num_vertices, pers.shape[0]), jnp.uint32)
    return V.at[pers, jnp.arange(pers.shape[0])].set(one_raw)


_personalization_matrix = personalization_matrix  # backwards-compat alias


# ----------------------------------------------------------------------------
# single-iteration bodies (shared by the scan drivers and the step API)
# ----------------------------------------------------------------------------
def _float_combine(xp, dangling_mass, Vmat, *, num_vertices: int, alpha: float):
    """eq. (1) elementwise combine — shared by the single-device and sharded
    steps so both apply bit-identical float ops after the SpMV."""
    return alpha * xp + (alpha / num_vertices) * dangling_mass[None, :] \
        + (1.0 - alpha) * Vmat


def _float_iteration(x, y, val, d, Vmat, P, *, num_vertices: int, alpha: float):
    dangling_mass = d @ P                                        # [K]
    xp = spmv_float(x, y, val, P, num_vertices)
    return _float_combine(xp, dangling_mass, Vmat,
                          num_vertices=num_vertices, alpha=alpha)


def _fixed_consts(fmt: QFormat, num_vertices: int, alpha: float):
    """Datapath scalars encoded in the format, so every multiply truncates
    exactly like the FPGA DSP chain.  α/|V| underflows to 0 when 1/|V| < 2^-f —
    exactly the behaviour the real datapath would exhibit (dangling mass
    vanishes for big V)."""
    return (np.uint32(int(alpha * fmt.scale)),
            np.uint32(int((1.0 - alpha) * fmt.scale)),
            np.uint32(int(alpha / num_vertices * fmt.scale)))


def _fixed_dangling_mass(d_raw, P):
    """Σ_{i dangling} P[i,k] — raw-domain exact sum, [K]."""
    return (d_raw[:, None] * P).astype(jnp.int32).sum(0).astype(jnp.uint32)


def _fixed_combine(xp, dangling_mass, Vmat, *, fmt: QFormat, alpha_raw,
                   one_minus_alpha_raw, alpha_over_v_raw):
    """eq. (1) combine in the raw domain — truncating multiplies, saturating
    adds; shared by the single-device and sharded steps (bit-identical)."""
    return fmt.add(
        fmt.add(fmt.mul(jnp.asarray(alpha_raw), xp),
                fmt.mul(jnp.asarray(alpha_over_v_raw), dangling_mass)[None, :]),
        fmt.mul(jnp.asarray(one_minus_alpha_raw), Vmat),
    )


def _fixed_iteration(x, y, val_raw, d_raw, Vmat, P, *, fmt: QFormat,
                     num_vertices: int, alpha_raw, one_minus_alpha_raw,
                     alpha_over_v_raw):
    dangling_mass = _fixed_dangling_mass(d_raw, P)
    xp = spmv_fixed(x, y, val_raw, P, num_vertices, fmt)
    return _fixed_combine(xp, dangling_mass, Vmat, fmt=fmt, alpha_raw=alpha_raw,
                          one_minus_alpha_raw=one_minus_alpha_raw,
                          alpha_over_v_raw=alpha_over_v_raw)


# ----------------------------------------------------------------------------
# step API — one eq. (1) iteration per call, for external drivers
# ----------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("num_vertices", "alpha"))
def ppr_step_float(
    x: Array, y: Array, val: Array, dangling: Array, Vmat: Array, P: Array,
    *, num_vertices: int, alpha: float,
) -> Array:
    """P_{t+1} from P_t, float32.  ``Vmat`` is the one-hot personalization matrix."""
    return _float_iteration(x, y, val, dangling.astype(jnp.float32), Vmat, P,
                            num_vertices=num_vertices, alpha=alpha)


@functools.lru_cache(maxsize=64)
def make_ppr_fixed_step(fmt: QFormat, num_vertices: int, alpha: float):
    """Jitted bit-exact single iteration in the raw uint32 domain of ``fmt``."""
    a_raw, oma_raw, aov_raw = _fixed_consts(fmt, num_vertices, alpha)

    @jax.jit
    def step(x: Array, y: Array, val_raw: Array, dangling: Array,
             Vmat: Array, P: Array) -> Array:
        return _fixed_iteration(
            x, y, val_raw, dangling.astype(jnp.uint32), Vmat, P,
            fmt=fmt, num_vertices=num_vertices, alpha_raw=a_raw,
            one_minus_alpha_raw=oma_raw, alpha_over_v_raw=aov_raw)

    return step


# ----------------------------------------------------------------------------
# sharded step API — one eq. (1) iteration over a mesh-partitioned edge stream
# ----------------------------------------------------------------------------
@functools.lru_cache(maxsize=32)
def make_ppr_sharded_float_step(mesh, axis: str, num_vertices: int, alpha: float):
    """Jitted float32 single iteration whose SpMV runs over a ``jax.sharding``
    mesh (edges pre-partitioned by dst range — ``partition_edges_by_dst``).

    Dangling mass and the eq. (1) combine are computed on the replicated [V, K]
    state with the exact same ops as ``ppr_step_float`` (``_float_combine``), so
    any numeric divergence from the single-device step can only come from the
    per-shard SpMV accumulation order.
    """
    spmv = make_sharded_spmv(mesh, axis, num_vertices)

    @jax.jit
    def step(x: Array, y: Array, val: Array, dangling: Array,
             Vmat: Array, P: Array) -> Array:
        d = dangling.astype(jnp.float32)
        dangling_mass = d @ P
        xp = spmv(x, y, val, P)
        return _float_combine(xp, dangling_mass, Vmat,
                              num_vertices=num_vertices, alpha=alpha)

    return step


@functools.lru_cache(maxsize=32)
def make_ppr_sharded_fixed_step(fmt: QFormat, mesh, axis: str,
                                num_vertices: int, alpha: float):
    """Jitted bit-exact fixed-point single iteration over a mesh.

    Per-shard raw accumulation is exact and each dst row lives on exactly one
    shard, so the result is *bit-identical* to ``make_ppr_fixed_step`` — the
    sharded fixed path inherits the single-device path's determinism.
    """
    a_raw, oma_raw, aov_raw = _fixed_consts(fmt, num_vertices, alpha)
    spmv = make_sharded_spmv_fixed(mesh, axis, num_vertices, fmt)

    @jax.jit
    def step(x: Array, y: Array, val_raw: Array, dangling: Array,
             Vmat: Array, P: Array) -> Array:
        dangling_mass = _fixed_dangling_mass(dangling.astype(jnp.uint32), P)
        xp = spmv(x, y, val_raw, P)
        return _fixed_combine(xp, dangling_mass, Vmat, fmt=fmt, alpha_raw=a_raw,
                              one_minus_alpha_raw=oma_raw, alpha_over_v_raw=aov_raw)

    return step


# ----------------------------------------------------------------------------
# float32 path (the paper's F32 reference architecture)
# ----------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("num_vertices", "iterations", "alpha"))
def ppr_float(
    x: Array, y: Array, val: Array, dangling: Array, pers: Array,
    *, num_vertices: int, iterations: int, alpha: float,
) -> Tuple[Array, Array]:
    """Returns (P [V,K] float32, deltas [iterations] convergence trace)."""
    V = personalization_matrix(num_vertices, pers)
    d = dangling.astype(jnp.float32)

    def body(P, _):
        Pn = _float_iteration(x, y, val, d, V, P,
                              num_vertices=num_vertices, alpha=alpha)
        delta = jnp.linalg.norm(Pn - P, axis=0).max()
        return Pn, delta

    P, deltas = jax.lax.scan(body, V, None, length=iterations)
    return P, deltas


# ----------------------------------------------------------------------------
# fixed-point path (the paper's contribution)
# ----------------------------------------------------------------------------
@functools.lru_cache(maxsize=64)
def make_ppr_fixed(fmt: QFormat, num_vertices: int, iterations: int, alpha: float):
    """Build a jitted bit-exact fixed-point PPR for one Q format."""
    a_raw, oma_raw, aov_raw = _fixed_consts(fmt, num_vertices, alpha)

    @jax.jit
    def run(x: Array, y: Array, val_raw: Array, dangling: Array, pers: Array):
        Vmat = personalization_matrix_fixed(num_vertices, pers, fmt)
        d_raw = dangling.astype(jnp.uint32)

        def body(P, _):
            Pn = _fixed_iteration(
                x, y, val_raw, d_raw, Vmat, P,
                fmt=fmt, num_vertices=num_vertices, alpha_raw=a_raw,
                one_minus_alpha_raw=oma_raw, alpha_over_v_raw=aov_raw)
            delta = jnp.abs(Pn.astype(jnp.float32) - P.astype(jnp.float32))
            return Pn, jnp.sqrt((delta * delta).sum(0)).max() / fmt.scale

        P, deltas = jax.lax.scan(body, Vmat, None, length=iterations)
        return P, deltas

    return run


# ----------------------------------------------------------------------------
# convenience drivers
# ----------------------------------------------------------------------------
def run_ppr(
    g: COOGraph,
    personalization: np.ndarray,
    cfg: PPRConfig = PPRConfig(),
    fmt: Optional[QFormat] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Run PPR on a host graph.  fmt=None → float32; else bit-exact Qm.f.

    Returns (scores [V,K] float64-ish numpy, convergence deltas [iters]).
    """
    pers = jnp.asarray(np.atleast_1d(personalization), jnp.int32)
    x = jnp.asarray(g.x)
    y = jnp.asarray(g.y)
    dang = jnp.asarray(g.dangling)
    if fmt is None:
        P, deltas = ppr_float(
            x, y, jnp.asarray(g.val), dang, pers,
            num_vertices=g.num_vertices, iterations=cfg.iterations, alpha=cfg.alpha,
        )
        return np.asarray(P), np.asarray(deltas)
    run = make_ppr_fixed(fmt, g.num_vertices, cfg.iterations, cfg.alpha)
    P_raw, deltas = run(x, y, jnp.asarray(g.quantized_val(fmt)), dang, pers)
    return np.asarray(P_raw).astype(np.float64) / fmt.scale, np.asarray(deltas)


def batched_ppr(
    g: COOGraph,
    all_vertices: np.ndarray,
    cfg: PPRConfig = PPRConfig(),
    fmt: Optional[QFormat] = None,
) -> np.ndarray:
    """Process many personalization requests in κ-sized batches (paper §5.1:
    '100 random personalization vertices' per measurement)."""
    out = np.zeros((g.num_vertices, len(all_vertices)))
    for i in range(0, len(all_vertices), cfg.kappa):
        batch = np.asarray(all_vertices[i: i + cfg.kappa])
        pad = cfg.kappa - batch.shape[0]
        padded = np.concatenate([batch, np.zeros(pad, np.int64)]) if pad else batch
        scores, _ = run_ppr(g, padded, cfg, fmt)
        out[:, i: i + batch.shape[0]] = scores[:, : batch.shape[0]]
    return out
