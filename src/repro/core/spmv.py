"""Streaming COO SpMV/SpMM — the paper's §4.1.1, in three implementations.

All compute X @ P for X in COO (x=dst rows, y=src cols, val) and dense P [V, K]
(K = κ batched personalization vectors; K=1 recovers plain SpMV).

Paths
-----
1. ``spmv_float``      pure-jnp float32: gather → multiply → segment-sum.  The XLA
                       production path (scatter-add lowers natively); also the
                       oracle shape for the Pallas kernel.
2. ``spmv_fixed``      bit-exact unsigned Qm.f: per-edge truncating multiply
                       (uint32 limb decomposition) then exact raw-domain
                       accumulation — faithful to the FPGA datapath where the
                       dp_buffer multiply truncates and the aggregator adds raw.
3. ``spmv_pallas``     the Pallas TPU kernel (repro.kernels.coo_spmv) over the
                       2-D BlockedCOO layout.
4. sharded             shard_map multi-device (``make_sharded_spmv`` float /
                       ``make_sharded_spmv_fixed`` bit-exact raw uint32): edges
                       partitioned by dst range on the ceil-division padded
                       layout of ``sharded_vertex_layout``, P_t all-gathered
                       over the mesh axis, each device produces its dst slice —
                       the paper's "partitioning techniques [18, 20]" integrated
                       as a first-class feature.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core.fixed_point import QFormat

Array = jax.Array


# ----------------------------------------------------------------------------
# 1. float path
# ----------------------------------------------------------------------------
def spmv_float(x: Array, y: Array, val: Array, p: Array, num_vertices: int) -> Array:
    """out[i, k] = Σ_{e: x[e]=i} val[e] · p[y[e], k]   (float32).

    Padding edges (val=0) contribute nothing regardless of their x/y.
    """
    contrib = val[:, None] * p[y]                     # [E, K] gather + multiply
    return jax.ops.segment_sum(contrib, x, num_segments=num_vertices)


# ----------------------------------------------------------------------------
# 2. bit-exact fixed-point path
# ----------------------------------------------------------------------------
def spmv_fixed(
    x: Array, y: Array, val_raw: Array, p_raw: Array, num_vertices: int, fmt: QFormat
) -> Array:
    """Fixed-point SpMM on raw uint32 values.

    Each edge product truncates to the format (the FPGA DSP behaviour); the
    aggregation is exact in the raw domain (sums stay < 2^total_bits because X@p
    entries are ≤ 1 for a stochastic X and probability p — DESIGN.md §2).
    """
    prod = fmt.mul(val_raw[:, None], p_raw[y])        # [E, K] uint32
    # segment_sum on uint32: cast to int32 view is unsafe near 2^31; raw values
    # stay < 2^27 for ≤26-bit formats so int32 accumulation is exact.
    acc = jax.ops.segment_sum(prod.astype(jnp.int32), x, num_segments=num_vertices)
    return acc.astype(jnp.uint32)


# ----------------------------------------------------------------------------
# 3. Pallas kernel path (imported lazily to keep core importable sans kernels)
# ----------------------------------------------------------------------------
def spmv_pallas(blocked, p: Array, *, interpret: bool = True) -> Array:
    from repro.kernels import ops as kops

    return kops.coo_spmv(blocked, p, interpret=interpret)


# ----------------------------------------------------------------------------
# 4. sharded path (graph partitioned by destination range)
# ----------------------------------------------------------------------------
def sharded_vertex_layout(num_vertices: int, n_shards: int) -> tuple:
    """(v_local, v_padded) of the ceil-division dst layout shared by the
    partitioner and every sharded kernel: each shard owns ``v_local =
    ceil(V / n_shards)`` destination rows, the concatenated output covers
    ``v_padded = n_shards · v_local ≥ V`` rows, and the ``v_padded − V``
    phantom rows of the last shard receive no edges (they are sliced away
    before anything downstream sees them)."""
    v_local = -(-num_vertices // n_shards)
    return v_local, n_shards * v_local


def make_sharded_spmv(mesh, axis: str, num_vertices: int):
    """Build a shard_map SpMV: edges pre-partitioned by dst into len(axis) shards.

    Each device holds an equal-size (padded) edge shard whose x all fall in its
    dst range, plus the full P (replicated via all-gather by the in_spec).  Output
    is the device's dst slice — concatenated by the out_spec and sliced back to
    ``num_vertices`` rows (the ceil-division layout of ``sharded_vertex_layout``
    pads the vertex space, so any V works on any shard count).  Collective cost:
    one all-gather of P per iteration = V·K·4 bytes — matches the paper's note
    that partitioned designs trade bandwidth for capacity.
    """
    n_shards = mesh.shape[axis]
    v_local, _ = sharded_vertex_layout(num_vertices, n_shards)

    def local_spmv(x_loc, y, val, p):
        # x_loc already local to the shard's dst range; p is full (replicated).
        contrib = val[:, None] * p[y]
        return jax.ops.segment_sum(contrib, x_loc, num_segments=v_local)

    sharded = shard_map(
        local_spmv,
        mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P()),
        out_specs=P(axis),
    )

    def spmv(x, y, val, p):
        return sharded(x, y, val, p)[:num_vertices]

    return spmv


def make_sharded_spmv_fixed(mesh, axis: str, num_vertices: int, fmt: QFormat):
    """Sharded counterpart of ``spmv_fixed``: raw uint32 domain, truncating
    ``fmt.mul`` per edge, exact raw-domain accumulation per shard.

    Integer accumulation is exact and order-independent, so the concatenated
    result is *bit-identical* to single-device ``spmv_fixed`` — partitioning
    only splits each destination row's sum into per-shard partial sums that
    never mix (each dst row lives on exactly one shard).
    """
    n_shards = mesh.shape[axis]
    v_local, _ = sharded_vertex_layout(num_vertices, n_shards)

    def local_spmv(x_loc, y, val_raw, p_raw):
        prod = fmt.mul(val_raw[:, None], p_raw[y])
        acc = jax.ops.segment_sum(prod.astype(jnp.int32), x_loc,
                                  num_segments=v_local)
        return acc.astype(jnp.uint32)

    sharded = shard_map(
        local_spmv,
        mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P()),
        out_specs=P(axis),
    )

    def spmv(x, y, val_raw, p_raw):
        return sharded(x, y, val_raw, p_raw)[:num_vertices]

    return spmv


def partition_edges_by_dst(x, y, val, num_vertices: int, n_shards: int, packet: int = 256):
    """Host-side: bucket edges by dst range and pad each shard to equal length.

    Ranges are ceil(num_vertices / n_shards) wide — ``sharded_vertex_layout``,
    the same layout the sharded kernels consume — so when num_vertices does not
    divide evenly the remainder vertices land in the (short) last shard instead
    of a phantom shard ``n_shards`` whose edges were silently dropped.

    ``val``'s dtype is preserved (float32 edge weights and raw uint32 quantized
    values partition through the same code path; pad edges carry val=0, which
    contributes nothing in either domain).
    """
    import numpy as np

    v_local, _ = sharded_vertex_layout(num_vertices, n_shards)
    shard_of = np.asarray(x) // v_local
    shards = []
    max_e = 0
    for s in range(n_shards):
        m = shard_of == s
        xs = np.asarray(x)[m] % v_local
        ys = np.asarray(y)[m]
        vs = np.asarray(val)[m]
        shards.append((xs, ys, vs))
        max_e = max(max_e, xs.shape[0])
    max_e = max(packet, (max_e + packet - 1) // packet * packet)
    X = np.zeros((n_shards, max_e), np.int32)
    Y = np.zeros((n_shards, max_e), np.int32)
    V = np.zeros((n_shards, max_e), np.asarray(val).dtype)
    for s, (xs, ys, vs) in enumerate(shards):
        X[s, : xs.shape[0]] = xs
        Y[s, : ys.shape[0]] = ys
        V[s, : vs.shape[0]] = vs
    return X.reshape(-1), Y.reshape(-1), V.reshape(-1)
