"""Config registry: ``get_config("<arch-id>")`` plus shape cells and smoke reductions."""
from repro.configs.archs import ALL_ARCHS
from repro.configs.base import (
    FULL_ATTN,
    MAMBA,
    SHAPES,
    ModelConfig,
    ShapeConfig,
    smoke_config,
)

# long_500k applicability (DESIGN.md §5): sub-quadratic mechanisms only.
LONG_CONTEXT_ARCHS = {
    "mamba2-1.3b",          # O(1) SSM state
    "zamba2-1.2b",          # hybrid: SSM + shared-attn KV
    "mixtral-8x7b",         # SWA 4096 — KV bounded by window
    "gemma2-27b",           # 1:1 local:global — local layers bounded
    "gemma3-4b",            # 5:1 local:global
}
LONG_SKIP_REASON = {
    "gemma-2b": "pure full attention (no windowing) — 500k KV has no sub-quadratic path",
    "starcoder2-15b": "pure full attention per assignment spec",
    "phi-3-vision-4.2b": "pure full attention; vision frontend caps practical context",
    "whisper-medium": "enc-dec audio: source is 1500 frames; 500k decode is meaningless",
    "moonshot-v1-16b-a3b": "pure full attention per assignment spec (48L global)",
}


def get_config(name: str) -> ModelConfig:
    if name not in ALL_ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ALL_ARCHS)}")
    return ALL_ARCHS[name]


def list_archs():
    return sorted(ALL_ARCHS)


def cells():
    """All (arch, shape) dry-run cells with applicability."""
    out = []
    for arch in list_archs():
        for shape_name, shape in SHAPES.items():
            if shape_name == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
                out.append((arch, shape_name, False, LONG_SKIP_REASON[arch]))
            else:
                out.append((arch, shape_name, True, ""))
    return out


__all__ = [
    "ALL_ARCHS", "SHAPES", "ModelConfig", "ShapeConfig", "smoke_config",
    "get_config", "list_archs", "cells", "LONG_CONTEXT_ARCHS",
    "FULL_ATTN", "MAMBA",
]
