"""The 10 assigned architectures, exact hyperparameters from the assignment table.

``layer_pattern`` encodes per-layer structure: 0 = global attention, W>0 = local
attention with window W, -1 = mamba2 layer (see configs/base.py).
"""
from __future__ import annotations

from repro.configs.base import FULL_ATTN, MAMBA, ModelConfig

# [arXiv:2408.00118] 46L, local(4096)/global alternating, GQA 32/16, softcaps.
GEMMA2_27B = ModelConfig(
    name="gemma2-27b", family="dense",
    num_layers=46, d_model=4608, num_heads=32, num_kv_heads=16, head_dim=128,
    d_ff=36864, vocab_size=256000,
    layer_pattern=(4096, FULL_ATTN) * 23,
    attn_softcap=50.0, logit_softcap=30.0, post_norms=True,
    act="gelu", embed_scale=True, tie_embeddings=True,
)

# [arXiv:2403.08295] 18L, MQA (kv=1), GeGLU, head_dim=256.
GEMMA_2B = ModelConfig(
    name="gemma-2b", family="dense",
    num_layers=18, d_model=2048, num_heads=8, num_kv_heads=1, head_dim=256,
    d_ff=16384, vocab_size=256000,
    layer_pattern=(FULL_ATTN,) * 18,
    act="gelu", embed_scale=True, tie_embeddings=True,
)

# [arXiv:2402.19173] 40L, GQA 48/4, RoPE theta=1e5, LayerNorm, plain-GELU MLP.
STARCODER2_15B = ModelConfig(
    name="starcoder2-15b", family="dense",
    num_layers=40, d_model=6144, num_heads=48, num_kv_heads=4, head_dim=128,
    d_ff=24576, vocab_size=49152,
    layer_pattern=(FULL_ATTN,) * 40,
    norm="layernorm", mlp="plain", act="gelu", rope_theta=1e5,
    tie_embeddings=False,
)

# [hf:google/gemma-3] 34L, 5:1 local(1024):global, GQA 8/4, qk-norm, 262k vocab.
GEMMA3_4B = ModelConfig(
    name="gemma3-4b", family="dense",
    num_layers=34, d_model=2560, num_heads=8, num_kv_heads=4, head_dim=256,
    d_ff=10240, vocab_size=262144,
    layer_pattern=((1024,) * 5 + (FULL_ATTN,)) * 5 + (1024,) * 4,
    use_qk_norm=True, post_norms=True, act="gelu", rope_theta=1e6,
    embed_scale=True, tie_embeddings=True,
)

# [hf:microsoft/Phi-3-vision] phi3-mini backbone (32L/3072/32H) + 576-patch stub.
PHI3_VISION_4B = ModelConfig(
    name="phi-3-vision-4.2b", family="vlm",
    num_layers=32, d_model=3072, num_heads=32, num_kv_heads=32, head_dim=96,
    d_ff=8192, vocab_size=32064,
    layer_pattern=(FULL_ATTN,) * 32,
    act="silu", tie_embeddings=False, num_patches=576,
)

# [arXiv:2212.04356] whisper-medium: 24 enc + 24 dec, d=1024, conv frontend stub.
WHISPER_MEDIUM = ModelConfig(
    name="whisper-medium", family="encdec",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16, head_dim=64,
    d_ff=4096, vocab_size=51865,
    layer_pattern=(FULL_ATTN,) * 24,
    norm="layernorm", mlp="plain", act="gelu", learned_pos=True,
    enc_layers=24, enc_len=1500, tie_embeddings=True,
)

# [arXiv:2401.04088] mixtral: 32L, 8 experts top-2, SWA 4096, GQA 32/8.
MIXTRAL_8X7B = ModelConfig(
    name="mixtral-8x7b", family="moe",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=32000,
    layer_pattern=(4096,) * 32,
    num_experts=8, experts_per_token=2, rope_theta=1e6,
    tie_embeddings=False,
)

# [hf:moonshotai/Moonlight-16B-A3B] 48L, 64 experts top-6, expert d_ff=1408.
MOONSHOT_16B = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    num_layers=48, d_model=2048, num_heads=16, num_kv_heads=16, head_dim=128,
    d_ff=1408, vocab_size=163840,
    layer_pattern=(FULL_ATTN,) * 48,
    num_experts=64, experts_per_token=6,
    tie_embeddings=False,
)

# [arXiv:2405.21060] mamba2: 48 SSD layers, d=2048, state=128, attention-free.
MAMBA2_1_3B = ModelConfig(
    name="mamba2-1.3b", family="ssm",
    num_layers=48, d_model=2048, num_heads=1, num_kv_heads=1, head_dim=64,
    d_ff=0, vocab_size=50280,
    layer_pattern=(MAMBA,) * 48,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2,
    tie_embeddings=True,
)

# [arXiv:2411.15242] zamba2: 38 mamba2 layers + shared attention block every 6.
ZAMBA2_1_2B = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=32000,
    layer_pattern=(MAMBA,) * 38,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2,
    shared_attn_every=6, tie_embeddings=True,
)

ALL_ARCHS = {
    c.name: c
    for c in [
        GEMMA2_27B, GEMMA_2B, STARCODER2_15B, GEMMA3_4B, PHI3_VISION_4B,
        WHISPER_MEDIUM, MIXTRAL_8X7B, MOONSHOT_16B, MAMBA2_1_3B, ZAMBA2_1_2B,
    ]
}
