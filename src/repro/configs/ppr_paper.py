"""The paper's own workload as a production-mesh configuration.

Scaled to the pod: the paper's single-FPGA envelope was |V| ≤ 1M (URAM-bound),
|E| ≤ 5B (DRAM-bound), κ = 8–16.  On a 256-chip pod with the dst-partitioned
shard_map SpMV (core/spmv.py), the model axis partitions the vertex space
(URAM → per-chip VMEM/HBM) and the data axis batches independent κ-groups —
so one pod serves 16 × κ personalization vertices per sweep over a graph 16×
the paper's maximum.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class PPRWorkload:
    name: str
    num_vertices: int
    num_edges: int
    kappa: int                 # personalization vertices per data shard
    bits: int                  # fixed-point width (paper: 20/22/24/26)
    iterations: int = 10
    alpha: float = 0.85


# paper-faithful single-FPGA envelope, on one model-axis group
PPR_PAPER_1M = PPRWorkload("ppr-paper-1m", num_vertices=1 << 20,
                           num_edges=16 << 20, kappa=16, bits=26)

# pod-scale: 16M vertices over the model axis, 16 κ-groups over data
PPR_POD_16M = PPRWorkload("ppr-pod-16m", num_vertices=16 << 20,
                          num_edges=256 << 20, kappa=16, bits=26)

PPR_WORKLOADS = {w.name: w for w in [PPR_PAPER_1M, PPR_POD_16M]}
