"""Model/shape configuration system.

One ``ModelConfig`` covers every assigned architecture family (dense / moe /
ssm / hybrid / encdec / vlm).  Per-layer structure (local vs global attention,
mamba vs attention) is encoded in ``layer_pattern`` so a single scanned layer
body covers the whole network (compile-time O(1) in depth — DESIGN.md §7).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

# layer_pattern entries
FULL_ATTN = 0          # global attention layer (window = whole sequence)
# any positive integer  = local attention with that window
MAMBA = -1             # mamba2 (SSD) layer


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    layer_pattern: Tuple[int, ...]   # len == num_layers (decoder side)

    # attention details
    rope_theta: float = 10_000.0
    attn_softcap: float = 0.0        # gemma2: 50.0
    logit_softcap: float = 0.0       # gemma2: 30.0
    use_qk_norm: bool = False        # gemma3
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    mlp: str = "glu"                 # glu (gate+up+down) | plain (fc+proj)
    act: str = "silu"                # silu | gelu
    post_norms: bool = False         # gemma2/3 post-attn/post-mlp norms
    tie_embeddings: bool = True
    embed_scale: bool = False        # gemma: x *= sqrt(d)
    learned_pos: bool = False        # whisper decoder
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    # hybrid (zamba2): shared attention block applied every N layers
    shared_attn_every: int = 0
    # encoder-decoder (whisper)
    enc_layers: int = 0
    enc_len: int = 0                 # precomputed frame embeddings (stub frontend)
    # vlm (phi-3-vision)
    num_patches: int = 0             # precomputed patch embeddings (stub frontend)
    compute_dtype: str = "bfloat16"  # activations dtype (params stay f32)

    # ------------------------------------------------------------------
    @property
    def act_dtype(self):
        import jax.numpy as jnp
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.compute_dtype]

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so it shards over any mesh axis."""
        return (self.vocab_size + 255) // 256 * 256

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Analytic parameter count (embedding + layers), for 6·N·D roofline."""
        d, f, hd = self.d_model, self.d_ff, self.head_dim
        attn = d * (self.num_heads * hd) * 2 + d * (self.num_kv_heads * hd) * 2
        if self.mlp == "glu":
            dense_mlp = 3 * d * f
        else:
            dense_mlp = 2 * d * f
        if self.num_experts:
            moe_mlp = self.num_experts * 3 * d * f + d * self.num_experts
        else:
            moe_mlp = 0
        mamba = 0
        if self.ssm_state:
            di, st, nh = self.ssm_d_inner, self.ssm_state, self.ssm_heads
            # in_proj (z,x,B,C,dt) + conv + out_proj + A,D
            mamba = d * (2 * di + 2 * st + nh) + di * self.ssm_conv + di * d + 2 * nh
        total = 0
        for w in self.layer_pattern:
            if w == MAMBA:
                total += mamba
            else:
                total += attn + (moe_mlp if self.num_experts else dense_mlp)
            total += 4 * d  # norms
        if self.shared_attn_every:
            total += attn + dense_mlp  # one shared block
        if self.enc_layers:
            total += self.enc_layers * (attn + dense_mlp + 4 * d)
            total += self.num_layers * (attn + 2 * d)  # cross attention
        total += self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts) for 6·N_active·D."""
        if not self.num_experts:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        full_moe = self.num_experts * 3 * d * f
        active_moe = self.experts_per_token * 3 * d * f
        n_moe_layers = sum(1 for w in self.layer_pattern if w != MAMBA)
        return self.param_count() - n_moe_layers * (full_moe - active_moe)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str              # train_4k | prefill_32k | decode_32k | long_500k
    kind: str              # train | prefill | decode
    seq_len: int
    global_batch: int
    microbatches: int = 1  # gradient accumulation (train only)


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests: few layers, small width,
    few experts, tiny vocab — preserves every structural feature."""
    n_layers = min(4, cfg.num_layers)
    pattern = cfg.layer_pattern[:n_layers]
    # keep at least one of each layer kind present in the original
    kinds = {w for w in cfg.layer_pattern}
    if MAMBA in kinds and MAMBA not in pattern:
        pattern = pattern[:-1] + (MAMBA,)
    if any(w > 0 for w in kinds) and not any(w > 0 for w in pattern):
        pattern = (8,) + pattern[1:]
    heads = min(4, cfg.num_heads)
    kv = max(1, min(cfg.num_kv_heads, heads))
    return dataclasses.replace(
        cfg,
        num_layers=n_layers,
        layer_pattern=tuple(min(w, 8) if w > 0 else w for w in pattern),
        d_model=128,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        num_experts=min(cfg.num_experts, 4),
        experts_per_token=min(cfg.experts_per_token, 2),
        moe_capacity_factor=float(max(4, cfg.num_experts or 4)),  # dropless in smoke
        ssm_state=min(cfg.ssm_state, 16),
        ssm_head_dim=32 if cfg.ssm_state else cfg.ssm_head_dim,
        shared_attn_every=2 if cfg.shared_attn_every else 0,
        enc_layers=min(cfg.enc_layers, 2),
        enc_len=min(cfg.enc_len, 16) if cfg.enc_len else 0,
        num_patches=min(cfg.num_patches, 8) if cfg.num_patches else 0,
    )
