"""Bounded per-graph store of last-converged PPR columns (warm-start seeds).

PPR's eq. (1) iteration is a contraction toward a personalization-pinned
stationary state: the starting point only decides the trajectory length, not
the destination.  After a topology delta, the pre-delta converged column of a
personalization vertex is therefore a far better ``V0`` than the one-hot
restart — the convergence monitor (repro.autotune.convergence) reaches the
absorbing state / epsilon exit in a fraction of the cold iterations.

On the fixed path the absorbing state reached from a warm seed can differ
from the cold trajectory's by trailing LSBs of quantization noise (truncation
is path-dependent); rankings agree in practice and the shadow quality
estimator keeps scoring warm-served results online.  Queries needing the
bit-exact cold result run on a service with ``warm_start`` off — the cache
key's warm flag keeps the two result families from aliasing.

Columns are stored host-side in the precision domain they were served at
(float32 for the f32 path, raw uint32 for fixed formats — keys carry the
precision key, so domains never mix), one ``LRUCache`` per graph keyed
``(vertex, precision)``.  ``grow`` zero-pads every stored column when a delta
grows the vertex space: new vertices start with zero rank, exactly what a
cold restart would give them.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Dict, Optional

import numpy as np

if TYPE_CHECKING:  # typing only — see the lazy import in __init__
    from repro.ppr_serving.cache import LRUCache


class WarmStartStore:
    """Per-graph LRU of converged state columns keyed (vertex, precision)."""

    def __init__(self, capacity_per_graph: int = 512):
        # imported lazily: ppr_serving.service imports this module, so a
        # module-level import of the ppr_serving package would be circular
        # when repro.graph_updates is imported first
        from repro.ppr_serving.cache import LRUCache
        if capacity_per_graph < 0:
            raise ValueError(
                f"capacity_per_graph must be >= 0, got {capacity_per_graph}")
        self.capacity_per_graph = capacity_per_graph
        self._lru_cls = LRUCache
        self._stores: Dict[str, "LRUCache"] = {}

    def __len__(self) -> int:
        return sum(len(s) for s in self._stores.values())

    def _store(self, graph: str) -> "LRUCache":
        if graph not in self._stores:
            self._stores[graph] = self._lru_cls(self.capacity_per_graph)
        return self._stores[graph]

    def get(self, graph: str, vertex: int, pkey: str) -> Optional[np.ndarray]:
        return self._store(graph).get((int(vertex), pkey))

    def put(self, graph: str, vertex: int, pkey: str, column: np.ndarray) -> None:
        self._store(graph).put((int(vertex), pkey), column)

    def grow(self, graph: str, new_num_vertices: int) -> None:
        """Zero-pad every stored column of ``graph`` to the grown vertex count
        (no-op for columns already that long)."""
        store = self._stores.get(graph)
        if store is None:
            return

        def pad(_key, col):
            n = new_num_vertices - col.shape[0]
            return np.concatenate([col, np.zeros(n, col.dtype)]) if n > 0 else col

        store.map_values(pad)

    def drop_graph(self, graph: str) -> int:
        """Full re-registration: stored columns describe a dead topology."""
        store = self._stores.pop(graph, None)
        return len(store) if store is not None else 0

    def stats(self) -> Dict[str, float]:
        agg = {"hits": 0, "misses": 0, "evictions": 0}
        for store in self._stores.values():
            s = store.stats()
            for k in agg:
                agg[k] += s[k]
        return {
            "size": len(self),
            "capacity_per_graph": self.capacity_per_graph,
            **{k: float(v) for k, v in agg.items()},
        }
