"""Dynamic graph updates — epoch-versioned edge-delta ingestion for a live
``PPRService``.

The paper motivates PPR as the building block of e-commerce and social-network
recommenders — workloads whose graphs change continuously.  Before this
subsystem the service could only absorb topology changes via full
``register_graph`` re-registration: whole-graph cache invalidation, every
pending query purged, the precision ladder reset.  Delta ingestion makes
updates a first-class serving operation.

DESIGN — component map
----------------------
``delta.py``      ``EdgeDelta``: batched add/remove edge lists + vertex
                  growth, with ``affected_frontier`` (touched vertices plus
                  their in-neighbors — the scoped-invalidation surface) and
                  ``random_delta`` for benchmarks/replay.  The host-side merge
                  itself lives in ``repro.core.coo.merge_edge_delta``: the
                  merged arrays are bit-identical to a from-scratch
                  ``from_edges`` build, but only touched sources are
                  renormalized and the returned ``EdgeMergeInfo`` lets
                  registered graphs requantize only changed ``val`` entries
                  per pre-registered Q format and repartition only affected
                  destination buckets on meshes.
``warmstart.py``  ``WarmStartStore``: bounded per-graph LRU of last-converged
                  PPR columns.  Waves seed ``V0`` from the stored column per
                  personalization vertex, so the convergence monitor
                  early-exits in far fewer iterations after a delta.

Service integration (``repro.ppr_serving.service``): ``PPRService.apply_delta``
bumps the graph's epoch (epoch-tagging cache keys and wave keys), drops only
cache entries / pending queries whose personalization vertex falls in the
delta's affected frontier — everything else is retagged to the new epoch and
kept — decays (rather than resets) the autotune quality windows, and reports
``deltas_applied`` / ``edges_added`` / ``edges_removed`` /
``scoped_invalidations`` / ``warm_start_iterations_saved`` telemetry.
"""
from repro.core.coo import EdgeMergeInfo, merge_edge_delta, quantize_values
from repro.graph_updates.delta import EdgeDelta, localized_delta, random_delta
from repro.graph_updates.warmstart import WarmStartStore

__all__ = [
    "EdgeDelta", "random_delta", "localized_delta", "WarmStartStore",
    "EdgeMergeInfo", "merge_edge_delta", "quantize_values",
]
