"""`EdgeDelta` — one batched topology update against a live graph.

A delta is the host-side unit of dynamic-graph ingestion (the CPU prepares
and patches the sparse structure while the accelerator keeps streaming it —
the CPU–FPGA synergy argument of arXiv 2004.13907): lists of edges to add and
remove, plus optional vertex growth.  ``apply`` merges it into a ``COOGraph``
through ``repro.core.coo.merge_edge_delta``, which renormalizes ``val`` and
``dangling`` only for touched source vertices and returns the bookkeeping for
incremental requantization / shard repartitioning.

``affected_frontier`` is the scoped-invalidation surface: the delta's touched
vertices plus their in-neighbors — every personalization vertex whose cached
top-K sees a first-order (one-hop, α-weighted) rank shift.  Entries outside
the frontier see only multi-hop, α²-damped mass shifts and are retained as
bounded-staleness approximations instead of being dropped with the whole
graph.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.core.coo import COOGraph, EdgeMergeInfo, merge_edge_delta


def _edge_array(a) -> np.ndarray:
    arr = np.atleast_1d(np.asarray(a, np.int64))
    if arr.ndim != 1:
        raise ValueError(f"edge endpoint list must be 1-D, got shape {arr.shape}")
    return arr


@dataclasses.dataclass(frozen=True, eq=False)
class EdgeDelta:
    """A batch of edge insertions/deletions and optional vertex growth.

    ``add_src[i] -> add_dst[i]`` are inserted, ``remove_src[i] -> remove_dst[i]``
    removed (one multi-edge instance per request; removing a missing edge is an
    error).  ``new_num_vertices`` may only grow the vertex space.

    ``eq=False``: a generated ``__eq__`` over ndarray fields would raise on
    comparison (ambiguous array truth value) — identity semantics instead.
    """

    add_src: np.ndarray = dataclasses.field(default_factory=lambda: np.zeros(0, np.int64))
    add_dst: np.ndarray = dataclasses.field(default_factory=lambda: np.zeros(0, np.int64))
    remove_src: np.ndarray = dataclasses.field(default_factory=lambda: np.zeros(0, np.int64))
    remove_dst: np.ndarray = dataclasses.field(default_factory=lambda: np.zeros(0, np.int64))
    new_num_vertices: Optional[int] = None

    def __post_init__(self):
        for f in ("add_src", "add_dst", "remove_src", "remove_dst"):
            object.__setattr__(self, f, _edge_array(getattr(self, f)))
        if self.add_src.shape != self.add_dst.shape:
            raise ValueError("add_src/add_dst length mismatch")
        if self.remove_src.shape != self.remove_dst.shape:
            raise ValueError("remove_src/remove_dst length mismatch")

    @property
    def num_added(self) -> int:
        return int(self.add_src.shape[0])

    @property
    def num_removed(self) -> int:
        return int(self.remove_src.shape[0])

    @property
    def num_edges(self) -> int:
        return self.num_added + self.num_removed

    def touched_vertices(self) -> np.ndarray:
        """Every endpoint of an added or removed edge (sorted, unique)."""
        return np.unique(np.concatenate(
            [self.add_src, self.add_dst, self.remove_src, self.remove_dst]))

    def affected_frontier(self, g: COOGraph) -> np.ndarray:
        """Touched vertices plus their in-neighbors in ``g`` (the pre-delta
        graph).  Added edges contribute no extra in-neighbors: an added edge
        into a touched vertex has a touched source by construction."""
        touched = self.touched_vertices()
        if touched.size == 0:
            return touched
        into_touched = np.isin(g.x, touched)
        return np.unique(np.concatenate(
            [touched, g.y[into_touched].astype(np.int64)]))

    def apply(self, g: COOGraph,
              outdeg: Optional[np.ndarray] = None
              ) -> Tuple[COOGraph, EdgeMergeInfo]:
        """Merge this delta into ``g`` (see ``merge_edge_delta``)."""
        return merge_edge_delta(
            g, self.add_src, self.add_dst, self.remove_src, self.remove_dst,
            new_num_vertices=self.new_num_vertices, outdeg=outdeg)


def random_delta(g: COOGraph, rng: np.random.Generator,
                 n_add: int = 16, n_remove: int = 8, grow: int = 0,
                 center: Optional[int] = None) -> EdgeDelta:
    """Synthesize a plausible delta against ``g`` (benchmarks / replay).

    ``center`` localizes the delta to the 1-hop neighborhood of one vertex
    (the scoped-invalidation showcase); otherwise endpoints are global.
    ``grow`` appends that many new vertices, each wired to one existing vertex
    so growth is observable in served rankings, not just shapes.
    """
    v = g.num_vertices
    if center is not None:
        nbhd = np.unique(np.concatenate(
            [[center], g.y[g.x == center], g.x[g.y == center]])).astype(np.int64)
        rem_pool = np.nonzero(np.isin(g.x, nbhd) | np.isin(g.y, nbhd))[0]
    else:
        nbhd = None
        rem_pool = np.arange(g.num_edges)
    n_remove = min(n_remove, rem_pool.shape[0])
    rem_idx = rng.choice(rem_pool, size=n_remove, replace=False) \
        if n_remove else np.zeros(0, np.int64)
    remove_src = g.y[rem_idx].astype(np.int64)
    remove_dst = g.x[rem_idx].astype(np.int64)
    pool = nbhd if nbhd is not None and nbhd.size >= 2 else np.arange(v)
    add_src = rng.choice(pool, size=n_add) if n_add else np.zeros(0, np.int64)
    add_dst = rng.choice(pool, size=n_add) if n_add else np.zeros(0, np.int64)
    new_v = None
    if grow:
        new_ids = np.arange(v, v + grow, dtype=np.int64)
        add_src = np.concatenate([add_src, new_ids])
        add_dst = np.concatenate([add_dst, rng.integers(0, v, grow)])
        new_v = v + grow
    return EdgeDelta(add_src=add_src, add_dst=add_dst,
                     remove_src=remove_src, remove_dst=remove_dst,
                     new_num_vertices=new_v)


def localized_delta(g: COOGraph, rng: np.random.Generator,
                    n_add: int = 4, n_remove: int = 1) -> EdgeDelta:
    """A delta whose affected frontier stays genuinely small.

    On heavy-tailed graphs almost every edge is incident to a hub, and
    touching a hub puts the hub's entire in-neighborhood in the frontier —
    ``random_delta(center=...)`` therefore still invalidates most of the
    cache.  This variant draws endpoints from the lowest-connectivity
    vertices (added edges among the quietest vertices, removed edges ranked
    by the combined degree of both endpoints), the scoped-invalidation
    showcase case: a localized update drops strictly fewer cache entries than
    a whole-graph flush.
    """
    conn = np.bincount(g.x, minlength=g.num_vertices).astype(np.int64) \
        + np.bincount(g.y, minlength=g.num_vertices)
    pool = np.argsort(conn, kind="stable")[: max(8, 2 * (n_add + n_remove))]
    add_src = rng.choice(pool, n_add) if n_add else np.zeros(0, np.int64)
    add_dst = rng.choice(pool, n_add) if n_add else np.zeros(0, np.int64)
    n_remove = min(n_remove, g.num_edges)
    if n_remove:
        score = conn[g.x] + conn[g.y]
        rem_idx = np.argsort(score, kind="stable")[:n_remove]
        remove_src = g.y[rem_idx].astype(np.int64)
        remove_dst = g.x[rem_idx].astype(np.int64)
    else:
        remove_src = remove_dst = np.zeros(0, np.int64)
    return EdgeDelta(add_src=add_src, add_dst=add_dst,
                     remove_src=remove_src, remove_dst=remove_dst)
