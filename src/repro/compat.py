"""Version shims for the jax API surface this repo uses.

The codebase targets current jax (``jax.shard_map``, ``jax.set_mesh``); these
aliases keep it running on the 0.4.x series where the same functionality lives
under different names.
"""
from __future__ import annotations

import contextlib

import jax

try:
    shard_map = jax.shard_map
except AttributeError:                      # jax < 0.6: under experimental
    from jax.experimental.shard_map import shard_map  # type: ignore[no-redef]


def set_mesh(mesh):
    """Context manager form of ``jax.set_mesh``; a Mesh is its own context
    manager on versions that predate the global setter."""
    setter = getattr(jax, "set_mesh", None)
    if setter is not None:
        return setter(mesh)
    return mesh if hasattr(mesh, "__enter__") else contextlib.nullcontext()


def compiled_cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` returns a dict on current jax, a
    one-per-program list on 0.4.x."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        return ca[0] if ca else {}
    return ca
