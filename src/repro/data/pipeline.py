"""Deterministic synthetic data pipeline.

At scale, determinism in (step, shard) is the fault-tolerance requirement: a
restarted host replays exactly its shard of the stream (no loss/duplication).
We derive every batch from fold_in(seed, step) so the stream is a pure function
of the step index — the same property a real tokenized-shard loader provides
via (shard_id, step) addressing.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    seed: int = 0
    # synthetic distribution: zipf-ish over the vocab (realistic token stats)
    zipf_a: float = 1.2


def synthetic_batch(cfg: ModelConfig, dcfg: DataConfig, step: int) -> Dict[str, jnp.ndarray]:
    """Pure function of (configs, step) → {tokens, targets, [frames|patches]}."""
    rng = np.random.default_rng(np.random.SeedSequence([dcfg.seed, step]))
    v = cfg.vocab_size
    # zipf sample clipped to vocab (cheap approximation of token frequencies)
    raw = rng.zipf(dcfg.zipf_a, size=(dcfg.global_batch, dcfg.seq_len + 1))
    toks = ((raw - 1) % v).astype(np.int32)
    batch = {
        "tokens": jnp.asarray(toks[:, :-1]),
        "targets": jnp.asarray(toks[:, 1:]),
    }
    if cfg.enc_len:
        batch["frames"] = jnp.asarray(
            rng.standard_normal((dcfg.global_batch, cfg.enc_len, cfg.d_model), np.float32))
    if cfg.num_patches:
        batch["patches"] = jnp.asarray(
            rng.standard_normal((dcfg.global_batch, cfg.num_patches, cfg.d_model), np.float32))
    return batch


def data_iterator(cfg: ModelConfig, dcfg: DataConfig, start_step: int = 0) -> Iterator:
    step = start_step
    while True:
        yield synthetic_batch(cfg, dcfg, step)
        step += 1
