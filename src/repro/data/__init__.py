from repro.data.pipeline import DataConfig, data_iterator, synthetic_batch

__all__ = ["DataConfig", "synthetic_batch", "data_iterator"]
