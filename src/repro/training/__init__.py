from repro.training.checkpoint import latest_step, restore, save, save_async, wait_pending
from repro.training.fault_tolerance import FaultConfig, run_resumable
from repro.training.optimizer import AdamWConfig, adamw_update, init_opt_state
from repro.training.train_loop import TrainState, init_train_state, make_train_step

__all__ = [
    "AdamWConfig", "adamw_update", "init_opt_state",
    "TrainState", "init_train_state", "make_train_step",
    "save", "save_async", "restore", "latest_step", "wait_pending",
    "FaultConfig", "run_resumable",
]
