"""train_step factory: microbatched gradient accumulation + AdamW + (optional)
fixed-point-compressed gradient exchange, all inside one jit.

The returned step is a pure function (TrainState, batch) → (TrainState, metrics)
suitable for pjit with the shardings from distributed/sharding.py.  Gradient
accumulation is a lax.scan over microbatches (activation memory ∝ 1/m), grads
accumulate in f32.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.quantization import truncate_to_grid
from repro.training.optimizer import AdamState, AdamWConfig, adamw_update, init_opt_state


class TrainState(NamedTuple):
    params: Any
    opt: AdamState
    residual: Any          # error-feedback residual (grad compression); None-like zeros


def init_train_state(params, compress: bool = False) -> TrainState:
    res = jax.tree.map(jnp.zeros_like, params) if compress else None
    return TrainState(params=params, opt=init_opt_state(params), residual=res)


def make_train_step(
    loss_fn,
    opt_cfg: AdamWConfig,
    microbatches: int = 1,
    grad_compress_bits: int = 0,
):
    """loss_fn(params, batch) → scalar.  batch leaves are [B_global, ...]."""

    def split_mb(batch):
        def r(x):
            b = x.shape[0]
            return x.reshape((microbatches, b // microbatches) + x.shape[1:])
        return jax.tree.map(r, batch)

    def train_step(state: TrainState, batch):
        params = state.params
        if microbatches > 1:
            mbs = split_mb(batch)

            def body(carry, mb):
                acc, lsum = carry
                loss, grads = jax.value_and_grad(loss_fn)(params, mb)
                acc = jax.tree.map(jnp.add, acc, grads)
                return (acc, lsum + loss), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gacc, lsum), _ = jax.lax.scan(body, (zeros, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / microbatches, gacc)
            loss = lsum / microbatches
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)

        residual = state.residual
        if grad_compress_bits and residual is not None:
            # paper's truncation quantizer + error feedback: the all-reduce that
            # XLA inserts for the data axis then moves (1+2+f)-bit payloads.
            def comp(g, r):
                corrected = g + r
                q = truncate_to_grid(corrected, grad_compress_bits)
                return q, corrected - q

            pairs = jax.tree.map(comp, grads, residual)
            grads = jax.tree.map(lambda t: t[0], pairs, is_leaf=lambda t: isinstance(t, tuple))
            residual = jax.tree.map(lambda t: t[1], pairs, is_leaf=lambda t: isinstance(t, tuple))

        new_params, new_opt, metrics = adamw_update(opt_cfg, grads, state.opt, params)
        metrics = dict(metrics, loss=loss)
        return TrainState(new_params, new_opt, residual), metrics

    return train_step
