"""Fault-tolerance runtime: restartable training driver with failure handling.

What "handles node failures" means in this framework (and how each piece is
exercised in this single-host container — tests/test_checkpoint.py):

1. **Checkpoint/restart**: ``run_resumable`` discovers the latest atomic
   checkpoint and resumes; any crash (simulated by killing the loop mid-step)
   loses at most ``save_every`` steps.  At scale, jax.distributed detects a
   failed host via the coordination service barrier timing out; the job
   restarts on the surviving + replacement nodes and takes this exact path.
2. **Elastic re-scale**: checkpoints store full logical arrays (mesh-agnostic),
   so a restart may pass a *different* mesh — restore re-shards (e.g. a 2-pod
   512-chip job falls back to 1 pod after a pod-level outage).
3. **Straggler mitigation**: per-step wall-time is tracked with an EWMA; steps
   slower than ``straggler_factor``× the EWMA are logged with the step index —
   at scale this feeds the scheduler that re-assigns slow hosts.  Data input is
   deterministic in (step, shard) so a restarted/reassigned host replays the
   exact stream (no sample loss / duplication).
4. **Preemption-safe saves**: saves are async + atomic; SIGTERM handlers flush
   pending saves (``checkpoint.wait_pending``).
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Optional

import jax

from repro.training import checkpoint as ckpt


@dataclasses.dataclass
class FaultConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    save_every: int = 50
    keep: int = 3
    straggler_factor: float = 2.0
    max_steps: int = 1000


def run_resumable(
    fault_cfg: FaultConfig,
    init_state_fn: Callable[[], Any],
    train_step,
    batch_fn: Callable[[int], Any],
    on_metrics: Optional[Callable[[int, dict], None]] = None,
    fail_at_step: Optional[int] = None,   # test hook: simulated node failure
):
    """Run (or resume) training with periodic async checkpoints.

    Returns (final_state, steps_run_this_invocation, straggler_steps).
    """
    last = ckpt.latest_step(fault_cfg.ckpt_dir)
    if last is not None:
        like = init_state_fn()
        state = ckpt.restore(fault_cfg.ckpt_dir, last, like)
        start = last
    else:
        state = init_state_fn()
        start = 0

    stop = {"flag": False}

    def _sigterm(signum, frame):   # preemption: flush and exit cleanly
        stop["flag"] = True

    old = signal.signal(signal.SIGTERM, _sigterm)
    ewma = None
    stragglers = []
    steps_run = 0
    try:
        for step in range(start, fault_cfg.max_steps):
            if stop["flag"]:
                break
            if fail_at_step is not None and step == fail_at_step:
                raise RuntimeError(f"simulated node failure at step {step}")
            t0 = time.monotonic()
            state, metrics = train_step(state, batch_fn(step))
            jax.block_until_ready(jax.tree.leaves(state.params)[0])
            dt = time.monotonic() - t0
            ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
            if dt > fault_cfg.straggler_factor * ewma and step > start + 3:
                stragglers.append((step, dt, ewma))
            steps_run += 1
            if on_metrics:
                on_metrics(step, metrics)
            if (step + 1) % fault_cfg.save_every == 0:
                ckpt.save_async(fault_cfg.ckpt_dir, step + 1, state, keep=fault_cfg.keep)
    finally:
        ckpt.wait_pending()
        signal.signal(signal.SIGTERM, old)
    return state, steps_run, stragglers
