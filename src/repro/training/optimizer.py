"""AdamW + cosine schedule + global-norm clipping, on raw pytrees (no optax)."""
from __future__ import annotations

import dataclasses
import math
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(1, cfg.warmup_steps), 1.0)
    progress = jnp.clip(
        (step - cfg.warmup_steps) / max(1, cfg.total_steps - cfg.warmup_steps), 0, 1
    )
    cos = 0.5 * (1 + jnp.cos(math.pi * progress))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init_opt_state(params) -> AdamState:
    zeros = jax.tree.map(jnp.zeros_like, params)
    return AdamState(step=jnp.zeros((), jnp.int32), mu=zeros, nu=zeros)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2)
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, grads, state: AdamState, params):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g * scale, grads)
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m2 / b1c
        vhat = v2 / b2c
        new_p = p - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p)
        return new_p, m2, v2

    out = jax.tree.map(upd, grads, state.mu, state.nu, params)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamState(step=step, mu=mu, nu=nu), {"grad_norm": gnorm, "lr": lr}
