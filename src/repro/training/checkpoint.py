"""Mesh-agnostic, atomic, async checkpointing (fault tolerance — DESIGN.md §6).

Design points for 1000+-node operation:
- **Mesh-agnostic**: every leaf is saved as a full logical array keyed by its
  pytree path.  Restore re-shards onto whatever mesh the job restarts with
  (elastic re-scale: 512 → 256 chips is a pure resharding load).
- **Atomic**: writes go to ``step_XXXX.tmp`` and are os.rename'd into place —
  a crash mid-save never corrupts the latest checkpoint.
- **Async**: ``save_async`` snapshots device arrays to host then writes on a
  background thread, so the train loop is blocked only for the device→host copy.
- **Keep-k GC** + ``latest_step`` discovery for automatic restart.

(At real scale each host would write only its addressable shards — the
single-process container collapses that to one writer; the layout and the
restore-with-resharding path are identical.)
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)
    leaves, treedef = flat
    out = {}
    for path, leaf in leaves:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path
        )
        out[key] = leaf
    return out, treedef


def save(ckpt_dir: str, step: int, tree: Any, keep: int = 3) -> str:
    """Synchronous atomic save.  Returns the final directory path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, _ = _flatten_with_paths(tree)
    arrays = {k: np.asarray(v) for k, v in leaves.items()}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": step, "keys": sorted(arrays)}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep)
    return final


_PENDING: list = []


def save_async(ckpt_dir: str, step: int, tree: Any, keep: int = 3) -> threading.Thread:
    """Device→host copy now; disk write on a daemon thread."""
    host_tree = jax.tree.map(np.asarray, tree)  # blocks only for D2H
    t = threading.Thread(target=save, args=(ckpt_dir, step, host_tree, keep), daemon=True)
    t.start()
    _PENDING.append(t)
    return t


def wait_pending():
    for t in _PENDING:
        t.join()
    _PENDING.clear()


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like: Any, shardings: Any = None) -> Any:
    """Restore into the structure of ``like``; optionally device_put onto
    ``shardings`` (a matching pytree of NamedSharding) — the elastic-rescale
    path: the stored full arrays are resharded onto the *current* mesh."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves, treedef = _flatten_with_paths(like)
    restored = {}
    for key, leaf in leaves.items():
        arr = data[key]
        restored[key] = arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr
    vals = [restored[k] for k in leaves]
    tree = jax.tree_util.tree_unflatten(treedef, vals)
    if shardings is not None:
        tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree, shardings)
    return tree


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)
