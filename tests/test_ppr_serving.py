"""PPR query-serving subsystem: scheduler waves, top-K vs argsort oracle,
LRU cache, edge-partition tail fix, and the end-to-end PPRService."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PPRConfig, format_for_bits, run_ppr
from repro.core.metrics import topk_indices
from repro.core.spmv import partition_edges_by_dst
from repro.graphs import erdos_renyi, holme_kim_powerlaw
from repro.ppr_serving import (
    LRUCache,
    PPRQuery,
    PPRService,
    WaveScheduler,
    topk_dense,
    topk_streaming,
)


@pytest.fixture(scope="module")
def graph():
    return holme_kim_powerlaw(600, m=5, seed=2)


def oracle_topk(scores: np.ndarray, k: int, exclude: int) -> np.ndarray:
    """Dense-rank argsort oracle with self-exclusion (metrics.topk_indices)."""
    s = np.asarray(scores, np.float64).copy()
    s[exclude] = -np.inf
    return topk_indices(s, k)


# ---------------------------------------------------------------------------
# scheduler: wave formation
# ---------------------------------------------------------------------------
class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_scheduler_full_wave_launches_immediately():
    clk = FakeClock()
    sch = WaveScheduler(kappa=4, max_wait=10.0, time_fn=clk)
    for i in range(9):
        sch.submit("key", i)
    waves = sch.ready_waves()
    assert [len(w) for w in waves] == [4, 4]
    assert all(w.full for w in waves)
    assert waves[0].items == [0, 1, 2, 3] and waves[1].items == [4, 5, 6, 7]
    assert sch.pending() == 1              # partial held back inside max_wait


def test_scheduler_deadline_flushes_partial_wave():
    clk = FakeClock()
    sch = WaveScheduler(kappa=4, max_wait=1.0, time_fn=clk)
    sch.submit("key", "a")
    clk.t = 0.5
    assert sch.ready_waves() == []          # oldest has waited only 0.5 < 1.0
    clk.t = 1.0
    waves = sch.ready_waves()
    assert len(waves) == 1 and not waves[0].full and waves[0].items == ["a"]
    assert sch.pending() == 0


def test_scheduler_query_deadline_tighter_than_max_wait():
    clk = FakeClock()
    sch = WaveScheduler(kappa=4, max_wait=10.0, time_fn=clk)
    sch.submit("key", "urgent", deadline=0.2)
    clk.t = 0.25
    waves = sch.ready_waves()
    assert len(waves) == 1 and waves[0].items == ["urgent"]


def test_scheduler_late_tight_deadline_flushes_whole_partial():
    """A newer occupant's tighter deadline governs — it must not wait on the
    oldest occupant's looser budget, and the partial flush takes everyone."""
    clk = FakeClock()
    sch = WaveScheduler(kappa=4, max_wait=10.0, time_fn=clk)
    sch.submit("key", "patient")
    clk.t = 0.1
    sch.submit("key", "urgent", deadline=0.2)
    clk.t = 0.25
    assert sch.ready_waves() == []          # urgent's budget ends at 0.3
    clk.t = 0.35
    waves = sch.ready_waves()
    assert len(waves) == 1 and waves[0].items == ["patient", "urgent"]


def test_scheduler_keys_do_not_mix():
    """Queries on different (graph, precision) streams never share a wave."""
    clk = FakeClock()
    sch = WaveScheduler(kappa=2, max_wait=10.0, time_fn=clk)
    sch.submit(("g1", "f32"), 1)
    sch.submit(("g2", "f32"), 2)
    sch.submit(("g1", "Q1.25"), 3)
    assert sch.ready_waves() == []          # three singleton queues, none full
    sch.submit(("g1", "f32"), 4)
    waves = sch.ready_waves()
    assert len(waves) == 1 and waves[0].key == ("g1", "f32")
    assert waves[0].items == [1, 4]


def test_scheduler_purge_graph_pending_across_multiple_precision_keys():
    """One graph's queries pending under several precision (and mesh) keys:
    a name-prefix purge must drop every one of them and nothing else."""
    sch = WaveScheduler(kappa=4, max_wait=10.0, time_fn=FakeClock())
    sch.submit(("g", "f32", "single", 0), "a")
    sch.submit(("g", "Q1.25", "single", 0), "b")
    sch.submit(("g", "Q1.19", "mesh:shardx4", 0), "c")
    sch.submit(("h", "f32", "single", 0), "d")
    assert sch.purge(lambda k: k[0] == "g") == 3
    assert sch.pending() == 1
    waves = sch.drain()
    assert len(waves) == 1 and waves[0].items == ["d"]


def test_scheduler_purge_with_item_predicate_keeps_cobatched():
    sch = WaveScheduler(kappa=4, max_wait=10.0, time_fn=FakeClock())
    for v in (1, 2, 3):
        sch.submit(("g", "f32"), v)
    sch.submit(("h", "f32"), 9)
    assert sch.purge(lambda k: k[0] == "g", lambda item: item == 2) == 1
    assert sch.pending() == 3                   # 1,3 under g + 9 under h
    waves = sch.drain()
    assert sorted(sum((w.items for w in waves), [])) == [1, 3, 9]


def test_scheduler_extract_preserves_budgets():
    clk = FakeClock()
    sch = WaveScheduler(kappa=4, max_wait=1.0, time_fn=clk)
    sch.submit(("g", 0), "a", deadline=0.5)
    clk.t = 0.3
    moved = sch.extract(lambda k: k[0] == "g")
    assert moved == [(("g", 0), "a", 0.0, 0.5)]
    assert sch.pending() == 0
    # re-submission under a new key with now=enqueued_at keeps the clock
    sch.submit(("g", 1), "a", deadline=0.5, now=0.0)
    assert sch.ready_waves() == []              # 0.3 < 0.5 budget
    clk.t = 0.6
    waves = sch.ready_waves()
    assert len(waves) == 1 and waves[0].items == ["a"]


def test_scheduler_flush_keys_is_targeted():
    sch = WaveScheduler(kappa=4, max_wait=10.0, time_fn=FakeClock())
    sch.submit(("g", 0), "a")
    sch.submit(("h", 0), "b")
    waves = sch.flush_keys({("g", 0)})
    assert len(waves) == 1 and waves[0].items == ["a"] and not waves[0].full
    assert sch.pending() == 1                   # ("h", 0) untouched


def test_scheduler_drain_chunks_by_kappa():
    sch = WaveScheduler(kappa=4, max_wait=10.0, time_fn=FakeClock())
    for i in range(6):
        sch.submit("key", i)
    waves = sch.drain()
    assert [len(w) for w in waves] == [4, 2]
    assert [w.full for w in waves] == [True, False]
    assert sch.pending() == 0


# ---------------------------------------------------------------------------
# top-K: dense and streaming vs the numpy argsort oracle
# ---------------------------------------------------------------------------
def test_topk_float_matches_oracle_with_ties():
    rng = np.random.default_rng(0)
    # coarse grid forces plenty of score ties → exercises tie-breaking
    P = (rng.integers(0, 20, (300, 5)) / 20.0).astype(np.float32)
    idx, vals = topk_dense(jnp.asarray(P), 7)
    for j in range(5):
        want = topk_indices(P[:, j], 7)
        np.testing.assert_array_equal(np.asarray(idx)[j], want)
        np.testing.assert_array_equal(np.asarray(vals)[j], P[want, j])


def test_topk_raw_uint32_matches_oracle():
    rng = np.random.default_rng(1)
    P = rng.integers(0, 50, (257, 4)).astype(np.uint32)   # many ties, odd V
    idx, vals = topk_dense(jnp.asarray(P), 9)
    for j in range(4):
        np.testing.assert_array_equal(np.asarray(idx)[j],
                                      topk_indices(P[:, j].astype(np.int64), 9))


def test_topk_excludes_query_vertex():
    P = np.zeros((40, 2), np.float32)
    P[[3, 5, 7], 0] = [0.9, 0.8, 0.7]
    P[[3, 5, 7], 1] = [0.9, 0.8, 0.7]
    idx, _ = topk_dense(jnp.asarray(P), 2, exclude=jnp.asarray([3, 9]))
    np.testing.assert_array_equal(np.asarray(idx), [[5, 7], [3, 5]])


def test_topk_exclusion_zero_score_column_raw_domain():
    """An excluded vertex must never re-enter via zero-score ties (the raw
    domain has no -inf, so exclusion is by deletion, not masking)."""
    P = np.zeros((30, 1), np.uint32)
    P[[2, 4], 0] = [100, 50]                 # only two nonzero ranks
    idx, _ = topk_dense(jnp.asarray(P), 5, exclude=jnp.asarray([0]))
    got = np.asarray(idx)[0]
    assert 0 not in got.tolist()
    np.testing.assert_array_equal(got[:2], [2, 4])
    np.testing.assert_array_equal(got[2:], [1, 3, 5])   # zero ties by ascending id


@pytest.mark.parametrize("v,v_tile", [(256, 64), (300, 64), (100, 128), (257, 17)])
@pytest.mark.parametrize("dtype", [np.float32, np.uint32])
def test_topk_streaming_matches_dense(v, v_tile, dtype):
    rng = np.random.default_rng(v)
    if dtype == np.uint32:
        P = rng.integers(0, 30, (v, 3)).astype(dtype)     # heavy ties
    else:
        P = (rng.integers(0, 30, (v, 3)) / 30.0).astype(dtype)
    excl = jnp.asarray(rng.integers(0, v, 3), jnp.int32)
    for exclude in (None, excl):
        di, dv = topk_dense(jnp.asarray(P), 8, exclude=exclude)
        si, sv = topk_streaming(jnp.asarray(P), 8, v_tile=v_tile, exclude=exclude)
        np.testing.assert_array_equal(np.asarray(di), np.asarray(si))
        np.testing.assert_array_equal(np.asarray(dv), np.asarray(sv))


def test_topk_streaming_rejects_small_tile():
    with pytest.raises(ValueError):
        topk_streaming(jnp.zeros((64, 2), jnp.float32), 10, v_tile=8)


# ---------------------------------------------------------------------------
# LRU result cache
# ---------------------------------------------------------------------------
def test_lru_eviction_order_and_counters():
    c = LRUCache(capacity=2)
    c.put("a", 1)
    c.put("b", 2)
    assert c.get("a") == 1                  # refreshes "a" → "b" now oldest
    c.put("c", 3)                           # evicts "b"
    assert c.get("b") is None
    assert c.get("a") == 1 and c.get("c") == 3
    assert c.hits == 3 and c.misses == 1 and c.evictions == 1
    assert c.hit_rate == 0.75
    assert len(c) == 2 and "a" in c and "b" not in c


def test_lru_zero_capacity_never_stores():
    c = LRUCache(capacity=0)
    c.put("a", 1)
    assert c.get("a") is None and len(c) == 0
    # no phantom eviction accounting: nothing was stored, nothing evicted,
    # but the failed probe still counts as a miss
    assert c.evictions == 0 and c.hits == 0 and c.misses == 1
    assert c.stats()["size"] == 0 and c.hit_rate == 0.0


def test_lru_invalidate_counter_accounting():
    c = LRUCache(capacity=8)
    for i in range(4):
        c.put(("g", i), i)
    assert c.invalidate(lambda k: k[1] % 2 == 0) == 2
    assert c.invalidations == 2 and len(c) == 2
    # a no-match pass adds nothing
    assert c.invalidate(lambda k: False) == 0
    assert c.invalidations == 2
    # invalidations never masquerade as evictions or misses
    assert c.evictions == 0 and c.misses == 0


def test_lru_repeated_put_same_key_never_evicts():
    c = LRUCache(capacity=2)
    for i in range(5):
        c.put("a", i)                           # refresh, not growth
    assert c.evictions == 0 and len(c) == 1
    assert c.get("a") == 4                      # latest value won
    c.put("b", 1)
    c.put("c", 2)                               # only now capacity overflows
    assert c.evictions == 1


def test_lru_remap_drop_retag_and_recency():
    c = LRUCache(capacity=8)
    c.put(("g", 0, 1), "v1")
    c.put(("g", 0, 2), "v2")
    c.put(("h", 0, 1), "w1")
    assert c.get(("g", 0, 1)) == "v1"           # refresh → ("g",0,2) oldest
    dropped, retagged = c.remap(
        lambda k: None if k[0] == "g" and k[2] == 2
        else ((k[0], 1, k[2]) if k[0] == "g" else k))
    assert (dropped, retagged) == (1, 1)
    assert c.invalidations == 1
    assert c.get(("g", 1, 1)) == "v1" and c.get(("g", 0, 1)) is None
    assert c.get(("h", 0, 1)) == "w1"           # untouched key kept as-is


def test_lru_remap_collision_keeps_most_recent():
    c = LRUCache(capacity=8)
    c.put(("a",), "old")
    c.put(("b",), "new")
    dropped, _ = c.remap(lambda k: ("same",))
    assert dropped == 1
    assert c.get(("same",)) == "new"


# ---------------------------------------------------------------------------
# satellite fix: partition_edges_by_dst must not drop tail edges
# ---------------------------------------------------------------------------
def test_partition_edges_tail_not_dropped():
    g = erdos_renyi(510, 4000, seed=3)      # 510 % 4 != 0
    n_shards = 4
    X, Y, V = partition_edges_by_dst(g.x, g.y, g.val, 510, n_shards, packet=8)
    assert (V > 0).sum() == g.num_edges     # every real edge survives
    # reconstruct the full SpMV from the shard-local layout
    v_local = -(-510 // n_shards)
    k = 3
    rng = np.random.default_rng(0)
    p = (rng.random((510, k)) / 510).astype(np.float32)
    out = np.zeros((n_shards * v_local, k), np.float32)
    e_per = X.shape[0] // n_shards
    for s in range(n_shards):
        xs = X[s * e_per:(s + 1) * e_per]
        ys = Y[s * e_per:(s + 1) * e_per]
        vs = V[s * e_per:(s + 1) * e_per]
        np.add.at(out[s * v_local:(s + 1) * v_local], xs, vs[:, None] * p[ys])
    ref = np.zeros((510, k), np.float32)
    np.add.at(ref, g.x, g.val[:, None] * p[g.y])
    np.testing.assert_allclose(out[:510], ref, atol=1e-5)


def test_partition_edges_divisible_unchanged():
    g = erdos_renyi(512, 2000, seed=4)
    X, Y, V = partition_edges_by_dst(g.x, g.y, g.val, 512, 8)
    assert (V > 0).sum() == g.num_edges


# ---------------------------------------------------------------------------
# end-to-end PPRService
# ---------------------------------------------------------------------------
def per_vertex_oracle(g, v, k, fmt=None, iterations=10):
    scores, _ = run_ppr(g, np.array([v]), PPRConfig(iterations=iterations), fmt=fmt)
    return oracle_topk(scores[:, 0], k, v)


def test_service_end_to_end(graph):
    """Acceptance: ≥32 queries through κ-batched waves, float and fixed, top-10
    matching the dense-rank argsort oracle, cache hits on repeat traffic."""
    svc = PPRService(kappa=8, iterations=10, cache_capacity=256)
    svc.register_graph("amz", graph, formats=[26])
    rng = np.random.default_rng(0)
    verts = rng.integers(0, graph.num_vertices, 16)
    queries = [PPRQuery("amz", int(v), k=10) for v in verts] + \
              [PPRQuery("amz", int(v), k=10, precision=26) for v in verts]
    recs = svc.serve(queries)

    assert len(recs) == 32
    assert all(r.source == "wave" for r in recs)
    fmt26 = format_for_bits(26)
    for i, v in enumerate(verts):
        np.testing.assert_array_equal(
            recs[i].vertices, per_vertex_oracle(graph, int(v), 10))
        np.testing.assert_array_equal(
            recs[16 + i].vertices, per_vertex_oracle(graph, int(v), 10, fmt26))
        assert int(v) not in recs[i].vertices.tolist()
        # ranked scores are descending and self-free
        assert (np.diff(recs[i].scores) <= 0).all()

    s = svc.telemetry_summary()
    assert s["queries_served"] == 32
    assert s["waves"] == 4                   # 2 precision groups × 16/κ
    assert s["mean_occupancy"] == 1.0

    # repeat traffic → pure cache hits, hit rate > 0
    again = svc.serve(queries[:8])
    assert all(r.source == "cache" for r in again)
    for i in range(8):
        np.testing.assert_array_equal(again[i].vertices, recs[i].vertices)
    assert svc.telemetry_summary()["cache_hit_rate"] > 0


def test_service_partial_wave_results_correct(graph):
    """3 queries on a κ=8 service: the drain path flushes a partial wave whose
    pad columns must not leak into results."""
    svc = PPRService(kappa=8, iterations=10)
    svc.register_graph("g", graph)
    verts = [7, 100, 201]
    recs = svc.serve([PPRQuery("g", v, k=5) for v in verts])
    assert len(recs) == 3
    for r, v in zip(recs, verts):
        np.testing.assert_array_equal(r.vertices, per_vertex_oracle(graph, v, 5))
    assert svc.telemetry.wave_occupancies == [3 / 8]


def test_service_streaming_topk_path(graph):
    """topk_tile switches top-K to the padded-tile streaming merge."""
    svc = PPRService(kappa=4, iterations=10, topk_tile=128)
    svc.register_graph("g", graph, formats=[20])
    verts = [11, 22, 33, 44]
    recs = svc.serve([PPRQuery("g", v, k=10, precision=20) for v in verts])
    fmt = format_for_bits(20)
    for r, v in zip(recs, verts):
        np.testing.assert_array_equal(r.vertices, per_vertex_oracle(graph, v, 10, fmt))


def test_service_deadline_flush_via_pump(graph):
    """A lone query launches only once its admission budget expires."""
    clk = FakeClock()
    svc = PPRService(kappa=8, iterations=5, max_wait=1.0, time_fn=clk)
    svc.register_graph("g", graph)
    assert not svc.submit(PPRQuery("g", 42, k=5)).done()
    assert svc.pump() == []                  # budget not yet spent
    clk.t = 1.5
    recs = svc.pump()
    assert len(recs) == 1 and recs[0].source == "wave"
    np.testing.assert_array_equal(
        recs[0].vertices, per_vertex_oracle(graph, 42, 5, iterations=5))


def test_service_serve_with_stale_submitted_query(graph):
    """A query queued via submit() before serve() rides along without crashing
    serve() or leaking into its results; its result lands in the cache."""
    svc = PPRService(kappa=4, iterations=5)
    svc.register_graph("g", graph)
    stale = PPRQuery("g", 250, k=5)
    assert not svc.submit(stale).done()
    verts = [1, 2, 3, 4]
    recs = svc.serve([PPRQuery("g", v, k=5) for v in verts])
    assert [r.query.vertex for r in recs] == verts
    # stale query was computed along the way
    assert svc.submit(stale).result().source == "cache"


def test_service_cache_immune_to_caller_mutation(graph):
    """Mutating a returned Recommendation must not poison later cache hits."""
    svc = PPRService(kappa=2, iterations=5)
    svc.register_graph("g", graph)
    q = PPRQuery("g", 50, k=5)
    first = svc.serve([q])[0]
    want = first.vertices.copy()
    first.vertices[:] = -1
    first.scores[:] = 0.0
    again = svc.serve([PPRQuery("g", 50, k=5)])[0]
    assert again.source == "cache"
    np.testing.assert_array_equal(again.vertices, want)


def test_service_rejects_unknown_graph_and_bad_vertex(graph):
    svc = PPRService()
    with pytest.raises(KeyError):
        svc.submit(PPRQuery("nope", 0))
    svc.register_graph("g", graph)
    with pytest.raises(ValueError):
        svc.submit(PPRQuery("g", graph.num_vertices))


def test_submit_validates_k_so_one_bad_query_cannot_poison_a_wave(graph):
    """Regression: k <= 0 or k >= V used to pass submit() and detonate inside
    the wave's top-K (k+1 > V), crashing pump() and losing every co-batched
    query's result.  Validation now happens at submit()."""
    V = graph.num_vertices
    svc = PPRService(kappa=4, iterations=5)
    svc.register_graph("g", graph)
    # three good queries enqueue...
    for v in (3, 17, 42):
        assert not svc.submit(PPRQuery("g", v, k=10)).done()
    # ...the bad ones are rejected at the door, in every invalid shape
    for bad_k in (0, -7, V, V + 3):
        with pytest.raises(ValueError, match="k"):
            svc.submit(PPRQuery("g", 5, k=bad_k))
    # the wave still launches and serves the good co-batched queries
    recs = svc.drain()
    assert len(recs) == 3 and all(r.source == "wave" for r in recs)
    # boundary: k = V-1 (every vertex but the query itself) is admissible
    svc2 = PPRService(kappa=1, iterations=2)
    svc2.register_graph("g", graph)
    rec = svc2.serve([PPRQuery("g", 0, k=V - 1)])[0]
    assert rec.vertices.shape == (V - 1,)
    assert 0 not in rec.vertices.tolist()


def test_normalize_precision_malformed_q_strings_fail_descriptively():
    """Regression: malformed "Q" strings used to raise the bare int() parse
    error instead of the intended "unknown precision spec"."""
    from repro.ppr_serving import normalize_precision
    for bad in ("Q1.25x", "Q.5", "Q1.", "Qx.y", "Q1.2.3", "Q0.5"):
        with pytest.raises(ValueError, match="unknown precision spec"):
            normalize_precision(bad)
    # well-formed specs still parse
    assert normalize_precision("Q1.25").name == "Q1.25"
    assert normalize_precision("Q2.14").name == "Q2.14"


def test_format_for_bits_rejects_degenerate_widths():
    """Regression: format_for_bits(0) used to fail with an opaque QFormat
    construction error rather than naming the bad bit-width."""
    for bits in (0, 1, -5):
        with pytest.raises(ValueError, match="bit-width"):
            format_for_bits(bits)
    assert format_for_bits(26).name == "Q1.25"


def test_service_mixed_graphs(graph):
    g2 = erdos_renyi(400, 2400, seed=9)
    svc = PPRService(kappa=2, iterations=8)
    svc.register_graph("a", graph)
    svc.register_graph("b", g2)
    qs = [PPRQuery("a", 5), PPRQuery("b", 5), PPRQuery("a", 6), PPRQuery("b", 6)]
    recs = svc.serve(qs)
    np.testing.assert_array_equal(
        recs[1].vertices, oracle_topk(
            run_ppr(g2, np.array([5]), PPRConfig(iterations=8))[0][:, 0], 10, 5))
    assert [r.query.graph for r in recs] == ["a", "b", "a", "b"]
