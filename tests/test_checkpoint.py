"""Checkpointing + fault tolerance: atomic roundtrip, resume-equivalence,
simulated node failure, keep-k GC."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_config
from repro.data import DataConfig, synthetic_batch
from repro.models import build_model
from repro.training import (
    AdamWConfig,
    FaultConfig,
    init_train_state,
    latest_step,
    make_train_step,
    restore,
    run_resumable,
    save,
    wait_pending,
)


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(smoke_config(get_config("gemma-2b")),
                              compute_dtype="float32", num_layers=2,
                              layer_pattern=(0, 0))
    api = build_model(cfg, remat=False)
    step = jax.jit(make_train_step(
        api.loss_fn, AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=40)))
    dcfg = DataConfig(seq_len=16, global_batch=4)
    return cfg, api, step, dcfg


def test_roundtrip(tmp_path, setup):
    cfg, api, step, dcfg = setup
    params = api.init_params(jax.random.PRNGKey(0))
    state = init_train_state(params)
    save(str(tmp_path), 7, state)
    assert latest_step(str(tmp_path)) == 7
    like = init_train_state(api.init_params(jax.random.PRNGKey(1)))
    back = restore(str(tmp_path), 7, like)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_k_gc(tmp_path, setup):
    cfg, api, step, dcfg = setup
    state = init_train_state(api.init_params(jax.random.PRNGKey(0)))
    for s in (1, 2, 3, 4, 5):
        save(str(tmp_path), s, state, keep=2)
    dirs = sorted(os.listdir(tmp_path))
    assert dirs == ["step_00000004", "step_00000005"]


def test_resume_equals_uninterrupted(tmp_path, setup):
    """Crash + restart reproduces the uninterrupted trajectory exactly
    (deterministic data pipeline + exact state restore)."""
    cfg, api, step, dcfg = setup

    def init_state():
        return init_train_state(api.init_params(jax.random.PRNGKey(0)))

    def batch_fn(s):
        return synthetic_batch(cfg, dcfg, s)

    # uninterrupted: 10 steps
    ref_state = init_state()
    for s in range(10):
        ref_state, _ = step(ref_state, batch_fn(s))

    # interrupted at step 6 (after a checkpoint at step 5), then resumed
    fault = FaultConfig(ckpt_dir=str(tmp_path / "ft"), save_every=5, max_steps=10)
    with pytest.raises(RuntimeError, match="simulated node failure"):
        run_resumable(fault, init_state, step, batch_fn, fail_at_step=6)
    wait_pending()
    assert latest_step(fault.ckpt_dir) == 5
    state, steps_run, _ = run_resumable(fault, init_state, step, batch_fn)
    assert steps_run == 5  # resumed from 5 → 10
    for a, b in zip(jax.tree.leaves(ref_state.params), jax.tree.leaves(state.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-7)


def test_atomic_no_tmp_left(tmp_path, setup):
    cfg, api, step, dcfg = setup
    state = init_train_state(api.init_params(jax.random.PRNGKey(0)))
    save(str(tmp_path), 1, state)
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


def test_restore_respects_shardings(tmp_path, setup):
    """Elastic-rescale path: restore onto explicit (1-device) shardings."""
    cfg, api, step, dcfg = setup
    params = api.init_params(jax.random.PRNGKey(0))
    save(str(tmp_path), 3, params)
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P

    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), params)
    back = restore(str(tmp_path), 3, params, shardings=sh)
    assert all(x.sharding == NamedSharding(mesh, P())
               for x in jax.tree.leaves(back))
