"""Per-architecture smoke tests: reduced same-family config, one forward +
train step on CPU, asserting shapes and no NaNs; decode consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config, list_archs, smoke_config
from repro.models import build_model
from repro.training import AdamWConfig, init_train_state, make_train_step

ARCHS = list_archs()


def _make_batch(cfg, b, s, rng, with_targets=True):
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)}
    if with_targets:
        batch["targets"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    if cfg.enc_len:
        batch["frames"] = jnp.asarray(
            rng.standard_normal((b, cfg.enc_len, cfg.d_model)), jnp.float32)
    if cfg.num_patches:
        batch["patches"] = jnp.asarray(
            rng.standard_normal((b, cfg.num_patches, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = smoke_config(get_config(arch))
    api = build_model(cfg, remat=True)
    params = api.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    b, s = 2, 16
    batch = _make_batch(cfg, b, s, rng)
    logits = api.forward(params, batch)
    exp_s = s + (cfg.num_patches or 0)
    assert logits.shape == (b, exp_s, cfg.padded_vocab)
    assert not np.isnan(np.asarray(logits, np.float32)).any()
    # one train step
    step = jax.jit(make_train_step(api.loss_fn, AdamWConfig(lr=1e-3, warmup_steps=1,
                                                            total_steps=10)))
    state = init_train_state(params)
    state, m = step(state, batch)
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"]))


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    """Teacher-forced forward == prefill+decode at the same position (f32)."""
    cfg = dataclasses.replace(smoke_config(get_config(arch)), compute_dtype="float32")
    api = build_model(cfg, remat=False)
    params = api.init_params(jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    b, s = 2, 12
    toks = rng.integers(0, cfg.vocab_size, (b, s + 1)).astype(np.int32)
    batch = _make_batch(cfg, b, s, rng, with_targets=False)
    batch["tokens"] = jnp.asarray(toks[:, :s])
    fb = dict(batch, tokens=jnp.asarray(toks))
    full = api.forward(params, fb)
    p = cfg.num_patches or 0
    cache = api.init_cache(b, 32)
    logits_p, cache = api.prefill(params, batch, cache)
    np.testing.assert_allclose(
        np.asarray(logits_p), np.asarray(full[:, p + s - 1]), rtol=2e-3, atol=2e-4)
    got, cache = api.decode_step(
        params, jnp.asarray(toks[:, s:]), jnp.asarray(p + s, jnp.int32), cache)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(full[:, p + s]), rtol=2e-3, atol=2e-4)


def test_param_counts_match_analytic():
    """config.param_count() tracks the real pytree within embedding padding."""
    for arch in ["gemma-2b", "mixtral-8x7b", "mamba2-1.3b"]:
        cfg = smoke_config(get_config(arch))
        api = build_model(cfg)
        shapes = jax.eval_shape(lambda k: api.init_params(k), jax.random.PRNGKey(0))
        real = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))
        approx = cfg.param_count()
        assert abs(real - approx) / real < 0.35, (arch, real, approx)


def test_local_window_attention_is_causal_and_local():
    """A token beyond the window cannot influence a query (gemma2 local layers)."""
    cfg = dataclasses.replace(
        smoke_config(get_config("gemma2-27b")), compute_dtype="float32")
    api = build_model(cfg, remat=False)
    params = api.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    s = 24
    toks = rng.integers(0, cfg.vocab_size, (1, s)).astype(np.int32)
    base = np.asarray(api.forward(params, {"tokens": jnp.asarray(toks)}))
    # causality: perturbing the last token must not change earlier logits
    toks2 = toks.copy()
    toks2[0, -1] = (toks2[0, -1] + 1) % cfg.vocab_size
    pert = np.asarray(api.forward(params, {"tokens": jnp.asarray(toks2)}))
    np.testing.assert_allclose(base[0, :-1], pert[0, :-1], atol=1e-5)


def test_shape_table_complete():
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k", "long_500k"}
    assert len(ARCHS) == 10


def test_find_segments_properties():
    """Segment compression reconstructs every pattern exactly."""
    from hypothesis import given, settings, strategies as st
    from repro.models.common import find_segments

    @given(st.lists(st.sampled_from([0, -1, 1024, 4096]), min_size=1, max_size=64))
    @settings(max_examples=200, deadline=None)
    def check(pattern):
        pattern = tuple(pattern)
        segs = find_segments(pattern)
        rebuilt = tuple(w for group, reps in segs for _ in range(reps) for w in group)
        assert rebuilt == pattern

    check()
    # known compressions
    from repro.configs import get_config
    assert find_segments(get_config("gemma2-27b").layer_pattern) == [((4096, 0), 23)]
    g3 = find_segments(get_config("gemma3-4b").layer_pattern)
    assert sum(len(g) * r for g, r in g3) == 34
