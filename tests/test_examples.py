"""Smoke coverage for ``examples/``: every script must at least compile, and
the quickstart (the README's front door, register → serve → apply_delta →
serve) must actually run end-to-end."""
import glob
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
EXAMPLES = sorted(glob.glob(os.path.join(ROOT, "examples", "*.py")))


def test_examples_exist():
    assert any(p.endswith("quickstart.py") for p in EXAMPLES)


@pytest.mark.parametrize("path", EXAMPLES, ids=[os.path.basename(p) for p in EXAMPLES])
def test_example_compiles(path):
    """Syntax-level smoke: a stale example must not rot silently."""
    with open(path) as f:
        compile(f.read(), path, "exec")


def test_quickstart_runs_end_to_end():
    """The quickstart is ported to the futures API: it must run end-to-end
    with DeprecationWarning escalated to an error, so a regression back onto
    the deprecated serve()/pump()/drain() wrappers fails loudly.  The filter
    is scoped to __main__ (where the wrappers' stacklevel attributes the
    warning) so unrelated jax/numpy deprecations cannot fail the smoke."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-W", "error::DeprecationWarning:__main__",
         os.path.join(ROOT, "examples", "quickstart.py")],
        capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "delta applied" in out.stdout
    assert "user  2000" in out.stdout          # the grown vertex was served
    assert "telemetry:" in out.stdout
