"""Mamba2 SSD: chunked algorithm vs naive step-by-step recurrence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.ssm import ssd_chunked


def naive_ssd(x, dt, A, B, C):
    """state_t = state·exp(dt_t A) + dt_t x_t ⊗ B_t;  y_t = C_t·state_t."""
    b, s, h, p = x.shape
    g, n = B.shape[-2], B.shape[-1]
    hpg = h // g
    state = np.zeros((b, h, p, n))
    ys = np.zeros_like(x)
    for t in range(s):
        decay = np.exp(dt[:, t] * A[None, :])                      # [b,h]
        Bh = np.repeat(B[:, t], hpg, axis=1)                       # [b,h,n]
        Ch = np.repeat(C[:, t], hpg, axis=1)
        state = state * decay[..., None, None] + \
            (dt[:, t][..., None] * x[:, t])[..., None] * Bh[:, :, None, :]
        ys[:, t] = np.einsum("bhpn,bhn->bhp", state, Ch)
    return ys, state


@pytest.mark.parametrize("s,chunk", [(8, 4), (16, 4), (32, 8), (12, 12)])
def test_chunked_matches_naive(s, chunk):
    rng = np.random.default_rng(s)
    b, h, p, n = 2, 4, 8, 16
    x = rng.standard_normal((b, s, h, p)).astype(np.float32)
    dt = rng.random((b, s, h)).astype(np.float32) * 0.5
    A = -rng.random(h).astype(np.float32)
    B = rng.standard_normal((b, s, 1, n)).astype(np.float32)
    C = rng.standard_normal((b, s, 1, n)).astype(np.float32)
    y, final = ssd_chunked(jnp.asarray(x), jnp.asarray(dt), jnp.asarray(A),
                           jnp.asarray(B), jnp.asarray(C), chunk)
    y_ref, final_ref = naive_ssd(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(final), final_ref, rtol=2e-4, atol=2e-4)


@given(st.integers(1, 4), st.integers(1, 3))
@settings(max_examples=8, deadline=None)
def test_chunked_property(batch, log_chunks):
    chunk = 4
    s = chunk * (2 ** log_chunks)
    rng = np.random.default_rng(batch * 10 + s)
    h, p, n = 2, 4, 8
    x = rng.standard_normal((batch, s, h, p)).astype(np.float32)
    dt = rng.random((batch, s, h)).astype(np.float32)
    A = -rng.random(h).astype(np.float32)
    B = rng.standard_normal((batch, s, 1, n)).astype(np.float32)
    C = rng.standard_normal((batch, s, 1, n)).astype(np.float32)
    y, _ = ssd_chunked(jnp.asarray(x), jnp.asarray(dt), jnp.asarray(A),
                       jnp.asarray(B), jnp.asarray(C), chunk)
    y_ref, _ = naive_ssd(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=5e-4, atol=5e-4)
