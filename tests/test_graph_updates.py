"""Dynamic graph updates: host-side merge equivalence vs from-scratch builds,
epoch-versioned apply_delta with scoped invalidation, incremental
requantization, warm-start seeding, the async prefetcher, and the mesh-sharded
delta path (subprocess, per run-book)."""
import os
import subprocess
import sys
import textwrap
from collections import Counter

import numpy as np
import pytest

from repro.core import COOGraph, format_for_bits, merge_edge_delta
from repro.graph_updates import (
    EdgeDelta,
    WarmStartStore,
    localized_delta,
    random_delta,
)
from repro.graphs import erdos_renyi, holme_kim_powerlaw
from repro.ppr_serving import PPRQuery, PPRService, PrefetchConfig, Prefetcher

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def graph():
    return holme_kim_powerlaw(400, m=4, seed=2)


def _oracle_merge(g: COOGraph, d: EdgeDelta) -> COOGraph:
    """Independent merge: edge multiset rebuild + from_edges from scratch."""
    c = Counter(zip(g.y.tolist(), g.x.tolist()))
    for s, t in zip(d.remove_src.tolist(), d.remove_dst.tolist()):
        c[(s, t)] -= 1
        assert c[(s, t)] >= 0, "oracle: removal of missing edge"
    for s, t in zip(d.add_src.tolist(), d.add_dst.tolist()):
        c[(s, t)] += 1
    src, dst = [], []
    for (s, t), n in c.items():
        src += [s] * n
        dst += [t] * n
    v = d.new_num_vertices or g.num_vertices
    return COOGraph.from_edges(np.asarray(src, np.int64),
                               np.asarray(dst, np.int64), v)


def assert_graphs_bit_identical(a: COOGraph, b: COOGraph):
    assert a.num_vertices == b.num_vertices
    np.testing.assert_array_equal(a.x, b.x)
    np.testing.assert_array_equal(a.y, b.y)
    # float32 val compared bitwise: 1/outdeg must reproduce exactly
    np.testing.assert_array_equal(a.val.view(np.uint32), b.val.view(np.uint32))
    np.testing.assert_array_equal(a.dangling, b.dangling)


# ---------------------------------------------------------------------------
# merge_edge_delta: bit-identical to a from-scratch from_edges build
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed,grow", [(0, 0), (1, 0), (2, 3), (3, 7)])
def test_merge_matches_from_scratch_build(graph, seed, grow):
    rng = np.random.default_rng(seed)
    d = random_delta(graph, rng, n_add=25, n_remove=12, grow=grow)
    merged, info = d.apply(graph)
    assert_graphs_bit_identical(merged, _oracle_merge(graph, d))
    # info maps surviving edges old→new consistently
    np.testing.assert_array_equal(merged.x[info.new_pos_of_kept],
                                  graph.x[info.kept_old_idx])
    np.testing.assert_array_equal(merged.y[info.new_pos_of_kept],
                                  graph.y[info.kept_old_idx])
    # unchanged entries kept their val bits without renormalization
    kept_unchanged = info.new_pos_of_kept[
        ~info.changed_mask[info.new_pos_of_kept]]
    assert kept_unchanged.size > 0
    # every added edge's slot is marked changed
    assert info.changed_mask.sum() >= d.num_added


def test_merge_removal_can_empty_a_source_to_dangling():
    g = COOGraph.from_edges(np.array([0, 0, 1]), np.array([1, 2, 2]), 4)
    d = EdgeDelta(remove_src=[0, 0], remove_dst=[1, 2])
    merged, _ = d.apply(g)
    assert merged.dangling[0]
    assert_graphs_bit_identical(merged, _oracle_merge(g, d))


def test_merge_multi_edge_multiplicity():
    src = np.array([0, 0, 0, 1])
    dst = np.array([1, 1, 2, 0])
    g = COOGraph.from_edges(src, dst, 3)
    merged, _ = EdgeDelta(remove_src=[0], remove_dst=[1]).apply(g)
    assert merged.num_edges == 3                  # one instance removed
    with pytest.raises(ValueError, match="more times than it exists"):
        EdgeDelta(remove_src=[0, 0, 0], remove_dst=[1, 1, 1]).apply(g)


def test_merge_validation_errors(graph):
    v = graph.num_vertices
    with pytest.raises(ValueError, match="shrinks"):
        merge_edge_delta(graph, [0], [1], [], [], new_num_vertices=v - 1)
    with pytest.raises(ValueError, match="out of range"):
        EdgeDelta(add_src=[v + 5], add_dst=[0]).apply(graph)
    with pytest.raises(ValueError, match="out of range"):
        EdgeDelta(remove_src=[v], remove_dst=[0]).apply(graph)
    with pytest.raises(ValueError, match="length mismatch"):
        EdgeDelta(add_src=[1, 2], add_dst=[3])


def test_growth_only_delta_adds_dangling_vertices(graph):
    d = EdgeDelta(new_num_vertices=graph.num_vertices + 5)
    merged, info = d.apply(graph)
    assert merged.num_vertices == graph.num_vertices + 5
    assert merged.dangling[-5:].all()
    assert merged.num_edges == graph.num_edges
    assert not info.changed_mask.any()


def test_affected_frontier_touched_plus_in_neighbors():
    # 0→1, 2→1, 3→2: touching vertex 1 must pull in-neighbors {0, 2}
    g = COOGraph.from_edges(np.array([0, 2, 3]), np.array([1, 1, 2]), 5)
    d = EdgeDelta(add_src=[1], add_dst=[4])
    np.testing.assert_array_equal(d.affected_frontier(g), [0, 1, 2, 4])


# ---------------------------------------------------------------------------
# apply_delta: cold-query equivalence vs full re-registration + recompute
# ---------------------------------------------------------------------------
def _raw_scores(rec, fmt):
    raw = np.asarray(rec.scores) * fmt.scale
    out = raw.round().astype(np.uint64)
    np.testing.assert_allclose(raw, out, atol=0)     # exactly representable
    return out


@pytest.mark.parametrize("grow", [0, 3])
def test_apply_delta_cold_query_equivalence_single_device(graph, grow):
    """Acceptance: apply_delta + cold query == fresh registration of the
    merged graph — bit-identical raw uint32 on the fixed path, exact float."""
    rng = np.random.default_rng(7)
    d = random_delta(graph, rng, n_add=18, n_remove=9, grow=grow)
    fmt = format_for_bits(26)

    svc = PPRService(kappa=4, iterations=8)
    svc.register_graph("g", graph, formats=[26])
    svc.serve([PPRQuery("g", v, k=10, precision=26) for v in (1, 5, 9, 13)])
    svc.apply_delta("g", d)

    merged, _ = d.apply(graph)
    fresh = PPRService(kappa=4, iterations=8)
    fresh.register_graph("g", merged, formats=[26])

    # device-side derived state is bit-identical to a from-scratch build
    rg, rf = svc._graphs["g"], fresh._graphs["g"]
    np.testing.assert_array_equal(np.asarray(rg.quantized(fmt)),
                                  np.asarray(rf.quantized(fmt)))
    np.testing.assert_array_equal(np.asarray(rg.val), np.asarray(rf.val))
    np.testing.assert_array_equal(np.asarray(rg.dangling),
                                  np.asarray(rf.dangling))

    probe = [2, 6, graph.num_vertices - 1]
    if grow:
        probe.append(graph.num_vertices + grow - 1)   # a grown vertex serves
    for v in probe:
        a = svc.serve([PPRQuery("g", v, k=10, precision=26)])[0]
        b = fresh.serve([PPRQuery("g", v, k=10, precision=26)])[0]
        assert a.source == "wave"                     # cold: no stale cache
        np.testing.assert_array_equal(a.vertices, b.vertices)
        np.testing.assert_array_equal(_raw_scores(a, fmt), _raw_scores(b, fmt))
        af = svc.serve([PPRQuery("g", v, k=10)])[0]
        bf = fresh.serve([PPRQuery("g", v, k=10)])[0]
        np.testing.assert_array_equal(af.vertices, bf.vertices)
        np.testing.assert_array_equal(af.scores, bf.scores)


def test_incremental_requantization_all_formats(graph):
    """Only changed val entries go through the quantizer, yet every
    pre-registered format's raw array equals a from-scratch quantization."""
    rng = np.random.default_rng(3)
    svc = PPRService(kappa=2, iterations=2)
    svc.register_graph("g", graph, formats=[20, 26])
    d = random_delta(graph, rng, n_add=30, n_remove=15)
    svc.apply_delta("g", d)
    merged, _ = d.apply(graph)
    rg = svc._graphs["g"]
    for bits in (20, 26):
        fmt = format_for_bits(bits)
        np.testing.assert_array_equal(rg._quantized_host[fmt],
                                      merged.quantized_val(fmt))


def test_epoch_bumps_and_cache_keys_do_not_alias(graph):
    svc = PPRService(kappa=1, iterations=4)
    svc.register_graph("g", graph)
    assert svc._graphs["g"].epoch == 0
    k0 = svc._cache_key(PPRQuery("g", 1, k=5), "f32")
    svc.apply_delta("g", EdgeDelta(add_src=[1], add_dst=[2]))
    assert svc._graphs["g"].epoch == 1
    k1 = svc._cache_key(PPRQuery("g", 1, k=5), "f32")
    assert k0 != k1 and k0[1] == 0 and k1[1] == 1


# ---------------------------------------------------------------------------
# scoped invalidation: frontier entries drop, the rest keep serving
# ---------------------------------------------------------------------------
def test_scoped_invalidation_drops_strictly_fewer_than_whole_graph(graph):
    svc = PPRService(kappa=8, iterations=5)
    svc.register_graph("g", graph, formats=[26])
    rng = np.random.default_rng(0)
    verts = rng.choice(graph.num_vertices, size=32, replace=False)
    svc.serve([PPRQuery("g", int(v), k=10, precision=26) for v in verts])
    cached = len(svc.cache)
    assert cached == 32
    d = localized_delta(graph, rng, n_add=2, n_remove=1)
    frontier = set(int(v) for v in d.affected_frontier(graph))
    report = svc.apply_delta("g", d)
    assert report["cache_dropped"] < cached            # strictly fewer
    assert report["cache_dropped"] + report["cache_retained"] == cached
    t = svc.telemetry_summary()
    assert t["deltas_applied"] == 1
    assert t["scoped_cache_retained"] == report["cache_retained"]
    # retained entries serve from cache at the new epoch; frontier recomputes
    hits = waves = 0
    for v in verts:
        rec = svc.serve([PPRQuery("g", int(v), k=10, precision=26)])[0]
        if int(v) in frontier:
            assert rec.source == "wave"
            waves += 1
        else:
            assert rec.source == "cache"
            hits += 1
    assert hits == report["cache_retained"]
    assert waves == report["cache_dropped"]


def test_scoped_purge_of_pending_queries(graph):
    """Pending frontier queries drop; survivors move to the new epoch's wave
    keys with their admission budgets intact and launch on the new graph."""
    svc = PPRService(kappa=8, iterations=4)
    svc.register_graph("g", graph)
    d = localized_delta(graph, np.random.default_rng(1), n_add=2, n_remove=1)
    frontier = set(int(v) for v in d.affected_frontier(graph))
    in_f = sorted(frontier)[0]
    out_f = next(v for v in range(graph.num_vertices) if v not in frontier)
    fut_in = svc.submit(PPRQuery("g", in_f, k=5))
    fut_out = svc.submit(PPRQuery("g", out_f, k=5))
    assert not fut_in.done() and not fut_out.done()
    report = svc.apply_delta("g", d)
    assert report["pending_dropped"] == 1
    assert report["pending_requeued"] == 1
    assert svc.scheduler.pending() == 1
    # the frontier future is rejected descriptively; the survivor stays pending
    assert fut_in.done() and fut_in.exception() is not None
    assert not fut_out.done()
    recs = svc.drain()
    assert len(recs) == 1 and recs[0].query.vertex == out_f
    assert fut_out.result() is recs[0]
    # the survivor computed on the NEW topology and cached at the new epoch
    assert svc.serve([PPRQuery("g", out_f, k=5)])[0].source == "cache"


def test_autotune_windows_decay_not_reset_on_delta(graph):
    svc = PPRService(kappa=2, iterations=3)
    svc.register_graph("g", graph)
    est = svc.controller.estimator
    for _ in range(8):
        est.record("g", "Q1.25", 0.97)
    svc.apply_delta("g", EdgeDelta(add_src=[1], add_dst=[2]))
    assert est.samples("g", "Q1.25") == 4          # halved, newest kept
    svc.register_graph("g", graph)                 # re-registration still resets
    assert est.samples("g", "Q1.25") == 0


# ---------------------------------------------------------------------------
# warm start
# ---------------------------------------------------------------------------
def test_warm_start_store_lru_and_grow():
    ws = WarmStartStore(capacity_per_graph=2)
    ws.put("g", 1, "f32", np.ones(4, np.float32))
    ws.put("g", 2, "f32", np.ones(4, np.float32))
    assert ws.get("g", 1, "f32") is not None       # refresh 1 → 2 oldest
    ws.put("g", 3, "f32", np.ones(4, np.float32))
    assert ws.get("g", 2, "f32") is None
    assert ws.stats()["evictions"] == 1
    ws.grow("g", 6)
    assert ws.get("g", 1, "f32").shape == (6,)
    assert ws.get("g", 1, "f32")[4:].sum() == 0
    assert ws.drop_graph("g") == 2 and len(ws) == 0


def test_warm_start_saves_iterations_after_delta(graph):
    svc = PPRService(kappa=2, iterations=60, early_exit=True, warm_start=True)
    svc.register_graph("g", graph, formats=[26])
    verts = [3, 9]
    svc.serve([PPRQuery("g", v, k=5, precision=26) for v in verts])
    t0 = svc.telemetry_summary()
    assert t0["warm_start_waves"] == 0             # first wave is cold
    d = EdgeDelta(add_src=verts, add_dst=[50, 60])
    svc.apply_delta("g", d)
    recs = svc.serve([PPRQuery("g", v, k=5, precision=26) for v in verts])
    assert all(r.source == "wave" for r in recs)   # frontier invalidated them
    t1 = svc.telemetry_summary()
    assert t1["warm_start_waves"] == 1
    assert t1["warm_start_columns"] == 2
    # warm results match a cold service on the same merged graph: identical
    # ranking; scores within a few LSBs of quantization noise (the absorbing
    # state reached from a warm seed may differ from the cold trajectory's by
    # trailing bits — the shadow estimator keeps scoring either)
    merged, _ = d.apply(graph)
    cold = PPRService(kappa=2, iterations=60, early_exit=True)
    cold.register_graph("g", merged, formats=[26])
    fmt = format_for_bits(26)
    for r, rc in zip(recs, cold.serve(
            [PPRQuery("g", v, k=5, precision=26) for v in verts])):
        np.testing.assert_array_equal(r.vertices, rc.vertices)
        np.testing.assert_allclose(r.scores, rc.scores, rtol=0,
                                   atol=4 * fmt.resolution)


def test_warm_start_disabled_keeps_cold_key_and_no_store(graph):
    svc = PPRService(kappa=1, iterations=4)
    assert svc._warm is None
    key = svc._cache_key(PPRQuery("g", 0, k=5), "f32")
    warm = PPRService(kappa=1, iterations=4, warm_start=True)
    assert key != warm._cache_key(PPRQuery("g", 0, k=5), "f32")


# ---------------------------------------------------------------------------
# prefetcher (satellite: ROADMAP async-prefetch follow-on)
# ---------------------------------------------------------------------------
def test_prefetch_warms_hot_vertices_on_idle_pump(graph):
    svc = PPRService(kappa=2, iterations=4,
                     prefetch=PrefetchConfig(top_n=4, k=5, max_per_pump=4,
                                             min_count=2))
    svc.register_graph("g", graph, formats=[26])
    for _ in range(2):
        svc.serve([PPRQuery("g", 3, k=5, precision="auto"),
                   PPRQuery("g", 7, k=5, precision="auto")])
    # hot vertices are already cached by real traffic → idle pump issues none
    # for them, and returns no synthetic recommendations either way
    before = svc.telemetry_summary()["prefetch_issued"]
    assert svc.pump() == []
    # cold-but-hot vertex: make 11 hot via traffic, then invalidate its entry
    for _ in range(2):
        svc.serve([PPRQuery("g", 11, k=5, precision="auto")])
    key = [k for k in svc.cache._store if k[2] == 11]
    assert key
    svc.cache.invalidate(lambda k: k[2] == 11)
    assert svc.pump() == []                        # idle pump prefetches it
    t = svc.telemetry_summary()
    assert t["prefetch_issued"] > before
    hits0 = t["lru_hits"]
    rec = svc.serve([PPRQuery("g", 11, k=5, precision="auto")])[0]
    assert rec.source == "cache"                   # warmed-hit through lru_*
    assert svc.telemetry_summary()["lru_hits"] == hits0 + 1


def test_prefetch_rewarms_delta_invalidated_hot_vertices(graph):
    svc = PPRService(kappa=2, iterations=4,
                     prefetch=PrefetchConfig(top_n=2, k=5, max_per_pump=4,
                                             min_count=2))
    svc.register_graph("g", graph, formats=[26])
    for _ in range(3):
        svc.serve([PPRQuery("g", 3, k=5, precision="auto")])
    d = EdgeDelta(add_src=[3], add_dst=[200])      # 3 is in its own frontier
    report = svc.apply_delta("g", d)
    assert report["cache_dropped"] >= 1
    assert svc.telemetry_summary()["prefetch_rewarms_queued"] == 1
    assert svc.pump() == []                        # re-warm fires, returns none
    rec = svc.serve([PPRQuery("g", 3, k=5, precision="auto")])[0]
    assert rec.source == "cache"


def test_prefetch_rewarms_explicit_precision_traffic_under_its_own_key(graph):
    """Regression: re-warm used to issue only at the controller's resolved
    rung, so hot entries from explicit-precision traffic were re-warmed under
    a key real traffic never probes.  The prefetcher now uses the vertex's
    last real (k, precision)."""
    svc = PPRService(kappa=2, iterations=4,
                     prefetch=PrefetchConfig(top_n=2, k=10, max_per_pump=4,
                                             min_count=2))
    svc.register_graph("g", graph, formats=[20])
    for _ in range(3):                                 # hot at explicit Q1.19
        svc.serve([PPRQuery("g", 3, k=7, precision=20)])
    svc.apply_delta("g", EdgeDelta(add_src=[3], add_dst=[200]))
    assert svc.pump() == []                            # idle pump re-warms
    rec = svc.serve([PPRQuery("g", 3, k=7, precision=20)])[0]
    assert rec.source == "cache" and rec.precision == "Q1.19"


def test_prefetch_rewarm_queue_survives_max_per_pump(graph):
    """Regression: candidates() used to clear the whole re-warm queue even
    when the per-pump cap let only a few issue — the overflow now waits for
    the next idle pump instead of being lost."""
    svc = PPRService(kappa=2, iterations=4,
                     prefetch=PrefetchConfig(top_n=2, k=5, max_per_pump=2,
                                             min_count=1))
    svc.register_graph("g", graph, formats=[26])
    hot = [3, 7, 11, 15]
    for v in hot:
        svc.serve([PPRQuery("g", v, k=5, precision="auto")])
    svc.prefetcher.note_invalidated("g", hot)
    svc.cache.invalidate(lambda k: True)
    assert svc.pump() == []                            # warms first 2 only
    assert svc.telemetry_summary()["prefetch_rewarms_pending"] == 2
    assert svc.pump() == []                            # next idle pump: rest
    assert svc.telemetry_summary()["prefetch_rewarms_pending"] == 0
    for v in hot:
        assert svc.serve([PPRQuery("g", v, k=5, precision="auto")])[0] \
            .source == "cache"


def test_prefetch_results_never_returned_but_real_riders_are(graph):
    """A real pending query sharing the prefetch wave's key rides along and
    IS returned; the synthetic queries are not."""
    svc = PPRService(kappa=4, iterations=4, max_wait=100.0,
                     prefetch=PrefetchConfig(top_n=2, k=5, max_per_pump=2,
                                             min_count=1))
    svc.register_graph("g", graph, formats=[26])
    svc.serve([PPRQuery("g", 5, k=5, precision="auto")])   # makes 5 "hot"
    svc.cache.invalidate(lambda k: True)
    # a real query waits in the queue (max_wait keeps it pending)...
    assert not svc.submit(PPRQuery("g", 5, k=5, precision="auto")).done()
    # ...until the idle pump's prefetch flush takes its key's queue along
    recs = svc.pump()
    assert [r.query.prefetch for r in recs] == [False]
    assert recs[0].query.vertex == 5 and recs[0].source == "wave"


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_prefetcher_demand_decay_unit_clock_injected():
    """counts halve per half-life (clock injected); fully-cooled entries are
    pruned; no configured half-life means the legacy cumulative counts."""
    clk = FakeClock()
    p = Prefetcher(PrefetchConfig(half_life_s=10.0), time_fn=clk)
    counts = {1: 8.0, 2: 0.08}
    last_seen = {1: (10, "Q1.25"), 2: (5, "f32")}
    p.decay_demand("g", counts, last_seen=last_seen)   # no time elapsed
    assert counts == {1: 8.0, 2: 0.08}
    clk.t = 10.0
    p.decay_demand("g", counts, last_seen=last_seen)   # exactly one half-life
    assert counts[1] == pytest.approx(4.0)
    assert 2 not in counts                 # cooled below the floor → pruned
    assert last_seen == {1: (10, "Q1.25")}  # (k, pkey) map pruned in lockstep
    clk.t = 30.0
    p.decay_demand("g", counts)            # two more half-lives
    assert counts[1] == pytest.approx(1.0)
    # out-of-order `now` never rewinds the stamp and over-ages later decays
    p.decay_demand("g", counts, now=5.0)
    assert counts[1] == pytest.approx(1.0)
    p.decay_demand("g", counts, now=40.0)  # one half-life since t=30, not 35
    assert counts[1] == pytest.approx(0.5)
    # a graph never decayed before ages from the prefetcher's construction
    # stamp, so the FIRST idle poll after a quiet stretch already decays
    clk.t = 0.0
    cold = Prefetcher(PrefetchConfig(half_life_s=10.0), time_fn=clk)
    stale = {7: 8.0}
    clk.t = 30.0
    cold.decay_demand("h", stale)          # three half-lives since construction
    assert stale[7] == pytest.approx(1.0)
    # decay state is per graph: "h" ages from p's construction stamp (t=0 →
    # clk.t=30, three half-lives), not from "g"'s later stamp at t=40
    other = {5: 8.0}
    p.decay_demand("h", other)
    assert other == {5: pytest.approx(1.0)}
    p.drop_graph("g")
    assert "g" not in p._last_decay
    # no half-life configured → decay is a no-op
    legacy = Prefetcher(PrefetchConfig(), time_fn=clk)
    c = {1: 5}
    legacy.decay_demand("g", c)
    clk.t = 1e9
    legacy.decay_demand("g", c)
    assert c == {1: 5}
    with pytest.raises(ValueError, match="half_life_s"):
        PrefetchConfig(half_life_s=0.0)


def test_prefetch_demand_decay_ages_out_stale_hotness(graph):
    """Satellite: a vertex hot long ago must stop ranking hot — under a
    half-life, idle polls decay the demand counts before ranking, so stale
    traffic no longer earns prefetch compute."""
    clk = FakeClock()
    svc = PPRService(kappa=2, iterations=4, time_fn=clk,
                     prefetch=PrefetchConfig(top_n=4, k=5, max_per_pump=4,
                                             min_count=2, half_life_s=10.0))
    svc.register_graph("g", graph, formats=[26])
    for _ in range(2):                     # vertex 3 becomes hot (count 2)
        svc.submit(PPRQuery("g", 3, k=5, precision="auto")).result()
    svc.cache.invalidate(lambda k: True)
    assert svc.poll() == 1                 # idle poll at t=0: 3 is prefetched
    issued = svc.telemetry_summary()["prefetch_issued"]
    assert issued == 1
    # 20 half-lives later the old demand has fully cooled and been pruned
    clk.t = 200.0
    svc.cache.invalidate(lambda k: True)
    assert svc.poll() == 0                 # nothing hot → nothing issued
    assert svc.telemetry_summary()["prefetch_issued"] == issued
    assert svc.telemetry.query_vertex_counts["g"] == {}
    # fresh traffic re-heats under the decayed regime
    for _ in range(2):
        svc.submit(PPRQuery("g", 7, k=5, precision="auto")).result()
    svc.cache.invalidate(lambda k: True)
    assert svc.poll() == 1                 # recent hotness still prefetches
    assert svc.telemetry_summary()["prefetch_issued"] == issued + 1


# ---------------------------------------------------------------------------
# mesh-sharded delta path (subprocess with forced host devices, per run-book)
# ---------------------------------------------------------------------------
def _run(script: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


def test_sharded_apply_delta_equivalence():
    """Acceptance: delta on a 4-shard mesh graph with non-divisible V — both
    the incremental-bucket path (no growth) and the full-repartition path
    (vertex growth changes the ceil-division layout) serve bit-identical to a
    fresh sharded registration AND to single-device serving."""
    print(_run("""
        import numpy as np, jax
        from repro.graphs import holme_kim_powerlaw
        from repro.graph_updates import random_delta
        from repro.ppr_serving import PPRQuery, PPRService

        g = holme_kim_powerlaw(203, m=4, seed=2)        # 203 % 4 != 0
        rng = np.random.default_rng(1)
        mesh = jax.make_mesh((4,), ("shard",))

        for grow, label in ((0, "incremental-bucket"), (5, "full-repartition")):
            d = random_delta(g, rng, n_add=15, n_remove=6, grow=grow)
            svc = PPRService(kappa=4, iterations=8, cache_capacity=0)
            svc.register_graph("g", g, formats=[26], mesh=mesh)
            svc.serve([PPRQuery("g", 9, k=8, precision=26)])
            svc.apply_delta("g", d)
            merged, _ = d.apply(g)
            fresh = PPRService(kappa=4, iterations=8, cache_capacity=0)
            fresh.register_graph("g", merged, formats=[26], mesh=mesh)
            single = PPRService(kappa=4, iterations=8, cache_capacity=0)
            single.register_graph("g", merged, formats=[26])
            probe = [0, 9, 150, 202] + ([202 + grow] if grow else [])
            for v in probe:
                qs = [PPRQuery("g", v, k=8, precision=26)]
                a, b, c = (s.serve(qs)[0] for s in (svc, fresh, single))
                np.testing.assert_array_equal(a.vertices, b.vertices)
                np.testing.assert_array_equal(a.scores, b.scores)
                np.testing.assert_array_equal(a.scores, c.scores)
                qf = [PPRQuery("g", v, k=8)]
                af, bf = (s.serve(qf)[0] for s in (svc, fresh))
                np.testing.assert_array_equal(af.vertices, bf.vertices)
                np.testing.assert_array_equal(af.scores, bf.scores)
            print(label, "OK")
    """))
