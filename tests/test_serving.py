"""Serving engine: batched greedy decode == manual step-by-step decode."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_config
from repro.models import build_model
from repro.serving import Request, ServingEngine


@pytest.fixture(scope="module")
def model():
    cfg = dataclasses.replace(smoke_config(get_config("gemma-2b")),
                              compute_dtype="float32")
    api = build_model(cfg, remat=False)
    params = api.init_params(jax.random.PRNGKey(0))
    return cfg, api, params


def _manual_greedy(api, params, prompt, n_new, max_len):
    cache = api.init_cache(1, max_len)
    logits, cache = api.prefill(params, {"tokens": jnp.asarray(prompt[None])}, cache)
    toks = []
    cur = int(jnp.argmax(logits[0]))
    pos = prompt.shape[0]
    for _ in range(n_new):
        toks.append(cur)
        logits, cache = api.decode_step(
            params, jnp.asarray([[cur]], jnp.int32), jnp.asarray(pos, jnp.int32), cache)
        cur = int(jnp.argmax(logits[0]))
        pos += 1
    return toks


def test_engine_matches_manual(model):
    cfg, api, params = model
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 8).astype(np.int32) for _ in range(3)]
    engine = ServingEngine(api, params, batch_size=3, max_len=64)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=5) for i, p in enumerate(prompts)]
    results = engine.serve(reqs)
    for i, p in enumerate(prompts):
        manual = _manual_greedy(api, params, p, 5, 64)
        assert results[i] == manual, (i, results[i], manual)


def test_engine_waves(model):
    """More requests than slots → multiple admission waves, all served."""
    cfg, api, params = model
    rng = np.random.default_rng(1)
    engine = ServingEngine(api, params, batch_size=2, max_len=64)
    reqs = [Request(uid=i, prompt=rng.integers(0, cfg.vocab_size, 6).astype(np.int32),
                    max_new_tokens=3) for i in range(5)]
    results = engine.serve(reqs)
    assert set(results) == set(range(5))
    assert all(len(v) == 3 for v in results.values())
