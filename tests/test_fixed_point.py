"""Property tests for the Qm.f fixed-point datapath (hypothesis)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.fixed_point import PAPER_FORMATS, QFormat, format_for_bits

FORMATS = list(PAPER_FORMATS.values()) + [QFormat(2, 14), QFormat(1, 30), QFormat(4, 8)]


@st.composite
def fmt_and_raws(draw, n=64):
    fmt = draw(st.sampled_from(FORMATS))
    raws = draw(st.lists(st.integers(0, fmt.max_raw), min_size=n, max_size=n))
    return fmt, np.array(raws, np.uint32)


@given(fmt_and_raws())
@settings(max_examples=50, deadline=None)
def test_mul_matches_bigint(data):
    """The 16-bit-limb uint32 multiply == exact Python bigint (a·b) >> f."""
    fmt, raws = data
    a, b = raws[: len(raws) // 2], raws[len(raws) // 2:]
    got = np.asarray(fmt.mul(jnp.asarray(a), jnp.asarray(b)))
    want = [(int(x) * int(y)) >> fmt.frac_bits for x, y in zip(a, b)]
    assert [int(g) for g in got] == want


@given(fmt_and_raws())
@settings(max_examples=30, deadline=None)
def test_add_saturates(data):
    fmt, raws = data
    a, b = raws[: len(raws) // 2], raws[len(raws) // 2:]
    got = np.asarray(fmt.add(jnp.asarray(a), jnp.asarray(b)))
    want = np.minimum(a.astype(np.uint64) + b.astype(np.uint64), fmt.max_raw)
    assert (got == want.astype(np.uint32)).all()


@given(st.lists(st.floats(0.0, 1.999, allow_nan=False), min_size=8, max_size=8),
       st.sampled_from([f for f in FORMATS if f.frac_bits <= 23]))
@settings(max_examples=50, deadline=None)
def test_f32_grid_matches_integer_path(vals, fmt):
    """quantize_f32 == from_float→to_float while the grid fits the f32 mantissa."""
    x = np.array(vals, np.float32)
    via_int = np.asarray(fmt.to_float(fmt.from_float(x)))
    via_f32 = np.asarray(fmt.quantize_f32(jnp.asarray(x)))
    assert np.array_equal(via_int, via_f32)


@given(st.floats(0.0, 1.999), st.sampled_from(FORMATS))
@settings(max_examples=100, deadline=None)
def test_truncation_towards_zero(v, fmt):
    """Quantization never rounds up (the paper's truncation policy).
    Checked in exact integer→f64 math (to_float's f32 cast may round)."""
    import jax
    with jax.experimental.enable_x64():
        raw = int(np.asarray(fmt.from_float(np.float64(v))))
    q = raw / fmt.scale   # exact for ≤53-bit significands
    assert q <= v + 1e-12
    assert v - q < fmt.resolution + 1e-12 or raw == fmt.max_raw


def test_paper_format_table():
    assert format_for_bits(26).frac_bits == 25
    assert format_for_bits(20).frac_bits == 19
    assert format_for_bits(26).name == "Q1.25"
    with pytest.raises(ValueError):
        QFormat(1, 32)  # > 32 bits


def test_mul_extremes():
    fmt = PAPER_FORMATS["Q1.25"]
    m = fmt.max_raw
    got = int(np.asarray(fmt.mul(jnp.asarray(np.uint32(m)), jnp.asarray(np.uint32(m)))))
    assert got == (m * m) >> fmt.frac_bits
