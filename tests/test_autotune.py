"""Adaptive-precision subsystem (repro.autotune): estimator windows, controller
hysteresis, early-exit convergence (bit-identity + savings), shadow-sampling
determinism, and the serving-layer integration (auto resolution, cache
invalidation on re-registration, cache-key numerics)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.autotune import (
    AutotuneConfig,
    ConvergencePolicy,
    PrecisionController,
    QualityEstimator,
    ShadowConfig,
    run_until_converged,
    score_quality,
)
from repro.core import format_for_bits
from repro.core.ppr import make_ppr_fixed_step, personalization_matrix_fixed
from repro.graphs import erdos_renyi, holme_kim_powerlaw
from repro.ppr_serving import FLOAT_KEY, PPRQuery, PPRService


@pytest.fixture(scope="module")
def graph():
    return holme_kim_powerlaw(300, m=3, seed=1)


# ---------------------------------------------------------------------------
# quality estimator
# ---------------------------------------------------------------------------
def test_estimator_window_mean_and_abstention():
    est = QualityEstimator(ShadowConfig(window=4, min_samples=3))
    est.record("g", "Q1.25", 0.9)
    est.record("g", "Q1.25", 1.0)
    assert est.estimate("g", "Q1.25") is None        # window too thin to act on
    est.record("g", "Q1.25", 0.8)
    assert abs(est.estimate("g", "Q1.25") - 0.9) < 1e-12
    for _ in range(4):                               # slide the old scores out
        est.record("g", "Q1.25", 1.0)
    assert est.estimate("g", "Q1.25") == 1.0
    assert est.estimate("g", "Q1.19") is None        # untouched format
    est.forget_graph("g")
    assert est.estimate("g", "Q1.25") is None


def test_shadow_sampling_deterministic_under_seed():
    a = QualityEstimator(ShadowConfig(sample_fraction=0.5, seed=7))
    b = QualityEstimator(ShadowConfig(sample_fraction=0.5, seed=7))
    seq_a = [a.should_sample() for _ in range(200)]
    seq_b = [b.should_sample() for _ in range(200)]
    assert seq_a == seq_b
    assert any(seq_a) and not all(seq_a)             # actually probabilistic
    c = QualityEstimator(ShadowConfig(sample_fraction=0.5, seed=8))
    assert [c.should_sample() for _ in range(200)] != seq_a


def test_score_quality_perfect_and_degraded():
    rng = np.random.default_rng(0)
    ref = rng.random(400)
    assert score_quality(ref, ref, metric="ndcg", k=50) == 1.0
    assert score_quality(ref, ref, metric="precision", k=50) == 1.0
    noisy = ref + rng.normal(0, 0.5, 400)
    assert score_quality(noisy, ref, metric="ndcg", k=50) < 1.0


# ---------------------------------------------------------------------------
# precision controller: ladder + hysteresis
# ---------------------------------------------------------------------------
def _controller(window=1, **kw):
    cfg = AutotuneConfig(shadow=ShadowConfig(min_samples=1, window=window), **kw)
    return PrecisionController(cfg)


def test_controller_starts_at_widest_fixed_format():
    ctl = _controller()
    fmt = ctl.resolve("g", 0.95)
    assert fmt is not None and fmt.name == "Q1.25"   # fixed, never float, day one


def test_controller_demotes_to_float_after_patience():
    ctl = _controller(demote_patience=2)
    ctl.observe_quality("g", "Q1.25", 0.5, target=0.95)
    assert ctl.resolve("g", 0.95).name == "Q1.25"    # one bad window: hold
    ctl.observe_quality("g", "Q1.25", 0.5, target=0.95)
    assert ctl.resolve("g", 0.95) is None            # second: float32 fallback
    assert ctl.demotions == 1


def test_controller_promotes_to_narrower_after_patience():
    ctl = _controller(promote_patience=3)
    for i in range(3):
        assert ctl.resolve("g", 0.9).name == "Q1.25"
        ctl.observe_quality("g", "Q1.25", 1.0, target=0.9)
    assert ctl.resolve("g", 0.9).name == "Q1.23"     # next-cheaper rung
    assert ctl.promotions == 1


def test_controller_hysteresis_no_thrash_on_alternating_windows():
    """window=1 makes each observation a window estimate; alternating
    good/bad estimates must never move the rung in either direction."""
    ctl = _controller(promote_patience=2, demote_patience=2)
    start = ctl.resolve("g", 0.95).name
    for i in range(20):
        ctl.observe_quality("g", "Q1.25", 1.0 if i % 2 == 0 else 0.5,
                            target=0.95)
    assert ctl.resolve("g", 0.95).name == start
    assert ctl.promotions == 0 and ctl.demotions == 0


def test_controller_dead_band_holds_and_resets_streaks():
    """Estimates on-target but inside the promote margin neither promote nor
    extend a demotion streak."""
    ctl = _controller(promote_patience=2, demote_patience=2,
                      promote_margin=0.02)
    for _ in range(10):
        ctl.observe_quality("g", "Q1.25", 0.955, target=0.95)  # in dead band
    assert ctl.resolve("g", 0.95).name == "Q1.25"
    assert ctl.promotions == 0 and ctl.demotions == 0


def test_controller_ignores_stale_format_samples():
    """Scores for a format that is not the current rung must not steer."""
    ctl = _controller(demote_patience=1)
    for _ in range(5):
        ctl.observe_quality("g", "Q1.19", 0.1, target=0.95)    # not the rung
    assert ctl.resolve("g", 0.95).name == "Q1.25"
    assert ctl.demotions == 0


def test_controller_per_target_states_are_independent():
    ctl = _controller(demote_patience=1)
    ctl.observe_quality("g", "Q1.25", 0.5, target=0.99)
    assert ctl.resolve("g", 0.99) is None            # demoted for target 0.99
    assert ctl.resolve("g", 0.90).name == "Q1.25"    # target 0.90 untouched


def test_controller_float_observations_climb_back_down():
    ctl = _controller(demote_patience=1, promote_patience=2)
    ctl.observe_quality("g", "Q1.25", 0.2, target=0.95)
    assert ctl.resolve("g", 0.95) is None
    for _ in range(2):                               # float serves are perfect
        ctl.observe_quality("g", FLOAT_KEY, 1.0, target=0.95)
    assert ctl.resolve("g", 0.95).name == "Q1.25"    # re-probing fixed point


def test_controller_backoff_on_persistently_failing_probe():
    """A narrower rung that keeps missing its target is re-probed with
    geometrically increasing patience instead of cycling forever."""
    ctl = _controller(promote_patience=1, demote_patience=1)
    gaps = []
    for _ in range(4):
        goods = 0
        while ctl.resolve("g", 0.95).name == "Q1.25":   # climb to the probe
            ctl.observe_quality("g", "Q1.25", 1.0, target=0.95)
            goods += 1
        gaps.append(goods)
        ctl.observe_quality("g", "Q1.23", 0.5, target=0.95)  # probe fails
        assert ctl.resolve("g", 0.95).name == "Q1.25"        # demoted back
    assert gaps == [1, 2, 4, 8]                          # exponential backoff


def test_controller_backoff_resets_after_successful_probe():
    ctl = _controller(promote_patience=1, demote_patience=1)
    state = lambda: ctl._states[("g", 0.95)]
    ctl.observe_quality("g", "Q1.25", 1.0, target=0.95)  # → Q1.23 (probe)
    ctl.observe_quality("g", "Q1.23", 0.5, target=0.95)  # fail → back
    assert state().promote_backoff == 2
    for _ in range(2):                                   # backoff'd patience
        ctl.observe_quality("g", "Q1.25", 1.0, target=0.95)
    assert ctl.resolve("g", 0.95).name == "Q1.23"        # probing again
    for _ in range(2):                                   # probe survives and
        ctl.observe_quality("g", "Q1.23", 1.0, target=0.95)
    assert ctl.resolve("g", 0.95).name == "Q1.21"        # promotes further
    assert state().promote_backoff == 1                  # trust restored


def test_controller_rejects_bad_targets_and_ladders():
    ctl = _controller()
    with pytest.raises(ValueError):
        ctl.resolve("g", 0.0)
    with pytest.raises(ValueError):
        ctl.resolve("g", 1.5)
    with pytest.raises(ValueError):
        AutotuneConfig(ladder=())
    with pytest.raises(ValueError):
        AutotuneConfig(ladder=(26, 20))


# ---------------------------------------------------------------------------
# early-exit convergence (paper Fig. 7)
# ---------------------------------------------------------------------------
def _fixed_step_closure(g, fmt, pers, alpha=0.85):
    gp = g.pad_to_packets(256)
    x, y = jnp.asarray(gp.x), jnp.asarray(gp.y)
    d, val = jnp.asarray(gp.dangling), jnp.asarray(gp.quantized_val(fmt))
    step = make_ppr_fixed_step(fmt, gp.num_vertices, alpha)
    V = personalization_matrix_fixed(gp.num_vertices, jnp.asarray(pers), fmt)
    return (lambda P: step(x, y, val, d, V, P)), V


def test_early_exit_bit_identical_to_full_budget(graph):
    """Fixed point settles into its absorbing state/cycle; exiting there must
    reproduce the full-budget state bit-for-bit at any budget parity."""
    fmt = format_for_bits(16)
    step, V = _fixed_step_closure(graph, fmt, np.array([3, 17], np.int32))
    for budget in (100, 101):                        # both parities
        P, n, _ = run_until_converged(step, V, budget, ConvergencePolicy(),
                                      fixed=True, scale=fmt.scale)
        assert n < budget                            # it did exit early
        P_full = V
        for _ in range(budget):
            P_full = step(P_full)
        assert bool(jnp.array_equal(P, P_full))


def test_early_exit_respects_budget_when_not_converged(graph):
    fmt = format_for_bits(26)                        # absorbs late (~94 iters)
    step, V = _fixed_step_closure(graph, fmt, np.array([3], np.int32))
    P, n, deltas = run_until_converged(step, V, 10, ConvergencePolicy(),
                                       fixed=True, scale=fmt.scale)
    assert n == 10 and deltas[-1] > 0.0


def test_service_early_exit_equals_full_run(graph):
    """Service-level: early-exited waves return the same recommendations as a
    full-budget service, and the saved iterations are telemetered."""
    budget = 100
    svc_ee = PPRService(kappa=4, iterations=budget, early_exit=True)
    svc_full = PPRService(kappa=4, iterations=budget)
    for s in (svc_ee, svc_full):
        s.register_graph("g", graph, formats=[16])
    verts = [3, 17, 42, 77]
    recs_ee = svc_ee.serve([PPRQuery("g", v, k=10, precision=16) for v in verts])
    recs_full = svc_full.serve([PPRQuery("g", v, k=10, precision=16) for v in verts])
    for a, b in zip(recs_ee, recs_full):
        np.testing.assert_array_equal(a.vertices, b.vertices)
        np.testing.assert_array_equal(a.scores, b.scores)
    assert svc_ee.telemetry.early_exit_waves == 1
    assert svc_ee.telemetry.iterations_saved > 0
    assert svc_full.telemetry.iterations_saved == 0


def test_service_float_early_exit_fires(graph):
    svc = PPRService(kappa=2, iterations=120, early_exit=True)
    svc.register_graph("g", graph)
    svc.serve([PPRQuery("g", 5), PPRQuery("g", 9)])
    assert svc.telemetry.early_exit_waves == 1       # float hits 1e-6 < 120
    assert svc.telemetry.iterations_saved > 0


def test_convergence_policy_validation():
    with pytest.raises(ValueError):
        ConvergencePolicy(min_iterations=0)
    with pytest.raises(ValueError):
        ConvergencePolicy(check_every=0)


def test_fixed_strict_exit_exact_above_float32_mantissa():
    """Regression: raw uint32 states >= 2^24 (scores >= 0.5 in Q1.25) differing
    by one LSB alias to float32 delta == 0.0; the strict absorbing-state check
    must use exact integer comparison, not the float delta."""
    from repro.autotune.convergence import ConvergenceMonitor, wave_delta

    scale = 1 << 25                                   # Q1.25
    a = jnp.full((4, 2), np.uint32(1 << 24), jnp.uint32)
    b = a.at[0, 0].add(np.uint32(1))                  # one LSB above 2^24
    # the float statistic is blind to this change — that is the trap
    assert wave_delta(b, a, scale=scale) == 0.0
    mon = ConvergenceMonitor(ConvergencePolicy(min_iterations=1),
                             fixed=True, scale=scale)
    assert mon.update(b, a) is False                  # must NOT exit
    assert not mon.converged
    # a genuinely absorbing state still exits
    mon2 = ConvergenceMonitor(ConvergencePolicy(min_iterations=1),
                              fixed=True, scale=scale)
    assert mon2.update(a, a) is True and mon2.converged


def test_run_until_converged_not_fooled_by_float_delta_alias():
    """A step that keeps moving by one LSB above 2^24 must burn the whole
    budget — the old delta==0.0 check exited after the first pair and returned
    a state that was not a fixed point."""
    def step(P):
        return P + np.uint32(1)

    P0 = jnp.full((8, 2), np.uint32(1 << 24), jnp.uint32)
    P, iters, _ = run_until_converged(
        step, P0, 6, ConvergencePolicy(min_iterations=1),
        fixed=True, scale=1 << 25)
    assert iters == 6
    np.testing.assert_array_equal(np.asarray(P), np.asarray(P0) + np.uint32(6))


# ---------------------------------------------------------------------------
# serving integration: precision="auto"
# ---------------------------------------------------------------------------
def _auto_service(graph, **svc_kw):
    cfg = AutotuneConfig(
        shadow=ShadowConfig(sample_fraction=1.0, min_samples=2, window=8))
    svc = PPRService(kappa=4, iterations=10, autotune=cfg, **svc_kw)
    svc.register_graph("g", graph)
    return svc


def test_auto_serves_fixed_point_and_meets_target(graph):
    """Acceptance: auto queries with an NDCG target >= 0.95 are served at a
    narrower format than float32 and the shadow estimator confirms the
    target is met."""
    svc = _auto_service(graph)
    rng = np.random.default_rng(0)
    queries = [PPRQuery("g", int(v), k=10, precision="auto", quality_target=0.95)
               for v in rng.integers(0, graph.num_vertices, 16)]
    recs = svc.serve(queries)
    assert len(recs) == 16
    assert all(r.precision != FLOAT_KEY for r in recs)     # narrower than f32
    s = svc.telemetry_summary()
    assert s["shadow_evaluations"] > 0
    assert s["shadow_quality_mean"] >= 0.95                # target met
    assert sum(v for k, v in s.items() if k.startswith("auto_")) == 16


def test_auto_batches_with_explicit_same_format_traffic(graph):
    """Auto resolution happens before admission, so auto queries share waves
    with explicit queries at the resolved format."""
    svc = _auto_service(graph)
    resolved = svc.controller.resolve("g", None).name
    qs = [PPRQuery("g", 1, precision="auto"),
          PPRQuery("g", 2, precision=resolved),
          PPRQuery("g", 3, precision="auto"),
          PPRQuery("g", 4, precision=resolved)]
    svc.serve(qs)
    assert svc.telemetry.waves == 1                        # one shared wave


def test_auto_shadow_pipeline_deterministic(graph):
    """Two identical services replaying the same query sequence make identical
    sampling decisions and produce identical shadow scores."""
    def run_once():
        cfg = AutotuneConfig(shadow=ShadowConfig(sample_fraction=0.5,
                                                 min_samples=2, seed=3))
        svc = PPRService(kappa=4, iterations=10, autotune=cfg)
        svc.register_graph("g", graph)
        rng = np.random.default_rng(1)
        qs = [PPRQuery("g", int(v), precision="auto")
              for v in rng.integers(0, graph.num_vertices, 16)]
        svc.serve(qs)
        return (svc.telemetry.shadow_scores,
                svc.telemetry.auto_resolved,
                svc.controller.estimator.shadow_evaluations)
    assert run_once() == run_once()


def test_auto_demotes_to_float_on_unreachable_target(graph):
    """A target no fixed format can meet walks the ladder up to float32.

    An Erdős–Rényi graph decorrelates vertex id from degree, so an 8-bit
    format (which truncates all but a handful of ranks to zero, leaving
    ascending-id tie-break fill) scores genuinely badly — NDCG@50 ≈ 0.65.
    On the power-law fixture hubs get the low ids and the same tie-break
    *accidentally* reconstructs the reference top-k, which is why this test
    needs its own graph."""
    g = erdos_renyi(300, 1800, seed=3)
    cfg = AutotuneConfig(
        ladder=(8,),                                   # Q1.7: hopeless on ER
        demote_patience=1,
        shadow=ShadowConfig(sample_fraction=1.0, min_samples=1, window=2))
    svc = PPRService(kappa=2, iterations=10, autotune=cfg)
    svc.register_graph("g", g)
    for v in (5, 9, 11, 21, 33, 41):
        svc.serve([PPRQuery("g", v, precision="auto", quality_target=0.95)])
    assert svc.controller.resolve("g", 0.95) is None       # float32 rung
    # ≥1: float successes periodically re-probe Q1.7, which re-demotes
    assert svc.controller.demotions >= 1
    served = svc.telemetry.served_by_precision
    assert FLOAT_KEY in served                             # later queries exact
    assert served.get("Q1.7", 0) >= 1                      # first probe was fixed


def test_normalize_precision_rejects_auto():
    from repro.ppr_serving import normalize_precision
    with pytest.raises(ValueError):
        normalize_precision("auto")


# ---------------------------------------------------------------------------
# cache correctness satellites
# ---------------------------------------------------------------------------
def test_register_graph_invalidates_stale_cache_entries(graph):
    svc = PPRService(kappa=2, iterations=5)
    svc.register_graph("g", graph)
    first = svc.serve([PPRQuery("g", 7, k=5)])[0]
    assert svc.serve([PPRQuery("g", 7, k=5)])[0].source == "cache"
    g2 = erdos_renyi(280, 1700, seed=9)                    # different topology
    svc.register_graph("g", g2)                            # same name
    again = svc.serve([PPRQuery("g", 7, k=5)])[0]
    assert again.source == "wave"                          # stale rank evicted
    assert svc.cache.invalidations > 0
    assert not np.array_equal(again.vertices, first.vertices)
    before = svc.cache.invalidations
    svc.register_graph("h", graph)                         # new name: no-op path
    assert svc.cache.invalidations == before


def test_register_graph_drops_pending_queries_for_old_topology(graph):
    """Queries validated against the old graph must not launch against the
    new one (their vertices may be out of range — JAX scatter would silently
    drop them and serve garbage)."""
    svc = PPRService(kappa=8, iterations=5)        # κ=8: the query stays queued
    svc.register_graph("g", graph)                 # |V| = 300
    fut = svc.submit(PPRQuery("g", 299, k=5))
    assert not fut.done()
    svc.register_graph("g", erdos_renyi(100, 600, seed=1))   # vertex 299 gone
    assert svc.scheduler.pending() == 0
    assert svc.drain() == []                       # nothing stale launches
    # the pending future was rejected descriptively, not left dangling
    assert fut.done() and fut.exception() is not None


def test_cache_key_separates_budget_and_early_exit_numerics(graph):
    q = PPRQuery("g", 1, k=5)
    k10 = PPRService(iterations=10)._cache_key(q, "Q1.25")
    k20 = PPRService(iterations=20)._cache_key(q, "Q1.25")
    kee = PPRService(iterations=10, early_exit=True)._cache_key(q, "Q1.25")
    kf = PPRService(iterations=10)._cache_key(q, FLOAT_KEY)
    assert len({k10, k20, kee, kf}) == 4


def test_lru_invalidate_predicate():
    from repro.ppr_serving import LRUCache
    c = LRUCache(capacity=8)
    c.put(("a", 1), "x")
    c.put(("a", 2), "y")
    c.put(("b", 1), "z")
    assert c.invalidate(lambda k: k[0] == "a") == 2
    assert c.get(("a", 1)) is None and c.get(("b", 1)) == "z"
    assert c.invalidations == 2
