"""HTTP serving tier: admission hysteresis (no flapping), load shedding,
SLO-aware quality degradation + recovery, rejection-path status mapping
(QueryRejected -> 409/410, never 500, no leaked futures), queue-depth
accessors, prefetch suppression under live traffic, wire schemas, and an
end-to-end asyncio server run whose admitted results match run_batch()."""
import asyncio
import json

import numpy as np
import pytest

from repro.graphs import holme_kim_powerlaw
from repro.graph_updates import localized_delta
from repro.ppr_serving import (
    AdmissionConfig,
    AdmissionController,
    PPRHTTPServer,
    PPRQuery,
    PPRService,
    QueryRejected,
    ServiceTelemetry,
    ServingApp,
    WaveScheduler,
)
from repro.ppr_serving.http import (
    HTTPRequest,
    PPRRequestSchema,
    SchemaError,
    http_request,
)


@pytest.fixture(scope="module")
def graph():
    return holme_kim_powerlaw(400, m=4, seed=2)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


# ---------------------------------------------------------------------------
# scheduler queue-depth accessors (satellite)
# ---------------------------------------------------------------------------
def test_scheduler_queue_depth_tracks_every_mutation():
    clk = FakeClock()
    sched = WaveScheduler(kappa=2, max_wait=100.0, time_fn=clk)
    assert sched.queue_depth() == 0
    for i in range(5):
        sched.submit(("g", "f32"), i)
    sched.submit(("g", 26), 99)
    assert sched.queue_depth() == 6
    # full waves pop kappa-sized chunks; the leftover partial stays queued
    waves = sched.ready_waves()
    assert sum(len(w.items) for w in waves) == 4
    assert sched.queue_depth() == 2
    # purge drops one key's pending
    assert sched.purge(lambda k: k == ("g", 26)) == 1
    assert sched.queue_depth() == 1
    # extract pops the rest
    assert len(sched.extract(lambda k: True)) == 1
    assert sched.queue_depth() == 0


def test_scheduler_flush_keys_decrements_depth():
    sched = WaveScheduler(kappa=4, max_wait=100.0, time_fn=FakeClock())
    for i in range(3):
        sched.submit(("g", "f32"), i)
    waves = sched.flush_keys([("g", "f32")])
    assert sum(len(w.items) for w in waves) == 3
    assert sched.queue_depth() == 0


def test_scheduler_oldest_wait_tracks_queue_head():
    clk = FakeClock()
    sched = WaveScheduler(kappa=8, max_wait=100.0, time_fn=clk)
    assert sched.oldest_wait_s() == 0.0
    sched.submit(("g", "f32"), 1)
    clk.t = 2.0
    sched.submit(("g", 26), 2)            # younger key must not win
    assert sched.oldest_wait_s() == pytest.approx(2.0)
    assert sched.oldest_wait_s(now=5.0) == pytest.approx(5.0)
    sched.flush_keys([("g", "f32")])
    assert sched.oldest_wait_s() == pytest.approx(0.0)  # head is now t=2.0


def test_service_exposes_depth_and_wait(graph):
    clk = FakeClock()
    svc = PPRService(kappa=8, iterations=3, max_wait=100.0, time_fn=clk)
    svc.register_graph("g", graph)
    for v in (3, 9, 11):
        svc.submit(PPRQuery("g", v, k=5))
    clk.t = 1.5
    assert svc.queue_depth() == 3
    assert svc.oldest_wait_s() == pytest.approx(1.5)
    svc.flush()
    assert svc.queue_depth() == 0
    t = svc.telemetry_summary()
    assert t["queue_depth_peak"] >= 0     # gauges exist even if never recorded


def test_telemetry_queue_gauges_last_and_peak():
    t = ServiceTelemetry()
    t.record_queue_depth(5, 0.2)
    t.record_queue_depth(2, 0.1)
    s = t.summary()
    assert s["queue_depth"] == 2 and s["queue_depth_peak"] == 5
    assert s["oldest_wait_s"] == pytest.approx(0.1)
    assert s["oldest_wait_peak_s"] == pytest.approx(0.2)


# ---------------------------------------------------------------------------
# admission controller: pure policy + hysteresis (no sockets, no jax)
# ---------------------------------------------------------------------------
class StubService:
    """The controller's whole service contract, with a dialable depth."""

    def __init__(self, kappa=4):
        self.kappa = kappa
        self.telemetry = ServiceTelemetry()
        self.depth = 0
        self.quality_log = []

    def queue_depth(self):
        return self.depth

    def oldest_wait_s(self, now=None):
        return 0.0

    def set_kappa(self, kappa):
        self.telemetry.record_kappa_change(deepened=kappa > self.kappa)
        self.kappa = kappa

    def degrade_quality(self, target):
        self.quality_log.append(("degrade", target))

    def restore_quality(self):
        self.quality_log.append(("restore", None))


def _cfg(**kw):
    base = dict(high_water=8, low_water=2, deepen_water=4, kappa_max=16,
                degrade_water=6, degrade_low_water=2, degraded_target=0.9)
    base.update(kw)
    return AdmissionConfig(**base)


def test_target_kappa_doubles_per_depth_doubling():
    ctl = AdmissionController(StubService(kappa=4), _cfg())
    assert [ctl.target_kappa(d) for d in (0, 3, 4, 7, 8, 100)] == \
        [4, 4, 8, 8, 16, 16]


def test_kappa_max_below_base_kappa_is_an_error():
    with pytest.raises(ValueError, match="kappa_max"):
        AdmissionController(StubService(kappa=32), _cfg(kappa_max=16))


@pytest.mark.parametrize("kw", [
    dict(low_water=0), dict(low_water=9),            # low > high
    dict(degrade_low_water=7),                        # > degrade_water
    dict(deepen_water=0), dict(kappa_max=0),
    dict(degraded_target=0.0), dict(degraded_target=1.5),
    dict(retry_after_s=0.0),
])
def test_admission_config_validation(kw):
    with pytest.raises(ValueError):
        _cfg(**kw)


def test_shed_hysteresis_does_not_flap():
    svc = StubService(kappa=4)
    ctl = AdmissionController(svc, _cfg())
    svc.depth = 8                         # == high_water: not yet shedding
    ctl.tick()
    assert not ctl.shedding
    svc.depth = 9                         # > high_water: engage
    ctl.tick()
    assert ctl.shedding
    # oscillating inside the (low_water, high_water] band must not flap
    for depth in (3, 8, 5, 8, 3, 7):
        svc.depth = depth
        ctl.tick()
        assert ctl.shedding
    s = svc.telemetry.summary()
    assert s["shed_engaged_events"] == 1 and s["shed_recovered_events"] == 0
    svc.depth = 2                         # <= low_water: recover
    ctl.tick()
    assert not ctl.shedding
    assert svc.telemetry.summary()["shed_recovered_events"] == 1


def test_degrade_hysteresis_and_quality_calls():
    svc = StubService(kappa=4)
    ctl = AdmissionController(svc, _cfg())
    svc.depth = 7                         # > degrade_water
    ctl.tick()
    assert ctl.degrading and svc.quality_log == [("degrade", 0.9)]
    for depth in (3, 6, 4, 7):            # inside the hysteresis band
        svc.depth = depth
        ctl.tick()
    assert svc.quality_log == [("degrade", 0.9)]      # exactly one call
    svc.depth = 2                         # <= degrade_low_water
    ctl.tick()
    assert not ctl.degrading
    assert svc.quality_log[-1] == ("restore", None)


def test_admit_counts_and_returns_retry_after():
    svc = StubService(kappa=4)
    ctl = AdmissionController(svc, _cfg(retry_after_s=0.25))
    assert ctl.admit() is None
    svc.depth = 9
    assert ctl.admit() == pytest.approx(0.25)
    assert (ctl.admitted, ctl.shed) == (1, 1)
    assert svc.telemetry.summary()["queries_shed"] == 1
    assert ctl.stats()["shedding"] is True


def test_tick_deepens_and_relaxes_kappa_through_service_hook():
    svc = StubService(kappa=4)
    ctl = AdmissionController(svc, _cfg())
    svc.depth = 8
    ctl.tick()
    assert svc.kappa == 16
    svc.depth = 0
    ctl.tick()
    assert svc.kappa == 4                 # back to base
    s = svc.telemetry.summary()
    assert s["kappa_deepen_events"] == 1 and s["kappa_relax_events"] == 1


# ---------------------------------------------------------------------------
# service-side load-control hooks
# ---------------------------------------------------------------------------
def test_degrade_quality_caps_auto_resolution_and_recovers(graph):
    svc = PPRService(kappa=2, iterations=3)
    svc.register_graph("g", graph, formats=[26])
    svc.degrade_quality(0.9)
    assert svc.controller.target_ceiling == pytest.approx(0.9)
    rec = svc.run_batch([PPRQuery("g", 7, k=5, precision="auto",
                                  quality_target=0.95)])[0]
    assert rec is not None
    t = svc.telemetry_summary()
    assert t["slo_degrade_events"] == 1
    assert t["slo_degraded_queries"] == 1  # requested .95, served under .9
    svc.restore_quality()
    assert svc.controller.target_ceiling is None
    assert svc.telemetry_summary()["slo_recover_events"] == 1
    # both are idempotent no-ops when already in that state
    svc.restore_quality()
    svc.degrade_quality(0.9)
    svc.degrade_quality(0.9)
    assert svc.telemetry_summary()["slo_degrade_events"] == 2


def test_set_kappa_applies_to_scheduler_and_validates(graph):
    svc = PPRService(kappa=4, iterations=3)
    svc.register_graph("g", graph)
    svc.set_kappa(8)
    assert svc.kappa == 8 and svc.scheduler.kappa == 8
    with pytest.raises(ValueError):
        svc.set_kappa(0)


def test_prefetch_yields_to_live_traffic(graph):
    """Satellite: an idle poll with pending live queries past the suppress
    depth skips prefetch and counts the suppression."""
    from repro.ppr_serving import PrefetchConfig
    clk = FakeClock()
    svc = PPRService(kappa=8, iterations=3, max_wait=100.0, time_fn=clk,
                     prefetch=PrefetchConfig(suppress_depth=2))
    svc.register_graph("g", graph)
    for v in (3, 9, 11):                  # partial wave, deadline far away
        svc.submit(PPRQuery("g", v, k=5))
    assert svc.poll() == 0                # idle poll, but 3 >= suppress_depth
    assert svc.prefetcher.suppressed == 1
    t = svc.telemetry_summary()
    assert t["prefetch_suppressed"] == 1 and t["prefetch_issued"] == 0
    svc.flush()
    svc.poll()                            # drained: prefetch eligible again
    assert svc.prefetcher.suppressed == 1


def test_prefetch_default_suppress_depth_is_kappa(graph):
    """Depth below κ is idle-enough: the PR-4/5 prefetch behaviour (fire
    while a lone query waits) must survive the new gate."""
    svc = PPRService(kappa=4, iterations=3, max_wait=100.0,
                     time_fn=FakeClock(), prefetch=True)
    svc.register_graph("g", graph)
    svc.submit(PPRQuery("g", 3, k=5))
    svc.poll()
    assert svc.prefetcher.suppressed == 0


# ---------------------------------------------------------------------------
# QueryRejected codes (satellite: machine-readable rejection classes)
# ---------------------------------------------------------------------------
def test_query_rejected_codes(graph):
    assert QueryRejected("x").code == "rejected"
    svc = PPRService(kappa=8, iterations=3, max_wait=100.0,
                     time_fn=FakeClock())
    svc.register_graph("g", graph)
    fut = svc.submit(PPRQuery("g", 3, k=5))
    svc.register_graph("g", graph)        # re-registration purges pending
    with pytest.raises(QueryRejected) as ei:
        fut.result()
    assert ei.value.code == "graph-replaced"


def test_delta_invalidation_code(graph):
    svc = PPRService(kappa=8, iterations=3, max_wait=100.0,
                     time_fn=FakeClock())
    svc.register_graph("g", graph)
    d = localized_delta(graph, np.random.default_rng(3), n_add=2, n_remove=1)
    frontier = sorted(int(v) for v in d.affected_frontier(graph))
    fut = svc.submit(PPRQuery("g", frontier[0], k=5))
    svc.apply_delta("g", d)
    with pytest.raises(QueryRejected) as ei:
        fut.result()
    assert ei.value.code == "delta-invalidated"


# ---------------------------------------------------------------------------
# wire schemas
# ---------------------------------------------------------------------------
def test_schema_parse_happy_path():
    spec = PPRRequestSchema.parse(json.dumps(
        {"graph": "g", "vertex": 3, "k": 5, "precision": "auto",
         "quality_target": 0.95, "deadline_s": 0.05}).encode())
    assert (spec.graph, spec.vertex, spec.k) == ("g", 3, 5)
    assert spec.precision == "auto"
    assert spec.quality_target == pytest.approx(0.95)


@pytest.mark.parametrize("body", [
    b"",                                       # empty
    b"not json",                               # invalid JSON
    b"[1,2]",                                  # not an object
    b'{"vertex": 3}',                          # missing graph
    b'{"graph": "g"}',                         # missing vertex
    b'{"graph": "g", "vertex": true}',         # bool is not an int
    b'{"graph": "g", "vertex": 3, "k": "x"}',  # wrong type
    b'{"graph": "g", "vertex": 3, "bogus": 1}',  # unknown field
])
def test_schema_parse_rejects(body):
    with pytest.raises(SchemaError):
        PPRRequestSchema.parse(body)


def test_app_routes_without_sockets(graph):
    svc = PPRService(kappa=2, iterations=3)
    svc.register_graph("g", graph)
    app = ServingApp(svc)

    def call(method, path, body=b""):
        return asyncio.run(app.handle(
            HTTPRequest(method=method, path=path, headers={}, body=body)))

    assert call("GET", "/v1/nope").status == 404
    assert call("DELETE", "/v1/ppr").status == 405
    assert call("POST", "/v1/ppr", b"{").status == 400
    r = call("POST", "/v1/ppr",
             b'{"graph": "missing", "vertex": 1}')
    assert r.status == 404 and r.payload["code"] == "unknown-graph"
    r = call("POST", "/v1/ppr",
             b'{"graph": "g", "vertex": 1, "k": 0}')   # submit's validation
    assert r.status == 400
    h = call("GET", "/v1/healthz")
    assert h.status == 200 and h.payload["graphs"] == ["g"]


# ---------------------------------------------------------------------------
# end-to-end over real sockets
# ---------------------------------------------------------------------------
async def _drain(host, port, timeout_s=30.0):
    loop = asyncio.get_running_loop()
    t0 = loop.time()
    while loop.time() - t0 < timeout_s:
        _, _, h = await http_request(host, port, "GET", "/v1/healthz")
        if h["queue_depth"] == 0 and not h["shedding"] and not h["degrading"]:
            return True
        await asyncio.sleep(0.02)
    return False


def test_e2e_shed_degrade_recover_and_run_batch_parity(graph):
    """The acceptance e2e: one real asyncio server driven through
    submit -> degrade -> shed -> recover, with admitted explicit-precision
    results identical to run_batch() on an untouched mirror service."""
    svc = PPRService(kappa=4, iterations=6, max_wait=0.002)
    svc.register_graph("g", graph, formats=[26])
    svc.run_batch([PPRQuery("g", v, k=5, precision="auto")
                   for v in range(4)])    # warm jit outside the burst
    svc.telemetry.reset()
    server = PPRHTTPServer(svc, admission=AdmissionConfig(
        high_water=20, low_water=2, deepen_water=8, kappa_max=8,
        degrade_water=3, degrade_low_water=1, degraded_target=0.9))

    async def flood(host, port, vertices, expect_admitted):
        """Fire a concurrent burst with the pump *paused*, so every arrival
        hits admission before any wave drains — the depth sequence (and so
        every shed/degrade decision) is exact, not a race against the pump.
        Returns the gather task once the queue holds the admitted set."""
        task = asyncio.gather(*[
            http_request(host, port, "POST", "/v1/ppr",
                         {"graph": "g", "vertex": int(v), "k": 5,
                          "precision": "auto", "quality_target": 0.95})
            for v in vertices])
        deadline = asyncio.get_running_loop().time() + 10.0
        while svc.queue_depth() < expect_admitted:
            assert asyncio.get_running_loop().time() < deadline, \
                f"queue never reached {expect_admitted}"
            await asyncio.sleep(0.002)
        server.pump.start()               # now let the waves drain it
        return await task

    async def scenario():
        await server.transport.start()    # transport up, pump held back
        host, port = server.host, server.port

        # --- phase A: burst of 10 > degrade_water but < high_water ---------
        # admission sees depths 0..9: degrade engages at depth 4 (the 5th
        # arrival), nothing sheds — so exactly 6 responses carry the flag
        rs = await flood(host, port, range(20, 30), expect_admitted=10)
        assert [r[0] for r in rs] == [200] * 10
        assert sum(r[2]["degraded"] for r in rs) == 6
        assert await _drain(host, port)   # queue empties -> quality restored
        _, _, stats = await http_request(host, port, "GET", "/v1/stats")
        assert stats["slo_degrade_events"] == 1
        assert stats["slo_recover_events"] == 1
        assert stats["slo_degraded_queries"] >= 6
        assert stats["queries_shed"] == 0
        await server.pump.stop()          # queue is empty: flush is a no-op

        # --- phase B: burst of 30 > high_water -----------------------------
        # depths 0..20 admit (shed engages when the 22nd arrival's tick sees
        # depth 21 > 20); the remaining 9 shed with the backoff hint
        rs = await flood(host, port, range(40, 70), expect_admitted=21)
        statuses = [r[0] for r in rs]
        assert statuses.count(200) == 21 and statuses.count(429) == 9
        shed = next(r for r in rs if r[0] == 429)
        assert float(shed[1]["retry-after"]) > 0    # the backoff hint
        assert shed[2]["code"] == "shed"
        assert await _drain(host, port)
        _, _, stats = await http_request(host, port, "GET", "/v1/stats")
        assert stats["shed_engaged_events"] == 1
        assert stats["shed_recovered_events"] == 1
        assert stats["queries_shed"] == 9
        assert stats["queue_depth_peak"] == 21

        # --- phase C: admitted results == run_batch() ----------------------
        # explicit precision: its resolution is load-independent, so the
        # mirror comparison is exact even after the degrade/recover cycle
        verts = [3, 9, 11, 17]
        rs = [await http_request(host, port, "POST", "/v1/ppr",
                                 {"graph": "g", "vertex": v, "k": 5,
                                  "precision": 26})
              for v in verts]
        assert [r[0] for r in rs] == [200] * 4
        await server.stop()
        assert svc.queue_depth() == 0     # nothing leaked pending
        return rs

    http_recs = asyncio.run(scenario())

    mirror = PPRService(kappa=4, iterations=6)
    mirror.register_graph("g", graph, formats=[26])
    batch = mirror.run_batch([PPRQuery("g", v, k=5, precision=26)
                              for v in (3, 9, 11, 17)])
    for (_, _, payload), rec in zip(http_recs, batch):
        assert payload["precision"] == rec.precision
        assert [r["vertex"] for r in payload["recommendations"]] == \
            [int(v) for v in rec.vertices]
        np.testing.assert_allclose(
            [r["score"] for r in payload["recommendations"]],
            np.asarray(rec.scores, dtype=float), rtol=0, atol=0)


def test_e2e_rejection_paths_are_clean_statuses(graph):
    """QueryRejected futures surface as 410 (graph-replaced) / 409
    (delta-invalidated) over the wire — never 500 — and leave no pending
    futures behind."""
    svc = PPRService(kappa=8, iterations=3, max_wait=100.0)
    svc.register_graph("g", graph)
    server = PPRHTTPServer(svc, pump_interval_s=0.01)

    async def scenario():
        await server.start()
        host, port = server.host, server.port

        async def pending_request(vertex):
            task = asyncio.create_task(http_request(
                host, port, "POST", "/v1/ppr",
                {"graph": "g", "vertex": vertex, "k": 5}))
            while svc.queue_depth() == 0:     # parked in a partial wave
                await asyncio.sleep(0.005)
            return task

        # graph replaced under a pending query -> 410
        task = await pending_request(3)
        svc.register_graph("g", graph)
        status, _, payload = await task
        assert status == 410 and payload["code"] == "graph-replaced"

        # delta frontier invalidates a pending query -> 409
        d = localized_delta(graph, np.random.default_rng(3),
                            n_add=2, n_remove=1)
        frontier = sorted(int(v) for v in d.affected_frontier(graph))
        task = await pending_request(frontier[0])
        svc.apply_delta("g", d)
        status, _, payload = await task
        assert status == 409 and payload["code"] == "delta-invalidated"

        assert svc.queue_depth() == 0
        await server.stop()

    asyncio.run(scenario())
