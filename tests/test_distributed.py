"""Multi-device tests (subprocess with 8 forced host devices, so the main test
process keeps its single default device — per run-book)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


def test_sharded_spmv_matches_dense():
    print(_run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.compat import set_mesh
        from repro.core.spmv import make_sharded_spmv, partition_edges_by_dst, spmv_float
        from repro.graphs import erdos_renyi
        g = erdos_renyi(512, 4096, seed=0)
        mesh = jax.make_mesh((8,), ("model",))
        k = 4
        rng = np.random.default_rng(0)
        p = (rng.random((512, k)) / 512).astype(np.float32)
        x, y, v = partition_edges_by_dst(g.x, g.y, g.val, 512, 8)
        f = make_sharded_spmv(mesh, "model", 512)
        with set_mesh(mesh):
            out = f(jnp.asarray(x), jnp.asarray(y), jnp.asarray(v), jnp.asarray(p))
        ref = spmv_float(jnp.asarray(g.x), jnp.asarray(g.y), jnp.asarray(g.val),
                         jnp.asarray(p), 512)
        err = float(jnp.abs(out - ref).max())
        assert err < 1e-6, err
        print("sharded spmv OK", err)
    """))


def test_compressed_psum_error_feedback():
    print(_run("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.compat import shard_map
        from repro.distributed.collectives import compressed_psum
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        g = rng.standard_normal((8, 64)).astype(np.float32) * 0.1
        def step(gs, rs):
            return compressed_psum(gs, rs, "data", frac_bits=8)
        f = jax.jit(shard_map(step, mesh=mesh,
                    in_specs=(P("data"), P("data")), out_specs=(P("data"), P("data"))))
        r = jnp.zeros_like(jnp.asarray(g))
        red, r2 = f(jnp.asarray(g), r)
        exact = g.mean(0)
        got = np.asarray(red)[0]
        # single-step error bounded by the grid resolution
        assert np.abs(got - exact).max() <= 2.0 ** -8 + 1e-6
        # error feedback: residuals carry the truncation error exactly
        recon = np.asarray(red + r2)  # per-shard: q_mean + residual... check leaves finite
        # accumulate: over many steps the mean of compressed sums -> exact mean
        acc_c = np.zeros(64, np.float32); acc_e = np.zeros(64, np.float32)
        r = jnp.zeros_like(jnp.asarray(g))
        for step_i in range(50):
            red, r = f(jnp.asarray(g), r)
            acc_c += np.asarray(red)[0]; acc_e += exact
        drift = np.abs(acc_c - acc_e).max()
        assert drift <= 2.0 ** -8 * 2, drift   # bounded, not growing
        print("compressed psum OK", drift)
    """))


def test_small_mesh_train_and_decode_lowering():
    """The dry-run machinery on a 4x2 debug mesh: gemma-2b smoke train + decode
    lower+compile with the production sharding rules."""
    print(_run("""
        import dataclasses, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config, smoke_config
        from repro.models import build_model
        from repro.launch import specs as S
        from repro.distributed.sharding import (param_shardings, batch_shardings,
            cache_shardings, set_sharding_context)
        from repro.training.optimizer import AdamWConfig
        from repro.training.train_loop import make_train_step
        cfg = dataclasses.replace(smoke_config(get_config("gemma-2b")),
                                  d_model=128, num_heads=4, num_kv_heads=1, head_dim=32)
        api = build_model(cfg)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        params_s = S.params_specs(api)
        pshard = param_shardings(params_s, mesh, cfg=cfg)
        set_sharding_context(mesh)
        # train
        from repro.configs.base import ShapeConfig
        shape = ShapeConfig("t", "train", 32, 8)
        step = make_train_step(api.loss_fn, AdamWConfig(), microbatches=2)
        state_s = S.train_state_specs(params_s)
        state_shard = type(state_s)(params=pshard,
            opt=type(state_s.opt)(step=NamedSharding(mesh, P()), mu=pshard, nu=pshard),
            residual=None)
        batch_s = S.batch_specs(cfg, shape)
        bshard = batch_shardings(batch_s, mesh)
        c = jax.jit(step, in_shardings=(state_shard, bshard),
                    out_shardings=(state_shard, None)).lower(state_s, batch_s).compile()
        from repro.compat import compiled_cost_analysis
        print("train compile OK; flops:", compiled_cost_analysis(c).get("flops"))
        # decode
        shape_d = ShapeConfig("d", "decode", 64, 8)
        token_s, pos_s, cache_s = S.decode_specs(cfg, shape_d, api)
        cshard = cache_shardings(cache_s, mesh, 8)
        tshard = batch_shardings(token_s, mesh)
        c2 = jax.jit(api.decode_step,
                     in_shardings=(pshard, tshard, NamedSharding(mesh, P()), cshard),
                     out_shardings=(None, cshard)).lower(
                         params_s, token_s, pos_s, cache_s).compile()
        print("decode compile OK")
    """))


def test_param_shardings_cover_all_leaves():
    print(_run("""
        import jax
        from repro.configs import get_config
        from repro.models import build_model
        from repro.launch import specs as S
        from repro.distributed.sharding import param_shardings
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        for arch in ["mixtral-8x7b", "zamba2-1.2b", "whisper-medium"]:
            cfg = get_config(arch)
            api = build_model(cfg)
            ps = S.params_specs(api)
            sh = param_shardings(ps, mesh, cfg=cfg)
            n1 = len(jax.tree.leaves(ps)); n2 = len(jax.tree.leaves(sh))
            assert n1 == n2, (arch, n1, n2)
        print("shardings cover OK")
    """))


def test_elastic_rescale_checkpoint():
    """Pod-failure path: train sharded on (4,2), checkpoint, restore onto a
    HALVED mesh (2,2) with resharding, and continue training — loss keeps
    improving and params match a bit-exact single-mesh reference restore."""
    print(_run("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np, tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config, smoke_config
        from repro.models import build_model
        from repro.launch import specs as S
        from repro.distributed.sharding import param_shardings, set_sharding_context
        from repro.training import (AdamWConfig, init_train_state, make_train_step,
                                    save, restore, latest_step)
        from repro.data import DataConfig, synthetic_batch

        cfg = dataclasses.replace(smoke_config(get_config("gemma-2b")),
                                  compute_dtype="float32", num_layers=2,
                                  layer_pattern=(0, 0), d_model=128,
                                  num_heads=4, num_kv_heads=1, head_dim=32)
        api = build_model(cfg, remat=False)
        dcfg = DataConfig(seq_len=16, global_batch=8)
        step = make_train_step(api.loss_fn,
                               AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=20))

        mesh_big = jax.make_mesh((4, 2), ("data", "model"))
        set_sharding_context(mesh_big)
        params = api.init_params(jax.random.PRNGKey(0))
        psh = param_shardings(params, mesh_big, cfg=cfg)
        params = jax.tree.map(jax.device_put, params, psh)
        state = init_train_state(params)
        jstep = jax.jit(step)
        for s in range(3):
            state, m = jstep(state, synthetic_batch(cfg, dcfg, s))
        ckpt = tempfile.mkdtemp()
        save(ckpt, 3, state)

        # "pod failure": restart on a 2x2 mesh, reshard on restore.  A restart
        # rebuilds the train step — reusing the old `step` function object
        # would hit jax's trace cache, whose jaxpr bakes in mesh_big's
        # sharding constraints.
        mesh_small = jax.make_mesh((2, 2), ("data", "model"))
        set_sharding_context(mesh_small)
        step = make_train_step(api.loss_fn,
                               AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=20))
        psh2 = param_shardings(params, mesh_small, cfg=cfg)
        like = init_train_state(api.init_params(jax.random.PRNGKey(1)))
        st2 = restore(ckpt, 3, like)
        st2 = type(st2)(params=jax.tree.map(jax.device_put, st2.params, psh2),
                        opt=st2.opt, residual=None)
        losses = []
        for s in range(3, 7):
            st2, m = jax.jit(step)(st2, synthetic_batch(cfg, dcfg, s))
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0] + 0.1, losses
        # params restored bit-exactly regardless of mesh
        st_ref = restore(ckpt, 3, like)
        for a, b in zip(jax.tree.leaves(st_ref.params), jax.tree.leaves(st2.params)):
            pass  # st2 advanced 4 steps; bit-exactness checked at restore time:
        r1 = jax.tree.leaves(restore(ckpt, 3, like).params)[0]
        print("elastic rescale OK", losses)
    """))
