"""SLO burn-rate monitoring: multi-window engage/recover state machine on an
injected clock (hysteresis, no flapping at the boundary), the three SLO kinds'
good/bad accounting, the admission controller's push/veto advisory coupling,
deadline-aware shedding (service + HTTP 504), and an end-to-end wire test —
a paused-pump flood drives the latency SLO to *burning*, visible in
``GET /v1/slo``, the flight recorder, and ``slo_burn_rate`` in
``GET /v1/metrics``."""
import asyncio

import pytest

from repro.graphs import holme_kim_powerlaw
from repro.obs import MetricsRegistry, FlightRecorder, SLOMonitor, SLOSpec, \
    default_slo_specs, format_slo
from repro.obs.slo import (
    DEADLINE_SHED_FAMILY,
    LATENCY_FAMILY,
    QUALITY_FAMILY,
    SERVED_FAMILY,
    SHED_FAMILY,
)
from repro.ppr_serving import (
    AdmissionConfig,
    AdmissionController,
    PPRHTTPServer,
    PPRQuery,
    PPRService,
    QueryRejected,
)
from repro.ppr_serving.http import http_request


class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t


#: bench-scale window set reused across the unit tests: the SRE algebra does
#: not care about absolute durations, only the short/long pairing
FAST = (5.0, 30.0)
SLOW = (30.0, 120.0)


def _spec(kind="latency", **kw):
    kw.setdefault("name", f"{kind}_slo")
    kw.setdefault("fast_windows", FAST)
    kw.setdefault("slow_windows", SLOW)
    if kind == "latency":
        kw.setdefault("objective", 0.001024)       # a bucket bound (2^10 µs)
    if kind == "quality":
        kw.setdefault("objective", 0.90)
    kw.setdefault("budget", 0.05)
    return SLOSpec(kind=kind, **kw)


def _monitor(spec, recorder=None, resolution_s=1.0):
    reg = MetricsRegistry()
    mon = SLOMonitor(reg, [spec], time_fn=FakeClock(), recorder=recorder,
                     resolution_s=resolution_s)
    return reg, mon


def _observe_latency(reg, seconds, n=1, graph="g"):
    hist = reg.histogram(LATENCY_FAMILY, labels=("graph",))
    for _ in range(n):
        hist.labels(graph=graph).observe(seconds)


# ---------------------------------------------------------------------------
# spec validation + defaults
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kw", [
    dict(name=""),
    dict(kind="throughput"),
    dict(budget=0.0),
    dict(budget=1.5),
    dict(kind="latency", objective=0.0),
    dict(kind="quality", objective=1.5),
    dict(fast_windows=(30.0, 5.0)),
    dict(slow_windows=(0.0, 120.0)),
    dict(fast_burn=2.0, slow_burn=6.0),            # fast < slow
    dict(recover_burn=0.0),
    dict(min_events=0),
])
def test_spec_validation_rejects(kw):
    base = dict(name="s", kind="latency", objective=0.25)
    base.update(kw)
    with pytest.raises(ValueError):
        SLOSpec(**base)


def test_default_specs_cover_all_kinds():
    specs = default_slo_specs()
    assert [s.kind for s in specs] == ["latency", "shed", "quality"]
    assert all(s.fast_burn >= s.slow_burn > s.recover_burn for s in specs)
    # distinct window lengths, ascending, shared bound deduplicated
    assert specs[0].windows == (300.0, 3600.0, 21600.0)


def test_monitor_rejects_empty_and_duplicate_specs():
    reg = MetricsRegistry()
    with pytest.raises(ValueError):
        SLOMonitor(reg, [])
    with pytest.raises(ValueError):
        SLOMonitor(reg, [_spec(), _spec()])


# ---------------------------------------------------------------------------
# burn-rate engage: multi-window AND semantics
# ---------------------------------------------------------------------------
def test_flood_right_after_boot_engages_without_history():
    """Partial-window evaluation: with no samples older than any window, the
    oldest sample is the baseline, so a boot-time flood alerts immediately
    instead of waiting an hour of history."""
    rec = FlightRecorder()
    reg, mon = _monitor(_spec("latency"), recorder=rec)
    mon.tick(0.0)                          # boot baseline (burn 0 by design)
    assert mon.states() == {"latency_slo": "ok"}
    _observe_latency(reg, 0.5, n=10)       # all above the 1.024 ms objective
    mon.tick(1.0)
    assert mon.states() == {"latency_slo": "burning"}
    assert mon.burning_kinds() == frozenset({"latency"})
    events = rec.events_of_kind("slo_burning")
    assert len(events) == 1
    assert events[0]["slo"] == "latency_slo"
    assert events[0]["burn_fast"] == pytest.approx(20.0)   # 1.0 / 0.05


def test_engage_requires_both_windows_of_a_pair():
    """A short spike that has aged out of the *short* fast window no longer
    engages, even while the long fast window still carries it — both windows
    of a pair must exceed the threshold (the workbook's AND)."""
    reg, mon = _monitor(_spec("latency"))
    mon.tick(0.0)
    _observe_latency(reg, 0.5, n=10)       # bad burst at t≈1
    mon.tick(1.0)
    assert mon.states()["latency_slo"] == "burning"
    # drown the *short* windows in good traffic: burn in the 5 s and 30 s
    # windows collapses; the 120 s window still remembers the burst
    for t in range(2, 60):
        _observe_latency(reg, 0.0001, n=50)
        mon.tick(float(t))
    st = mon.status()["specs"][0]
    assert st["state"] == "ok"             # recovered despite 120 s burn > 0
    assert st["windows"]["120"]["burn_rate"] > 0.0
    assert st["windows"]["5"]["burn_rate"] < 1.0


def test_recovery_has_hysteresis_and_does_not_flap_at_the_boundary():
    """Hold the bad fraction between the recover and engage thresholds: burn
    ≈ 5 in every window (above recover=1, below fast=14 and slow=6).  The
    alert must neither re-engage nor recover — exactly one transition."""
    rec = FlightRecorder()
    reg, mon = _monitor(_spec("latency"), recorder=rec)
    mon.tick(0.0)
    _observe_latency(reg, 0.5, n=20)       # engage hard
    mon.tick(1.0)
    assert mon.states()["latency_slo"] == "burning"
    # steady state: 1 bad per 3 good → frac 0.25 → burn 5.0
    for t in range(2, 200):
        _observe_latency(reg, 0.5, n=1)
        _observe_latency(reg, 0.0001, n=3)
        mon.tick(float(t))
    st = mon.status()["specs"][0]
    assert st["state"] == "burning"        # burn 5 ≥ recover threshold 1
    assert st["transitions"] == 1          # never flapped
    assert 4.0 < st["windows"]["5"]["burn_rate"] < 6.5
    assert rec.events_of_kind("slo_recovered") == []
    # now stop the bad traffic entirely: recovery once short windows drain
    for t in range(200, 360):
        _observe_latency(reg, 0.0001, n=3)
        mon.tick(float(t))
    st = mon.status()["specs"][0]
    assert st["state"] == "ok"
    assert st["transitions"] == 2          # one engage + one recover, total
    assert len(rec.events_of_kind("slo_recovered")) == 1


def test_burn_gauges_and_transition_counters_exported():
    reg, mon = _monitor(_spec("latency"))
    mon.tick(0.0)
    _observe_latency(reg, 0.5, n=10)
    mon.tick(1.0)
    burn = reg.gauge("slo_burn_rate", labels=("slo", "window"))
    assert burn.labels(slo="latency_slo", window="5").value == \
        pytest.approx(20.0)
    state = reg.gauge("slo_state", labels=("slo",))
    assert state.labels(slo="latency_slo").value == 1.0
    trans = reg.counter("slo_transitions_total", labels=("slo", "state"))
    assert trans.labels(slo="latency_slo", state="burning").value == 1
    assert reg.counter("slo_ticks_total").get().value == 2
    # the human rendering carries the same story
    text = format_slo(mon.status())
    assert "burning: latency_slo" in text and "latency_slo" in text


def test_min_events_suppresses_empty_window_noise():
    reg, mon = _monitor(_spec("latency", min_events=5))
    mon.tick(0.0)
    _observe_latency(reg, 0.5, n=4)        # 4 bad events < min_events=5
    mon.tick(1.0)
    st = mon.status()["specs"][0]
    assert st["state"] == "ok"
    assert st["windows"]["5"]["burn_rate"] == 0.0
    _observe_latency(reg, 0.5, n=1)        # the 5th crosses the floor
    mon.tick(2.0)
    assert mon.states()["latency_slo"] == "burning"


# ---------------------------------------------------------------------------
# the three kinds' good/bad accounting
# ---------------------------------------------------------------------------
def test_latency_objective_resolves_at_bucket_granularity():
    """Observations at/below the largest bucket bound ≤ objective are good;
    anything past it is bad — no interpolation, never over-counting good."""
    reg, mon = _monitor(_spec("latency", objective=0.001024))
    mon.tick(0.0)
    _observe_latency(reg, 0.001, n=7)      # lands in the ≤1.024 ms bucket
    _observe_latency(reg, 0.002, n=3)      # past it
    mon.tick(1.0)
    st = mon.status()["specs"][0]
    assert (st["good_total"], st["bad_total"]) == (7.0, 3.0)
    assert st["windows"]["5"]["bad_fraction"] == pytest.approx(0.3)


def test_shed_kind_counts_both_shed_flavors_against_served():
    reg, mon = _monitor(_spec("shed"))
    served = reg.counter(SERVED_FAMILY, labels=("graph",))
    shed = reg.counter(SHED_FAMILY, labels=("graph",))
    late = reg.counter(DEADLINE_SHED_FAMILY, labels=("graph",))
    mon.tick(0.0)
    served.labels(graph="g").inc(6)
    shed.labels(graph="g").inc(3)
    late.labels(graph="g").inc(1)
    mon.tick(1.0)
    st = mon.status()["specs"][0]
    assert (st["good_total"], st["bad_total"]) == (6.0, 4.0)
    assert st["state"] == "burning"        # 40%% shed vs a 5%% budget


def test_shed_kind_graph_scoping():
    reg, mon = _monitor(_spec("shed", graph="a"))
    served = reg.counter(SERVED_FAMILY, labels=("graph",))
    shed = reg.counter(SHED_FAMILY, labels=("graph",))
    mon.tick(0.0)
    served.labels(graph="a").inc(10)
    shed.labels(graph="b").inc(50)         # someone else's pain
    mon.tick(1.0)
    st = mon.status()["specs"][0]
    assert st["bad_total"] == 0.0 and st["state"] == "ok"


def test_quality_kind_scores_below_floor_are_bad():
    reg, mon = _monitor(_spec("quality", objective=0.90, budget=0.02))
    from repro.obs.slo import _UNIT_BUCKETS
    hist = reg.histogram(QUALITY_FAMILY, bounds=_UNIT_BUCKETS)
    mon.tick(0.0)
    for v in (0.95, 0.92, 0.97):           # at/above the floor: good
        hist.get().observe(v)
    for v in (0.40, 0.70):                 # below: bad
        hist.get().observe(v)
    mon.tick(1.0)
    st = mon.status()["specs"][0]
    assert (st["good_total"], st["bad_total"]) == (3.0, 2.0)
    assert st["state"] == "burning"        # frac 0.4 / budget 0.02 = burn 20
    assert mon.burning_kinds() == frozenset({"quality"})


def test_sample_ring_is_pruned_to_the_longest_window():
    reg, mon = _monitor(_spec("latency"), resolution_s=1.0)
    for t in range(500):
        mon.tick(float(t))
    ring = mon._states["latency_slo"].samples
    # 120 s horizon at 1 s resolution: ~window/resolution entries, not O(t)
    assert len(ring) <= 123


# ---------------------------------------------------------------------------
# admission controller coupling: push + veto advisories
# ---------------------------------------------------------------------------
class _StubSLO:
    """Dialable burning-kinds signal, monitor-shaped."""

    def __init__(self):
        self.kinds = frozenset()
        self.ticks = 0

    def tick(self, now=None):
        self.ticks += 1

    def burning_kinds(self):
        return self.kinds

    def burning(self):
        return sorted(self.kinds)


class _StubService:
    def __init__(self, kappa=4):
        self.kappa = kappa
        self.depth = 0
        self.degraded = None
        from repro.ppr_serving.telemetry import ServiceTelemetry
        self.telemetry = ServiceTelemetry()
        self.recorder = FlightRecorder()
        self.time_fn = FakeClock()

    def queue_depth(self):
        return self.depth

    def oldest_wait_s(self, now=None):
        return 0.0

    def set_kappa(self, kappa):
        self.kappa = kappa

    def degrade_quality(self, target):
        self.degraded = target

    def restore_quality(self):
        self.degraded = None


def test_latency_burn_pushes_the_ladder_ahead_of_depth():
    svc, slo = _StubService(kappa=4), _StubSLO()
    ctl = AdmissionController(svc, AdmissionConfig(
        high_water=64, low_water=16, deepen_water=16, kappa_max=32,
        degrade_water=32, degrade_low_water=8), slo=slo)
    ctl.tick(0.0)
    assert svc.kappa == 4 and svc.degraded is None and slo.ticks == 1

    slo.kinds = frozenset({"latency"})     # burn engages while depth is 0
    ctl.tick(1.0)
    assert svc.kappa == 8                  # deepened to the first rung
    assert svc.degraded is not None        # quality ceiling engaged
    assert svc.telemetry.slo_advisories == {"deepen": 1, "degrade": 1}
    kinds = [e["kind"] for e in svc.recorder.events(10)]
    assert kinds.count("slo_advisory") == 2

    # recovery is held while the burn persists, even with an empty queue...
    ctl.tick(2.0)
    assert svc.degraded is not None
    # ...and releases once the SLO recovers
    slo.kinds = frozenset()
    ctl.tick(3.0)
    assert svc.kappa == 4 and svc.degraded is None


def test_quality_burn_vetoes_and_lifts_degradation():
    svc, slo = _StubService(kappa=4), _StubSLO()
    ctl = AdmissionController(svc, AdmissionConfig(
        high_water=64, low_water=16, deepen_water=16, kappa_max=32,
        degrade_water=8, degrade_low_water=2), slo=slo)
    svc.depth = 10                         # past degrade_water: ceiling on
    ctl.tick(0.0)
    assert svc.degraded is not None

    slo.kinds = frozenset({"quality"})     # quality budget now burning
    ctl.tick(1.0)
    assert svc.degraded is None            # veto lifted the active ceiling
    ctl.tick(2.0)                          # depth still high: veto holds it off
    assert svc.degraded is None
    assert svc.telemetry.slo_advisories["veto"] == 2
    assert ctl.stats()["slo_burning"] == ["quality"]


def test_controller_without_slo_is_depth_driven_only():
    svc = _StubService(kappa=4)
    ctl = AdmissionController(svc, AdmissionConfig(
        high_water=64, low_water=16, deepen_water=16, kappa_max=32,
        degrade_water=32, degrade_low_water=8))
    assert ctl.slo is None
    ctl.tick(0.0)
    assert svc.kappa == 4 and "slo_burning" not in ctl.stats()


# ---------------------------------------------------------------------------
# deadline-aware shedding (service level)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def graph():
    return holme_kim_powerlaw(400, m=4, seed=2)


def test_deadline_exceeded_queries_shed_at_wave_launch(graph):
    clk = FakeClock()
    svc = PPRService(kappa=2, iterations=3, max_wait=100.0, time_fn=clk)
    svc.register_graph("g", graph)
    late = svc.submit(PPRQuery("g", 3, k=5, deadline=0.5))
    ok = svc.submit(PPRQuery("g", 9, k=5))           # no deadline: immune
    clk.t = 2.0                                      # both waited 2 s
    svc.flush()
    with pytest.raises(QueryRejected) as ei:
        late.result()
    assert ei.value.code == "deadline-exceeded"
    assert ok.done() and len(ok.result().vertices) == 5
    assert svc.telemetry.queries_deadline_shed == 1
    assert svc.telemetry.queries_deadline_shed_by_graph == {"g": 1}
    assert svc.telemetry.summary()["queries_deadline_shed"] == 1


def test_deadline_flush_at_exact_budget_still_serves(graph):
    """max_wait-triggered flushes launch *at* the deadline; the shed check is
    strictly greater-than so those queries still serve."""
    clk = FakeClock()
    svc = PPRService(kappa=4, iterations=3, max_wait=0.5, time_fn=clk)
    svc.register_graph("g", graph)
    fut = svc.submit(PPRQuery("g", 3, k=5, deadline=0.5))
    clk.t = 0.5                                      # exactly at budget
    svc.poll()
    assert fut.done() and len(fut.result().vertices) == 5
    assert svc.telemetry.queries_deadline_shed == 0


def test_deadline_shed_over_http_is_504(graph):
    svc = PPRService(kappa=8, iterations=3, max_wait=0.05)
    svc.register_graph("g", graph)
    server = PPRHTTPServer(svc, pump_interval_s=0.005)

    async def scenario():
        await server.transport.start()     # pump paused: the wait is real
        host, port = server.host, server.port
        task = asyncio.create_task(http_request(
            host, port, "POST", "/v1/ppr",
            {"graph": "g", "vertex": 3, "k": 5, "deadline_s": 0.01}))
        while svc.queue_depth() == 0:
            await asyncio.sleep(0.002)
        await asyncio.sleep(0.05)          # let the deadline lapse queued
        server.pump.start()
        status, _, payload = await task
        assert status == 504
        assert payload["code"] == "deadline-exceeded"
        _, _, stats = await http_request(host, port, "GET", "/v1/stats")
        assert stats["queries_deadline_shed"] == 1
        await server.stop()

    asyncio.run(scenario())


# ---------------------------------------------------------------------------
# e2e: paused-pump flood → latency SLO burns on the wire
# ---------------------------------------------------------------------------
def test_e2e_flood_burns_latency_slo_on_the_wire(graph):
    """The acceptance e2e: flood a paused-pump server, then let it drain —
    admission waits blow the (tiny) latency objective, the burn-rate monitor
    transitions to *burning*, and all three surfaces agree: ``GET /v1/slo``,
    the flight recorder (via ``recent_events``), and ``slo_burn_rate`` in
    ``GET /v1/metrics``."""
    specs = (SLOSpec("latency_p95", "latency", objective=0.000001,
                     budget=0.05, fast_windows=(0.5, 2.0),
                     slow_windows=(2.0, 8.0)),
             SLOSpec("shed_rate", "shed", budget=0.05,
                     fast_windows=(0.5, 2.0), slow_windows=(2.0, 8.0)))
    svc = PPRService(kappa=4, iterations=3, max_wait=0.002, slo=specs)
    svc.register_graph("g", graph, formats=[26])
    svc.run_batch([PPRQuery("g", v, k=5) for v in range(4)])  # warm jit
    server = PPRHTTPServer(svc, admission=AdmissionConfig(
        high_water=64, low_water=8, deepen_water=16, kappa_max=8,
        degrade_water=32, degrade_low_water=4), pump_interval_s=0.002)

    async def scenario():
        await server.transport.start()     # pump paused: queue builds
        host, port = server.host, server.port
        task = asyncio.gather(*[
            http_request(host, port, "POST", "/v1/ppr",
                         {"graph": "g", "vertex": int(v), "k": 5})
            for v in range(100, 116)])    # disjoint from the warmup cache
        deadline = asyncio.get_running_loop().time() + 10.0
        while svc.queue_depth() < 16:
            assert asyncio.get_running_loop().time() < deadline
            await asyncio.sleep(0.002)
        server.pump.start()                # drain: latencies include the wait
        rs = await task
        assert [r[0] for r in rs] == [200] * 16

        # the monitor must reach burning while results carry the queue wait
        deadline = asyncio.get_running_loop().time() + 10.0
        status = None
        while asyncio.get_running_loop().time() < deadline:
            _, _, status = await http_request(host, port, "GET", "/v1/slo")
            lat = next(s for s in status["specs"]
                       if s["name"] == "latency_p95")
            if lat["state"] == "burning":
                break
            await asyncio.sleep(0.01)
        assert lat["state"] == "burning", format_slo(status)
        assert "latency_p95" in status["burning"]
        assert lat["windows"]["0.5"]["burn_rate"] >= 14.0
        # the flight-recorder transition rides along in the same response
        kinds = [e["kind"] for e in status["recent_events"]]
        assert "slo_burning" in kinds
        # ...and the burn gauge is on the Prometheus surface
        st, _, text = await http_request(host, port, "GET", "/v1/metrics")
        assert st == 200
        assert 'slo_burn_rate{slo="latency_p95",window="0.5"}' in text
        assert "slo_transitions_total" in text

        # ?n= caps the event tail; a bad n is a clean 400
        _, _, capped = await http_request(host, port, "GET", "/v1/slo?n=1")
        assert len(capped["recent_events"]) <= 1
        st, _, err = await http_request(host, port, "GET", "/v1/slo?n=zero")
        assert st == 400 and err["code"] == "bad-request"
        await server.stop()

    asyncio.run(scenario())


def test_slo_endpoint_404_when_monitoring_off(graph):
    svc = PPRService(kappa=4, iterations=3)
    svc.register_graph("g", graph)
    server = PPRHTTPServer(svc, pump_interval_s=0.01)

    async def scenario():
        await server.start()
        st, _, payload = await http_request(server.host, server.port,
                                            "GET", "/v1/slo")
        assert st == 404 and payload["code"] == "slo-monitoring-off"
        await server.stop()

    asyncio.run(scenario())


def test_service_slo_true_uses_house_default_specs(graph):
    svc = PPRService(kappa=4, iterations=3, slo=True)
    assert [s.kind for s in svc.slo.specs] == ["latency", "shed", "quality"]
    svc2 = PPRService(kappa=4, iterations=3)
    assert svc2.slo is None                # off stays zero-cost
