"""OTLP/HTTP exporter: span/metric payload encoding against a committed
golden fixture (byte-determinism is the contract — trace/span ids derive from
the tracer's monotone ids, keys are sorted), delta temporality across pushes,
bounded-queue overflow, retry/backoff + drop accounting, the ``due``/``tick``
/``flush`` cadence, and the fan-out sink (export beside the flight recorder,
never instead of it).

Regenerate the fixture after an *intentional* wire-format change:

    PYTHONPATH=src python tests/test_otlp.py --write
"""
import json
import os
import sys

import pytest

from repro.obs import (
    FlightRecorder,
    MetricsRegistry,
    OTLPExporter,
    Tracer,
    fanout_sink,
)

GOLDEN = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                      "otlp_golden.json")


class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t


class CaptureTransport:
    """The injectable send seam: records (url, decoded payload); optionally
    fails the first ``fail_first`` sends to drive the retry path."""

    def __init__(self, fail_first=0):
        self.sent = []
        self.fail_first = fail_first
        self.attempts = 0

    def __call__(self, url, body):
        self.attempts += 1
        if self.attempts <= self.fail_first:
            raise ConnectionError("collector unreachable")
        self.sent.append((url, json.loads(body.decode("utf-8"))))


def _exporter(clk, transport, **kw):
    kw.setdefault("flush_interval_s", 5.0)
    kw.setdefault("backoff_s", 0.0)        # no real sleeps in tests
    return OTLPExporter("http://collector:4318", transport=transport,
                        time_fn=clk, **kw)


def _golden_scenario():
    """One deterministic export cycle: a two-level trace plus one delta
    metrics push over a small registry exercising every instrument kind."""
    clk = FakeClock()
    transport = CaptureTransport()
    exp = _exporter(clk, transport)
    tracer = Tracer(time_fn=clk, sink=exp.record_trace)

    tr = tracer.start("query", "query", graph="g", vertex=7, sampled=True)
    clk.t = 0.25
    sp = tr.span("wave", clk(), kappa=4)
    clk.t = 0.75
    sp.child("resolve", clk(), precision=26).end(0.875)
    sp.end(1.0)
    clk.t = 2.0
    tracer.finish(tr, outcome="resolved", scores=(0.5, 0.25))

    reg = MetricsRegistry()
    c = reg.counter("requests_total", "Requests.", labels=("route",))
    c.labels(route="/v1/ppr").inc(3)
    c.labels(route="/v1/metrics").inc()
    g = reg.gauge("queue_depth", "Pending queries.")
    g.get().set(5.0)
    g.get().set(2.0)
    h = reg.histogram("wait_seconds", "Admission wait.",
                      bounds=(0.001, 0.01, 0.1))
    for v in (0.0005, 0.05, 0.05, 2.0):
        h.get().observe(v)
    r = reg.reservoir("wave_ms", "Wave latency.", size=8)
    for v in (1.0, 2.0, 3.0, 4.0):
        r.get().add(v)

    clk.t = 6.0                            # past the flush interval
    posts = exp.tick(reg)
    return exp, transport, posts


def build_golden() -> str:
    _, transport, _ = _golden_scenario()
    return json.dumps(
        [{"url": url, "payload": payload} for url, payload in transport.sent],
        indent=2, sort_keys=True) + "\n"


# ---------------------------------------------------------------------------
# the golden snapshot
# ---------------------------------------------------------------------------
def test_payloads_match_committed_golden_fixture():
    got = build_golden()
    with open(GOLDEN, encoding="utf-8") as fh:
        want = fh.read()
    assert got == want, (
        "OTLP wire payloads changed. If intentional, regenerate with:\n"
        "  PYTHONPATH=src python tests/test_otlp.py --write")


def test_golden_scenario_shape():
    """Sanity on the fixture's structure, independent of exact bytes."""
    exp, transport, posts = _golden_scenario()
    assert posts == 2                      # one span batch + one metric push
    (turl, tpayload), (murl, mpayload) = transport.sent
    assert turl.endswith("/v1/traces") and murl.endswith("/v1/metrics")

    spans = tpayload["resourceSpans"][0]["scopeSpans"][0]["spans"]
    assert [s["name"] for s in spans] == ["query", "wave", "resolve"]
    root, wave, resolve = spans
    assert root["traceId"] == f"{1:032x}"
    assert root["spanId"] == f"{1 << 16:016x}"
    assert "parentSpanId" not in root
    assert wave["parentSpanId"] == root["spanId"]
    assert resolve["parentSpanId"] == wave["spanId"]
    assert root["startTimeUnixNano"] == "0"
    assert root["endTimeUnixNano"] == str(2 * 10**9)
    attrs = {a["key"]: a["value"] for a in root["attributes"]}
    assert attrs["trace.kind"] == {"stringValue": "query"}
    assert attrs["sampled"] == {"boolValue": True}   # bool, not int 1
    assert attrs["vertex"] == {"intValue": "7"}
    assert attrs["scores"]["arrayValue"]["values"] == \
        [{"doubleValue": 0.5}, {"doubleValue": 0.25}]

    metrics = {m["name"]: m
               for m in mpayload["resourceMetrics"][0]
               ["scopeMetrics"][0]["metrics"]}
    assert metrics["requests_total"]["sum"]["aggregationTemporality"] == 1
    assert metrics["requests_total"]["sum"]["isMonotonic"] is True
    assert metrics["queue_depth"]["gauge"]["dataPoints"][0]["asDouble"] == 2.0
    assert metrics["queue_depth_peak"]["gauge"]["dataPoints"][0] \
        ["asDouble"] == 5.0
    hist = metrics["wait_seconds"]["histogram"]
    assert hist["aggregationTemporality"] == 1
    dp = hist["dataPoints"][0]
    assert dp["count"] == "4" and dp["bucketCounts"] == ["1", "0", "2", "1"]
    summ = metrics["wave_ms"]["summary"]["dataPoints"][0]
    assert summ["count"] == "4"
    assert [q["quantile"] for q in summ["quantileValues"]] == [0.5, 0.95, 0.99]


# ---------------------------------------------------------------------------
# delta temporality
# ---------------------------------------------------------------------------
def test_counters_and_histograms_push_deltas_not_totals():
    clk = FakeClock()
    transport = CaptureTransport()
    exp = _exporter(clk, transport)
    reg = MetricsRegistry()
    c = reg.counter("hits_total", "Hits.")
    h = reg.histogram("lat", "Latency.", bounds=(1.0, 2.0))

    c.get().inc(10)
    h.get().observe(0.5)
    clk.t = 5.0
    exp.tick(reg)
    c.get().inc(4)                         # 14 cumulative, 4 new
    h.get().observe(1.5)
    clk.t = 10.0
    exp.tick(reg)

    def metric(i, name):
        ms = transport.sent[i][1]["resourceMetrics"][0]["scopeMetrics"][0][
            "metrics"]
        return next(m for m in ms if m["name"] == name)

    assert metric(0, "hits_total")["sum"]["dataPoints"][0]["asDouble"] == 10.0
    assert metric(1, "hits_total")["sum"]["dataPoints"][0]["asDouble"] == 4.0
    assert metric(1, "lat")["histogram"]["dataPoints"][0]["bucketCounts"] == \
        ["0", "1", "0"]
    # the delta window's start advances to the previous push
    dp = metric(1, "hits_total")["sum"]["dataPoints"][0]
    assert dp["startTimeUnixNano"] == str(5 * 10**9)
    assert dp["timeUnixNano"] == str(10 * 10**9)


def test_metric_push_cadence_respects_flush_interval():
    clk = FakeClock()
    transport = CaptureTransport()
    exp = _exporter(clk, transport, flush_interval_s=5.0)
    reg = MetricsRegistry()
    assert exp.due(0.0)                    # first push is always owed
    assert exp.tick(reg, now=0.0) == 1
    assert not exp.due(3.0)
    assert exp.tick(reg, now=3.0) == 0     # interval not elapsed: no POST
    assert exp.due(5.0)
    assert exp.tick(reg, now=5.0) == 1
    # flush forces a push regardless of the interval
    assert exp.flush(reg, now=5.5) == 1
    assert exp.stats()["metric_pushes"] == 3


# ---------------------------------------------------------------------------
# failure policy: bounded queue, retries, drop accounting
# ---------------------------------------------------------------------------
def test_span_queue_drops_oldest_past_capacity():
    clk = FakeClock()
    transport = CaptureTransport()
    exp = _exporter(clk, transport, queue_capacity=3)
    tracer = Tracer(time_fn=clk, sink=exp.record_trace)
    for i in range(5):                     # 5 single-span traces
        tracer.finish(tracer.start("query", f"q{i}"))
    s = exp.stats()
    assert s["queue_depth"] == 3 and s["spans_dropped"] == 2
    assert s["spans_queued"] == 5
    exp.tick()
    # the survivors are the *newest* three (fresh beats stale)
    (_, payload), = transport.sent
    names = [s["name"] for s in
             payload["resourceSpans"][0]["scopeSpans"][0]["spans"]]
    assert names == ["q2", "q3", "q4"]


def test_send_retries_then_succeeds():
    clk = FakeClock()
    slept = []
    transport = CaptureTransport(fail_first=2)
    exp = OTLPExporter("http://c:4318", transport=transport, time_fn=clk,
                       max_retries=2, backoff_s=0.1,
                       sleep_fn=slept.append)
    tracer = Tracer(time_fn=clk, sink=exp.record_trace)
    tracer.finish(tracer.start("query", "q"))
    exp.tick()
    s = exp.stats()
    assert s["spans_exported"] == 1 and s["span_batches_sent"] == 1
    assert s["send_retries"] == 2 and s["send_failures"] == 0
    assert slept == [0.1, 0.2]             # exponential backoff


def test_exhausted_retries_drop_the_batch_and_count_failures():
    clk = FakeClock()
    transport = CaptureTransport(fail_first=99)
    exp = OTLPExporter("http://c:4318", transport=transport, time_fn=clk,
                       max_retries=1, backoff_s=0.0)
    tracer = Tracer(time_fn=clk, sink=exp.record_trace)
    tracer.finish(tracer.start("query", "q"))
    exp.tick()
    s = exp.stats()
    assert s["send_failures"] == 1 and s["spans_dropped"] == 1
    assert s["spans_exported"] == 0 and s["queue_depth"] == 0


def test_failed_metric_push_advances_the_window_without_double_report():
    clk = FakeClock()
    transport = CaptureTransport(fail_first=1)
    exp = OTLPExporter("http://c:4318", transport=transport, time_fn=clk,
                       max_retries=0, backoff_s=0.0, flush_interval_s=5.0)
    reg = MetricsRegistry()
    c = reg.counter("hits_total", "Hits.")
    c.get().inc(7)
    exp.tick(reg, now=5.0)                 # POST fails: window dropped
    assert exp.stats()["send_failures"] == 1
    c.get().inc(2)
    exp.tick(reg, now=10.0)                # only the *new* delta reports
    ms = transport.sent[0][1]["resourceMetrics"][0]["scopeMetrics"][0][
        "metrics"]
    dp = next(m for m in ms if m["name"] == "hits_total")["sum"]["dataPoints"]
    assert dp[0]["asDouble"] == 2.0        # the failed window's 7 is lost
    assert dp[0]["startTimeUnixNano"] == str(5 * 10**9)


def test_span_batching_splits_at_max_batch():
    clk = FakeClock()
    transport = CaptureTransport()
    exp = _exporter(clk, transport, max_batch=2)
    tracer = Tracer(time_fn=clk, sink=exp.record_trace)
    for i in range(5):
        tracer.finish(tracer.start("query", f"q{i}"))
    exp.tick()
    trace_posts = [p for url, p in transport.sent if url.endswith("/traces")]
    sizes = [len(p["resourceSpans"][0]["scopeSpans"][0]["spans"])
             for p in trace_posts]
    assert sizes == [2, 2, 1]
    assert exp.stats()["span_batches_sent"] == 3


# ---------------------------------------------------------------------------
# registry mirror + fan-out
# ---------------------------------------------------------------------------
def test_bound_registry_mirrors_exporter_counters():
    clk = FakeClock()
    reg = MetricsRegistry()
    exp = _exporter(clk, CaptureTransport(), registry=reg)
    tracer = Tracer(time_fn=clk, sink=exp.record_trace)
    tracer.finish(tracer.start("query", "q"))
    exp.tick()
    assert reg.counter("otlp_spans_queued_total").get().value == 1
    assert reg.counter("otlp_spans_exported_total").get().value == 1
    assert reg.counter("otlp_batches_sent_total").get().value == 1


def test_fanout_sink_feeds_recorder_and_exporter():
    clk = FakeClock()
    rec = FlightRecorder()
    exp = _exporter(clk, CaptureTransport())
    tracer = Tracer(time_fn=clk,
                    sink=fanout_sink(rec.record_trace, exp.record_trace))
    tracer.finish(tracer.start("query", "q", vertex=3))
    assert len(rec.traces()) == 1          # the local record survives
    assert exp.stats()["spans_queued"] == 1
    # single/None composition collapses to the sink itself (no wrapper)
    append = [].append
    assert fanout_sink(append) is append
    assert fanout_sink(None, append, None) is append


@pytest.mark.parametrize("kw", [
    dict(flush_interval_s=0.0),
    dict(max_batch=0),
    dict(queue_capacity=0),
    dict(max_retries=-1),
    dict(backoff_s=-0.1),
])
def test_exporter_validation_rejects(kw):
    with pytest.raises(ValueError):
        OTLPExporter("http://c:4318", **kw)


if __name__ == "__main__":
    if "--write" in sys.argv:
        with open(GOLDEN, "w", encoding="utf-8") as fh:
            fh.write(build_golden())
        print(f"wrote {GOLDEN}")
    else:
        print(__doc__)
