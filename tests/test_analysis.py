"""repro.analysis: per-rule firing + non-firing fixtures for all three packs
(FXP fixed-point width safety, JAX hot-path hygiene, ASY async-serving
discipline), the inline suppression contract (reasoned allow suppresses,
bare allow is itself a finding), baseline round-trip (write -> check passes,
fix -> stale entry fails --check), and the CLI surface (exit codes, --json
report, --list-rules)."""
import json
import textwrap

import pytest

from repro.analysis.cli import main as cli_main
from repro.analysis.core import all_rules, analyze_paths, get_rule


def run(tmp_path, source, rule_id=None, name="mod.py"):
    """Analyze one dedented source string; optionally restrict to one rule."""
    f = tmp_path / name
    f.write_text(textwrap.dedent(source))
    rules = None if rule_id is None else [get_rule(rule_id)]
    return analyze_paths([str(f)], str(tmp_path), rules=rules)


def rule_ids(result):
    return [f.rule_id for f in result.findings]


# ---------------------------------------------------------------------------
# FXP pack — fixed-point width safety
# ---------------------------------------------------------------------------
def test_fxp001_fires_on_unguarded_raw_accumulation(tmp_path):
    r = run(tmp_path, """
        def accumulate(raw_vals, seg):
            return segment_sum(raw_vals, seg)
    """, "FXP001")
    assert rule_ids(r) == ["FXP001"]


def test_fxp001_quiet_with_width_guard(tmp_path):
    r = run(tmp_path, """
        def accumulate(raw_vals, seg, raw_acc):
            a = segment_sum(raw_vals.astype(jnp.int64), seg)
            b = raw_acc.astype(jnp.int32).sum(0)
            return a + b
    """, "FXP001")
    assert rule_ids(r) == []


def test_fxp001_fires_on_raw_dot_sum(tmp_path):
    r = run(tmp_path, """
        def total(raw_acc):
            return raw_acc.sum(0)
    """, "FXP001")
    assert rule_ids(r) == ["FXP001"]


def test_fxp002_fires_when_shift_exceeds_lane(tmp_path):
    r = run(tmp_path, """
        def pack():
            x = 0x3FFFFFF
            return x << 10
    """, "FXP002")
    assert rule_ids(r) == ["FXP002"]
    assert "exceeds the 32-bit lane" in r.findings[0].message


def test_fxp002_quiet_when_shift_fits_or_width_unknown(tmp_path):
    r = run(tmp_path, """
        def fits():
            x = 0x3FFFFFF
            return x << 4

        def unknown_operand(y):
            return y << 30
    """, "FXP002")
    # 26+4 fits; y's width is unresolved so the rule must stay silent rather
    # than assume full width and spray false positives
    assert rule_ids(r) == []


def test_fxp002_seeds_module_level_masks(tmp_path):
    r = run(tmp_path, """
        _MASK16 = np.uint32(0xFFFF)

        def lift():
            return _MASK16 << 20
    """, "FXP002")
    assert rule_ids(r) == ["FXP002"]


def test_fxp002_infers_width_across_local_calls(tmp_path):
    # the callee's return width is resolved from the call site's argument
    # widths — one call overflows the lane, the narrower one fits
    r = run(tmp_path, """
        def widen(v):
            return v << 4

        def overflows():
            a = 0x3FFFFFF
            b = widen(a)
            return b << 6

        def fits():
            a = 0xFFFF
            b = widen(a)
            return b << 6
    """, "FXP002")
    assert rule_ids(r) == ["FXP002"]
    assert r.findings[0].line > 0
    assert "~30-bit" in r.findings[0].message


def test_fxp002_quiet_on_unresolvable_callee(tmp_path):
    # imported/external callees have no derivable return width: stay silent
    # instead of assuming full width
    r = run(tmp_path, """
        def lift(u):
            return external(u) << 30
    """, "FXP002")
    assert rule_ids(r) == []


def test_fxp002_constant_mask_blesses_unknown_operand(tmp_path):
    # (unknown & 0xFF) is bounded by the mask — the shift is checkable even
    # though the operand itself is unresolved, and 8 + 30 overflows
    r = run(tmp_path, """
        def lift(u):
            return (u & 0xFF) << 30

        def fits(u):
            return (u & 0xFF) << 20
    """, "FXP002")
    assert rule_ids(r) == ["FXP002"]


def test_fxp002_recursive_callee_degrades_to_unknown(tmp_path):
    # self-recursion must neither loop nor produce a bogus bound
    r = run(tmp_path, """
        def spin(v):
            return spin(v << 8)

        def lift():
            a = 0x3FFFFFF
            return spin(a) << 10
    """, "FXP002")
    assert rule_ids(r) == []


def test_fxp003_fires_on_raw_times_raw_outside_mul(tmp_path):
    r = run(tmp_path, """
        def combine(a_raw, b_raw):
            return a_raw * b_raw
    """, "FXP003")
    assert rule_ids(r) == ["FXP003"]


def test_fxp003_quiet_inside_blessed_helpers(tmp_path):
    r = run(tmp_path, """
        def mul(a_raw, b_raw):
            return a_raw * b_raw
    """, "FXP003")
    assert rule_ids(r) == []


def test_fxp003_fires_on_raw_float_literal_mix(tmp_path):
    r = run(tmp_path, """
        def scale(x_raw):
            return x_raw * 0.5
    """, "FXP003")
    assert rule_ids(r) == ["FXP003"]
    clean = run(tmp_path, """
        def scale(x):
            return x * 0.5
    """, "FXP003", name="clean.py")
    assert rule_ids(clean) == []


# ---------------------------------------------------------------------------
# JAX pack — hot-path hygiene
# ---------------------------------------------------------------------------
def test_jax101_fires_on_sync_cast_in_jit(tmp_path):
    r = run(tmp_path, """
        @jax.jit
        def step(x):
            return float(x)
    """, "JAX101")
    assert rule_ids(r) == ["JAX101"]


def test_jax101_static_shapes_and_argnames_are_exempt(tmp_path):
    r = run(tmp_path, """
        @functools.partial(jax.jit, static_argnames=("n",))
        def step(x, n):
            rows = float(x.shape[0])
            return x * (rows + int(n))
    """, "JAX101")
    assert rule_ids(r) == []


def test_jax101_hot_path_marker_arms_unjitted_functions(tmp_path):
    r = run(tmp_path, """
        # repro: hot-path
        def step(x):
            return x.item()
    """, "JAX101")
    assert rule_ids(r) == ["JAX101"]


def test_jax102_fires_on_host_numpy_over_traced(tmp_path):
    r = run(tmp_path, """
        @jax.jit
        def rank(x):
            return np.argsort(x)
    """, "JAX102")
    assert rule_ids(r) == ["JAX102"]
    clean = run(tmp_path, """
        @jax.jit
        def rank(x):
            return jnp.argsort(x)
    """, "JAX102", name="clean.py")
    assert rule_ids(clean) == []


def test_jax103_fires_only_inside_actual_jit(tmp_path):
    r = run(tmp_path, """
        @jax.jit
        def clamp(x):
            if x > 0:
                return x
            return -x
    """, "JAX103")
    assert rule_ids(r) == ["JAX103"]
    # marked-hot but unjitted: Python branching on arrays is legal there
    marked = run(tmp_path, """
        # repro: hot-path
        def clamp(x):
            if x > 0:
                return x
            return -x
    """, "JAX103", name="marked.py")
    assert rule_ids(marked) == []


def test_jax103_is_none_test_is_static(tmp_path):
    r = run(tmp_path, """
        @jax.jit
        def seed(x, warm):
            if warm is None:
                return x
            return warm
    """, "JAX103")
    assert rule_ids(r) == []


# ---------------------------------------------------------------------------
# ASY pack — async-serving discipline
# ---------------------------------------------------------------------------
def test_asy301_fires_on_time_sleep_in_async(tmp_path):
    r = run(tmp_path, """
        import time

        async def tick():
            time.sleep(0.1)
    """, "ASY301")
    assert rule_ids(r) == ["ASY301"]


def test_asy301_quiet_on_awaited_sleep_and_sync_defs(tmp_path):
    r = run(tmp_path, """
        import asyncio, time

        async def tick():
            await asyncio.sleep(0.1)

        def sync_retry():
            time.sleep(0.1)
    """, "ASY301")
    assert rule_ids(r) == []


def test_asy302_fires_on_untimed_result_in_async(tmp_path):
    r = run(tmp_path, """
        async def handler(fut):
            return fut.result()
    """, "ASY302")
    assert rule_ids(r) == ["ASY302"]
    probe = run(tmp_path, """
        async def handler(fut):
            return fut.result(timeout=0)
    """, "ASY302", name="probe.py")
    assert rule_ids(probe) == []


def test_asy303_fires_on_direct_service_drive(tmp_path):
    r = run(tmp_path, """
        async def run(self):
            self.service.poll()
    """, "ASY303")
    assert rule_ids(r) == ["ASY303"]


def test_asy303_quiet_when_offloaded(tmp_path):
    r = run(tmp_path, """
        async def run(self, loop, ex):
            return await loop.run_in_executor(ex, self.service.poll)
    """, "ASY303")
    assert rule_ids(r) == []


def test_asy304_fires_on_discarded_submit(tmp_path):
    r = run(tmp_path, """
        async def handle(svc, q):
            svc.submit(q)
    """, "ASY304")
    assert rule_ids(r) == ["ASY304"]
    held = run(tmp_path, """
        async def handle(svc, q):
            fut = svc.submit(q)
            return fut
    """, "ASY304", name="held.py")
    assert rule_ids(held) == []


# ---------------------------------------------------------------------------
# suppression contract
# ---------------------------------------------------------------------------
def test_reasoned_allow_suppresses_same_line(tmp_path):
    r = run(tmp_path, """
        def combine(a_raw, b_raw):
            return a_raw * b_raw  # repro: allow[FXP003] exactness proven in tests
    """)
    assert rule_ids(r) == []
    assert r.suppressed == 1


def test_reasoned_allow_on_own_line_covers_next_line(tmp_path):
    r = run(tmp_path, """
        def combine(a_raw, b_raw):
            # repro: allow[FXP003] exactness proven in tests
            return a_raw * b_raw
    """)
    assert rule_ids(r) == []
    assert r.suppressed == 1


def test_bare_allow_is_itself_a_finding_and_suppresses_nothing(tmp_path):
    r = run(tmp_path, """
        def combine(a_raw, b_raw):
            return a_raw * b_raw  # repro: allow[FXP003]
    """)
    assert sorted(rule_ids(r)) == ["FXP003", "SUP000"]
    assert r.suppressed == 0


def test_allow_for_wrong_rule_does_not_suppress(tmp_path):
    r = run(tmp_path, """
        def combine(a_raw, b_raw):
            return a_raw * b_raw  # repro: allow[FXP001] not the rule that fires
    """)
    assert rule_ids(r) == ["FXP003"]
    assert r.suppressed == 0


# ---------------------------------------------------------------------------
# baseline round-trip + CLI surface
# ---------------------------------------------------------------------------
VIOLATION = "def combine(a_raw, b_raw):\n    return a_raw * b_raw\n"
CLEAN = "def combine(a, b):\n    return a * b\n"


def test_baseline_round_trip(tmp_path, capsys):
    mod = tmp_path / "mod.py"
    mod.write_text(VIOLATION)
    root = str(tmp_path)

    # no baseline yet: the finding fails the run
    assert cli_main([str(mod), "--root", root]) == 1

    # record it, then the same tree passes --check
    assert cli_main([str(mod), "--root", root, "--write-baseline"]) == 0
    assert (tmp_path / "ANALYSIS_baseline.json").exists()
    assert cli_main([str(mod), "--root", root, "--check"]) == 0

    # a NEW violation (same rule, same message — multiset budget) still fails
    mod.write_text(VIOLATION + "\n\ndef again(c_raw, d_raw):\n"
                   "    return c_raw * d_raw\n")
    assert cli_main([str(mod), "--root", root, "--check"]) == 1

    # fixing everything leaves a stale ledger entry: --check fails (the
    # ledger only shrinks), a plain run passes
    mod.write_text(CLEAN)
    assert cli_main([str(mod), "--root", root]) == 0
    assert cli_main([str(mod), "--root", root, "--check"]) == 1
    out = capsys.readouterr().out
    assert "stale baseline entry" in out


def test_cli_json_report(tmp_path):
    mod = tmp_path / "mod.py"
    mod.write_text(VIOLATION)
    report = tmp_path / "report.json"
    rc = cli_main([str(mod), "--root", str(tmp_path),
                   "--json", str(report)])
    assert rc == 1
    payload = json.loads(report.read_text())
    assert payload["version"] == 1
    assert payload["files_scanned"] == 1
    assert payload["baselined"] == 0
    assert [f["rule"] for f in payload["findings"]] == ["FXP003"]
    f = payload["findings"][0]
    assert f["path"] == "mod.py" and f["line"] == 2


def test_cli_list_rules_prints_full_catalogue(capsys):
    assert cli_main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rid in ("FXP001", "FXP002", "FXP003", "JAX101", "JAX102", "JAX103",
                "ASY301", "ASY302", "ASY303", "ASY304"):
        assert rid in out


def test_repo_tree_is_clean_under_committed_baseline():
    """The acceptance gate, as a test: the shipped tree analyzes clean."""
    import os
    root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    result = analyze_paths(["src/repro", "benchmarks", "examples"], root)
    assert [f.render() for f in result.findings] == []


def test_rule_catalogue_is_stable():
    ids = [r.id for r in all_rules()]
    assert ids == sorted(ids) and len(ids) == len(set(ids))
    assert {"FXP001", "FXP002", "FXP003", "JAX101", "JAX102", "JAX103",
            "ASY301", "ASY302", "ASY303", "ASY304"} <= set(ids)
