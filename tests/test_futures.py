"""Futures-based serving API + engine-backend layer: PPRFuture lifecycle
(cache-hit fast path, deadline flush, delta epoch bump, purge rejection,
callbacks, driving result()), wrapper-vs-futures equivalence, the engine
registry, and per-engine telemetry."""
import warnings

import numpy as np
import pytest

from repro.graphs import erdos_renyi, holme_kim_powerlaw
from repro.graph_updates import EdgeDelta, localized_delta
from repro.ppr_serving import (
    PPRFuture,
    PPRQuery,
    PPRService,
    QueryRejected,
    WaveEngine,
    engine_families,
    engine_for,
    engine_names,
    get_engine,
)


@pytest.fixture(scope="module")
def graph():
    return holme_kim_powerlaw(400, m=4, seed=2)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


# ---------------------------------------------------------------------------
# PPRFuture lifecycle
# ---------------------------------------------------------------------------
def test_cache_hit_fast_path_resolves_before_submit_returns(graph):
    svc = PPRService(kappa=2, iterations=4)
    svc.register_graph("g", graph)
    first = svc.submit(PPRQuery("g", 7, k=5))
    assert not first.done()
    assert first.result().source == "wave"       # result() drives the service
    again = svc.submit(PPRQuery("g", 7, k=5))
    assert again.done()                          # resolved inside submit()
    rec = again.result()
    assert rec.source == "cache"
    np.testing.assert_array_equal(rec.vertices, first.result().vertices)
    # a done future's result is idempotent and never re-drives
    assert again.result() is rec
    assert again.exception() is None


def test_deadline_flush_resolves_batched_futures(graph):
    """A partial wave's futures resolve when the admission budget expires and
    poll() launches the deadline flush."""
    clk = FakeClock()
    svc = PPRService(kappa=8, iterations=4, max_wait=1.0, time_fn=clk)
    svc.register_graph("g", graph)
    futs = [svc.submit(PPRQuery("g", v, k=5)) for v in (3, 9, 11)]
    assert svc.poll() == 0                       # budget not yet spent
    assert not any(f.done() for f in futs)
    clk.t = 1.5
    assert svc.poll() == 1                       # one partial wave flushed
    assert all(f.done() for f in futs)
    recs = [f.result() for f in futs]
    assert all(r.source == "wave" for r in recs)
    assert {r.wave_id for r in recs} == {recs[0].wave_id}   # co-batched


def test_result_timeout_zero_is_a_nonblocking_probe(graph):
    svc = PPRService(kappa=8, iterations=4)
    svc.register_graph("g", graph)
    fut = svc.submit(PPRQuery("g", 5, k=5))
    with pytest.raises(TimeoutError):
        fut.result(timeout=0)
    assert not fut.done()                        # the probe did not drive
    assert fut.result().source == "wave"         # a real result() still works


def test_result_drives_only_its_own_wave_key(graph):
    """result() on a pending future flushes that future's wave; co-queued
    queries on *other* keys stay pending (no global drain).  max_wait keeps
    the partial waves un-ready, so only the targeted flush launches."""
    svc = PPRService(kappa=8, iterations=4, max_wait=100.0)
    svc.register_graph("g", graph, formats=[26])
    f_fixed = svc.submit(PPRQuery("g", 3, k=5, precision=26))
    f_float = svc.submit(PPRQuery("g", 9, k=5))
    assert f_fixed.result().source == "wave"
    assert not f_float.done()                    # float key untouched
    assert f_float.result().source == "wave"


def test_add_done_callback_immediate_deferred_and_swallowed(graph):
    svc = PPRService(kappa=1, iterations=3)
    svc.register_graph("g", graph)
    seen = []
    fut = svc.submit(PPRQuery("g", 5, k=5))
    fut.add_done_callback(lambda f: seen.append(("deferred", f.done())))
    fut.add_done_callback(lambda f: 1 / 0)       # must be swallowed
    assert seen == []
    svc.flush()
    assert seen == [("deferred", True)]
    fut.add_done_callback(lambda f: seen.append(("immediate", f.done())))
    assert seen[-1] == ("immediate", True)


def test_apply_delta_epoch_bump_with_pending_future(graph):
    """Satellite: a pending future outside the delta's frontier survives the
    epoch bump and resolves against the new topology; a frontier future is
    rejected descriptively instead of dangling."""
    svc = PPRService(kappa=8, iterations=4)
    svc.register_graph("g", graph)
    d = localized_delta(graph, np.random.default_rng(3), n_add=2, n_remove=1)
    frontier = set(int(v) for v in d.affected_frontier(graph))
    in_f = sorted(frontier)[0]
    out_f = next(v for v in range(graph.num_vertices) if v not in frontier)
    f_in = svc.submit(PPRQuery("g", in_f, k=5))
    f_out = svc.submit(PPRQuery("g", out_f, k=5))
    svc.apply_delta("g", d)
    assert f_in.done()
    with pytest.raises(QueryRejected, match="affected frontier"):
        f_in.result()
    assert isinstance(f_in.exception(), QueryRejected)
    assert not f_out.done()
    rec = f_out.result()                         # resolves on the new epoch
    assert rec.source == "wave"
    epoch_keys = [k for k in svc.cache._store if k[2] == out_f]
    assert epoch_keys and all(k[1] == 1 for k in epoch_keys)


def test_reregistration_rejects_pending_futures_descriptively(graph):
    """Satellite: purge on re-registration rejects pending futures with a
    descriptive error instead of leaving them forever-pending."""
    svc = PPRService(kappa=8, iterations=4)
    svc.register_graph("g", graph)
    fut = svc.submit(PPRQuery("g", 42, k=5))
    callback_state = []
    fut.add_done_callback(lambda f: callback_state.append(type(f.exception())))
    svc.register_graph("g", erdos_renyi(100, 600, seed=1))
    assert fut.done()
    assert callback_state == [QueryRejected]
    with pytest.raises(QueryRejected, match="re-registered"):
        fut.result()


def test_flush_resolves_everything_and_counts_waves(graph):
    svc = PPRService(kappa=2, iterations=3)
    svc.register_graph("g", graph, formats=[26])
    futs = [svc.submit(PPRQuery("g", v, k=5, precision=p))
            for v, p in ((1, 26), (2, 26), (3, None), (4, 26))]
    # two full/partial fixed waves' worth + one float partial
    assert svc.flush() == 3
    assert all(f.done() for f in futs)
    assert svc.flush() == 0                      # nothing left


# ---------------------------------------------------------------------------
# deprecated wrappers: behaviour preserved, warning emitted, results identical
# ---------------------------------------------------------------------------
def _futures_batch(svc, queries):
    futures = [svc.submit(q) for q in queries]
    svc.flush()
    return [f.result() for f in futures]


def test_run_batch_is_the_supported_batch_entry_point(graph):
    """run_batch (futures-native, no DeprecationWarning) returns the same
    submission-order results the deprecated serve() wrapper does."""
    svc = PPRService(kappa=4, iterations=6)
    svc.register_graph("g", graph, formats=[26])
    stream = [PPRQuery("g", v, k=5, precision=p)
              for v in (1, 2, 3) for p in (26, None)]
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        recs = svc.run_batch(stream)
    assert [r.query for r in recs] == stream
    svc2 = PPRService(kappa=4, iterations=6)
    svc2.register_graph("g", graph, formats=[26])
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        recs2 = svc2.serve(stream)
    for a, b in zip(recs, recs2):
        np.testing.assert_array_equal(a.vertices, b.vertices)
        np.testing.assert_array_equal(a.scores, b.scores)


def test_wrappers_emit_deprecation_warning(graph):
    svc = PPRService(kappa=2, iterations=3)
    svc.register_graph("g", graph)
    with pytest.warns(DeprecationWarning, match="serve"):
        svc.serve([PPRQuery("g", 1, k=5)])
    with pytest.warns(DeprecationWarning, match="pump"):
        svc.pump()
    with pytest.warns(DeprecationWarning, match="drain"):
        svc.drain()


def test_wrappers_match_futures_path_on_same_query_stream(graph):
    """Acceptance: serve()/pump()/drain() return the identical Recommendation
    lists the futures path produces for the same query stream."""
    rng = np.random.default_rng(0)
    verts = rng.integers(0, graph.num_vertices, 12)
    stream = [PPRQuery("g", int(v), k=8, precision=p)
              for v in verts for p in (26, None)]

    svc_new = PPRService(kappa=4, iterations=6)
    svc_new.register_graph("g", graph, formats=[26])
    recs_new = _futures_batch(svc_new, stream)

    svc_old = PPRService(kappa=4, iterations=6)
    svc_old.register_graph("g", graph, formats=[26])
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        recs_old = svc_old.serve(stream)

    assert len(recs_new) == len(recs_old) == len(stream)
    for a, b in zip(recs_new, recs_old):
        assert a.query is not b.query or a.query == b.query
        np.testing.assert_array_equal(a.vertices, b.vertices)
        np.testing.assert_array_equal(a.scores, b.scores)
        assert a.source == b.source and a.precision == b.precision

    # pump()/drain() wrappers return exactly what the launched waves resolved
    q = PPRQuery("g", int(verts[0]), k=8, precision=26)   # cached by now
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        f = svc_old.submit(PPRQuery("g", 17, k=8))
        drained = svc_old.drain()
    assert [r.query.vertex for r in drained] == [17]
    assert f.result() is drained[0]
    assert svc_old.submit(q).result().source == "cache"


# ---------------------------------------------------------------------------
# engine registry + per-engine telemetry
# ---------------------------------------------------------------------------
def test_engine_registry_names_families_and_lookup():
    assert set(engine_names()) >= {"float", "fixed",
                                   "sharded_float", "sharded_fixed"}
    assert set(engine_families()) >= {"single", "sharded"}
    assert isinstance(get_engine("float"), WaveEngine)
    assert engine_for("single", False).key == "float"
    assert engine_for("single", True).key == "fixed"
    assert engine_for("sharded", False).key == "sharded_float"
    assert engine_for("sharded", True).key == "sharded_fixed"
    with pytest.raises(KeyError, match="no engine"):
        get_engine("warp_drive")
    with pytest.raises(KeyError, match="no engine family"):
        engine_for("warp", False)


def test_register_graph_engine_selection_and_validation(graph):
    svc = PPRService(kappa=2, iterations=3)
    rg = svc.register_graph("g", graph, engine="single")
    assert rg.engine_family == "single"
    with pytest.raises(ValueError, match="unknown engine family"):
        svc.register_graph("h", graph, engine="warp")
    with pytest.raises(ValueError, match="needs a mesh"):
        svc.register_graph("h", graph, engine="sharded")
    # serving still works through the explicitly selected family
    assert svc.submit(PPRQuery("g", 3, k=5)).result().source == "wave"


def test_fixed_only_plugin_family_registers_and_serves(graph):
    """A plug-in family with no float member is legal: family metadata
    resolves through any member, registration and fixed waves work, and the
    shadow path degrades gracefully (no float reference to run)."""
    from repro.ppr_serving import FixedEngine, family_members
    from repro.ppr_serving.engine import base as engine_base

    @engine_base.register_engine
    class TestOnlyFixed(FixedEngine):
        key = "test_fixed_only"
        family = "test_fixedonly"

    try:
        assert [e.key for e in family_members("test_fixedonly")] == \
            ["test_fixed_only"]
        svc = PPRService(kappa=2, iterations=4)
        rg = svc.register_graph("g", graph, formats=[26],
                                engine="test_fixedonly")
        assert rg.engine_family == "test_fixedonly"
        rec = svc.submit(PPRQuery("g", 3, k=5, precision=26)).result()
        assert rec.source == "wave" and rec.precision == "Q1.25"
        t = svc.telemetry_summary()
        assert t["engine_test_fixed_only_waves"] == 1
    finally:
        engine_base._ENGINES.pop("test_fixed_only", None)
        engine_base._FAMILIES.pop("test_fixedonly", None)


def test_per_engine_wave_latency_telemetry(graph):
    svc = PPRService(kappa=2, iterations=3)
    svc.register_graph("g", graph, formats=[26])
    _futures_batch(svc, [PPRQuery("g", v, k=5, precision=26) for v in (1, 2)])
    _futures_batch(svc, [PPRQuery("g", v, k=5) for v in (3, 4, 5, 6)])
    t = svc.telemetry_summary()
    assert t["engine_fixed_waves"] == 1
    assert t["engine_float_waves"] == 2
    for ekey in ("fixed", "float"):
        assert t[f"engine_{ekey}_latency_mean_s"] > 0
        assert t[f"engine_{ekey}_latency_p95_s"] >= \
            t[f"engine_{ekey}_latency_mean_s"] * 0.5
    stats = svc.telemetry.engine_stats()
    assert stats["float"]["waves"] == 2
