"""Multi-host sharded serving: sharded SpMV / PPR-step parity against the
single-device paths (bit-for-bit on the fixed path) and the end-to-end
PPRService mesh-vs-single-device equivalence.

Every num_vertices here is deliberately NOT divisible by the shard count —
the ceil-division padded layout (``sharded_vertex_layout``) is the regression
surface: ``make_sharded_spmv`` used to reject non-divisible V outright while
``partition_edges_by_dst`` already bucketed by ceil-division.

Subprocess with 8 forced host devices, so the main test process keeps its
single default device — per run-book (same pattern as test_distributed.py).
"""
import os
import subprocess
import sys
import textwrap

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


def test_sharded_spmv_parity_nondivisible_vertices():
    """Float and fixed sharded SpMV vs spmv_float / spmv_fixed on V=500 over
    8 shards (ceil layout: v_local=63, 4 phantom rows on the last shard).
    The fixed path must be bit-for-bit; the float path numerically equal."""
    print(_run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.compat import set_mesh
        from repro.core.fixed_point import Q1_25
        from repro.core.spmv import (make_sharded_spmv, make_sharded_spmv_fixed,
                                     partition_edges_by_dst, sharded_vertex_layout,
                                     spmv_fixed, spmv_float)
        from repro.graphs import erdos_renyi

        V, S = 500, 8
        v_local, v_pad = sharded_vertex_layout(V, S)
        assert v_local == 63 and v_pad == 504
        g = erdos_renyi(V, 4096, seed=0)
        mesh = jax.make_mesh((S,), ("shard",))
        rng = np.random.default_rng(0)
        p = (rng.random((V, 4)) / V).astype(np.float32)

        # float path
        x, y, v = partition_edges_by_dst(g.x, g.y, g.val, V, S)
        f = make_sharded_spmv(mesh, "shard", V)
        with set_mesh(mesh):
            out = f(jnp.asarray(x), jnp.asarray(y), jnp.asarray(v), jnp.asarray(p))
        ref = spmv_float(jnp.asarray(g.x), jnp.asarray(g.y), jnp.asarray(g.val),
                         jnp.asarray(p), V)
        assert out.shape == (V, 4), out.shape
        err = float(jnp.abs(out - ref).max())
        assert err < 1e-6, err

        # fixed path: bit-for-bit
        fmt = Q1_25
        vraw = g.quantized_val(fmt)
        xq, yq, vq = partition_edges_by_dst(g.x, g.y, vraw, V, S)
        assert vq.dtype == np.uint32, vq.dtype     # partitioner preserves dtype
        praw = fmt.from_float(jnp.asarray(p))
        ff = make_sharded_spmv_fixed(mesh, "shard", V, fmt)
        with set_mesh(mesh):
            outq = ff(jnp.asarray(xq), jnp.asarray(yq), jnp.asarray(vq), praw)
        refq = spmv_fixed(jnp.asarray(g.x), jnp.asarray(g.y), jnp.asarray(vraw),
                          praw, V, fmt)
        assert outq.shape == (V, 4)
        assert bool(jnp.array_equal(outq, refq)), "fixed sharded SpMV not bit-exact"
        print("sharded spmv parity OK", err)
    """))


def test_sharded_ppr_steps_match_single_device():
    """10 driven iterations of the sharded step bodies vs the single-device
    step bodies: fixed bit-identical, float numerically equal.  V=389 (prime)
    over 8 shards."""
    print(_run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.fixed_point import Q1_23
        from repro.core.ppr import (make_ppr_fixed_step, make_ppr_sharded_fixed_step,
                                    make_ppr_sharded_float_step,
                                    personalization_matrix,
                                    personalization_matrix_fixed, ppr_step_float)
        from repro.core.spmv import partition_edges_by_dst
        from repro.graphs import holme_kim_powerlaw

        V, S, alpha = 389, 8, 0.85
        g = holme_kim_powerlaw(V, m=4, seed=3)
        mesh = jax.make_mesh((S,), ("shard",))
        pers = jnp.asarray([0, 17, 388], jnp.int32)
        dang = jnp.asarray(g.dangling)

        fmt = Q1_23
        vraw = g.quantized_val(fmt)
        xq, yq, vq = partition_edges_by_dst(g.x, g.y, vraw, V, S)
        Vm = personalization_matrix_fixed(V, pers, fmt)
        s_step = make_ppr_sharded_fixed_step(fmt, mesh, "shard", V, alpha)
        d_step = make_ppr_fixed_step(fmt, V, alpha)
        Ps = Pd = Vm
        for _ in range(10):
            Ps = s_step(jnp.asarray(xq), jnp.asarray(yq), jnp.asarray(vq),
                        dang, Vm, Ps)
            Pd = d_step(jnp.asarray(g.x), jnp.asarray(g.y), jnp.asarray(vraw),
                        dang, Vm, Pd)
        assert bool(jnp.array_equal(Ps, Pd)), "sharded fixed step not bit-exact"

        x, y, v = partition_edges_by_dst(g.x, g.y, g.val, V, S)
        Vmf = personalization_matrix(V, pers)
        sf_step = make_ppr_sharded_float_step(mesh, "shard", V, alpha)
        Pfs = Pfd = Vmf
        for _ in range(10):
            Pfs = sf_step(jnp.asarray(x), jnp.asarray(y), jnp.asarray(v),
                          dang, Vmf, Pfs)
            Pfd = ppr_step_float(jnp.asarray(g.x), jnp.asarray(g.y),
                                 jnp.asarray(g.val), dang, Vmf, Pfd,
                                 num_vertices=V, alpha=alpha)
        err = float(jnp.abs(Pfs - Pfd).max())
        assert err < 1e-7, err
        print("sharded ppr steps OK", err)
    """))


def test_service_mesh_vs_single_device_topk():
    """Acceptance: a graph registered on a 4-shard mesh with non-divisible
    num_vertices serves top-K bit-identical (fixed) / numerically equal
    (float) to single-device serving, with per-mesh wave telemetry."""
    print(_run("""
        import numpy as np, jax
        from repro.graphs import holme_kim_powerlaw
        from repro.ppr_serving import (PPRQuery, PPRService, RegisteredGraph,
                                       ShardedRegisteredGraph)

        g = holme_kim_powerlaw(601, m=5, seed=2)       # 601 % 4 != 0
        mesh = jax.make_mesh((4,), ("shard",))
        verts = np.random.default_rng(0).integers(0, g.num_vertices, 8)

        def serve(mesh_arg):
            svc = PPRService(kappa=8, iterations=10)
            rg = svc.register_graph("g", g, formats=[26], mesh=mesh_arg)
            qs = [PPRQuery("g", int(v), k=10, precision=26) for v in verts] + \\
                 [PPRQuery("g", int(v), k=10) for v in verts]
            return svc, rg, svc.serve(qs)

        svc_m, rg_m, recs_m = serve(mesh)
        svc_s, rg_s, recs_s = serve(None)
        assert isinstance(rg_m, ShardedRegisteredGraph)
        assert type(rg_s) is RegisteredGraph
        assert rg_m.mesh_key == "mesh:shardx4"
        for i, (a, b) in enumerate(zip(recs_m, recs_s)):
            np.testing.assert_array_equal(a.vertices, b.vertices)
            if i < 8:   # fixed-point half: scores bit-identical through dequant
                np.testing.assert_array_equal(a.scores, b.scores)
            else:       # float half: numerically equal
                np.testing.assert_allclose(a.scores, b.scores, rtol=0, atol=1e-7)

        t = svc_m.telemetry_summary()
        assert t["waves_mesh:shardx4"] == 2, t
        assert t["queries_mesh:shardx4"] == 16, t
        ts = svc_s.telemetry_summary()
        assert ts["waves_single"] == 2 and ts["queries_single"] == 16, ts

        # repeat traffic on the meshed service hits the cache
        again = svc_m.serve([PPRQuery("g", int(verts[0]), k=10, precision=26)])
        assert again[0].source == "cache"
        print("mesh service e2e OK")
    """))


def test_fixed_engine_vs_sharded_fixed_engine_raw_uint32_equality():
    """Acceptance (engine layer): `FixedEngine` and `ShardedFixedEngine` plans
    driven over the same graph produce bit-identical raw uint32 state and
    identical top-K on non-divisible V — the backend seam did not perturb the
    datapath."""
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.fixed_point import Q1_25
        from repro.graphs import holme_kim_powerlaw
        from repro.ppr_serving import PPRService, engine_for
        from repro.ppr_serving.graphs import (RegisteredGraph,
                                              ShardedRegisteredGraph)

        V = 389                                        # prime: no shard count divides it
        g = holme_kim_powerlaw(V, m=4, seed=3)
        mesh = jax.make_mesh((8,), ("shard",))
        rg_single = RegisteredGraph("g", g)
        rg_sharded = ShardedRegisteredGraph("g", g, mesh)
        fixed = engine_for("single", True)
        sharded = engine_for("sharded", True)
        assert fixed.key == "fixed" and sharded.key == "sharded_fixed"

        plans = [eng.plan(rg, Q1_25, alpha=0.85, iterations=10)
                 for eng, rg in ((fixed, rg_single), (sharded, rg_sharded))]
        pers = jnp.asarray([0, 17, 200, 388], jnp.int32)
        states = []
        for plan in plans:
            assert plan.fixed and plan.scale == Q1_25.scale
            Vmat = plan.initial(pers)
            P, iters = plan.iterate(lambda P_: plan.step(Vmat, P_), Vmat)
            assert iters == 10
            states.append(np.asarray(P))
        assert states[0].dtype == states[1].dtype == np.uint32
        np.testing.assert_array_equal(states[0], states[1])   # raw bit equality

        tops = [plan.topk(jnp.asarray(s), 10, pers)
                for plan, s in zip(plans, states)]
        np.testing.assert_array_equal(np.asarray(tops[0][0]),
                                      np.asarray(tops[1][0]))
        np.testing.assert_array_equal(np.asarray(tops[0][1]),
                                      np.asarray(tops[1][1]))
        print("engine raw parity OK")
    """))


def test_sharded_graph_pre_quantizes_shards_and_purges_on_reregister():
    """register_graph(formats=[...], mesh=...) pre-partitions quantized shard
    values; re-registration drops the meshed graph's pending queries (3-part
    wave keys must keep the name-prefix purge working)."""
    print(_run("""
        import jax
        from repro.core.fixed_point import Q1_25
        from repro.graphs import erdos_renyi
        from repro.ppr_serving import PPRQuery, PPRService

        g = erdos_renyi(203, 1500, seed=1)             # 203 % 4 != 0
        mesh = jax.make_mesh((4,), ("shard",))
        svc = PPRService(kappa=8, iterations=5)
        rg = svc.register_graph("g", g, formats=[26], mesh=mesh)
        assert Q1_25 in rg._sharded_quantized          # pre-partitioned at registration

        assert not svc.submit(PPRQuery("g", 3, k=5, precision=26)).done()
        assert svc.scheduler.pending() == 1
        svc.register_graph("g", g, formats=[26], mesh=mesh)
        assert svc.scheduler.pending() == 0            # purge saw the 3-part key
        print("sharded registration OK")
    """))
