"""API-surface snapshot: the public names + signatures of `repro.ppr_serving`
asserted against a checked-in manifest, so any future API drift (a renamed
method, a changed default, a dropped export) is an explicit diff in review
instead of a silent break for downstream users of the serving API.

Regenerate after an *intentional* API change:

    PYTHONPATH=src python tests/test_api_surface.py --write
"""
import difflib
import inspect
import os
import sys

MANIFEST = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "api_surface_ppr_serving.txt")


def _sig(fn) -> str:
    try:
        return str(inspect.signature(fn))
    except (TypeError, ValueError):   # pragma: no cover - C-level callables
        return "(...)"


def _class_lines(name, cls):
    lines = [f"class {name}{_sig(cls.__init__)}"]
    # repo-defined public attributes across the MRO (inherited repo methods
    # are part of the surface users see; builtin machinery is not)
    members = {}
    for klass in reversed(cls.__mro__):
        if klass.__module__.split(".")[0] != "repro":
            continue
        for attr, value in vars(klass).items():
            if not attr.startswith("_"):
                members[attr] = value
    for attr in sorted(members):
        value = members[attr]
        if isinstance(value, property):
            lines.append(f"  {attr}: property")
        elif isinstance(value, (classmethod, staticmethod)):
            lines.append(f"  {attr}{_sig(value.__func__)} "
                         f"[{type(value).__name__}]")
        elif callable(value):
            lines.append(f"  {attr}{_sig(value)}")
        else:
            lines.append(f"  {attr} = {value!r}")
    return lines


def build_manifest() -> str:
    import repro.ppr_serving as pkg

    lines = [
        "# Public API surface of repro.ppr_serving (generated — do not edit).",
        "# Regenerate after an intentional API change:",
        "#   PYTHONPATH=src python tests/test_api_surface.py --write",
        "",
    ]
    for name in sorted(pkg.__all__):
        obj = getattr(pkg, name)
        if inspect.isclass(obj):
            lines.extend(_class_lines(name, obj))
        elif callable(obj):
            lines.append(f"def {name}{_sig(obj)}")
        else:
            lines.append(f"{name} = {obj!r}")
    return "\n".join(lines) + "\n"


def test_ppr_serving_api_surface_matches_manifest():
    current = build_manifest()
    assert os.path.exists(MANIFEST), (
        f"missing API manifest {MANIFEST} — generate it with "
        f"'PYTHONPATH=src python tests/test_api_surface.py --write'")
    with open(MANIFEST) as f:
        committed = f.read()
    if current != committed:
        diff = "\n".join(difflib.unified_diff(
            committed.splitlines(), current.splitlines(),
            fromfile="committed manifest", tofile="current API", lineterm=""))
        raise AssertionError(
            "repro.ppr_serving's public API drifted from the committed "
            "manifest.  If the change is intentional, regenerate with "
            "'PYTHONPATH=src python tests/test_api_surface.py --write' and "
            "commit the diff.\n" + diff)


if __name__ == "__main__":
    if "--write" in sys.argv:
        with open(MANIFEST, "w") as f:
            f.write(build_manifest())
        print(f"wrote {MANIFEST}")
    else:
        print(build_manifest(), end="")
