"""Roofline machinery: HLO collective parsing, term composition, model flops."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.roofline.analysis import (
    collective_bytes,
    model_flops_forward,
    model_flops_train,
    roofline,
)

HLO_FIXTURE = """
  %x = f32[256,4096]{1,0} parameter(0)
  %ar = f32[256,4096]{1,0} all-reduce(f32[256,4096]{1,0} %x), replica_groups={}
  %ag = bf16[64,128]{1,0} all-gather(bf16[32,128]{1,0} %y), dimensions={0}
  %rs = f32[16]{0} reduce-scatter(f32[256]{0} %z), dimensions={0}
  %cp = u32[8,8]{1,0} collective-permute(u32[8,8]{1,0} %w), source_target_pairs={}
  %notacoll = f32[999]{0} add(f32[999]{0} %a, f32[999]{0} %b)
"""


def test_collective_parse_fixture():
    got = collective_bytes(HLO_FIXTURE)
    assert got["all-reduce"] == 256 * 4096 * 4
    assert got["all-gather"] == 64 * 128 * 2
    assert got["reduce-scatter"] == 16 * 4
    assert got["collective-permute"] == 8 * 8 * 4
    assert "add" not in got


def test_collective_parse_real_module():
    """Parse a real SPMD-partitioned module containing a psum."""
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P

    def f(x):
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P())) * 2

    txt = jax.jit(f).lower(jax.ShapeDtypeStruct((128,), jnp.float32)).compile().as_text()
    # single-device: no collectives expected, parser must not crash
    assert isinstance(collective_bytes(txt), dict)


def test_roofline_terms_and_bottleneck():
    cost = {"flops": 197e12, "bytes accessed": 819e9 * 2}
    t = roofline(cost, HLO_FIXTURE, chips=4, model_flops=197e12 * 2)
    assert abs(t.compute_s - 1.0) < 1e-9
    assert abs(t.memory_s - 2.0) < 1e-9
    assert t.bottleneck == "memory"
    assert abs(t.useful_flops_ratio - 2.0 / 4.0) < 1e-9


def test_model_flops_moe_uses_active_params():
    dense = get_config("gemma-2b")
    moe = get_config("mixtral-8x7b")
    assert model_flops_train(dense, 1000) == 6.0 * dense.param_count() * 1000
    assert model_flops_train(moe, 1000) == 6.0 * moe.active_param_count() * 1000
    assert moe.active_param_count() < moe.param_count() / 2


def test_param_counts_sane():
    """Analytic counts within expected ballparks of the published sizes."""
    approx = {
        "gemma2-27b": 27e9, "starcoder2-15b": 15e9, "mixtral-8x7b": 46e9,
        "mamba2-1.3b": 1.3e9, "gemma-2b": 2.5e9,
    }
    for arch, want in approx.items():
        got = get_config(arch).param_count()
        assert 0.5 * want < got < 1.7 * want, (arch, got, want)
