"""Shared test setup: keep the tier-1 suite collectable on bare environments.

Several modules (test_fixed_point, test_kernels, test_ssd) use hypothesis
property tests.  When hypothesis is not installed, a hard import error would
take down *collection* of every test in those files — including the plain
parametrized ones.  Install a thin fallback instead: strategy expressions
evaluate to inert placeholders and each ``@given`` test becomes a skip, so
the rest of the suite runs unchanged.  ``pip install -r requirements-dev.txt``
restores the real property tests.
"""
import sys
import types

try:  # pragma: no cover - trivial when hypothesis is present
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import pytest

    def _strategy(*args, **kwargs):
        return None  # inert placeholder; only ever passed to the stub `given`

    strategies = types.ModuleType("hypothesis.strategies")
    for _name in ("integers", "floats", "lists", "sampled_from", "booleans",
                  "tuples", "just", "text", "binary", "one_of"):
        setattr(strategies, _name, _strategy)

    def _composite(fn):
        def build(*args, **kwargs):
            return None
        build.__name__ = getattr(fn, "__name__", "composite")
        return build

    strategies.composite = _composite

    def _given(*args, **kwargs):
        def deco(fn):
            # zero-arg wrapper: pytest must not try to resolve the test's
            # strategy parameters as fixtures
            def skipper():
                pytest.skip("hypothesis not installed (see requirements-dev.txt)")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

    def _settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco

    hyp = types.ModuleType("hypothesis")
    hyp.given = _given
    hyp.settings = _settings
    hyp.strategies = strategies
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = strategies
